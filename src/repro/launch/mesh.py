"""Production mesh construction.

Single pod: (16, 16) = 256 chips, axes ("data", "model").
Multi-pod:  (2, 16, 16) = 512 chips, axes ("pod", "data", "model") — the
"pod" axis carries pure data parallelism across ICI-disconnected pods (DCN),
so only gradient all-reduces cross it.

A FUNCTION (not a module constant) so importing this module never touches
jax device state — the dry-run sets XLA_FLAGS before first jax init.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import Mesh

from ..distributed.sharding import ShardCtx

__all__ = ["make_production_mesh", "make_ctx", "small_mesh"]


def _make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]) -> Mesh:
    """jax.make_mesh across jax versions (axis_types landed after 0.4.x)."""
    try:
        return jax.make_mesh(
            shape, axes,
            axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
    except (AttributeError, TypeError):
        return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_ctx(mesh: Optional[Mesh]) -> ShardCtx:
    """ShardCtx with dp covering (pod,) data axes."""
    if mesh is None:
        return ShardCtx(mesh=None)
    names = mesh.axis_names
    dp = tuple(n for n in ("pod", "data") if n in names)
    # fsdp spans the pod axis too: parameter/optimizer shards scale with
    # TOTAL chips (512 on the 2-pod mesh), which is what makes >100B
    # configs trainable at all
    fsdp = dp if "data" in names else None
    if fsdp is not None and len(fsdp) == 1:
        fsdp = fsdp[0]
    return ShardCtx(mesh=mesh, dp=dp or ("data",),
                    fsdp=fsdp,
                    tp="model" if "model" in names else None,
                    sp="model" if "model" in names else None)


def small_mesh(data: int = 2, model: int = 2) -> Mesh:
    """Reduced mesh for tests (requires enough local/virtual devices)."""
    return _make_mesh((data, model), ("data", "model"))
