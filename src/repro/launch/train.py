"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch stablelm-1.6b \
        --preset smoke --steps 50

Presets scale the run to the hardware at hand: ``smoke`` (CPU CI), ``100m``
(a ~100M-param model for a few hundred steps — the end-to-end driver), and
``full`` (the assigned config on a real mesh). The trainer itself is the
conditional taskflow of repro/train/trainer.py (prefetch / device step /
async checkpoint / loop condition), executed by the paper's work-stealing
executor.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time

import jax

from ..configs import get_config
from ..distributed.sharding import ShardCtx
from ..optim.adamw import OptConfig
from ..train.trainer import Trainer, TrainerConfig


def build_cfg(arch: str, preset: str):
    cfg = get_config(arch)
    if preset == "smoke":
        return cfg.smoke(), 4, 64
    if preset == "100m":
        # ~100M-param member of the same family
        cfg = dataclasses.replace(
            cfg.smoke(), name=cfg.name + "-100m",
            num_layers=12, d_model=768,
            num_heads=0 if cfg.attention_free else 12,
            num_kv_heads=0 if cfg.attention_free else 4,
            head_dim=0 if cfg.attention_free else 64,
            d_ff=2048 if cfg.d_ff else 0,
            vocab_size=32000,
            attn_chunk_q=128, ssm_chunk=64, max_seq_len=2048)
        return cfg, 8, 512
    return cfg, 256, 4096  # full


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--preset", default="smoke",
                    choices=["smoke", "100m", "full"])
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=0)
    ap.add_argument("--seq", type=int, default=0)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg, batch, seq = build_cfg(args.arch, args.preset)
    batch = args.batch or batch
    seq = args.seq or seq
    opt = OptConfig(lr=args.lr, warmup_steps=max(10, args.steps // 20),
                    total_steps=args.steps)
    tc = TrainerConfig(total_steps=args.steps, ckpt_every=args.ckpt_every,
                       log_every=args.log_every,
                       microbatches=args.microbatches)
    print(f"training {cfg.name}: {cfg.param_count()/1e6:.1f}M params, "
          f"batch={batch} seq={seq} steps={args.steps} on "
          f"{len(jax.devices())} device(s)")
    t0 = time.time()
    tr = Trainer(cfg, tc, batch=batch, seq_len=seq, opt=opt,
                 ckpt_dir=args.ckpt_dir)
    out = tr.run()
    dt = time.time() - t0
    hist = out["history"]
    toks = batch * seq * args.steps
    print(f"done in {dt:.1f}s ({toks/dt:.0f} tok/s); restarts="
          f"{out['restarts']}")
    for h in hist:
        print(f"  step {h['step']:5d} loss {h['loss']:.4f} "
              f"lr {h['lr']:.2e} gnorm {h['grad_norm']:.2f}")
    print(json.dumps({"final_loss": hist[-1]["loss"],
                      "first_loss": hist[0]["loss"],
                      "tokens_per_s": toks / dt}))


if __name__ == "__main__":
    main()
