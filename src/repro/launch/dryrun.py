import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# (test hook — must come after the two mandated lines above; jax is not
# imported yet so the flag still applies at first init)
if os.environ.get("REPRO_DRYRUN_DEVICES"):
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count="
                               + os.environ["REPRO_DRYRUN_DEVICES"])

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this script:
  1. builds the production mesh (16x16 single pod / 2x16x16 multi-pod)
     out of 512 virtual host devices,
  2. lowers the appropriate step (train_step for train shapes, prefill /
     decode serve steps otherwise) with fully-sharded ShapeDtypeStruct
     inputs (NO device allocation),
  3. compiles, prints memory_analysis() (proves the per-device footprint)
     and cost_analysis() (FLOPs / bytes for the roofline),
  4. parses the post-SPMD optimized HLO for collective ops and sums their
     shaped bytes (all-gather / all-reduce / reduce-scatter / all-to-all /
     collective-permute),
  5. derives the three roofline terms (EXPERIMENTS.md §Roofline) against
     TPU v5e constants, and appends a JSON record to the results file.

Usage:
  python -m repro.launch.dryrun --arch qwen2.5-32b --shape train_4k
  python -m repro.launch.dryrun --all [--mesh single|multi|both]
"""
import argparse
import json
import re
import sys
import time
import traceback
from pathlib import Path

import jax
import numpy as np

from ..configs import ARCHS, SHAPES_BY_NAME, get_config, shape_applicable
from ..distributed.hlo_analysis import analyze_hlo
from ..train.train_step import (make_decode_step, make_prefill_step,
                                train_input_specs)
from .mesh import make_ctx, make_production_mesh

# ------------------------------------------------------------ TPU v5e model
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link (per-chip aggregate model)

_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"\b(f64|f32|f16|bf16|s64|u64|s32|u32|s16|u16|s8|u8|"
                       r"pred|f8e4m3fn|f8e5m2|c64|c128)\[([0-9,]*)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str):
    """Sum result-shape bytes of every collective op, by op type."""
    out = {c: 0 for c in _COLLECTIVES}
    counts = {c: 0 for c in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        # "%name = TYPE op-name(...)" — match the op right after the type
        m = re.match(r"^%?[\w\.\-]+\s*=\s*(\(?[^=]*?)\s+"
                     r"(all-gather|all-reduce|reduce-scatter|all-to-all|"
                     r"collective-permute)(-start)?\(", s)
        if not m:
            continue
        type_str, op = m.group(1), m.group(2)
        if m.group(3) == "-start" or "-done(" in s:
            pass
        b = _shape_bytes(type_str)
        out[op] += b
        counts[op] += 1
    return out, counts


def apply_variant(cfg, variant: str):
    """'opt' switches on the beyond-paper §Perf optimizations; baseline
    keeps the paper-faithful first implementation."""
    if variant != "opt":
        return cfg
    import dataclasses
    pad = 0
    if cfg.moe and (cfg.num_experts % 16):
        pad = -cfg.num_experts % 16       # 60 -> 64 inert experts
    # H2 (hoist the FSDP gather out of the microbatch loop) trades ~2 bytes
    # per param of HBM for 16x less gather traffic — affordable below ~5B
    # params on 16GB v5e (measured: +7.5GB at 33B, rejected there).
    hoist = cfg.param_count() < 5e9
    return dataclasses.replace(
        cfg, attn_bwd_remat=True, hoist_weight_gather=hoist,
        ssm_scan_constrain=True, moe_expert_pad=pad)


def build_cell(arch: str, shape_name: str, multi_pod: bool,
               variant: str = ""):
    cfg = apply_variant(get_config(arch), variant)
    shape = SHAPES_BY_NAME[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    ctx = make_ctx(mesh)
    if shape.kind == "train":
        from ..optim.adamw import OptConfig
        # >100B params: bf16 moments, or optimizer state alone exceeds HBM
        opt = OptConfig(moment_dtype="bfloat16"
                        if cfg.param_count() > 1e11 else "float32")
        step, specs, _ = train_input_specs(cfg, ctx, shape, opt=opt)
    elif shape.kind == "prefill":
        step, specs, _ = make_prefill_step(cfg, ctx, shape)
    else:
        step, specs, _ = make_decode_step(cfg, ctx, shape)
    return cfg, shape, mesh, ctx, step, specs


def model_flops(cfg, shape) -> float:
    """6*N_active*D tokens (train: fwd+bwd; serve: 2*N per token)."""
    n = cfg.active_param_count()
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                   else 1)
    if shape.kind == "train":
        return 6.0 * n * tokens
    return 2.0 * n * tokens


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             donate: bool = True, extra_tag: str = "",
             variant: str = "") -> dict:
    t0 = time.time()
    cfg, shape, mesh, ctx, step, specs = build_cell(arch, shape_name,
                                                    multi_pod, variant)
    chips = int(np.prod(list(mesh.shape.values())))
    donate_argnums = ()
    if donate and shape.kind == "train":
        donate_argnums = (0, 1)
    elif donate and shape.kind == "decode":
        donate_argnums = (1,)
    jitted = jax.jit(step, donate_argnums=donate_argnums)
    with mesh:
        lowered = jitted.lower(*specs)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    # trip-count-aware analysis (cost_analysis counts while bodies ONCE —
    # verified; see distributed/hlo_analysis.py)
    hc = analyze_hlo(hlo)
    coll = hc.collective_bytes
    coll_counts = hc.collective_counts
    coll_total = hc.collective_total

    flops_per_dev = float(hc.flops)
    bytes_per_dev = float(hc.bytes_accessed)
    flops_raw = float(cost.get("flops", 0.0))
    bytes_raw = float(cost.get("bytes accessed", 0.0))
    compute_s = flops_per_dev / PEAK_FLOPS
    memory_s = bytes_per_dev / HBM_BW
    collective_s = coll_total / ICI_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    dominant = max(terms, key=terms.get)
    bound_s = max(compute_s, memory_s, collective_s)
    mf = model_flops(cfg, shape)
    mf_per_dev = mf / chips
    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16", "chips": chips,
        "kind": shape.kind, "tag": extra_tag,
        "ok": True,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "peak_bytes": (mem.argument_size_in_bytes
                           + mem.temp_size_in_bytes),
            "fits_16gb": (mem.argument_size_in_bytes
                          + mem.temp_size_in_bytes) < 16e9,
        },
        "flops_per_dev": flops_per_dev,
        "bytes_per_dev": bytes_per_dev,
        "flops_per_dev_rawca": flops_raw,    # cost_analysis (loops once)
        "bytes_per_dev_rawca": bytes_raw,
        "unknown_trip_loops": hc.unknown_trip_loops,
        "collective_bytes": coll, "collective_counts": coll_counts,
        "collective_bytes_total": coll_total,
        "roofline": {
            **terms, "dominant": dominant,
            "step_lower_bound_s": bound_s,
            "model_flops_global": mf,
            "model_flops_per_dev": mf_per_dev,
            "useful_flops_frac": (mf_per_dev / flops_per_dev
                                  if flops_per_dev else 0.0),
            "roofline_frac": (mf_per_dev / PEAK_FLOPS) / bound_s
            if bound_s else 0.0,
        },
    }
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun.jsonl")
    ap.add_argument("--tag", default="")
    ap.add_argument("--variant", default="", choices=["", "opt"])
    ap.add_argument("--skip-done", action="store_true")
    args = ap.parse_args()
    if args.variant and not args.tag:
        args.tag = args.variant

    out = Path(args.out)
    out.parent.mkdir(parents=True, exist_ok=True)
    done = set()
    if args.skip_done and out.exists():
        for line in out.read_text().splitlines():
            try:
                r = json.loads(line)
                if r.get("ok"):
                    done.add((r["arch"], r["shape"], r["mesh"], r.get("tag", "")))
            except json.JSONDecodeError:
                pass

    archs = [args.arch] if args.arch else list(ARCHS)
    shapes = [args.shape] if args.shape else list(SHAPES_BY_NAME)
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    n_ok = n_skip = n_fail = 0
    for arch in archs:
        cfg = get_config(arch)
        for shape_name in shapes:
            ok, why = shape_applicable(cfg, SHAPES_BY_NAME[shape_name])
            if not ok:
                print(f"[skip] {arch} x {shape_name}: {why}", flush=True)
                with out.open("a") as f:
                    f.write(json.dumps({
                        "arch": arch, "shape": shape_name, "mesh": "-",
                        "ok": False, "skipped": True, "why": why,
                        "tag": args.tag}) + "\n")
                n_skip += 1
                continue
            for mp in meshes:
                mesh_name = "2x16x16" if mp else "16x16"
                if (arch, shape_name, mesh_name, args.tag) in done:
                    print(f"[done] {arch} x {shape_name} x {mesh_name}",
                          flush=True)
                    continue
                print(f"[run ] {arch} x {shape_name} x {mesh_name} ...",
                      flush=True)
                try:
                    rec = run_cell(arch, shape_name, mp, extra_tag=args.tag,
                                   variant=args.variant)
                    r = rec["roofline"]
                    print(f"       ok  compile={rec['compile_s']}s "
                          f"peak={rec['memory']['peak_bytes']/1e9:.2f}GB "
                          f"dom={r['dominant']} "
                          f"roofline_frac={r['roofline_frac']:.3f}",
                          flush=True)
                    n_ok += 1
                except Exception as e:  # noqa: BLE001
                    rec = {"arch": arch, "shape": shape_name,
                           "mesh": mesh_name, "ok": False, "tag": args.tag,
                           "error": f"{type(e).__name__}: {e}",
                           "trace": traceback.format_exc()[-2000:]}
                    print(f"       FAIL {type(e).__name__}: {e}", flush=True)
                    n_fail += 1
                with out.open("a") as f:
                    f.write(json.dumps(rec) + "\n")
    print(f"dryrun complete: ok={n_ok} skip={n_skip} fail={n_fail}",
          flush=True)
    sys.exit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
