"""Serving launcher: batched greedy generation with the compiled
prefill + chunked-decode programs.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-14b \
        --preset smoke --batch 4 --prompt-len 32 --max-new 32
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from ..configs import get_config
from ..models import lm
from ..serve.engine import ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--preset", default="smoke", choices=["smoke", "full"])
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--decode-chunk", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.preset == "smoke":
        cfg = cfg.smoke()
    if cfg.frontend != "none":
        print(f"note: {cfg.name} uses a stub frontend; serving the text "
              "backbone only")
    params = lm.init_params(cfg, jax.random.PRNGKey(args.seed))
    eng = ServeEngine(cfg, params, decode_chunk=args.decode_chunk)
    rng = np.random.default_rng(args.seed)
    prompts = [rng.integers(0, cfg.vocab_size, size=args.prompt_len)
               .astype(np.int32) for _ in range(args.batch)]
    t0 = time.time()
    outs = eng.generate(prompts, max_new=args.max_new)
    dt = time.time() - t0
    total_new = args.batch * args.max_new
    print(f"{cfg.name}: generated {total_new} tokens in {dt:.2f}s "
          f"({total_new/dt:.1f} tok/s, batch={args.batch})")
    print("sample:", outs[0][:16].tolist())


if __name__ == "__main__":
    main()
