"""Serving launcher: the resident continuous-batching engine.

Requests are submitted one by one against the long-running pipeline
(``submit()``/``result()``); with ``--stagger`` the submissions arrive
spaced out, so later requests join the batch while earlier ones are
mid-decode — the continuous-batching path, for every architecture
(attention models page their KV; SSM/hybrid models slot their recurrent
state). ``--per-call`` runs the retired per-call grouped pipeline for
comparison.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-14b \
        --preset smoke --batch 4 --prompt-len 32 --max-new 32 --stagger 0.05

Observability (:mod:`repro.obs`): ``--stats-interval N`` prints a one-line
runtime summary every N seconds (tok/s, queue depth, resident rows, pool
occupancy, preempt/stall counts, TTFT p50); ``--trace PATH`` writes a
Chrome trace-event JSON of the run — open it at https://ui.perfetto.dev
or ``chrome://tracing`` to see every request's lifecycle on its slot
track next to the engine-cycle and pipeline-line tracks:

    PYTHONPATH=src python -m repro.launch.serve \
        --stats-interval 1 --trace out.json

Durability (``docs/robustness.md``): ``--state-dir DIR`` journals every
request transition to ``DIR/journal.wal`` and recovers on startup —
incomplete requests from a previous crash replay bit-identically, and a
prior ``engine.snap`` warm-starts the prefix cache (a corrupt snapshot
falls back cold, typed). SIGTERM triggers a graceful drain
(``--drain-deadline`` bounds it: past the deadline residents are
checkpoint-preempted), then a snapshot + journal flush, then close:

    PYTHONPATH=src python -m repro.launch.serve --state-dir /var/lib/repro
"""
from __future__ import annotations

import argparse
import os
import signal
import threading
import time

import jax
import numpy as np

from ..configs import get_config
from ..distributed.sharding import validate_serve_mesh
from ..models import lm
from ..obs import Observability, StatsLogger
from ..serve.engine import SNAPSHOT_FILE, ServeEngine
from .mesh import make_ctx, small_mesh


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b",
                    help="model architecture (default: the quick smoke "
                         "workload's stablelm-1.6b)")
    ap.add_argument("--preset", default="smoke", choices=["smoke", "full"])
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--decode-chunk", type=int, default=8)
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    help="prompt tokens per chunked-prefill window "
                         "(default: decode_chunk * block_size)")
    ap.add_argument("--kv-blocks", type=int, default=128)
    ap.add_argument("--block-size", type=int, default=16)
    ap.add_argument("--stagger", type=float, default=0.0,
                    help="seconds between submissions (0 = all at once)")
    ap.add_argument("--async-decode", default=None,
                    action=argparse.BooleanOptionalAction,
                    help="async decode lookahead: device-resident carry + "
                         "one-chunk dispatch pipelining. Unset defers to "
                         "REPRO_ASYNC_DECODE; --no-async-decode forces the "
                         "synchronous reference path even with the env set")
    ap.add_argument("--per-call", action="store_true",
                    help="use the generate() batch-call shim instead of "
                         "submit/result")
    ap.add_argument("--priority", type=int, default=0,
                    help="scheduling tier for the submitted requests "
                         "(0 = highest/SLO tier; larger = best-effort)")
    ap.add_argument("--deadline", type=float, default=None, metavar="S",
                    help="per-request deadline in seconds (expired "
                         "requests fail typed DeadlineExceeded)")
    ap.add_argument("--tier-target", action="append", default=None,
                    metavar="TIER=SHARE",
                    help="guaranteed minimum admission share for a tier "
                         "under sustained higher-tier load (repeatable, "
                         "e.g. --tier-target 1=0.25)")
    ap.add_argument("--shed-budget", type=float, default=None, metavar="S",
                    help="load-shedding queue-wait budget (seconds, all "
                         "tiers): submit() raises Overloaded when the "
                         "estimated wait exceeds it. Unset defers to "
                         "REPRO_SHED_BUDGET_S")
    ap.add_argument("--watchdog", type=float, default=None, metavar="S",
                    help="engine watchdog budget: fail all futures typed "
                         "WatchdogTimeout when a busy engine makes no "
                         "progress for S seconds. Unset defers to "
                         "REPRO_WATCHDOG_S")
    ap.add_argument("--fault-inject", default=None, metavar="SPEC",
                    help="deterministic fault-injection spec (see "
                         "repro.serve.faultinject), e.g. "
                         "'grow_fail:p=0.05,seed=11'. Unset defers to "
                         "REPRO_FAULT_INJECT")
    ap.add_argument("--mesh-model", type=int, default=None, metavar="N",
                    help="shard the serve data plane N-way over the mesh "
                         "'model' axis (KV-head-partitioned pool + "
                         "tensor-parallel decode). N must divide the "
                         "model's KV heads, heads and d_model; on CPU set "
                         "XLA_FLAGS=--xla_force_host_platform_device_count"
                         "=N first. Unset defers to REPRO_MESH_MODEL")
    ap.add_argument("--state-dir", default=None, metavar="DIR",
                    help="durability state directory: journal every "
                         "request transition to DIR/journal.wal, recover "
                         "(replay incomplete requests + warm-start the "
                         "prefix cache from DIR/engine.snap) on startup, "
                         "and snapshot on graceful shutdown/SIGTERM")
    ap.add_argument("--drain-deadline", type=float, default=10.0,
                    metavar="S",
                    help="graceful-drain budget on SIGTERM: residents get "
                         "S seconds to finish before being "
                         "checkpoint-preempted (default 10)")
    ap.add_argument("--fsync-every", type=int, default=1, metavar="N",
                    help="journal fsync cadence: every N records (1 = "
                         "maximal durability, 0 = only at flush/close)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--stats-interval", type=float, default=None,
                    help="print a one-line runtime stats summary every N "
                         "seconds (implies observability on)")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="write a Chrome trace-event JSON (Perfetto/"
                         "chrome://tracing) of the run (implies "
                         "observability on)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.preset == "smoke":
        cfg = cfg.smoke()
    if cfg.frontend != "none":
        print(f"note: {cfg.name} uses a stub frontend; serving the text "
              "backbone only")
    ctx = None
    if args.mesh_model is not None and args.mesh_model > 1:
        # typed MeshDivisibilityError on KV-head counts the axis can't
        # divide — refuse up front rather than shard a lopsided pool
        validate_serve_mesh(cfg, args.mesh_model)
        ctx = make_ctx(small_mesh(data=1, model=args.mesh_model))
        print(f"mesh: model axis = {args.mesh_model} "
              f"({jax.device_count()} devices)")

    params = lm.init_params(cfg, jax.random.PRNGKey(args.seed))
    rng = np.random.default_rng(args.seed)
    prompts = [rng.integers(0, cfg.vocab_size, size=args.prompt_len)
               .astype(np.int32) for _ in range(args.batch)]
    total_new = args.batch * args.max_new

    obs = Observability() \
        if (args.stats_interval is not None or args.trace) else None
    logger = None
    if args.stats_interval is not None:
        logger = StatsLogger(obs.metrics, interval=args.stats_interval)

    tier_targets = None
    if args.tier_target:
        tier_targets = {}
        for spec in args.tier_target:
            tier, _, share = spec.partition("=")
            tier_targets[int(tier)] = float(share)

    with ServeEngine(cfg, params, ctx=ctx, decode_chunk=args.decode_chunk,
                     prefill_chunk=args.prefill_chunk,
                     kv_blocks=args.kv_blocks,
                     block_size=args.block_size,
                     async_decode=args.async_decode,
                     tier_targets=tier_targets,
                     shed_budget_s=args.shed_budget,
                     watchdog_s=args.watchdog,
                     fault_inject=args.fault_inject,
                     obs=obs) as eng:
        replayed = {}
        if args.state_dir:
            # crash/restart recovery: warm-start from a prior snapshot
            # (typed cold fallback on corruption) and re-submit every
            # journal-incomplete request — greedy decode replays them
            # bit-identically — then journal this run at the same path
            replayed = eng.recover(args.state_dir,
                                   fsync_every=args.fsync_every)
            if replayed or eng.stats["warm_started"]:
                print(f"recovered: {len(replayed)} incomplete request(s) "
                      f"replaying ({eng.stats['replayed_tokens']} prompt "
                      f"tokens), {eng.stats['warm_started']} warm prefix "
                      f"node(s)")

        def _graceful(signum, frame):
            # runs the drain off the signal frame: the main thread may be
            # blocked in result(), and drain/snapshot must not run there
            def run():
                print(f"SIGTERM: draining "
                      f"(deadline {args.drain_deadline:.1f}s)")
                eng.drain(deadline_s=args.drain_deadline)
                if args.state_dir:
                    path = os.path.join(args.state_dir, SNAPSHOT_FILE)
                    n = eng.snapshot(path)
                    print(f"snapshot: {n} bytes -> {path}")
                eng.close()
                os._exit(0)
            threading.Thread(target=run, name="serve-drain",
                             daemon=True).start()
        signal.signal(signal.SIGTERM, _graceful)

        if logger is not None:
            logger.start()
        t0 = time.time()
        if args.per_call:
            # the retired per-call grouped pipeline, kept as the baseline
            outs = eng._generate_grouped(prompts, args.max_new)
        else:
            # every arch serves through the resident pipeline now: paged KV
            # for attention models, the slot-state pool for SSM/hybrid
            reqs = []
            for p in prompts:
                reqs.append(eng.submit(p, max_new=args.max_new,
                                       priority=args.priority,
                                       deadline_s=args.deadline))
                if args.stagger:
                    time.sleep(args.stagger)
            outs = [eng.result(r, timeout=600.0) for r in reqs]
        for r in replayed.values():
            eng.result(r, timeout=600.0)
        dt = time.time() - t0
        print(f"{cfg.name}: generated {total_new} tokens in {dt:.2f}s "
              f"({total_new/dt:.1f} tok/s, batch={args.batch}, "
              f"mode={'per-call' if args.per_call else 'continuous'})")
        print("engine stats:", eng.stats)
        print("sample:", outs[0][:16].tolist())
        if args.state_dir:
            # clean exit: settle and leave a warm snapshot for the next run
            eng.drain(deadline_s=args.drain_deadline)
            n = eng.snapshot(os.path.join(args.state_dir, SNAPSHOT_FILE))
            print(f"snapshot: {n} bytes -> "
                  f"{os.path.join(args.state_dir, SNAPSHOT_FILE)}")
        if logger is not None:
            logger.stop()
    if args.trace:
        obs.export(args.trace)
        print(f"trace written to {args.trace} "
              f"({len(obs.tracer)} spans; open at https://ui.perfetto.dev)")


if __name__ == "__main__":
    main()
