"""Low-overhead span recorder for the serve-layer timeline (tfprof-style).

The Taskflow paper ships a built-in profiler (tfprof, §VI) that records
per-worker task intervals and renders them as an execution timeline; this
module is the serve-stack analogue. A :class:`Tracer` holds a bounded RING
BUFFER of completed spans — plain ``(name, track, t_start, t_end, args)``
tuples on the ``time.perf_counter`` clock — that the engine, the pipeline
and the launcher append to from worker threads:

* **tracks** partition the timeline the way tfprof partitions by worker:
  one track per decode slot (``"slot3"``) carrying that seat's request
  lifecycle spans (queued → admitted → prefill_window → decode →
  stalled → retired), one ``"engine"`` track carrying the per-cycle phase
  spans (admission, merge, prefill_window, growth, dispatch, sync,
  bookkeeping, cycle), and one ``"lineN"`` track per pipeline line with
  the raw pipe-body intervals (the promotion of
  :attr:`repro.pipeline.Pipeline.stage_times` into spans);
* an *instant* is a span with ``t_end == t_start`` (exported as a Chrome
  trace instant event) — used for point events like ``retired`` and
  ``preempted``.

Design constraints (the serve hot loop calls this every cycle):

* ``add`` is one lock acquisition + one list write; the buffer never
  grows past ``capacity`` — old spans are overwritten oldest-first and
  counted in :attr:`dropped` (a trace that wrapped says so instead of
  silently lying);
* a disabled tracer (``enabled=False``) returns before touching the lock,
  and every instrumentation site in the engine additionally guards on its
  obs handle being ``None``, so the disabled path costs attribute checks
  only (the <2%% overhead budget on the quick serve bench).

Export to Chrome trace-event JSON (Perfetto / ``chrome://tracing``) lives
in :mod:`repro.obs.export`.
"""
from __future__ import annotations

import threading
import time
from contextlib import contextmanager
from typing import Any, Dict, List, Optional, Tuple

__all__ = ["Span", "Tracer", "TRACK_ENGINE"]

#: the engine-cycle track name (one per engine; slot tracks are "slotN")
TRACK_ENGINE = "engine"

#: (name, track, t_start, t_end, args) — t_* on the perf_counter clock
Span = Tuple[str, str, float, float, Optional[Dict[str, Any]]]


class Tracer:
    """Thread-safe bounded span recorder (see module docstring).

    Parameters
    ----------
    capacity:
        ring-buffer size in spans; the newest ``capacity`` spans are kept
        and :attr:`dropped` counts overwritten ones.
    enabled:
        ``False`` makes every recording method a near-no-op (checked
        before the lock). Flip :attr:`enabled` at will — recording sites
        re-check it on every call.
    """

    def __init__(self, capacity: int = 65536, enabled: bool = True) -> None:
        if capacity < 1:
            raise ValueError("tracer capacity must be >= 1")
        self.capacity = capacity
        self.enabled = enabled
        #: perf_counter origin — export rebases timestamps onto it
        self.t0 = time.perf_counter()
        self._lock = threading.Lock()
        self._buf: List[Span] = []
        self._write = 0          # overwrite cursor once the buffer is full
        self.dropped = 0         # spans overwritten by ring wrap

    # -------------------------------------------------------------- recording
    def add(self, name: str, track: str, t_start: float, t_end: float,
            args: Optional[Dict[str, Any]] = None) -> None:
        """Record one completed span. ``t_end == t_start`` is an instant."""
        if not self.enabled:
            return
        span = (name, track, t_start, t_end, args)
        with self._lock:
            if len(self._buf) < self.capacity:
                self._buf.append(span)
            else:
                self._buf[self._write] = span
                self._write = (self._write + 1) % self.capacity
                self.dropped += 1

    def instant(self, name: str, track: str, t: Optional[float] = None,
                args: Optional[Dict[str, Any]] = None) -> None:
        """Record a point event (a zero-duration span)."""
        if not self.enabled:
            return
        if t is None:
            t = time.perf_counter()
        self.add(name, track, t, t, args)

    @contextmanager
    def span(self, name: str, track: str,
             args: Optional[Dict[str, Any]] = None):
        """Context manager: record the wrapped block as one span."""
        if not self.enabled:
            yield
            return
        t0 = time.perf_counter()
        try:
            yield
        finally:
            self.add(name, track, t0, time.perf_counter(), args)

    # ---------------------------------------------------------------- reading
    def spans(self) -> List[Span]:
        """A chronological (oldest-first) copy of the buffered spans."""
        with self._lock:
            return self._buf[self._write:] + self._buf[:self._write]

    def __len__(self) -> int:
        with self._lock:
            return len(self._buf)

    def clear(self) -> None:
        """Drop every buffered span (the perf_counter origin is kept, so
        spans recorded before and after a clear stay on one clock)."""
        with self._lock:
            self._buf = []
            self._write = 0
            self.dropped = 0
