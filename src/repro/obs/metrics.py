"""Named runtime metrics: counters, gauges, exponential-bucket histograms.

A :class:`MetricsRegistry` is the second half of the serve-layer
observability subsystem (spans in :mod:`repro.obs.tracing` answer *when*,
these answer *how much*): the engine registers pool occupancy gauges,
preemption/stall counters and latency histograms (TTFT, queue wait, chunk
sync, per-cycle breakdown) against it, and :meth:`MetricsRegistry.snapshot`
returns one JSON-able dict the stats logger, the benchmarks and the trace
export all read.

Hot-path discipline: callers cache the metric HANDLE once
(``m = registry.counter("serve.tokens_out")``) and call ``m.inc()`` /
``m.record()`` per event — one lock + one arithmetic op; the registry dict
is only touched at registration time. Every metric zeroes IN PLACE on
:meth:`MetricsRegistry.reset` so cached handles survive a benchmark's
warm-up reset.

The histogram is exponential-bucketed (geometric bucket bounds — latencies
span µs to seconds, so linear buckets would waste either resolution or
range) and additionally retains up to ``keep_samples`` raw samples: for
the serve benchmarks' request counts the reported p50/p99 are EXACT, and
only beyond the retention cap do percentiles fall back to geometric
bucket interpolation.
"""
from __future__ import annotations

import math
import threading
from typing import Any, Dict, List, Optional, Union

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry"]


class Counter:
    """Monotone event counter."""

    __slots__ = ("name", "_lock", "_value")

    def __init__(self, name: str) -> None:
        self.name = name
        self._lock = threading.Lock()
        self._value = 0

    def inc(self, n: int = 1) -> None:
        with self._lock:
            self._value += n

    @property
    def value(self) -> int:
        with self._lock:
            return self._value

    def reset(self) -> None:
        with self._lock:
            self._value = 0


class Gauge:
    """Last-write-wins instantaneous value (pool occupancy, queue depth)."""

    __slots__ = ("name", "_lock", "_value")

    def __init__(self, name: str) -> None:
        self.name = name
        self._lock = threading.Lock()
        self._value: Union[int, float] = 0

    def set(self, v: Union[int, float]) -> None:
        with self._lock:
            self._value = v

    def inc(self, n: Union[int, float] = 1) -> None:
        with self._lock:
            self._value += n

    @property
    def value(self) -> Union[int, float]:
        with self._lock:
            return self._value

    def reset(self) -> None:
        with self._lock:
            self._value = 0


class Histogram:
    """Exponential-bucket histogram with exact small-count percentiles.

    Bucket ``0`` holds values below ``base``; bucket ``i >= 1`` holds
    ``[base * growth**(i-1), base * growth**i)``; the last bucket is
    open-ended. Defaults (10 µs base, ×2 growth, 40 buckets) cover
    10 µs .. ~5.5e6 s — every latency the serve stack can produce.
    """

    __slots__ = ("name", "base", "growth", "_lock", "_buckets", "_count",
                 "_sum", "_min", "_max", "_samples", "_keep")

    def __init__(self, name: str, base: float = 1e-5, growth: float = 2.0,
                 num_buckets: int = 40, keep_samples: int = 4096) -> None:
        if base <= 0 or growth <= 1.0 or num_buckets < 2:
            raise ValueError("histogram needs base > 0, growth > 1, "
                             ">= 2 buckets")
        self.name = name
        self.base = base
        self.growth = growth
        self._lock = threading.Lock()
        self._buckets = [0] * num_buckets
        self._count = 0
        self._sum = 0.0
        self._min = math.inf
        self._max = -math.inf
        self._samples: List[float] = []
        self._keep = keep_samples

    def _bucket_index(self, v: float) -> int:
        if v < self.base:
            return 0
        i = 1 + int(math.log(v / self.base) / math.log(self.growth))
        return min(i, len(self._buckets) - 1)

    def record(self, v: float) -> None:
        v = float(v)
        with self._lock:
            self._buckets[self._bucket_index(v)] += 1
            self._count += 1
            self._sum += v
            if v < self._min:
                self._min = v
            if v > self._max:
                self._max = v
            if len(self._samples) < self._keep:
                self._samples.append(v)

    # ------------------------------------------------------------- summaries
    @property
    def count(self) -> int:
        with self._lock:
            return self._count

    @property
    def buckets(self) -> List[int]:
        with self._lock:
            return list(self._buckets)

    def bucket_bound(self, i: int) -> float:
        """Exclusive upper bound of bucket ``i`` (inf for the last)."""
        if i >= len(self._buckets) - 1:
            return math.inf
        return self.base * self.growth ** i

    def percentile(self, q: float) -> float:
        """Value at percentile ``q`` (0..100): exact (nearest-rank over the
        retained samples) while every recorded value is retained, geometric
        bucket interpolation beyond the retention cap; 0.0 when empty."""
        if not 0.0 <= q <= 100.0:
            raise ValueError("percentile must be in [0, 100]")
        with self._lock:
            if self._count == 0:
                return 0.0
            if self._count <= len(self._samples):
                s = sorted(self._samples)
                rank = max(1, math.ceil(q / 100.0 * len(s)))
                return s[rank - 1]
            target = max(1, math.ceil(q / 100.0 * self._count))
            cum = 0
            for i, n in enumerate(self._buckets):
                cum += n
                if cum >= target:
                    lo = self.base * self.growth ** (i - 1) if i >= 1 \
                        else min(self._min, self.base)
                    hi = self.base * self.growth ** i if i >= 1 else self.base
                    hi = min(hi, self._max)
                    lo = min(lo, hi)
                    return math.sqrt(lo * hi) if lo > 0 else hi
            return self._max       # unreachable (cum == count at the end)

    def summary(self) -> Dict[str, float]:
        with self._lock:
            count, total = self._count, self._sum
            mn = self._min if count else 0.0
            mx = self._max if count else 0.0
        return {"count": count, "sum": total,
                "mean": total / count if count else 0.0,
                "min": mn, "max": mx,
                "p50": self.percentile(50.0), "p99": self.percentile(99.0)}

    def reset(self) -> None:
        with self._lock:
            self._buckets = [0] * len(self._buckets)
            self._count = 0
            self._sum = 0.0
            self._min = math.inf
            self._max = -math.inf
            self._samples = []


class MetricsRegistry:
    """Get-or-create registry of named metrics (thread-safe).

    ``counter("x")`` / ``gauge("x")`` / ``histogram("x")`` return the live
    metric, creating it on first use; re-registering a name as a different
    kind raises. :meth:`snapshot` returns ``{name: value-or-summary}`` and
    :meth:`reset` zeroes every metric in place (handles stay valid).
    """

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._metrics: Dict[str, Any] = {}

    def _get_or_create(self, name: str, cls, **kw):
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = cls(name, **kw)
                self._metrics[name] = m
            elif not isinstance(m, cls):
                raise TypeError(
                    f"metric {name!r} already registered as "
                    f"{type(m).__name__}, not {cls.__name__}")
            return m

    def counter(self, name: str) -> Counter:
        return self._get_or_create(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get_or_create(name, Gauge)

    def histogram(self, name: str, **kw) -> Histogram:
        return self._get_or_create(name, Histogram, **kw)

    def get(self, name: str) -> Optional[Any]:
        with self._lock:
            return self._metrics.get(name)

    def names(self) -> List[str]:
        with self._lock:
            return sorted(self._metrics)

    def snapshot(self) -> Dict[str, Any]:
        """One JSON-able dict: counters/gauges -> value, histograms ->
        their :meth:`Histogram.summary` dict."""
        with self._lock:
            items = list(self._metrics.items())
        out: Dict[str, Any] = {}
        for name, m in sorted(items):
            out[name] = m.summary() if isinstance(m, Histogram) else m.value
        return out

    def reset(self) -> None:
        """Zero every metric IN PLACE — cached handles keep working (the
        benchmark warm-up reset)."""
        with self._lock:
            metrics = list(self._metrics.values())
        for m in metrics:
            m.reset()
