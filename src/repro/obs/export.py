"""Trace + stats export: Chrome trace-event JSON and a periodic stats line.

:func:`export_chrome_trace` serialises a :class:`repro.obs.tracing.Tracer`
into the Chrome trace-event format (the JSON Perfetto and
``chrome://tracing`` load directly — the tfprof rendering path of the
source paper, §VI): every tracer track becomes one named thread row
(``"engine"`` first, then slot and pipeline-line tracks in natural order),
completed spans become ``"X"`` duration events, zero-duration spans become
``"i"`` instants, and the metrics registry snapshot rides along in
``otherData`` so one artifact carries the whole picture.

:class:`StatsLogger` is the terminal counterpart: a daemon thread that
prints ONE line per interval (token throughput over the window, queue
depth, resident rows, pool occupancy, preempt/stall counts, TTFT p50)
from the same registry — ``launch/serve.py --stats-interval``.
"""
from __future__ import annotations

import json
import re
import threading
import time
from typing import Any, Callable, Dict, List, Optional

from .metrics import MetricsRegistry
from .tracing import TRACK_ENGINE, Tracer

__all__ = ["chrome_trace_events", "export_chrome_trace", "StatsLogger"]

_PID = 1  # single-process serve stack: one trace process row


def _track_sort_key(track: str):
    """engine first, then tracks in natural (slot2 < slot10) order."""
    if track == TRACK_ENGINE:
        return (0, "", 0)
    m = re.match(r"^(.*?)(\d+)$", track)
    if m:
        return (1, m.group(1), int(m.group(2)))
    return (1, track, -1)


def chrome_trace_events(tracer: Tracer) -> List[Dict[str, Any]]:
    """Flatten the tracer's ring buffer into trace-event dicts.

    Timestamps are rebased onto the tracer's origin (``tracer.t0``) and
    expressed in microseconds, as the format requires. Metadata events
    name the process and one thread per track; ``thread_sort_index`` pins
    the engine track to the top of the Perfetto timeline.
    """
    spans = tracer.spans()
    tracks = sorted({track for _, track, _, _, _ in spans},
                    key=_track_sort_key)
    tids = {track: i for i, track in enumerate(tracks)}
    events: List[Dict[str, Any]] = [{
        "name": "process_name", "ph": "M", "pid": _PID, "tid": 0,
        "args": {"name": "repro-serve"},
    }]
    for track, tid in tids.items():
        events.append({"name": "thread_name", "ph": "M", "pid": _PID,
                       "tid": tid, "args": {"name": track}})
        events.append({"name": "thread_sort_index", "ph": "M", "pid": _PID,
                       "tid": tid, "args": {"sort_index": tid}})
    for name, track, t_start, t_end, args in spans:
        ts = (t_start - tracer.t0) * 1e6
        ev: Dict[str, Any] = {"name": name, "ph": "X", "pid": _PID,
                              "tid": tids[track], "ts": ts,
                              "args": dict(args) if args else {}}
        if t_end > t_start:
            ev["dur"] = (t_end - t_start) * 1e6
        else:
            ev["ph"] = "i"
            ev["s"] = "t"          # instant scoped to its thread (track)
        events.append(ev)
    return events


def export_chrome_trace(path: str, tracer: Tracer,
                        metrics: Optional[MetricsRegistry] = None) -> str:
    """Write the Chrome-trace JSON object form to ``path`` and return it.

    ``otherData`` carries the metrics snapshot plus the tracer's drop
    count, so a wrapped ring buffer is visible in the artifact rather
    than silently truncating history.
    """
    other: Dict[str, Any] = {"spans": len(tracer),
                             "dropped_spans": tracer.dropped}
    if metrics is not None:
        other["metrics"] = metrics.snapshot()
    payload = {
        "traceEvents": chrome_trace_events(tracer),
        "displayTimeUnit": "ms",
        "otherData": other,
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
        f.write("\n")
    return path


class StatsLogger:
    """Periodic one-line serve stats from a :class:`MetricsRegistry`.

    Counters are reported as deltas over the interval window (so the
    throughput column is a live rate, not a lifetime mean); gauges and
    histogram percentiles are instantaneous. ``emit`` defaults to
    ``print`` — pass a callable to capture lines in tests.
    """

    #: counters whose per-window deltas feed the line
    _DELTAS = ("serve.tokens_out", "serve.requests.retired",
               "serve.requests.preempted", "serve.requests.stalled")

    def __init__(self, metrics: MetricsRegistry, interval: float = 1.0,
                 emit: Optional[Callable[[str], None]] = None) -> None:
        if interval <= 0:
            raise ValueError("stats interval must be > 0")
        self.metrics = metrics
        self.interval = interval
        self._emit = emit or (lambda line: print(line, flush=True))
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self._prev: Dict[str, int] = {}
        self._prev_t = time.perf_counter()

    # --------------------------------------------------------------- the line
    def line(self) -> str:
        """Format one stats line from the current snapshot (advances the
        delta window)."""
        now = time.perf_counter()
        dt = max(now - self._prev_t, 1e-9)
        snap = self.metrics.snapshot()
        delta = {}
        for name in self._DELTAS:
            cur = int(snap.get(name, 0) or 0)
            delta[name] = cur - self._prev.get(name, 0)
            self._prev[name] = cur
        self._prev_t = now
        ttft = snap.get("serve.ttft_s") or {}
        return (f"[obs] tok/s {delta['serve.tokens_out'] / dt:8.1f} | "
                f"retired {delta['serve.requests.retired']} | "
                f"queue {int(snap.get('serve.queue_depth', 0) or 0)} | "
                f"resident {int(snap.get('serve.resident_rows', 0) or 0)} | "
                f"blocks free/used/deferred "
                f"{int(snap.get('pool.blocks_free', 0) or 0)}/"
                f"{int(snap.get('pool.blocks_used', 0) or 0)}/"
                f"{int(snap.get('pool.blocks_deferred', 0) or 0)} | "
                f"preempt {delta['serve.requests.preempted']} "
                f"stall {delta['serve.requests.stalled']} | "
                f"ttft_p50 {1e3 * ttft.get('p50', 0.0):.0f}ms")

    # -------------------------------------------------------------- lifecycle
    def _loop(self) -> None:
        while not self._stop.wait(self.interval):
            self._emit(self.line())

    def start(self) -> "StatsLogger":
        if self._thread is not None:
            raise RuntimeError("stats logger already started")
        self._prev_t = time.perf_counter()
        self._stop.clear()
        self._thread = threading.Thread(target=self._loop,
                                        name="repro-obs-stats", daemon=True)
        self._thread.start()
        return self

    def stop(self, final_line: bool = True) -> None:
        if self._thread is None:
            return
        self._stop.set()
        self._thread.join(timeout=5.0)
        self._thread = None
        if final_line:
            self._emit(self.line())
