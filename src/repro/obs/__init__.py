"""Serve-layer observability: span tracing, metrics, Perfetto export.

The Taskflow paper ships tfprof (§VI) — a built-in profiler whose
per-worker timelines make the runtime's scheduling decisions visible.
This package is the serve-stack analogue for our reproduction:

* :mod:`repro.obs.tracing` — :class:`Tracer`, a thread-safe ring buffer
  of ``(name, track, t_start, t_end, args)`` spans (request lifecycle on
  per-slot tracks, engine cycle phases on the ``"engine"`` track,
  pipeline pipe bodies on ``"lineN"`` tracks);
* :mod:`repro.obs.metrics` — :class:`MetricsRegistry` of named counters,
  gauges and exponential-bucket histograms (pool occupancy, queue depth,
  preempt/stall counts, TTFT, queue wait, per-cycle dispatch/sync/
  bookkeeping seconds) with a JSON-able ``snapshot()``;
* :mod:`repro.obs.export` — Chrome trace-event JSON export (loads in
  Perfetto / ``chrome://tracing``) and the ``--stats-interval`` one-line
  :class:`StatsLogger`.

:class:`Observability` bundles one tracer + one registry and is what
``ServeEngine(obs=...)`` accepts; :func:`from_env` builds one when the
``REPRO_OBS`` environment variable is truthy (``1``/``true``/``yes``/
``on``), which is how the launcher and benchmarks opt in without
plumbing a handle through every constructor.
"""
from __future__ import annotations

import os
from typing import Optional

from .export import StatsLogger, chrome_trace_events, export_chrome_trace
from .metrics import Counter, Gauge, Histogram, MetricsRegistry
from .tracing import TRACK_ENGINE, Span, Tracer

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "Span", "Tracer", "TRACK_ENGINE",
    "StatsLogger", "chrome_trace_events", "export_chrome_trace",
    "Observability", "env_enabled", "from_env",
]

_TRUTHY = {"1", "true", "yes", "on"}


class Observability:
    """One tracer + one metrics registry, handed to ``ServeEngine(obs=)``.

    The engine treats a ``None`` obs handle as fully disabled (hot paths
    guard on a single attribute check), so constructing an
    ``Observability`` *is* the enable switch.
    """

    def __init__(self, tracer: Optional[Tracer] = None,
                 metrics: Optional[MetricsRegistry] = None,
                 trace_capacity: int = 65536) -> None:
        self.tracer = tracer if tracer is not None \
            else Tracer(capacity=trace_capacity)
        self.metrics = metrics if metrics is not None else MetricsRegistry()

    def export(self, path: str) -> str:
        """Write the Chrome-trace JSON artifact (spans + metric snapshot)."""
        return export_chrome_trace(path, self.tracer, self.metrics)

    def reset(self) -> None:
        """Clear spans and zero metrics in place (handles stay valid)."""
        self.tracer.clear()
        self.metrics.reset()


def env_enabled(env: Optional[str] = None) -> bool:
    """True when ``REPRO_OBS`` (or an explicit value) is truthy."""
    v = os.environ.get("REPRO_OBS", "") if env is None else env
    return v.strip().lower() in _TRUTHY


def from_env() -> Optional[Observability]:
    """An :class:`Observability` when ``REPRO_OBS`` opts in, else None."""
    return Observability() if env_enabled() else None
