"""Pure-jnp oracles for every Pallas kernel (the correctness contract).

Each kernel's test sweeps shapes/dtypes and asserts allclose against these.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["flash_attention_ref", "mamba_scan_ref", "lsdnn_layer_ref"]


def flash_attention_ref(q, k, v, causal: bool = True):
    """q: (B,S,H,hd); k,v: (B,T,KV,hd); GQA causal softmax attention.
    Returns (B,S,H,hd) in q.dtype; softmax in fp32."""
    B, S, H, hd = q.shape
    T, KV = k.shape[1], k.shape[2]
    G = H // KV
    qg = q.reshape(B, S, KV, G, hd)
    s = jnp.einsum("bqkgh,bskh->bkgqs", qg, k,
                   preferred_element_type=jnp.float32) * (hd ** -0.5)
    if causal:
        mask = jnp.arange(S)[:, None] >= jnp.arange(T)[None, :]
        s = jnp.where(mask[None, None, None], s, -2.0 ** 30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskh->bqkgh", p.astype(v.dtype), v)
    return o.reshape(B, S, H, hd).astype(q.dtype)


def mamba_scan_ref(dt, A, Bc, Cc, x, h0=None):
    """Sequential selective-scan oracle.

    dt, x: (B,S,dI); A: (dI,N); Bc,Cc: (B,S,N). fp32 recurrence.
    Returns y (B,S,dI) fp32 and final state (B,dI,N).
    """
    Bb, S, dI = x.shape
    N = A.shape[1]
    dt = dt.astype(jnp.float32)
    x = x.astype(jnp.float32)
    Bc = Bc.astype(jnp.float32)
    Cc = Cc.astype(jnp.float32)
    if h0 is None:
        h0 = jnp.zeros((Bb, dI, N), jnp.float32)

    def step(h, inp):
        dt_t, x_t, b_t, c_t = inp
        a = jnp.exp(dt_t[..., None] * A)              # (B,dI,N)
        h = a * h + (dt_t * x_t)[..., None] * b_t[:, None, :]
        y = jnp.einsum("bdn,bn->bd", h, c_t)
        return h, y

    hT, ys = jax.lax.scan(step, h0, (dt.swapaxes(0, 1), x.swapaxes(0, 1),
                                     Bc.swapaxes(0, 1), Cc.swapaxes(0, 1)))
    return ys.swapaxes(0, 1), hT


def lsdnn_layer_ref(y, w, b, cap: float = 32.0):
    """One LSDNN inference layer (paper §5.3 workload, HPEC sparse-DNN
    challenge semantics): Y' = clamp(relu(Y @ W + b), 0, cap)."""
    z = jnp.einsum("tf,fg->tg", y, w,
                   preferred_element_type=jnp.float32)
    z = z + b.astype(jnp.float32)
    return jnp.clip(z, 0.0, cap).astype(y.dtype)
