"""Paged decode-attention: gather-free reads through the block table.

The continuous-batching engine's hot path is one-token decode against the
paged KV pool. The original read path (``serve.kvcache.gather_pages``)
materialized a contiguous ``(B, KV, max_blocks*block_size, hd)`` copy of
every row's pages per layer per token and attended over the fully padded
span — O(capacity) HBM traffic and FLOPs regardless of how short the rows
actually are. Both implementations here read K/V pages *in place* through
the block table and skip blocks past each row's true length, making
per-row cost proportional to **occupancy** instead of **capacity**:

* :func:`paged_attention` with ``impl="pallas"`` — the TPU kernel. Grid is
  ``(batch, kv_head, kv_block)`` with the kv-block axis innermost; online
  softmax state ``(acc, m, l)`` lives in VMEM scratch across kv iterations
  (same pattern as ``flash_attention.py``). The block tables and per-row
  lengths are **scalar-prefetched** (``pltpu.PrefetchScalarGridSpec``) so
  the K/V BlockSpec index maps resolve ``tables[b, j]`` *before* the body
  runs — the DMA engine fetches pages straight from the pool and no
  gathered copy ever exists. Blocks past ``ceil((pos+1)/block_size)`` are
  skipped outright: ``pl.when`` guards the compute, and the index maps
  clamp to the last active block so Mosaic's revisiting-block elision
  issues no new fetch. Validated in interpret mode on CPU (bit-level
  parity with the gather reference is exercised in
  ``tests/test_paged_attention.py``); pass ``interpret=False`` on TPU for
  the Mosaic lowering.

* ``impl="xla"`` — the same blockwise online-softmax algorithm lowered
  through plain XLA for backends without Mosaic (this container is
  CPU-only): a ``lax.fori_loop`` over pages whose trip count is
  ``max(lengths)//block_size + 1`` — a *traced* bound, so short rows in a
  large pool pay for their pages only. Each iteration touches one
  ``(2, B, KV, block_size, hd)`` page pair; the full padded span is never
  materialized. This is the engine's default read path off-TPU
  (``repro.kernels.ops.default_paged_impl``) and what
  ``benchmarks/paged_decode_microbench.py`` measures against the gather
  reference.

K and V live *stacked* in one pool array ``(2, N, KV, block, hd)``
(``serve.kvcache.init_kv_pool``), so the write path appends both with a
single scatter and the read path fetches page pairs with a single gather.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.0 ** 30

__all__ = ["paged_attention"]


def _paged_kernel(tables_ref, lengths_ref, q_ref, k_ref, v_ref, o_ref,
                  acc_ref, m_ref, l_ref, *, block_size: int):
    b = pl.program_id(0)
    j = pl.program_id(2)
    pos = lengths_ref[b]
    nb = pos // block_size + 1      # active blocks: ceil((pos+1)/block)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    @pl.when(j < nb)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)          # (G, hd)
        k = k_ref[0, 0, 0].astype(jnp.float32)       # (bs, hd)
        v = v_ref[0, 0, 0].astype(jnp.float32)
        hd = q.shape[-1]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = s * (hd ** -0.5)                         # (G, bs)
        kpos = j * block_size + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, 1)
        s = jnp.where(kpos <= pos, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(j == nb - 1)
    def _finish():
        l = l_ref[...]
        l = jnp.where(l == 0.0, 1.0, l)              # fully-masked rows
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def _paged_attention_pallas(q, pool_kv, tables, lengths, interpret: bool):
    B, H, hd = q.shape
    _, _, KV, bs, _ = pool_kv.shape
    G = H // KV
    mb = tables.shape[1]
    qg = q.reshape(B, KV, G, hd)

    # scalar-prefetched index maps: the page fetched at grid step (b, h, j)
    # is pool_kv[0|1, tables[b, j]]; past-the-length steps clamp to the last
    # active block, so the revisited window needs no new fetch
    def kv_map(half):
        def index_map(b, h, j, tables, lengths):
            jc = jnp.minimum(j, lengths[b] // bs)
            return half, tables[b, jc], h, 0, 0
        return index_map

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, KV, mb),
        in_specs=[
            pl.BlockSpec((1, 1, G, hd), lambda b, h, j, t, ln: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, 1, bs, hd), kv_map(0)),
            pl.BlockSpec((1, 1, 1, bs, hd), kv_map(1)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, hd),
                               lambda b, h, j, t, ln: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, hd), jnp.float32),   # acc
            pltpu.VMEM((G, 1), jnp.float32),    # running max
            pltpu.VMEM((G, 1), jnp.float32),    # running denom
        ],
    )
    out = pl.pallas_call(
        functools.partial(_paged_kernel, block_size=bs),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KV, G, hd), q.dtype),
        interpret=interpret,
    )(tables, lengths, qg, pool_kv, pool_kv)
    return out.reshape(B, H, hd)


def _paged_attention_xla(q, pool_kv, tables, lengths):
    """Blockwise online softmax as a traced-bound page loop (see module
    docstring). Decode is inference-only, so the while-loop lowering is
    fine; the loop body is the same math as the Pallas kernel body."""
    B, H, hd = q.shape
    _, _, KV, bs, _ = pool_kv.shape
    G = H // KV
    qg = q.reshape(B, KV, G, hd).astype(jnp.float32)
    nb_row = lengths // bs + 1
    nb_max = jnp.max(nb_row)

    def body(j, carry):
        acc, m, l = carry
        jc = jnp.minimum(j, nb_row - 1)              # clamp per row
        blk = jnp.take_along_axis(tables, jc[:, None], axis=1)[:, 0]
        kv_j = pool_kv[:, blk].astype(jnp.float32)   # (2, B, KV, bs, hd)
        s = jnp.einsum("bkgh,bksh->bkgs", qg, kv_j[0],
                       preferred_element_type=jnp.float32) * (hd ** -0.5)
        kpos = jc[:, None] * bs + jnp.arange(bs, dtype=jnp.int32)
        # rows whose pages ran out contribute nothing (jc would re-read
        # their LAST page — without the j < nb_row term it double-counts)
        mask = (kpos <= lengths[:, None]) & (j < nb_row)[:, None]
        s = jnp.where(mask[:, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m - m_new)
        l = alpha * l + jnp.sum(p, axis=-1, keepdims=True)
        acc = acc * alpha + jnp.einsum("bkgs,bksh->bkgh", p, kv_j[1])
        return acc, m_new, l

    acc = jnp.zeros((B, KV, G, hd), jnp.float32)
    m = jnp.full((B, KV, G, 1), NEG_INF, jnp.float32)
    l = jnp.zeros((B, KV, G, 1), jnp.float32)
    acc, m, l = jax.lax.fori_loop(0, nb_max, body, (acc, m, l))
    l = jnp.where(l == 0.0, 1.0, l)
    return (acc / l).reshape(B, H, hd).astype(q.dtype)


@functools.partial(jax.jit, static_argnames=("impl", "interpret"))
def paged_attention(q, pool_kv, tables, lengths, impl: str = "pallas",
                    interpret: bool = True):
    """One-token decode attention straight off the paged KV pool.

    q: (B, H, hd) current-token queries (post-RoPE); pool_kv: (2, N, KV,
    block, hd) stacked K/V pages of ONE layer; tables: (B, max_blocks)
    int32 block tables (unused tail entries point at the sink block);
    lengths: (B,) int32 per-row position ``pos`` — the row attends over
    key positions ``0..pos`` inclusive, i.e. the entry :func:`append_kv`
    just wrote plus everything before it. Returns (B, H, hd).

    impl="pallas" is the Pallas kernel (interpret=True for the CPU-correct
    interpreter, False for Mosaic on TPU); impl="xla" is the traced-bound
    page loop. Both skip pages past each row's length.
    """
    if impl == "pallas":
        return _paged_attention_pallas(q, pool_kv, tables, lengths,
                                       interpret=interpret)
    if impl == "xla":
        return _paged_attention_xla(q, pool_kv, tables, lengths)
    raise ValueError(f"unknown paged attention impl {impl!r} "
                     "(expected 'pallas' or 'xla')")
