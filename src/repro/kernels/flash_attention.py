"""Flash attention Pallas TPU kernel (GQA, causal, online softmax).

TPU adaptation of the blockwise-attention pattern: the grid is
(batch, q_head, q_block, kv_block) with the kv_block axis innermost — TPU
grids execute sequentially, so the (acc, m, l) online-softmax state lives in
VMEM scratch across kv iterations and the output block is written once on
the last kv step. Block shapes default to MXU-aligned (128, head_dim).

Causal handling: kv blocks strictly above the diagonal are PRUNED — the
``pl.when`` guard skips their compute entirely and the k/v index maps clamp
to the last at-or-below-diagonal block so the revisited block window issues
no new fetch (``prune=False`` restores the old mask-to-NEG_INF behaviour;
the two are bit-identical, see ``tests/test_paged_attention.py``). Blocks
straddling the diagonal still mask element-wise.

GQA: q head h reads kv head h // (H // KV) via the k/v BlockSpec index maps
— no KV replication in VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.0 ** 30

__all__ = ["flash_attention"]


def _last_kv_block(qi, block_q: int, block_k: int, nk: int):
    """Index of the last kv block holding any position <= the q block's
    maximum position (blocks after it are fully above the diagonal)."""
    return jnp.minimum((qi * block_q + block_q - 1) // block_k, nk - 1)


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  block_q: int, block_k: int, causal: bool, prune: bool):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)
    last = _last_kv_block(qi, block_q, block_k, nk) if causal and prune \
        else nk - 1

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    @pl.when(ki <= last)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)            # (bq, hd)
        k = k_ref[0, 0].astype(jnp.float32)            # (bk, hd)
        v = v_ref[0, 0].astype(jnp.float32)            # (bk, hd)
        hd = q.shape[-1]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = s * (hd ** -0.5)                           # (bq, bk)
        if causal:
            qpos = qi * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0)
            kpos = ki * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1)
            s = jnp.where(qpos >= kpos, s, NEG_INF)

        m_prev = m_ref[...]                            # (bq, 1)
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                         # (bq, bk)
        alpha = jnp.exp(m_prev - m_new)                # (bq, 1)
        l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == last)
    def _finish():
        l = l_ref[...]
        l = jnp.where(l == 0.0, 1.0, l)            # fully-masked rows
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                             "prune", "interpret"))
def flash_attention(q, k, v, causal: bool = True,
                    block_q: int = 128, block_k: int = 128,
                    prune: bool = True, interpret: bool = True):
    """q: (B,S,H,hd); k,v: (B,T,KV,hd) -> (B,S,H,hd).

    prune=True (causal only) skips kv blocks fully above the diagonal —
    compute AND fetch — instead of masking them; output is bit-identical.
    interpret=True executes the kernel body with the Pallas interpreter
    (CPU-correct); on TPU pass interpret=False for the Mosaic lowering.
    """
    B, S, H, hd = q.shape
    T, KV = k.shape[1], k.shape[2]
    G = H // KV
    block_q = min(block_q, S)
    block_k = min(block_k, T)
    assert S % block_q == 0 and T % block_k == 0, (S, T, block_q, block_k)
    grid = (B, H, S // block_q, T // block_k)
    nk = T // block_k

    qt = q.transpose(0, 2, 1, 3)                   # (B, H, S, hd)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)

    if causal and prune:
        # fully-above-diagonal steps re-address the last active block, so
        # the pipelined copy is elided along with the skipped compute
        def kv_map(b, h, qi, ki):
            return b, h // G, jnp.minimum(
                ki, _last_kv_block(qi, block_q, block_k, nk)), 0
    else:
        def kv_map(b, h, qi, ki):
            return b, h // G, ki, 0

    out = pl.pallas_call(
        functools.partial(_flash_kernel, block_q=block_q, block_k=block_k,
                          causal=causal, prune=prune),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd),
                         lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, block_k, hd), kv_map),
            pl.BlockSpec((1, 1, block_k, hd), kv_map),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, hd),
                               lambda b, h, qi, ki: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, hd), jnp.float32),   # acc
            pltpu.VMEM((block_q, 1), jnp.float32),    # running max
            pltpu.VMEM((block_q, 1), jnp.float32),    # running denom
        ],
        interpret=interpret,
    )(qt, kt, vt)
    return out.transpose(0, 2, 1, 3)
