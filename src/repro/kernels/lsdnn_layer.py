"""Fused LSDNN inference layer Pallas TPU kernel.

The paper's flagship heterogeneous workload (§5.3) is the HPEC Large Sparse
Deep Neural Network challenge: 1920 layers of Y <- clamp(relu(Y @ W + b)).
On GPU the reference decomposes this into cuSPARSE spmm + bias + relu
launches; the TPU adaptation fuses the whole layer into one blocked-matmul
kernel with the clamped-relu epilogue applied in registers on the final
K-step — one VMEM round-trip per tile instead of three HBM round-trips.

Grid: (T/bm, G/bn, F/bk), K innermost with an f32 accumulator in VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["lsdnn_layer"]


def _lsdnn_kernel(y_ref, w_ref, b_ref, o_ref, acc_ref, *, cap: float):
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        y_ref[...].astype(jnp.float32), w_ref[...].astype(jnp.float32),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(ki == nk - 1)
    def _finish():
        z = acc_ref[...] + b_ref[...].astype(jnp.float32)
        o_ref[...] = jnp.clip(z, 0.0, cap).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("cap", "block_m", "block_n",
                                             "block_k", "interpret"))
def lsdnn_layer(y, w, b, cap: float = 32.0, block_m: int = 256,
                block_n: int = 256, block_k: int = 512,
                interpret: bool = True):
    """y: (T, F); w: (F, G); b: (G,) -> clamp(relu(y @ w + b), 0, cap)."""
    T, F = y.shape
    G = w.shape[1]
    block_m = min(block_m, T)
    block_n = min(block_n, G)
    block_k = min(block_k, F)
    assert T % block_m == 0 and G % block_n == 0 and F % block_k == 0
    grid = (T // block_m, G // block_n, F // block_k)
    b2 = b.reshape(1, G)

    return pl.pallas_call(
        functools.partial(_lsdnn_kernel, cap=cap),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda t, g, k: (t, k)),
            pl.BlockSpec((block_k, block_n), lambda t, g, k: (k, g)),
            pl.BlockSpec((1, block_n), lambda t, g, k: (0, g)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda t, g, k: (t, g)),
        out_shape=jax.ShapeDtypeStruct((T, G), y.dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        interpret=interpret,
    )(y, w, b2)
