"""Jit'd public wrappers for the Pallas kernels.

Selects the execution mode per backend: Mosaic lowering on TPU,
interpreter on CPU (correctness validation — this container is CPU-only;
TPU v5e is the target, DESIGN.md §2.3).
"""
from __future__ import annotations

import jax

from .flash_attention import flash_attention as _flash
from .lsdnn_layer import lsdnn_layer as _lsdnn
from .mamba_scan import mamba_scan as _mamba_scan

__all__ = ["flash_attention", "mamba_scan", "lsdnn_layer", "on_tpu"]


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def flash_attention(q, k, v, causal: bool = True, block_q: int = 128,
                    block_k: int = 128):
    return _flash(q, k, v, causal=causal, block_q=block_q, block_k=block_k,
                  interpret=not on_tpu())


def mamba_scan(dt, x, Bc, Cc, A, block_d: int = 512, chunk: int = 128):
    return _mamba_scan(dt, x, Bc, Cc, A, block_d=block_d, chunk=chunk,
                       interpret=not on_tpu())


def lsdnn_layer(y, w, b, cap: float = 32.0, **blocks):
    return _lsdnn(y, w, b, cap=cap, interpret=not on_tpu(), **blocks)
