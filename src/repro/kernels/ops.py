"""Jit'd public wrappers for the Pallas kernels.

Selects the execution mode per backend: Mosaic lowering on TPU,
interpreter on CPU (correctness validation — this container is CPU-only;
TPU v5e is the target, DESIGN.md §2.3).

:func:`default_paged_impl` resolves which paged decode-attention read path
the serve engine uses (see ``paged_attention.py``): the ``REPRO_PAGED_IMPL``
environment variable (``pallas`` | ``xla`` | ``gather``) wins, otherwise
``pallas`` (Mosaic) on TPU and ``xla`` (the traced-bound page loop — the
interpreter's per-step overhead makes the Pallas kernel a correctness tool,
not a fast path, off-TPU) everywhere else. ``gather`` is the original
materialize-then-mask reference oracle in ``repro.models.attention``.
"""
from __future__ import annotations

import os

import jax

from .flash_attention import flash_attention as _flash
from .lsdnn_layer import lsdnn_layer as _lsdnn
from .mamba_scan import mamba_scan as _mamba_scan
from .paged_attention import paged_attention as _paged

__all__ = ["flash_attention", "mamba_scan", "lsdnn_layer", "paged_attention",
           "default_paged_impl", "on_tpu"]

PAGED_IMPLS = ("pallas", "xla", "gather")


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def default_paged_impl() -> str:
    env = os.environ.get("REPRO_PAGED_IMPL", "").strip().lower()
    if env:
        if env not in PAGED_IMPLS:
            raise ValueError(
                f"REPRO_PAGED_IMPL={env!r}: expected one of {PAGED_IMPLS}")
        return env
    return "pallas" if on_tpu() else "xla"


def flash_attention(q, k, v, causal: bool = True, block_q: int = 128,
                    block_k: int = 128, prune: bool = True):
    return _flash(q, k, v, causal=causal, block_q=block_q, block_k=block_k,
                  prune=prune, interpret=not on_tpu())


def paged_attention(q, pool_kv, tables, lengths, impl: str = "pallas"):
    return _paged(q, pool_kv, tables, lengths, impl=impl,
                  interpret=not on_tpu())


def mamba_scan(dt, x, Bc, Cc, A, block_d: int = 512, chunk: int = 128):
    return _mamba_scan(dt, x, Bc, Cc, A, block_d=block_d, chunk=chunk,
                       interpret=not on_tpu())


def lsdnn_layer(y, w, b, cap: float = 32.0, **blocks):
    return _lsdnn(y, w, b, cap=cap, interpret=not on_tpu(), **blocks)
