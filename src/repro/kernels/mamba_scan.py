"""Selective-scan (Mamba1 core) Pallas TPU kernel.

The GPU reference implementation fuses the recurrence into a warp-level
scan; the TPU adaptation instead blocks the *channel* dimension over the
grid and keeps the (block_d, N) state resident in VMEM scratch while the
sequence axis streams through the innermost grid dimension chunk by chunk
(TPU grids are sequential, so the carry is exact). Within a chunk the
recurrence runs as a `fori_loop` over time with all (block_d, N) lanes
vectorized — N=16 channels x 128-lane blocks keep the VPU full.

    h_t = exp(dt_t * A) * h_{t-1} + (dt_t * x_t) * B_t      (per channel d)
    y_t = <h_t, C_t> + skipped D*x (applied by the caller)

Inputs: dt, x: (B, S, dI); A: (dI, N); Bc, Cc: (B, S, N).
Outputs: y (B, S, dI) fp32 and final state (B, dI, N) fp32.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["mamba_scan"]


def _scan_kernel(dt_ref, x_ref, b_ref, c_ref, a_ref, y_ref, hT_ref, h_ref, *,
                 chunk: int):
    ci = pl.program_id(2)
    nc = pl.num_programs(2)

    @pl.when(ci == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    A = a_ref[...]                                   # (bd, N) fp32

    def step(t, h):
        dt_t = dt_ref[0, t, :].astype(jnp.float32)   # (bd,)
        x_t = x_ref[0, t, :].astype(jnp.float32)     # (bd,)
        b_t = b_ref[0, t, :].astype(jnp.float32)     # (N,)
        c_t = c_ref[0, t, :].astype(jnp.float32)     # (N,)
        a = jnp.exp(dt_t[:, None] * A)               # (bd, N)
        h = a * h + (dt_t * x_t)[:, None] * b_t[None, :]
        y_ref[0, t, :] = jnp.sum(h * c_t[None, :], axis=1)
        return h

    h_ref[...] = jax.lax.fori_loop(0, chunk, step, h_ref[...])

    @pl.when(ci == nc - 1)
    def _finish():
        hT_ref[0] = h_ref[...]


@functools.partial(jax.jit,
                   static_argnames=("block_d", "chunk", "interpret"))
def mamba_scan(dt, x, Bc, Cc, A, block_d: int = 512, chunk: int = 128,
               interpret: bool = True):
    """Returns (y (B,S,dI) fp32, hT (B,dI,N) fp32)."""
    B, S, dI = x.shape
    N = A.shape[1]
    block_d = min(block_d, dI)
    chunk = min(chunk, S)
    assert dI % block_d == 0 and S % chunk == 0, (dI, S, block_d, chunk)
    grid = (B, dI // block_d, S // chunk)

    y, hT = pl.pallas_call(
        functools.partial(_scan_kernel, chunk=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, block_d), lambda b, d, c: (b, c, d)),
            pl.BlockSpec((1, chunk, block_d), lambda b, d, c: (b, c, d)),
            pl.BlockSpec((1, chunk, N), lambda b, d, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, d, c: (b, c, 0)),
            pl.BlockSpec((block_d, N), lambda b, d, c: (d, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, block_d), lambda b, d, c: (b, c, d)),
            pl.BlockSpec((1, block_d, N), lambda b, d, c: (b, d, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, dI), jnp.float32),
            jax.ShapeDtypeStruct((B, dI, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((block_d, N), jnp.float32)],
        interpret=interpret,
    )(dt, x, Bc, Cc, A.astype(jnp.float32))
    return y, hT
