"""Trip-count-aware cost analysis of optimized (post-SPMD) HLO text.

``Compiled.cost_analysis()`` counts a ``while`` body ONCE regardless of trip
count (verified: a scan of 8 matmuls reports 1/8 of the FLOPs). Since the
whole framework relies on scan-over-layers to keep compiles tractable, the
roofline would be understated by ~num_layers x. This module re-derives cost
from the optimized HLO itself:

* FLOPs: every ``dot`` contributes 2 * numel(result) * K (K = contracted
  extent, resolved from the operand's defining instruction); convolutions
  contribute 2 * numel(result) * prod(kernel non-output dims).
* Collective bytes: max(result, operand) shaped bytes per all-gather /
  all-reduce / reduce-scatter / all-to-all / collective-permute.
* Call graph: fusion/call/while/conditional costs roll up; while bodies are
  multiplied by the trip count recovered from the loop condition (the s32
  bound of the LT/LE compare — scans lower to 0..L-1 induction). Unbounded
  loops (lax.while_loop with data-dependent exit) multiply by 1 and are
  counted in ``unknown_trip_loops``.

This makes the §Roofline compute/collective terms HLO-grounded while staying
dry-run-only (no execution).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

__all__ = ["analyze_hlo", "HloCost"]

_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
                "f8e5m2fnuz": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1}

_TYPE_RE = re.compile(
    r"\b(f64|f32|f16|bf16|s64|u64|s32|u32|s16|u16|s8|u8|s4|u4|pred|"
    r"f8e4m3fn|f8e4m3|f8e5m2fnuz|f8e5m2|c64|c128)\[([0-9,]*)\]")

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\((.*)$")

_COMP_START_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->")


def _shapes_in(type_str: str) -> List[Tuple[str, List[int]]]:
    out = []
    for dt, dims in _TYPE_RE.findall(type_str):
        out.append((dt, [int(d) for d in dims.split(",")] if dims else []))
    return out


def _bytes_of(type_str: str) -> int:
    total = 0
    for dt, dims in _shapes_in(type_str):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


def _max_shape_bytes(type_str: str) -> int:
    """Largest SINGLE shape in a (possibly tuple) type string. Collectives
    are classified by this rather than by :func:`_bytes_of`: an async
    ``all-gather-start`` result is the tuple ``(operand_alias, gathered)``
    and summing it would double-count the aliased input on top of the real
    transfer."""
    best = 0
    for dt, dims in _shapes_in(type_str):
        n = _DTYPE_BYTES.get(dt, 4)
        for d in dims:
            n *= d
        best = max(best, n)
    return best


def _numel(type_str: str) -> int:
    total = 0
    for _, dims in _shapes_in(type_str):
        n = 1
        for d in dims:
            n *= d
        total += n
    return total


@dataclass
class _Instr:
    name: str
    type_str: str
    opcode: str
    rest: str          # everything after the opening paren (operands + attrs)


@dataclass
class HloCost:
    flops: float = 0.0
    transcendentals: float = 0.0
    bytes_accessed: float = 0.0   # fusion-boundary traffic, trip-corrected
    collective_bytes: Dict[str, float] = field(
        default_factory=lambda: {c: 0.0 for c in _COLLECTIVES})
    collective_counts: Dict[str, float] = field(
        default_factory=lambda: {c: 0.0 for c in _COLLECTIVES})
    #: largest SINGLE collective of each type (max over operand/result
    #: bytes of one instruction — never multiplied by trip counts). The
    #: sharded-serving CI invariant keys on this: an accidental gather of
    #: the paged KV pool shows up as one pool-shard-sized all-gather no
    #: matter how many tiny activation gathers the program also contains.
    collective_max_bytes: Dict[str, float] = field(
        default_factory=lambda: {c: 0.0 for c in _COLLECTIVES})
    unknown_trip_loops: int = 0

    @property
    def collective_total(self) -> float:
        return sum(self.collective_bytes.values())

    def add(self, other: "HloCost", mult: float = 1.0) -> None:
        self.flops += other.flops * mult
        self.transcendentals += other.transcendentals * mult
        self.bytes_accessed += other.bytes_accessed * mult
        for c in _COLLECTIVES:
            self.collective_bytes[c] += other.collective_bytes[c] * mult
            self.collective_counts[c] += other.collective_counts[c] * mult
            # a loop repeats the SAME transfer: the largest single
            # collective is unchanged by the trip count
            self.collective_max_bytes[c] = max(
                self.collective_max_bytes[c], other.collective_max_bytes[c])
        self.unknown_trip_loops += other.unknown_trip_loops


def _parse_computations(text: str) -> Dict[str, List[_Instr]]:
    comps: Dict[str, List[_Instr]] = {}
    cur: Optional[str] = None
    for line in text.splitlines():
        if cur is None:
            if line.rstrip().endswith("{"):
                m = _COMP_START_RE.match(line.strip())
                if m:
                    cur = m.group(1)
                    comps[cur] = []
        else:
            if line.strip() == "}":
                cur = None
                continue
            m = _INSTR_RE.match(line)
            if m:
                comps[cur].append(_Instr(m.group(1), m.group(2),
                                         m.group(3), m.group(4)))
    return comps


_OPERAND_NAME_RE = re.compile(r"%([\w\.\-]+)")


def _operand_names(rest: str) -> List[str]:
    # Operands are the %names inside the first balanced paren group. Each
    # entry is printed as ``f32[128,128]{1,0} %name`` (type prefix first), so
    # extract the %-prefixed identifiers in order; type/layout text contains
    # no ``%``, and attributes (metadata, calls=...) sit past the close paren.
    depth = 1
    token = ""
    for ch in rest:
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                break
        token += ch
    return _OPERAND_NAME_RE.findall(token)


_CALLS_RE = re.compile(r"calls=%?([\w\.\-]+)")
_KNOWN_TRIP_RE = re.compile(r'"known_trip_count"\s*:\s*\{\s*"n"\s*:\s*"(\d+)"')
_WHILE_RE = re.compile(r"condition=%?([\w\.\-]+),\s*body=%?([\w\.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_CDIMS_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")


def _trip_count(cond_name: str, comps: Dict[str, List[_Instr]]) -> Optional[int]:
    """Largest s32 constant in the condition region (+1 for LE compares)."""
    instrs = comps.get(cond_name, [])
    consts: List[int] = []
    le = False
    names = [cond_name]
    for ins in instrs:
        m = _CALLS_RE.search(ins.rest)
        if m:
            names.append(m.group(1))
    for nm in names:
        for ins in comps.get(nm, []):
            if ins.opcode == "constant" and ins.type_str.startswith(("s32", "s64", "u32")):
                mm = re.search(r"constant\((-?\d+)", "constant(" + ins.rest)
                if mm:
                    consts.append(int(mm.group(1)))
            if "direction=LE" in ins.rest:
                le = True
    if not consts:
        return None
    t = max(consts)
    return t + 1 if le else t


def _comp_cost(name: str, comps: Dict[str, List[_Instr]],
               memo: Dict[str, HloCost]) -> HloCost:
    if name in memo:
        return memo[name]
    memo[name] = HloCost()  # cycle guard
    cost = HloCost()
    instrs = comps.get(name, [])
    types = {i.name: i.type_str for i in instrs}

    _FREE = {"parameter", "constant", "tuple", "get-tuple-element",
             "bitcast", "after-all", "partition-id", "replica-id", "iota",
             "while", "conditional"}

    _SLICY = {"dynamic-slice", "slice", "gather", "bitcast", "reshape",
              "broadcast"}

    def _fusion_read_bytes(called: str, operands: List[str]) -> float:
        """Bytes READ by a fusion: per parameter, if every consumer inside
        the fused computation is a slicing op, count the slices' results
        (a dynamic-slice of the stacked layer weights reads one layer, not
        the whole stack); a dynamic-update-slice consuming the parameter as
        its target buffer is an in-place aliased write (reads ~the update);
        otherwise count the full operand."""
        fin = comps.get(called)
        if fin is None:
            return sum(_bytes_of(types[o]) for o in operands if o in types)
        ftypes = {i.name: i.type_str for i in fin}
        params: Dict[int, str] = {}
        for i in fin:
            if i.opcode == "parameter":
                mm = re.match(r"\s*(\d+)", i.rest)
                if mm:
                    params[int(mm.group(1))] = i.name
        total = 0.0
        for idx, opnd in enumerate(operands):
            pname = params.get(idx)
            full = _bytes_of(types.get(opnd, "")) if opnd in types else 0
            if pname is None:
                total += full
                continue
            consumers = [i for i in fin
                         if pname in _operand_names(i.rest)]
            part = 0.0
            ok = bool(consumers)
            for c in consumers:
                if c.opcode in _SLICY:
                    part += _bytes_of(c.type_str)
                elif c.opcode == "dynamic-update-slice" and \
                        _operand_names(c.rest)[:1] == [pname]:
                    co = _operand_names(c.rest)
                    part += _bytes_of(ftypes.get(co[1], "")) if \
                        len(co) > 1 else 0.0
                else:
                    ok = False
                    break
            total += part if ok else full
        return total

    def _fusion_result_bytes(ins: _Instr) -> float:
        """A fusion whose root is a dynamic-update-slice writes only the
        update region (the target aliases an operand)."""
        m = _CALLS_RE.search(ins.rest)
        fin = comps.get(m.group(1)) if m else None
        if fin:
            ftypes = {i.name: i.type_str for i in fin}
            roots = [i for i in fin if i.opcode == "dynamic-update-slice"]
            if roots and _bytes_of(roots[-1].type_str) == \
                    _bytes_of(ins.type_str):
                co = _operand_names(roots[-1].rest)
                if len(co) > 1 and co[1] in ftypes:
                    return _bytes_of(ftypes[co[1]])
        return _bytes_of(ins.type_str)

    def _traffic(ins: _Instr) -> float:
        # Mirrors HloCostAnalysis conventions: an op writes its result and
        # reads what it actually touches — dynamic-(update-)slice and
        # gather/scatter touch slice-sized regions, fusions read slices of
        # operands that are only sliced inside.
        op = ins.opcode
        res = _bytes_of(ins.type_str)
        ops = _operand_names(ins.rest)
        if op in ("dynamic-slice", "slice"):
            return 2.0 * res
        if op == "dynamic-update-slice":
            upd = _bytes_of(types[ops[1]]) if len(ops) > 1 and \
                ops[1] in types else res
            return 2.0 * upd
        if op == "gather":
            return 2.0 * res
        if op == "scatter":
            upd = _bytes_of(types[ops[-1]]) if ops and ops[-1] in types \
                else res
            return 2.0 * upd
        if op == "fusion":
            m = _CALLS_RE.search(ins.rest)
            reads = _fusion_read_bytes(m.group(1), ops) if m else \
                sum(_bytes_of(types[o]) for o in ops if o in types)
            return _fusion_result_bytes(ins) + reads
        b = res
        for o in ops:
            if o in types:
                b += _bytes_of(types[o])
        return b

    for ins in instrs:
        op = ins.opcode
        if op not in _FREE:
            cost.bytes_accessed += _traffic(ins)
        if op == "dot":
            ops = _operand_names(ins.rest)
            k = 1
            if ops and ops[0] in types:
                lhs_shapes = _shapes_in(types[ops[0]])
                m = _CDIMS_RE.search(ins.rest)
                if m and lhs_shapes:
                    dims = lhs_shapes[0][1]
                    for di in (int(x) for x in m.group(1).split(",") if x):
                        if di < len(dims):
                            k *= dims[di]
            cost.flops += 2.0 * _numel(ins.type_str) * k
        elif op == "convolution":
            ops = _operand_names(ins.rest)
            kelems = 1
            if len(ops) > 1 and ops[1] in types:
                kshapes = _shapes_in(types[ops[1]])
                if kshapes:
                    dims = kshapes[0][1]
                    n = 1
                    for d in dims:
                        n *= d
                    # exclude output-feature dim (largest heuristic)
                    kelems = n // max(dims) if dims else 1
            cost.flops += 2.0 * _numel(ins.type_str) * kelems
        elif op in ("exponential", "log", "tanh", "rsqrt", "sqrt", "power",
                    "logistic", "sine", "cosine"):
            cost.transcendentals += _numel(ins.type_str)
        elif op.rstrip("-start").rstrip("-done") in _COLLECTIVES or \
                any(op == c or op == c + "-start" for c in _COLLECTIVES):
            base = op[:-len("-start")] if op.endswith("-start") else op
            if base in _COLLECTIVES:
                ops = _operand_names(ins.rest)
                b = _max_shape_bytes(ins.type_str)
                for o in ops:
                    if o in types:
                        b = max(b, _max_shape_bytes(types[o]))
                cost.collective_bytes[base] += b
                cost.collective_counts[base] += 1
                cost.collective_max_bytes[base] = max(
                    cost.collective_max_bytes[base], b)
        if op == "while":
            m = _WHILE_RE.search(ins.rest)
            if m:
                cond_name, body_name = m.group(1), m.group(2)
                # XLA stamps scan-lowered loops with an exact trip count in
                # the backend config — authoritative; fall back to the
                # condition-region bound heuristic otherwise.
                kt = _KNOWN_TRIP_RE.search(ins.rest)
                trip = int(kt.group(1)) if kt else \
                    _trip_count(cond_name, comps)
                if trip is None:
                    trip = 1
                    cost.unknown_trip_loops += 1
                body = _comp_cost(body_name, comps, memo)
                cond = _comp_cost(cond_name, comps, memo)
                cost.add(body, trip)
                cost.add(cond, trip)
        elif op == "conditional":
            m = _BRANCHES_RE.search(ins.rest)
            if m:
                worst = HloCost()
                for bn in m.group(1).split(","):
                    bn = bn.strip().lstrip("%")
                    bc = _comp_cost(bn, comps, memo)
                    if bc.flops >= worst.flops:
                        worst = bc
                cost.add(worst)
        else:
            m = _CALLS_RE.search(ins.rest)
            if m and op in ("fusion", "call", "custom-call", "reduce",
                            "map", "scatter", "sort", "reduce-window",
                            "select-and-scatter", "async-start"):
                sub = _comp_cost(m.group(1), comps, memo)
                # flops/collectives roll up; bytes are already accounted at
                # this call site (fusion-boundary traffic), so don't recurse
                cost.flops += sub.flops
                cost.transcendentals += sub.transcendentals
                for cc in _COLLECTIVES:
                    cost.collective_bytes[cc] += sub.collective_bytes[cc]
                    cost.collective_counts[cc] += sub.collective_counts[cc]
                    cost.collective_max_bytes[cc] = max(
                        cost.collective_max_bytes[cc],
                        sub.collective_max_bytes[cc])
                cost.unknown_trip_loops += sub.unknown_trip_loops
    memo[name] = cost
    return cost


def analyze_hlo(text: str) -> HloCost:
    comps = _parse_computations(text)
    entry = None
    for line in text.splitlines():
        if line.startswith("ENTRY"):
            m = _COMP_START_RE.match(line.strip()[len("ENTRY"):].strip() if
                                     False else line.strip())
            m = re.match(r"ENTRY\s+%?([\w\.\-]+)", line.strip())
            if m:
                entry = m.group(1)
            break
    if entry is None:
        raise ValueError("no ENTRY computation found")
    return _comp_cost(entry, comps, {})
