"""Sharding rules: map logical tensor axes onto the production mesh.

Logical axes used by the model code:
    ``dp``   — data-parallel axes for the batch dim (``("pod","data")`` on
               the multi-pod mesh, ``("data",)`` single-pod)
    ``fsdp`` — parameter/optimizer sharding axis (ZeRO-3 style), = "data"
    ``tp``   — tensor/expert-parallel axis, = "model"
    ``sp``   — sequence-parallel axis for long-context KV caches, = "model"

The model calls :func:`constrain` on activations; :func:`param_specs`
assigns a PartitionSpec to every parameter by path-based rules (Megatron
column/row pattern for attention/MLP, expert-dim sharding for MoE, inner-dim
sharding for Mamba). Everything degrades to no-ops when no mesh is active so
the same model code runs single-device smoke tests unchanged.
"""
from __future__ import annotations

import contextlib
import dataclasses
import re
import threading
from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["ShardCtx", "use_shard_ctx", "current_ctx", "constrain",
           "param_specs", "named_sharding", "logical_to_spec",
           "gather_tp", "manual_serve_map", "serve_tp_size",
           "serve_attn_sharded", "serve_mlp_sharded", "serve_param_specs",
           "serve_param_shardings", "serve_pool_spec", "serve_kv_cache_spec",
           "MeshDivisibilityError", "validate_serve_mesh"]


@dataclass
class ShardCtx:
    mesh: Optional[Mesh]
    dp: Tuple[str, ...] = ("data",)
    #: fsdp may span multiple mesh axes (("pod","data") on the multi-pod
    #: mesh, so parameter/optimizer state scales with TOTAL chips)
    fsdp: Optional[Any] = "data"
    tp: Optional[str] = "model"
    sp: Optional[str] = "model"
    #: True while tracing inside a shard_map body: shapes are per-shard,
    #: with_sharding_constraint is illegal, and gather_tp becomes live
    manual: bool = False

    def axis_size(self, logical: str) -> int:
        if self.mesh is None:
            return 1

        def tup(x):
            if x is None:
                return ()
            return x if isinstance(x, tuple) else (x,)

        names = {"dp": self.dp, "fsdp": tup(self.fsdp),
                 "tp": tup(self.tp), "sp": tup(self.sp)}[logical]
        size = 1
        for n in names:
            size *= self.mesh.shape[n]
        return size


_local = threading.local()


def current_ctx() -> Optional[ShardCtx]:
    return getattr(_local, "ctx", None)


@contextlib.contextmanager
def use_shard_ctx(ctx: Optional[ShardCtx]):
    prev = current_ctx()
    _local.ctx = ctx
    try:
        yield ctx
    finally:
        _local.ctx = prev


def logical_to_spec(ctx: ShardCtx, logical: Sequence[Any]) -> P:
    """Translate ('dp', None, 'tp') style logical specs to a PartitionSpec."""
    out = []
    for a in logical:
        if a is None:
            out.append(None)
        elif a == "dp":
            out.append(ctx.dp if len(ctx.dp) > 1 else ctx.dp[0])
        elif a == "fsdp":
            out.append(ctx.fsdp)
        elif a in ("tp", "sp"):
            out.append(getattr(ctx, a))
        else:  # raw mesh axis name
            out.append(a)
    return P(*out)


def constrain(x: Any, *logical: Any) -> Any:
    """with_sharding_constraint under the active ShardCtx (no-op without).

    Inside a shard_map body (``ctx.manual``) constraints are illegal —
    shardings there are determined by the in/out specs — so this degrades
    to identity and :func:`gather_tp` takes over at the hand-off points.
    """
    ctx = current_ctx()
    if ctx is None or ctx.mesh is None or ctx.manual:
        return x
    spec = logical_to_spec(ctx, logical)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(ctx.mesh, spec))


def named_sharding(ctx: ShardCtx, *logical: Any) -> NamedSharding:
    return NamedSharding(ctx.mesh, logical_to_spec(ctx, logical))


# --------------------------------------------------------------------------- #
# parameter sharding rules
# --------------------------------------------------------------------------- #

def _divisible(dim: int, ctx: ShardCtx, axis) -> bool:
    if axis is None or ctx.mesh is None:
        return False
    axes = axis if isinstance(axis, tuple) else (axis,)
    size = 1
    for a in axes:
        size *= ctx.mesh.shape[a]
    return dim % size == 0


def _rule(path: str, shape: Tuple[int, ...], ctx: ShardCtx) -> P:
    """PartitionSpec for one parameter (path is '/'-joined key names)."""
    tp, fsdp = ctx.tp, ctx.fsdp
    leaf = path.rsplit("/", 1)[-1]

    def guard(spec_axes):
        """Drop mesh axes that do not divide the dim (uneven shards)."""
        fixed = []
        for dim, ax in zip(shape, spec_axes):
            fixed.append(ax if _divisible(dim, ctx, ax) else None)
        return P(*fixed)

    if leaf in ("embed", "pos_embed"):
        return guard((tp, fsdp))                       # (V, D)
    if leaf == "lm_head":
        return guard((fsdp, tp))                       # (D, V)
    if leaf in ("wq", "wk", "wv", "wi", "wg", "in_proj", "dt_proj",
                "shared_wi", "shared_wg", "dense_wi", "dense_wg",
                "fused_proj"):
        return guard((fsdp, tp))                       # column parallel
    if leaf in ("wo", "wd", "out_proj", "shared_wd", "dense_wd"):
        return guard((tp, fsdp))                       # row parallel
    if leaf in ("bq", "bk", "bv"):
        return guard((tp,))
    if leaf == "router":
        return guard((fsdp, None))                     # (D, E)
    if leaf in ("e_wi", "e_wg"):                       # (E, D, F)
        if _divisible(shape[0], ctx, tp):
            return guard((tp, fsdp, None))
        return guard((None, fsdp, tp))
    if leaf == "e_wd":                                 # (E, F, D)
        if _divisible(shape[0], ctx, tp):
            return guard((tp, None, fsdp))
        return guard((None, tp, fsdp))
    if leaf in ("conv_w", "conv_b", "x_proj", "A_log", "ssm_D", "dt_bias",
                "ssm_norm"):
        return guard((tp,) + (None,) * (len(shape) - 1))  # (dI, ...)
    # norms / scalars / biases: replicated
    return P(*([None] * len(shape)))


def param_specs(params: Any, ctx: ShardCtx, stacked_prefixes=("blocks",)) -> Any:
    """Tree of PartitionSpec matching ``params``; arrays under a stacked
    prefix (scan-over-layers) get a leading unsharded layer dim."""

    def visit(path_keys, leaf):
        path = "/".join(str(getattr(k, "key", k)) for k in path_keys)
        shape = tuple(np.shape(leaf))
        stacked = any(path.startswith(p) for p in stacked_prefixes)
        if stacked:
            spec = _rule(path, shape[1:], ctx)
            return P(*((None,) + tuple(spec)))
        return _rule(path, shape, ctx)

    return jax.tree_util.tree_map_with_path(visit, params)


# --------------------------------------------------------------------------- #
# serve-time tensor parallelism (exact-bit, shard_map manual mode)
# --------------------------------------------------------------------------- #
# The serve data plane shards the paged KV pool by KV head over the
# ``model`` mesh axis and runs decode/prefill steps under shard_map. To
# keep greedy streams BIT-IDENTICAL to the single-device oracle, the
# parallelism is "exact-bit": every projection weight is sharded on its
# OUTPUT-column dim (wq/wk/wv/bq/bk/bv on heads; wo/wd on d_model columns;
# wi/wg on d_ff columns), so every contraction runs over an UNSHARDED dim
# and each shard's output is a bitwise column-slice of the single-device
# result. Shards are reassembled with tiled all-gathers — pure bit
# concatenation, no arithmetic — so no floating-point reassociation can
# perturb the stream (a psum-of-partials in bf16 flips ~37% of output
# elements; see docs/sharded_serving.md). The collectives are tiny
# activation-sized all-gathers; the pool itself is never gathered, which
# hlo_analysis-based CI tests enforce.

class MeshDivisibilityError(ValueError):
    """Model-axis size does not divide the head/feature counts it shards."""


#: leaves sharded on their last (output-column) dim under serve TP
_SERVE_ATTN_LEAVES = frozenset({"wq", "wk", "wv", "bq", "bk", "bv", "wo"})
_SERVE_MLP_LEAVES = frozenset({"wi", "wg", "wd"})


def serve_tp_size(ctx: Optional[ShardCtx]) -> int:
    """Size of the tensor-parallel mesh axis (1 when no mesh is active)."""
    if ctx is None or ctx.mesh is None or ctx.tp is None:
        return 1
    return ctx.axis_size("tp")


def serve_attn_sharded(cfg: Any, mp: int) -> bool:
    """True when the attention cluster (and thus the KV pool) shards mp-way."""
    if mp <= 1 or cfg.ssm or cfg.hybrid_attn_every:
        return False
    return (cfg.num_kv_heads % mp == 0 and cfg.num_heads % mp == 0
            and cfg.d_model % mp == 0)


def serve_mlp_sharded(cfg: Any, mp: int) -> bool:
    """True when the dense-MLP cluster shards mp-way (MoE experts never do)."""
    if mp <= 1 or cfg.ssm or cfg.hybrid_attn_every:
        return False
    return cfg.d_ff % mp == 0 and cfg.d_model % mp == 0


def validate_serve_mesh(cfg: Any, mp: int) -> None:
    """Raise :class:`MeshDivisibilityError` for head counts mp can't shard.

    SSM/hybrid architectures serve fully replicated on any mesh size, so
    only attention architectures are constrained.
    """
    if mp <= 1 or cfg.ssm or cfg.hybrid_attn_every:
        return
    if not serve_attn_sharded(cfg, mp):
        raise MeshDivisibilityError(
            f"{cfg.name}: mesh model axis {mp} must divide num_kv_heads="
            f"{cfg.num_kv_heads}, num_heads={cfg.num_heads} and d_model="
            f"{cfg.d_model} to shard the KV pool by head; pick a divisor "
            "or run single-device")


def serve_param_specs(cfg: Any, params: Any, ctx: ShardCtx) -> Any:
    """PartitionSpec tree for serve TP: output-column sharding only.

    Every sharded leaf gets ``P(..., tp)`` on its LAST dim (rank-derived,
    so stacked ``blocks`` leaves need no special casing); everything else
    — embed, lm_head, norms, routers, MoE experts, SSM state — stays
    replicated so per-shard compute is bitwise identical.
    """
    mp = serve_tp_size(ctx)
    attn_ok = serve_attn_sharded(cfg, mp)
    mlp_ok = serve_mlp_sharded(cfg, mp)

    def visit(path_keys, leaf):
        keys = [str(getattr(k, "key", k)) for k in path_keys]
        ndim = np.ndim(leaf)
        name = keys[-1] if keys else ""
        in_blocks = bool(keys) and keys[0] == "blocks"
        sharded = in_blocks and (
            (attn_ok and name in _SERVE_ATTN_LEAVES)
            or (mlp_ok and name in _SERVE_MLP_LEAVES))
        if sharded:
            return P(*([None] * (ndim - 1) + [ctx.tp]))
        return P(*([None] * ndim))

    return jax.tree_util.tree_map_with_path(visit, params)


def serve_param_shardings(cfg: Any, params: Any, ctx: ShardCtx) -> Any:
    """NamedSharding tree matching :func:`serve_param_specs`."""
    return jax.tree_util.tree_map(
        lambda spec: NamedSharding(ctx.mesh, spec),
        serve_param_specs(cfg, params, ctx),
        is_leaf=lambda x: isinstance(x, P))


def serve_pool_spec(cfg: Any, ctx: ShardCtx) -> P:
    """Spec for the stacked paged pool (L, 2, N, KV, bs, hd): KV sharded."""
    if serve_attn_sharded(cfg, serve_tp_size(ctx)):
        return P(None, None, None, ctx.tp, None, None)
    return P(None, None, None, None, None, None)


def serve_kv_cache_spec(cfg: Any, ctx: ShardCtx) -> P:
    """Spec for contiguous prefill caches k/v (L, B, KV, S, hd): KV sharded."""
    if serve_attn_sharded(cfg, serve_tp_size(ctx)):
        return P(None, None, ctx.tp, None, None)
    return P(None, None, None, None, None)


def gather_tp(x: Any, axis: int = -1) -> Any:
    """Reassemble per-shard output columns: tiled all-gather along ``axis``.

    Live only inside a shard_map body under serve TP (``ctx.manual``);
    identity otherwise. Tiled all-gather concatenates the shards' bits in
    mesh order — no arithmetic — which is what makes the sharded decode
    bit-exact vs the single-device oracle.
    """
    ctx = current_ctx()
    if (ctx is None or ctx.mesh is None or not ctx.manual
            or ctx.tp is None or ctx.mesh.shape[ctx.tp] == 1):
        return x
    return jax.lax.all_gather(x, ctx.tp, axis=axis % x.ndim, tiled=True)


def manual_serve_map(fn, ctx: ShardCtx, in_specs, out_specs):
    """shard_map ``fn`` over ``ctx.mesh`` with the manual ShardCtx active.

    ``check_rep=False`` because replicated outputs (sampled tokens, carry)
    are produced by identical per-shard compute on gathered — bitwise
    identical — operands, which the replication checker cannot see.
    """
    from jax.experimental.shard_map import shard_map

    mctx = dataclasses.replace(ctx, manual=True)

    def body(*args):
        with use_shard_ctx(mctx):
            return fn(*args)

    return shard_map(body, mesh=ctx.mesh, in_specs=in_specs,
                     out_specs=out_specs, check_rep=False)
