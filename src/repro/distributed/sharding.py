"""Sharding rules: map logical tensor axes onto the production mesh.

Logical axes used by the model code:
    ``dp``   — data-parallel axes for the batch dim (``("pod","data")`` on
               the multi-pod mesh, ``("data",)`` single-pod)
    ``fsdp`` — parameter/optimizer sharding axis (ZeRO-3 style), = "data"
    ``tp``   — tensor/expert-parallel axis, = "model"
    ``sp``   — sequence-parallel axis for long-context KV caches, = "model"

The model calls :func:`constrain` on activations; :func:`param_specs`
assigns a PartitionSpec to every parameter by path-based rules (Megatron
column/row pattern for attention/MLP, expert-dim sharding for MoE, inner-dim
sharding for Mamba). Everything degrades to no-ops when no mesh is active so
the same model code runs single-device smoke tests unchanged.
"""
from __future__ import annotations

import contextlib
import re
import threading
from dataclasses import dataclass, field
from typing import Any, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["ShardCtx", "use_shard_ctx", "current_ctx", "constrain",
           "param_specs", "named_sharding", "logical_to_spec"]


@dataclass
class ShardCtx:
    mesh: Optional[Mesh]
    dp: Tuple[str, ...] = ("data",)
    #: fsdp may span multiple mesh axes (("pod","data") on the multi-pod
    #: mesh, so parameter/optimizer state scales with TOTAL chips)
    fsdp: Optional[Any] = "data"
    tp: Optional[str] = "model"
    sp: Optional[str] = "model"

    def axis_size(self, logical: str) -> int:
        if self.mesh is None:
            return 1

        def tup(x):
            if x is None:
                return ()
            return x if isinstance(x, tuple) else (x,)

        names = {"dp": self.dp, "fsdp": tup(self.fsdp),
                 "tp": tup(self.tp), "sp": tup(self.sp)}[logical]
        size = 1
        for n in names:
            size *= self.mesh.shape[n]
        return size


_local = threading.local()


def current_ctx() -> Optional[ShardCtx]:
    return getattr(_local, "ctx", None)


@contextlib.contextmanager
def use_shard_ctx(ctx: Optional[ShardCtx]):
    prev = current_ctx()
    _local.ctx = ctx
    try:
        yield ctx
    finally:
        _local.ctx = prev


def logical_to_spec(ctx: ShardCtx, logical: Sequence[Any]) -> P:
    """Translate ('dp', None, 'tp') style logical specs to a PartitionSpec."""
    out = []
    for a in logical:
        if a is None:
            out.append(None)
        elif a == "dp":
            out.append(ctx.dp if len(ctx.dp) > 1 else ctx.dp[0])
        elif a == "fsdp":
            out.append(ctx.fsdp)
        elif a in ("tp", "sp"):
            out.append(getattr(ctx, a))
        else:  # raw mesh axis name
            out.append(a)
    return P(*out)


def constrain(x: Any, *logical: Any) -> Any:
    """with_sharding_constraint under the active ShardCtx (no-op without)."""
    ctx = current_ctx()
    if ctx is None or ctx.mesh is None:
        return x
    spec = logical_to_spec(ctx, logical)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(ctx.mesh, spec))


def named_sharding(ctx: ShardCtx, *logical: Any) -> NamedSharding:
    return NamedSharding(ctx.mesh, logical_to_spec(ctx, logical))


# --------------------------------------------------------------------------- #
# parameter sharding rules
# --------------------------------------------------------------------------- #

def _divisible(dim: int, ctx: ShardCtx, axis) -> bool:
    if axis is None or ctx.mesh is None:
        return False
    axes = axis if isinstance(axis, tuple) else (axis,)
    size = 1
    for a in axes:
        size *= ctx.mesh.shape[a]
    return dim % size == 0


def _rule(path: str, shape: Tuple[int, ...], ctx: ShardCtx) -> P:
    """PartitionSpec for one parameter (path is '/'-joined key names)."""
    tp, fsdp = ctx.tp, ctx.fsdp
    leaf = path.rsplit("/", 1)[-1]

    def guard(spec_axes):
        """Drop mesh axes that do not divide the dim (uneven shards)."""
        fixed = []
        for dim, ax in zip(shape, spec_axes):
            fixed.append(ax if _divisible(dim, ctx, ax) else None)
        return P(*fixed)

    if leaf in ("embed", "pos_embed"):
        return guard((tp, fsdp))                       # (V, D)
    if leaf == "lm_head":
        return guard((fsdp, tp))                       # (D, V)
    if leaf in ("wq", "wk", "wv", "wi", "wg", "in_proj", "dt_proj",
                "shared_wi", "shared_wg", "dense_wi", "dense_wg",
                "fused_proj"):
        return guard((fsdp, tp))                       # column parallel
    if leaf in ("wo", "wd", "out_proj", "shared_wd", "dense_wd"):
        return guard((tp, fsdp))                       # row parallel
    if leaf in ("bq", "bk", "bv"):
        return guard((tp,))
    if leaf == "router":
        return guard((fsdp, None))                     # (D, E)
    if leaf in ("e_wi", "e_wg"):                       # (E, D, F)
        if _divisible(shape[0], ctx, tp):
            return guard((tp, fsdp, None))
        return guard((None, fsdp, tp))
    if leaf == "e_wd":                                 # (E, F, D)
        if _divisible(shape[0], ctx, tp):
            return guard((tp, None, fsdp))
        return guard((None, tp, fsdp))
    if leaf in ("conv_w", "conv_b", "x_proj", "A_log", "ssm_D", "dt_bias",
                "ssm_norm"):
        return guard((tp,) + (None,) * (len(shape) - 1))  # (dI, ...)
    # norms / scalars / biases: replicated
    return P(*([None] * len(shape)))


def param_specs(params: Any, ctx: ShardCtx, stacked_prefixes=("blocks",)) -> Any:
    """Tree of PartitionSpec matching ``params``; arrays under a stacked
    prefix (scan-over-layers) get a leading unsharded layer dim."""

    def visit(path_keys, leaf):
        path = "/".join(str(getattr(k, "key", k)) for k in path_keys)
        shape = tuple(np.shape(leaf))
        stacked = any(path.startswith(p) for p in stacked_prefixes)
        if stacked:
            spec = _rule(path, shape[1:], ctx)
            return P(*((None,) + tuple(spec)))
        return _rule(path, shape, ctx)

    return jax.tree_util.tree_map_with_path(visit, params)
