from .sharding import ShardCtx, constrain, param_specs, use_shard_ctx
