"""Deterministic fault-injection harness for the serve runtime.

The engine's hazard paths — deferred-free fences, CoW guards,
stall-not-preempt, shedding/expiry, watchdog — exist for conditions that
are hard to reach organically in a unit test (pool races, device
exceptions, latency spikes). This module makes them reachable ON DEMAND
and DETERMINISTICALLY: the engine consults a :class:`FaultInjector` at
named sites, and each site fires according to a seeded per-site schedule
that depends only on how many times the site was reached — never on
wall-clock time or interpreter hash state. The same spec + the same
request sequence therefore reproduces the same faults bit-for-bit.

Spec grammar (``REPRO_FAULT_INJECT`` env var or
``ServeEngine(fault_inject=...)``)::

    spec    := clause (';' clause)*
    clause  := site [':' param (',' param)*]
    param   := key '=' value

Sites (where the engine consults the injector):

==================  =====================================================
``alloc_fail``      admission block allocation returns None (the group
                    requeues and retries — benign, exercises the
                    park/evict/requeue path)
``grow_fail``       a mid-decode ``grow_table`` returns None (exercises
                    prefix eviction, stall-not-preempt and the
                    cost-model preemption path — benign: greedy replay
                    is bit-identical)
``chunk_sync_exc``  raises :class:`FaultInjected` at the decode chunk
                    sync point (exercises per-row failure isolation:
                    seated rows fail typed, the engine keeps serving)
``chunk_latency``   sleeps ``ms`` milliseconds at the sync point
                    (exercises the watchdog and SLO expiry under load)
``preempt``         force-preempts one resident row (cost-model victim
                    order — benign replay)
``evict``           force-evicts one parked prefix block (benign)
``crash_at``        hard process death (``os._exit``) at the decode
                    chunk sync point — no cleanup, no atexit, no
                    journal flush beyond what fsync cadence already
                    persisted. The kill-and-recover driver uses
                    ``crash_at:at=N`` for a deterministic mid-stream
                    crash (``tests/test_serve_recover.py``)
``snapshot_corrupt``  flips a payload byte in the snapshot file right
                    after ``ServeEngine.snapshot`` writes it —
                    exercises the checksum + typed
                    :class:`~repro.serve.errors.SnapshotCorrupt`
                    cold-start fallback in ``recover()``
==================  =====================================================

Params (one *trigger* per clause — ``p``, ``at`` or ``every``; bare
sites fire on every opportunity):

``p=F``       fire with probability F per opportunity (seeded RNG)
``at=N``      fire exactly on the N-th opportunity (1-based)
``every=N``   fire on every N-th opportunity
``n=N``       cap: stop after N fires (default unlimited; bare-site
              clauses without a trigger default to ``n=1``)
``ms=F``      sleep duration for ``chunk_latency`` (milliseconds)
``seed=N``    per-clause RNG seed for ``p`` (default 0)

Example — the CI chaos leg's low-rate benign spec::

    REPRO_FAULT_INJECT="alloc_fail:p=0.05,seed=11;grow_fail:p=0.05,seed=11"

Opportunity counters are per-injector (one injector per engine), so two
engines with the same spec see identical schedules.
"""
from __future__ import annotations

import random
import threading
from typing import Dict, Optional

__all__ = ["FaultInjected", "FaultInjector", "SITES"]

#: Named injection sites the engine consults (see module docstring).
SITES = ("alloc_fail", "grow_fail", "chunk_sync_exc", "chunk_latency",
         "preempt", "evict", "crash_at", "snapshot_corrupt")

_TRIGGERS = ("p", "at", "every")
_KEYS = _TRIGGERS + ("n", "ms", "seed")


class FaultInjected(RuntimeError):
    """Raised by the engine at a ``chunk_sync_exc`` site."""

    def __init__(self, site: str) -> None:
        super().__init__(f"injected fault at site {site!r}")
        self.site = site


class _Rule:
    __slots__ = ("site", "p", "at", "every", "n", "ms", "_rng",
                 "opportunities", "fires")

    def __init__(self, site: str, p: Optional[float], at: Optional[int],
                 every: Optional[int], n: Optional[int], ms: float,
                 seed: int) -> None:
        self.site = site
        self.p = p
        self.at = at
        self.every = every
        self.n = n
        self.ms = ms
        self._rng = random.Random(seed)
        self.opportunities = 0
        self.fires = 0

    def fire(self) -> bool:
        self.opportunities += 1
        if self.n is not None and self.fires >= self.n:
            return False
        if self.at is not None:
            hit = self.opportunities == self.at
        elif self.every is not None:
            hit = self.opportunities % self.every == 0
        elif self.p is not None:
            hit = self._rng.random() < self.p
        else:
            hit = True
        if hit:
            self.fires += 1
        return hit


class FaultInjector:
    """Seeded per-site fault schedule (see module docstring). Thread-safe;
    the engine calls :meth:`fire` at each site opportunity."""

    def __init__(self) -> None:
        self._rules: Dict[str, _Rule] = {}
        self._lock = threading.Lock()

    @classmethod
    def parse(cls, spec: str) -> "FaultInjector":
        """Build an injector from the spec grammar; raises ``ValueError``
        on unknown sites/keys, duplicate clauses, or multiple triggers."""
        inj = cls()
        for clause in spec.split(";"):
            clause = clause.strip()
            if not clause:
                continue
            site, _, rest = clause.partition(":")
            site = site.strip()
            if site not in SITES:
                raise ValueError(
                    f"unknown fault site {site!r} (expected one of {SITES})")
            if site in inj._rules:
                raise ValueError(f"duplicate fault clause for site {site!r}")
            kw: Dict[str, float] = {}
            if rest.strip():
                for param in rest.split(","):
                    key, eq, val = param.partition("=")
                    key = key.strip()
                    if not eq or key not in _KEYS:
                        raise ValueError(
                            f"bad fault param {param!r} for site {site!r} "
                            f"(expected key=value with key in {_KEYS})")
                    kw[key] = float(val)
            triggers = [k for k in _TRIGGERS if k in kw]
            if len(triggers) > 1:
                raise ValueError(
                    f"site {site!r}: at most one trigger of {_TRIGGERS}")
            n = kw.get("n")
            if not triggers and n is None:
                n = 1    # bare site: fire once, not forever
            inj._rules[site] = _Rule(
                site,
                p=kw.get("p"),
                at=int(kw["at"]) if "at" in kw else None,
                every=int(kw["every"]) if "every" in kw else None,
                n=int(n) if n is not None else None,
                ms=kw.get("ms", 0.0),
                seed=int(kw.get("seed", 0)))
        return inj

    def fire(self, site: str) -> bool:
        """One opportunity at ``site``: returns True when the fault should
        trigger now. Sites with no clause never fire (and cost one dict
        probe)."""
        rule = self._rules.get(site)
        if rule is None:
            return False
        with self._lock:
            return rule.fire()

    def latency_s(self, site: str) -> float:
        """Sleep duration (seconds) configured for ``site`` (``ms=`` param)."""
        rule = self._rules.get(site)
        return rule.ms / 1000.0 if rule is not None else 0.0

    def counts(self) -> Dict[str, Dict[str, int]]:
        """Per-site ``{opportunities, fires}`` — diagnostics for tests."""
        with self._lock:
            return {s: {"opportunities": r.opportunities, "fires": r.fires}
                    for s, r in self._rules.items()}
