"""Continuously-batched serving engine: a RESIDENT 4-stage pipeline fed by
a request queue.

PR 1's engine built and tore down a fresh pipeline per ``generate()`` call;
this one keeps ONE cyclic :class:`repro.pipeline.DataPipeline` alive for the
life of the engine — the Taskflow thesis (keep the task graph resident, let
in-graph control flow re-enter it) applied to serving:

    admit (SERIAL)    -> pop an admission group from the request queue
                         (length-bucketed FIFO), allocate its KV blocks;
                         park via ``pf.defer(token)`` when the block pool is
                         exhausted (deferred-token admission), or emit a
                         plain decode-pump cycle when nothing is admittable
    prefill (SERIAL)  -> one compiled prefill launch for the group
    decode (SERIAL,   -> merge the group into the resident batch (scatter
      accel domain)      prefilled KV into pool pages, assign slots), then
                         advance EVERY running row by one compiled chunk of
                         ``decode_chunk`` paged decode steps
    complete (PARALLEL)-> retire rows that just finished: fulfil their
                         request futures, free their blocks/slots — per
                         sequence, WITHOUT draining the pipeline

Each pipeline token is one engine *cycle*. While cycle ``t`` runs its decode
chunk, cycle ``t+1`` is already prefilling the next admission group — the
prefill/decode overlap continuous batching wants, expressed purely as
pipeline scheduling. Sequences join and leave at chunk boundaries; the KV
pool (:mod:`repro.serve.kvcache`) is written ONLY by the SERIAL decode
stage, so pool updates are single-writer by construction. The compiled
chunk reads the pool gather-free (``paged_impl``: the Pallas kernel or
its XLA page-loop lowering, see :mod:`repro.serve`), so per-row decode
cost follows the row's true length, not the pool's capacity.

Client API: :meth:`submit` returns a :class:`ServeRequest` future;
:meth:`ServeRequest.result` blocks for the tokens. :meth:`generate` remains
as a thin compatibility shim over submit/result (greedy tokens bit-identical
to the per-call engine it replaces). SSM / hybrid architectures — whose
recurrent state is O(1) per sequence and has no KV to page — keep the
per-call grouped pipeline under ``generate()``.

The pipeline goes idle (stop-drain) when no requests are waiting or
running; ``submit()`` re-arms it without rebuilding the task graph
(:meth:`repro.pipeline.Pipeline.run` on the same resident grid). A failure
inside any stage cancels the topology, fails every outstanding request
future (``result()`` raises instead of deadlocking) and marks the engine
broken.
"""
from __future__ import annotations

import threading
import time
from collections import OrderedDict, deque
from typing import Any, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..core import ACCEL, HOST, Executor
from ..distributed.sharding import ShardCtx, use_shard_ctx
from ..models import lm
from ..pipeline import DataPipe, DataPipeline, PipeType
from .kvcache import BlockPool, init_kv_pool, scatter_prefill_rows
from .scheduler import Scheduler, ServeRequest

__all__ = ["ServeEngine", "ServeRequest"]


class ServeEngine:
    """Resident continuous-batching engine (see module docstring).

    Parameters
    ----------
    decode_chunk:
        decode steps per compiled chunk launch — also the admission
        granularity (sequences join/leave at chunk boundaries).
    max_batch:
        decode slot count; the compiled chunk program always runs this many
        rows (inactive rows are masked), so batch composition changes never
        recompile.
    kv_blocks / block_size:
        paged KV pool geometry. Block 0 is the reserved sink.
    max_admit:
        cap on requests admitted per cycle (one prefill launch).
    max_seq_len:
        per-sequence cap on ``prompt + max_new`` (sets the block-table
        width). Defaults to 32 blocks worth, clamped to the pool size.
    paged_impl:
        attention read path of the compiled decode chunk: ``"pallas"``
        (gather-free Pallas kernel, Mosaic on TPU), ``"xla"`` (gather-free
        traced-bound page loop), or ``"gather"`` (materializing reference
        oracle). None resolves via
        :func:`repro.kernels.ops.default_paged_impl` (honors the
        ``REPRO_PAGED_IMPL`` env var; pallas on TPU, xla elsewhere).
    record_stages:
        keep an in-memory (stage, cycle-token, info, t) event log — the
        observer hook the overlap tests read.
    """

    def __init__(self, cfg: ModelConfig, params,
                 ctx: Optional[ShardCtx] = None,
                 decode_chunk: int = 8,
                 executor: Optional[Executor] = None,
                 pipeline_lines: int = 3,
                 max_batch: int = 8,
                 kv_blocks: int = 128,
                 block_size: int = 16,
                 max_admit: int = 4,
                 max_seq_len: Optional[int] = None,
                 paged_impl: Optional[str] = None,
                 record_stages: bool = False):
        self.cfg = cfg
        self.params = params
        self.ctx = ctx or ShardCtx(mesh=None)
        self.decode_chunk = decode_chunk
        self.pipeline_lines = pipeline_lines
        self._executor = executor
        self._own_executor = False
        self._prefill = jax.jit(self._prefill_impl,
                                static_argnames=("max_len",))
        self._decode_n = jax.jit(self._decode_n_impl,
                                 static_argnames=("n",),
                                 donate_argnums=(1,))

        #: paged continuous batching needs a pageable attention KV cache;
        #: SSM/hybrid recurrent state is O(1)/seq and keeps the grouped path
        self.paged = not (cfg.ssm or cfg.hybrid_attn_every)
        from ..kernels.ops import PAGED_IMPLS, default_paged_impl
        if paged_impl is not None and paged_impl not in PAGED_IMPLS:
            raise ValueError(f"paged_impl={paged_impl!r}: expected one of "
                             f"{PAGED_IMPLS} (or None for the default)")
        #: read path of the compiled decode chunk; None on non-paged archs
        self.paged_impl = (paged_impl or default_paged_impl()) \
            if self.paged else None
        self._closing = False
        self._broken: Optional[BaseException] = None
        self._stage_log = [] if record_stages else None
        self._log_lock = threading.Lock()
        if not self.paged:
            return

        self._pool = BlockPool(kv_blocks, block_size)
        self._pkv = init_kv_pool(cfg, kv_blocks, block_size)
        self._max_seq = min(max_seq_len or 32 * block_size,
                            (kv_blocks - 1) * block_size)
        mb = self._pool.blocks_for(self._max_seq)
        B = max_batch
        self._scheduler = Scheduler(max_admit=max_admit)
        # slot state: written by the SERIAL decode stage (merge/step) and the
        # complete stage (free) under _state_lock; admit only reads counts
        self._tables = np.zeros((B, mb), np.int32)
        self._lengths = np.zeros((B,), np.int32)
        self._rem = np.zeros((B,), np.int32)
        self._last = np.zeros((B,), np.int32)
        self._slot_req: List[Optional[ServeRequest]] = [None] * B
        self._slot_blocks: List[Optional[List[int]]] = [None] * B
        self._slot_out: List[Optional[List[int]]] = [None] * B
        self._free_slots = list(range(B - 1, -1, -1))
        self._slots_reserved = 0       # admitted but not yet merged
        self._inflight: set = set()    # admitted, not yet retired (failure
        #                                cleanup: these must see set_error)
        self._cycle_tokens: set = set()  # cycles minted and not yet completed
        self._state_lock = threading.Lock()
        self._pump_lock = threading.Lock()
        self._topo = None
        self._pipeline: Optional[DataPipeline] = None
        self.stats = {"admitted": 0, "admit_parks": 0, "pump_cycles": 0,
                      "decode_cycles": 0, "prefills": 0, "tokens_out": 0,
                      "retired": 0}
        self._decode_paged = jax.jit(self._decode_paged_impl,
                                     static_argnames=("n",),
                                     donate_argnums=(1,))
        self._scatter = jax.jit(self._scatter_impl, donate_argnums=(0,))

    # ---------------------------------------------------------- compiled fns
    def _prefill_impl(self, params, tokens, max_len: int):
        with use_shard_ctx(self.ctx):
            return lm.prefill(self.cfg, params, tokens, max_len=max_len)

    def _decode_n_impl(self, params, cache, token, n: int):
        """n contiguous decode steps in one XLA launch (grouped fallback)."""
        with use_shard_ctx(self.ctx):
            def body(carry, _):
                cache, tok = carry
                logits, cache = lm.decode_step(self.cfg, params, cache, tok)
                nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
                return (cache, nxt), nxt

            (cache, tok), toks = jax.lax.scan(body, (cache, token),
                                              None, length=n)
            return cache, toks.swapaxes(0, 1)  # (B, n)

    def _decode_paged_impl(self, params, pkv, tables, lengths, last,
                           rem, n: int):
        """One chunk: ``n`` paged decode steps over the resident batch in a
        single XLA launch. Rows with ``rem == 0`` are inactive: their KV
        writes go to the sink block and their emitted tokens are discarded
        host-side. The attention read path is ``self.paged_impl``.
        Returns the advanced state + (B, n) greedy tokens."""
        with use_shard_ctx(self.ctx):
            def body(carry, _):
                pkv, tok, ln, rm = carry
                active = rm > 0
                logits, pkv = lm.decode_step_paged(
                    self.cfg, params, pkv, tables, ln, tok, active,
                    impl=self.paged_impl)
                nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
                nxt = jnp.where(active, nxt, tok)
                ln = ln + active.astype(jnp.int32)
                rm = rm - active.astype(jnp.int32)
                return (pkv, nxt, ln, rm), nxt

            (pkv, tok, ln, rm), toks = jax.lax.scan(
                body, (pkv, last, lengths, rem), None, length=n)
            return pkv, tok, ln, rm, toks.swapaxes(0, 1)

    def _scatter_impl(self, pkv, blocks, krows, vrows):
        return scatter_prefill_rows(pkv, blocks, krows, vrows)

    # ------------------------------------------------------------- lifecycle
    def _ensure_executor(self) -> Executor:
        if self._executor is None:
            self._executor = Executor(domains={HOST: 2, ACCEL: 1})
            self._own_executor = True
        return self._executor

    def _ensure_pipeline(self, ex: Executor) -> DataPipeline:
        if self._pipeline is None:
            decode_domain = ACCEL if ex.has_domain(ACCEL) else HOST
            self._pipeline = DataPipeline(
                self.pipeline_lines,
                DataPipe(PipeType.SERIAL, self._st_admit, name="admit"),
                DataPipe(PipeType.SERIAL, self._st_prefill, name="prefill"),
                DataPipe(PipeType.SERIAL, self._st_decode, name="decode",
                         domain=decode_domain),
                DataPipe(PipeType.PARALLEL, self._st_complete,
                         name="complete"),
                name="serve-continuous")
        return self._pipeline

    def close(self, timeout: float = 300.0) -> None:
        """Drain outstanding requests, then release the executor. Idempotent."""
        self._closing = True
        if self.paged and self._pipeline is not None:
            deadline = time.perf_counter() + timeout
            while time.perf_counter() < deadline:
                if self._broken is not None:
                    break
                if self._pipeline.idle() and \
                        self._scheduler.num_waiting == 0:
                    break
                time.sleep(0.005)
        if self._own_executor and self._executor is not None:
            self._executor.shutdown()
            self._executor = None
            self._own_executor = False

    def __enter__(self) -> "ServeEngine":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # ------------------------------------------------------- stage callables
    def _log(self, stage: str, token: int, info: Any) -> None:
        if self._stage_log is not None:
            with self._log_lock:
                self._stage_log.append((stage, token, info,
                                        time.perf_counter()))

    @property
    def stage_log(self) -> List[tuple]:
        """(stage, cycle-token, info, timestamp) events (record_stages=True)."""
        with self._log_lock:
            return list(self._stage_log or [])

    def _st_admit(self, pf):
        with self._state_lock:
            occupied = any(r is not None for r in self._slot_req)
            reserved = self._slots_reserved
            deps = set(self._cycle_tokens)
            free_slots = len(self._free_slots) - reserved
        waiting = self._scheduler.num_waiting
        if not waiting and not occupied and reserved == 0:
            # fully idle — nothing queued, no live rows, and no admitted
            # group still in flight toward its decode merge: drain so the
            # engine parks at zero cost; the next submit() re-arms the SAME
            # resident grid (no rebuild)
            pf.stop()
            return None
        group = self._scheduler.try_admit(free_slots, self._pool.num_free,
                                          self._pool.blocks_for)
        if group is not None:
            # only admit allocates and complete only frees, so the budget
            # try_admit just checked cannot shrink before these allocs
            alloc = []
            for req in group:
                blocks = self._pool.alloc(
                    self._pool.blocks_for(req.prompt_len + req.max_new))
                alloc.append((req, blocks))
            with self._state_lock:
                self._slots_reserved += len(group)
                self._inflight.update(group)
                self._cycle_tokens.add(pf.token)
                self.stats["admitted"] += len(group)
            self._log("admit", pf.token, [r.id for r in group])
            return ("admit", alloc)
        if waiting and deps:
            # deferred-token admission: the head request does not fit the
            # pool. Park THIS cycle until the oldest in-flight cycle fully
            # completes (its complete stage frees retired blocks), instead
            # of spinning empty admissions; the in-flight cycles keep the
            # decode pump alive meanwhile.
            dep = min(deps)
            with self._state_lock:
                self.stats["admit_parks"] += 1
            self._log("park", pf.token, dep)
            pf.defer(dep)
            return None
        # nothing admittable but sequences are running (or their retirement
        # is still in flight): emit a pure decode-pump cycle
        with self._state_lock:
            self._cycle_tokens.add(pf.token)
            self.stats["pump_cycles"] += 1
        self._log("pump", pf.token, None)
        return ("pump", None)

    def _st_prefill(self, pf, msg):
        kind, payload = msg
        if kind != "admit":
            return msg
        group = payload
        reqs = [r for r, _ in group]
        # pad the group to the admission cap: ONE compiled prefill shape per
        # prompt length, however many requests the Poisson arrivals happened
        # to bucket together (dummy rows repeat the last prompt; their KV is
        # scattered to the sink block and their sampled token is discarded)
        A = self._scheduler.max_admit
        toks = np.stack([r.prompt for r in reqs]
                        + [reqs[-1].prompt] * (A - len(reqs)))
        S = int(toks.shape[1])
        logits, cache = self._prefill(self.params, jnp.asarray(toks),
                                      max_len=S)
        first = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        with self._state_lock:
            self.stats["prefills"] += 1
        self._log("prefill", pf.token, [r.id for r in reqs])
        return ("admit", (group, cache["k"], cache["v"], first))

    def _st_decode(self, pf, msg):
        kind, payload = msg
        if kind == "admit":
            group, ck, cv, first = payload
            first = np.asarray(first)
            for i, (req, blocks) in enumerate(group):
                with self._state_lock:
                    slot = self._free_slots.pop()
                    self._slots_reserved -= 1
                    self._slot_req[slot] = req
                    self._slot_blocks[slot] = blocks
                    self._slot_out[slot] = [int(first[i])]
                self._tables[slot] = 0
                self._tables[slot, :len(blocks)] = blocks
                self._lengths[slot] = req.prompt_len
                self._last[slot] = first[i]
                self._rem[slot] = req.max_new - 1
            # single-writer pool update: one scatter launch for the whole
            # group's prefilled KV. Block lists are trimmed to the PROMPT
            # footprint (equal within a length bucket) and padded to the
            # admission cap with sink rows (matching the padded prefill),
            # so the compiled shape keys on the prompt length alone — never
            # on group size or max_new.
            nbp = self._pool.blocks_for(group[0][0].prompt_len)
            blocks2d = np.zeros((ck.shape[1], nbp), np.int32)  # sink-filled
            for i, (_, blocks) in enumerate(group):
                blocks2d[i] = blocks[:nbp]
            self._pkv = self._scatter(self._pkv, jnp.asarray(blocks2d),
                                      ck, cv)
        rem_before = self._rem.copy()
        if not (rem_before > 0).any():
            self._log("decode", pf.token, 0)
            return ("cycle", self._collect_finished(rem_before))
        n = self.decode_chunk
        pkv, tok, ln, rm, toks = self._decode_paged(
            self.params, self._pkv, jnp.asarray(self._tables),
            jnp.asarray(self._lengths), jnp.asarray(self._last),
            jnp.asarray(self._rem), n=n)
        self._pkv = pkv
        toks = np.asarray(toks)        # (B, n): the chunk's device sync
        # np.array (not asarray): device views are read-only and these
        # mirrors are mutated by the next cycle's merge
        self._last = np.array(tok)
        self._lengths = np.array(ln)
        self._rem = np.array(rm)
        emitted = 0
        for b in np.nonzero(rem_before > 0)[0]:
            k = int(min(n, rem_before[b]))
            self._slot_out[b].extend(toks[b, :k].tolist())
            emitted += k
        with self._state_lock:
            self.stats["decode_cycles"] += 1
            self.stats["tokens_out"] += emitted
        self._log("decode", pf.token, emitted)
        return ("cycle", self._collect_finished(rem_before))

    def _collect_finished(self, rem_before) -> List[tuple]:
        """Rows that just hit rem==0: detach them from the batch (their slot
        stays reserved until complete frees it)."""
        retire = []
        for b in range(len(self._rem)):
            if self._slot_req[b] is not None and self._rem[b] == 0:
                req = self._slot_req[b]
                out = np.asarray(self._slot_out[b], np.int32)
                with self._state_lock:
                    self._slot_req[b] = None
                    self._slot_out[b] = None
                    self._inflight.discard(req)
                # zero the detached row's mirrors (still inside the SERIAL
                # decode stage: single-writer): the gather-free read paths
                # bound their page loop by max(lengths), so a retired slot
                # must not keep advertising its old length
                self._tables[b] = 0
                self._lengths[b] = 0
                self._last[b] = 0
                retire.append((b, req, out))
        return retire

    def _st_complete(self, pf, msg):
        _, retire = msg
        now = time.perf_counter()
        for slot, req, out in retire:
            self._scheduler.finish(req, out, now)
            with self._state_lock:
                self._pool.free(self._slot_blocks[slot])
                self._slot_blocks[slot] = None
                self._free_slots.append(slot)
                self.stats["retired"] += 1
        with self._state_lock:
            self._cycle_tokens.discard(pf.token)
        self._log("complete", pf.token, len(retire))
        return None

    # --------------------------------------------------------------- pumping
    def _pump(self) -> None:
        ex = self._ensure_executor()
        pl = self._ensure_pipeline(ex)
        with self._pump_lock:
            if self._broken is not None or not pl.idle():
                return
            with self._state_lock:
                occupied = any(r is not None for r in self._slot_req)
            if self._scheduler.num_waiting == 0 and not occupied:
                return
            self._topo = pl.run(ex, self._on_topo_done)

    def _on_topo_done(self, topo) -> None:
        if topo.exceptions:
            err = topo.exceptions[0]
            self._broken = err
            self._fail_outstanding(err)
            return
        if self._scheduler.num_waiting:
            self._pump()   # a submit raced the stop-drain: re-arm

    def _fail_outstanding(self, err: BaseException) -> None:
        self._scheduler.fail_all_waiting(err)
        with self._state_lock:
            live = list(self._inflight)  # admitted: slotted or pre-merge
            self._inflight.clear()
        for r in live:
            r.set_error(err)

    # ----------------------------------------------------------- client API
    def submit(self, prompt, max_new: int = 16) -> ServeRequest:
        """Enqueue one generation request on the resident pipeline and
        return its future. Thread-safe; callable while earlier requests are
        mid-decode — that is the point."""
        if not self.paged:
            raise NotImplementedError(
                f"{self.cfg.name}: submit/result requires a paged attention "
                "cache; SSM/hybrid archs serve through generate()")
        if self._broken is not None:
            raise RuntimeError("serve pipeline is broken") from self._broken
        if self._closing:
            raise RuntimeError("engine is closed")
        req = ServeRequest(prompt, max_new)
        total = req.prompt_len + req.max_new
        if total > self._max_seq:
            raise ValueError(
                f"prompt+max_new = {total} exceeds max_seq_len "
                f"{self._max_seq}")
        req.submitted_at = time.perf_counter()
        self._scheduler.enqueue(req)
        self._pump()
        return req

    def result(self, req: ServeRequest,
               timeout: Optional[float] = 300.0) -> np.ndarray:
        return req.result(timeout)

    def generate(self, prompts: List[Any], max_new: int) -> List[Any]:
        """Compatibility shim: submit every prompt, gather results in input
        order. Greedy tokens are bit-identical to the per-call engine this
        replaces (same compiled prefill math, same argmax chain — verified
        against the contiguous reference in tests). SSM/hybrid archs take
        the retained per-call grouped pipeline."""
        if not prompts:
            return []
        if not self.paged:
            return self._generate_grouped(prompts, max_new)
        reqs = [self.submit(p, max_new) for p in prompts]
        return [self.result(r, timeout=600.0) for r in reqs]

    # ----------------------------------------- per-call fallback (ssm/hybrid)
    def _generate_grouped(self, prompts: List[Any], max_new: int
                          ) -> List[Any]:
        """PR 1's per-call pipeline: length groups flow admit -> prefill ->
        chunked contiguous decode -> complete through a throwaway
        DataPipeline. Kept for architectures without a pageable KV cache."""
        groups: "OrderedDict[int, List[int]]" = OrderedDict()
        arrs = [np.asarray(p, np.int32) for p in prompts]
        for i, a in enumerate(arrs):
            groups.setdefault(len(a), []).append(i)
        work = deque(groups.values())
        results: List[Any] = [None] * len(prompts)

        def admit(pf):
            if not work:
                pf.stop()
                return None
            return work.popleft()

        def prefill(pf, idxs):
            toks = np.stack([arrs[i] for i in idxs])
            max_len = toks.shape[1] + max_new + 1
            logits, cache = self._prefill(self.params, jnp.asarray(toks),
                                          max_len=max_len)
            cur = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            return idxs, cache, cur

        def decode(pf, state):
            idxs, cache, cur = state
            chunks = [cur[:, None]]
            remaining = max_new - 1
            while remaining > 0:
                n = min(self.decode_chunk, remaining)
                cache, chunk = self._decode_n(self.params, cache, cur, n)
                chunks.append(chunk)
                cur = chunk[:, -1]
                remaining -= n
            return idxs, chunks

        def complete(pf, state):
            idxs, chunks = state
            seqs = np.concatenate([np.asarray(c) for c in chunks], axis=1)
            for row, i in enumerate(idxs):  # rows scatter to disjoint slots
                results[i] = seqs[row]
            return None

        ex = self._ensure_executor()
        decode_domain = ACCEL if ex.has_domain(ACCEL) else HOST
        pl = DataPipeline(
            max(1, min(len(work), self.pipeline_lines)),
            DataPipe(PipeType.SERIAL, admit, name="admit"),
            DataPipe(PipeType.SERIAL, prefill, name="prefill"),
            DataPipe(PipeType.SERIAL, decode, name="decode",
                     domain=decode_domain),
            DataPipe(PipeType.PARALLEL, complete, name="complete"),
            name="serve-generate")
        pl.run(ex).wait()
        return results
