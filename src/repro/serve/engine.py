"""Continuously-batched serving engine: a RESIDENT 4-stage pipeline fed by
a request queue, with TWO-PHASE memory admission.

PR 2 kept ONE cyclic :class:`repro.pipeline.DataPipeline` alive for the
life of the engine; PR 3 made the paged *read* path occupancy-proportional.
This revision makes the *write/admission* half follow live token counts
too — the Taskflow memory thesis (resources follow control flow
incrementally, not worst-case up front) applied to KV admission:

    admit (SERIAL)    -> pop an admission group from ONE FIFO (no length
                         buckets: chunked prefill makes per-window shapes
                         uniform, so mixed-length groups admit together) and
                         allocate its PROMPT-ONLY block footprint; park via
                         ``pf.defer(token)`` when the head does not fit, or
                         emit a plain decode-pump cycle
    prefill (SERIAL)  -> one compiled launch for the group's FIRST prompt
                         window (fixed window size, prompts right-padded);
                         SSM/hybrid archs prefill each member's whole prompt
                         here instead (recurrent state is O(1)/sequence)
    decode (SERIAL,   -> merge the group (scatter window-0 KV / recurrent
      accel domain)      state into the pool, assign slots), stream ONE more
                         prefill window for every mid-prefill row, grow
                         block tables lazily for rows about to cross a block
                         boundary (preempting the youngest row on pool
                         exhaustion), then advance every decoding row by one
                         compiled chunk of ``decode_chunk`` steps
    complete (PARALLEL)-> retire rows that just finished: fulfil their
                         request futures, free their blocks/slots — per
                         sequence, WITHOUT draining the pipeline

Two-phase admission
-------------------
*Phase 1 (admit):* a request is admitted when the pool covers its PROMPT
KV footprint — not ``prompt + max_new``. *Phase 2 (grow):* every
``block_size`` decode tokens, the decode stage grants the row one more
block (``BlockPool.grow_table`` + a device-side table-extension scatter);
on pool exhaustion it preempts the YOUNGEST resident row back onto the
wait queue (its blocks freed, its request re-queued at the head) instead
of deadlocking. Long prompts are *chunked*: window 0 lands via the prefill
stage, the rest stream through the decode stage one fixed-size window per
cycle, scattered straight into the paged pool — resident rows keep
decoding in the overlapped cycles.

The KV pool and the block-table array are written ONLY by the SERIAL
decode stage, so pool updates stay single-writer by construction; the
block table is device-resident across cycles (growth is an in-place
scatter, not a re-upload). The compiled chunk reads the pool gather-free
(``paged_impl``: the Pallas kernel or its XLA page-loop lowering, see
:mod:`repro.serve`).

SSM / hybrid architectures (mamba, zamba2) serve through the SAME
resident pipeline via a fixed-slot recurrent-state pool: prefilled
``(conv, h)`` states (plus zamba2's shared-block KV span) are scattered
into a per-slot pool, rows decode side by side at per-row positions
(:func:`repro.models.lm.decode_step_slots`), and slots free at
retirement. The old per-call grouped fallback is retired from
``submit()``/``generate()`` and survives only as the benchmark baseline
(:meth:`ServeEngine._generate_grouped`).

Client API: :meth:`submit` returns a :class:`ServeRequest` future;
:meth:`ServeRequest.result` blocks for the tokens. :meth:`generate` remains
as a thin compatibility shim over submit/result (greedy tokens bit-identical
to the per-call engine it replaces).

The pipeline goes idle (stop-drain) when no requests are waiting or
running; ``submit()`` re-arms it without rebuilding the task graph
(:meth:`repro.pipeline.Pipeline.run` on the same resident grid). A failure
inside any stage cancels the topology, fails every outstanding request
future (``result()`` raises instead of deadlocking) and marks the engine
broken.
"""
from __future__ import annotations

import threading
import time
from collections import OrderedDict, deque
from typing import Any, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..core import ACCEL, HOST, Executor
from ..distributed.sharding import ShardCtx, use_shard_ctx
from ..models import lm
from ..pipeline import DataPipe, DataPipeline, PipeType
from .kvcache import (BlockPool, extend_block_tables, init_kv_pool,
                      scatter_prefill_rows, set_table_rows)
from .scheduler import Scheduler, ServeRequest

__all__ = ["ServeEngine", "ServeRequest"]


class ServeEngine:
    """Resident continuous-batching engine (see module docstring).

    Parameters
    ----------
    decode_chunk:
        decode steps per compiled chunk launch — also the admission
        granularity (sequences join/leave at chunk boundaries).
    prefill_chunk:
        prompt tokens per prefill window. A prompt longer than this
        prefills across multiple pipeline cycles (window 0 in the prefill
        stage, the rest streamed by the decode stage) while resident rows
        keep decoding. Defaults to ``decode_chunk * block_size``. Paged
        (attention) archs only; SSM/hybrid prompts prefill whole.
    max_batch:
        decode slot count; the compiled chunk program always runs this many
        rows (inactive rows are masked), so batch composition changes never
        recompile.
    kv_blocks / block_size:
        paged KV pool geometry. Block 0 is the reserved sink.
    max_admit:
        cap on requests admitted per cycle (one prefill launch).
    max_seq_len:
        per-sequence cap on ``prompt + max_new`` (sets the block-table
        width; for zamba2 it sizes the shared-block KV span per slot).
        Defaults to 32 blocks worth, clamped to the pool size (512 for
        SSM/hybrid).
    paged_impl:
        attention read path of the compiled decode chunk: ``"pallas"``
        (gather-free Pallas kernel, Mosaic on TPU), ``"xla"`` (gather-free
        traced-bound page loop), or ``"gather"`` (materializing reference
        oracle). None resolves via
        :func:`repro.kernels.ops.default_paged_impl` (honors the
        ``REPRO_PAGED_IMPL`` env var; pallas on TPU, xla elsewhere).
    record_stages:
        keep an in-memory (stage, cycle-token, info, t) event log — the
        observer hook the overlap tests read.
    """

    def __init__(self, cfg: ModelConfig, params,
                 ctx: Optional[ShardCtx] = None,
                 decode_chunk: int = 8,
                 prefill_chunk: Optional[int] = None,
                 executor: Optional[Executor] = None,
                 pipeline_lines: int = 3,
                 max_batch: int = 8,
                 kv_blocks: int = 128,
                 block_size: int = 16,
                 max_admit: int = 4,
                 max_seq_len: Optional[int] = None,
                 paged_impl: Optional[str] = None,
                 record_stages: bool = False):
        self.cfg = cfg
        self.params = params
        self.ctx = ctx or ShardCtx(mesh=None)
        self.decode_chunk = decode_chunk
        self.pipeline_lines = pipeline_lines
        self._executor = executor
        self._own_executor = False
        self._prefill = jax.jit(self._prefill_impl,
                                static_argnames=("max_len",))
        self._decode_n = jax.jit(self._decode_n_impl,
                                 static_argnames=("n",),
                                 donate_argnums=(1,))

        #: continuous batching pages the attention KV cache; SSM/hybrid
        #: recurrent state is O(1)/seq and lives in a fixed-slot state pool
        self.paged = not (cfg.ssm or cfg.hybrid_attn_every)
        from ..kernels.ops import PAGED_IMPLS, default_paged_impl
        if paged_impl is not None and paged_impl not in PAGED_IMPLS:
            raise ValueError(f"paged_impl={paged_impl!r}: expected one of "
                             f"{PAGED_IMPLS} (or None for the default)")
        #: read path of the compiled decode chunk; None on non-paged archs
        self.paged_impl = (paged_impl or default_paged_impl()) \
            if self.paged else None
        self._closing = False
        self._broken: Optional[BaseException] = None
        self._stage_log = [] if record_stages else None
        self._log_lock = threading.Lock()

        B = max_batch
        self._scheduler = Scheduler(max_admit=max_admit)
        # slot state: written by the SERIAL decode stage (merge/window/grow/
        # step) and the complete stage (free) under _state_lock; admit only
        # reads counts
        self._lengths = np.zeros((B,), np.int32)   # KV/state tokens written
        self._rem = np.zeros((B,), np.int32)       # decode steps remaining
        self._last = np.zeros((B,), np.int32)      # last emitted token
        self._slot_req: List[Optional[ServeRequest]] = [None] * B
        self._slot_out: List[Optional[List[int]]] = [None] * B
        self._slot_phase: List[Optional[str]] = [None] * B  # prefill|decode
        self._free_slots = list(range(B - 1, -1, -1))
        self._slots_reserved = 0       # admitted but not yet merged
        self._inflight: set = set()    # admitted, not yet retired (failure
        #                                cleanup: these must see set_error)
        self._cycle_tokens: set = set()  # cycles minted and not yet completed
        self._state_lock = threading.Lock()
        self._pump_lock = threading.Lock()
        self._topo = None
        self._pipeline: Optional[DataPipeline] = None
        self.stats = {"admitted": 0, "admit_parks": 0, "pump_cycles": 0,
                      "decode_cycles": 0, "prefills": 0,
                      "prefill_windows": 0, "tokens_out": 0, "retired": 0,
                      "grown_blocks": 0, "preempted": 0}

        if self.paged:
            self._pool = BlockPool(kv_blocks, block_size)
            self._pkv = init_kv_pool(cfg, kv_blocks, block_size)
            self._max_seq = min(max_seq_len or 32 * block_size,
                                (kv_blocks - 1) * block_size)
            self.prefill_chunk = prefill_chunk or decode_chunk * block_size
            if self.prefill_chunk < 1:
                raise ValueError("prefill_chunk must be >= 1")
            mb = self._pool.blocks_for(self._max_seq)
            # block tables: host mirror for growth decisions + a DEVICE-
            # resident array the compiled programs read; growth/merge/retire
            # update the device copy with in-place scatters
            self._tables = np.zeros((B, mb), np.int32)
            self._tables_dev = jnp.zeros((B, mb), jnp.int32)
            self._pref_pos = np.zeros((B,), np.int32)  # prompt tokens done
            self._slot_blocks: List[Optional[List[int]]] = [None] * B
            self._slot_prompt: List[Optional[np.ndarray]] = [None] * B
            # worst-case blocks granted in one cycle: every row crosses into
            # ceil(decode_chunk / block_size) new blocks plus one boundary
            # block — the fixed width of the growth scatter
            self._grow_burst_max = B * (-(-decode_chunk // block_size) + 1)
            self._decode_paged = jax.jit(self._decode_paged_impl,
                                         static_argnames=("n",),
                                         donate_argnums=(1,))
            self._scatter = jax.jit(self._scatter_impl, donate_argnums=(0,))
            self._prefill_window = jax.jit(self._prefill_window_impl,
                                           donate_argnums=(1,))
            self._extend_tables = jax.jit(extend_block_tables)
            self._set_rows = jax.jit(set_table_rows)
        else:
            self._max_seq = max_seq_len or 512
            self.prefill_chunk = None
            # fixed-slot recurrent-state pool: init_cache's pytree with the
            # scalar pos replaced by the per-row _lengths mirror
            self._sstate = {k: v
                            for k, v in lm.init_cache(cfg, B,
                                                      self._max_seq).items()
                            if k != "pos"}
            self._decode_slots = jax.jit(self._decode_slots_impl,
                                         static_argnames=("n",),
                                         donate_argnums=(1,))

    # ---------------------------------------------------------- compiled fns
    def _prefill_impl(self, params, tokens, last_positions, max_len: int):
        with use_shard_ctx(self.ctx):
            return lm.prefill(self.cfg, params, tokens, max_len=max_len,
                              last_positions=last_positions)

    def _decode_n_impl(self, params, cache, token, n: int):
        """n contiguous decode steps in one XLA launch (per-call baseline)."""
        with use_shard_ctx(self.ctx):
            def body(carry, _):
                cache, tok = carry
                logits, cache = lm.decode_step(self.cfg, params, cache, tok)
                nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
                return (cache, nxt), nxt

            (cache, tok), toks = jax.lax.scan(body, (cache, token),
                                              None, length=n)
            return cache, toks.swapaxes(0, 1)  # (B, n)

    def _decode_paged_impl(self, params, pkv, tables, lengths, last,
                           rem, n: int):
        """One chunk: ``n`` paged decode steps over the resident batch in a
        single XLA launch. Rows with ``rem == 0`` are inactive: their KV
        writes go to the sink block and their emitted tokens are discarded
        host-side. The attention read path is ``self.paged_impl``.
        Returns the advanced state + (B, n) greedy tokens."""
        with use_shard_ctx(self.ctx):
            def body(carry, _):
                pkv, tok, ln, rm = carry
                active = rm > 0
                logits, pkv = lm.decode_step_paged(
                    self.cfg, params, pkv, tables, ln, tok, active,
                    impl=self.paged_impl)
                nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
                nxt = jnp.where(active, nxt, tok)
                ln = ln + active.astype(jnp.int32)
                rm = rm - active.astype(jnp.int32)
                return (pkv, nxt, ln, rm), nxt

            (pkv, tok, ln, rm), toks = jax.lax.scan(
                body, (pkv, last, lengths, rem), None, length=n)
            return pkv, tok, ln, rm, toks.swapaxes(0, 1)

    def _decode_slots_impl(self, params, state, last, lengths, rem, n: int):
        """One chunk over the SSM/hybrid slot-state pool: ``n`` steps of
        :func:`repro.models.lm.decode_step_slots` at per-row positions.
        Inactive slots step on stale state harmlessly (row-wise math; their
        tokens are discarded host-side and their slot is overwritten at the
        next admission)."""
        with use_shard_ctx(self.ctx):
            def body(carry, _):
                st, tok, ln, rm = carry
                active = rm > 0
                logits, st = lm.decode_step_slots(self.cfg, params, st, tok,
                                                  ln)
                nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
                nxt = jnp.where(active, nxt, tok)
                ln = ln + active.astype(jnp.int32)
                rm = rm - active.astype(jnp.int32)
                return (st, nxt, ln, rm), nxt

            (st, tok, ln, rm), toks = jax.lax.scan(
                body, (state, last, lengths, rem), None, length=n)
            return st, tok, ln, rm, toks.swapaxes(0, 1)

    def _prefill_window_impl(self, params, pkv, tables, tokens, start,
                             valid, last_idx):
        with use_shard_ctx(self.ctx):
            return lm.prefill_window_paged(self.cfg, params, pkv, tables,
                                           tokens, start, valid, last_idx)

    def _scatter_impl(self, pkv, blocks, krows, vrows):
        return scatter_prefill_rows(pkv, blocks, krows, vrows)

    # ------------------------------------------------------------- lifecycle
    def _ensure_executor(self) -> Executor:
        if self._executor is None:
            self._executor = Executor(domains={HOST: 2, ACCEL: 1})
            self._own_executor = True
        return self._executor

    def _ensure_pipeline(self, ex: Executor) -> DataPipeline:
        if self._pipeline is None:
            decode_domain = ACCEL if ex.has_domain(ACCEL) else HOST
            self._pipeline = DataPipeline(
                self.pipeline_lines,
                DataPipe(PipeType.SERIAL, self._st_admit, name="admit"),
                DataPipe(PipeType.SERIAL, self._st_prefill, name="prefill"),
                DataPipe(PipeType.SERIAL, self._st_decode, name="decode",
                         domain=decode_domain),
                DataPipe(PipeType.PARALLEL, self._st_complete,
                         name="complete"),
                name="serve-continuous")
        return self._pipeline

    def close(self, timeout: float = 300.0) -> None:
        """Drain outstanding requests, then release the executor. Idempotent."""
        self._closing = True
        if self._pipeline is not None:
            deadline = time.perf_counter() + timeout
            while time.perf_counter() < deadline:
                if self._broken is not None:
                    break
                if self._pipeline.idle() and \
                        self._scheduler.num_waiting == 0:
                    break
                time.sleep(0.005)
        if self._own_executor and self._executor is not None:
            self._executor.shutdown()
            self._executor = None
            self._own_executor = False

    def __enter__(self) -> "ServeEngine":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # ------------------------------------------------------- stage callables
    def _log(self, stage: str, token: int, info: Any) -> None:
        if self._stage_log is not None:
            with self._log_lock:
                self._stage_log.append((stage, token, info,
                                        time.perf_counter()))

    @property
    def stage_log(self) -> List[tuple]:
        """(stage, cycle-token, info, timestamp) events (record_stages=True)."""
        with self._log_lock:
            return list(self._stage_log or [])

    def _st_admit(self, pf):
        with self._state_lock:
            occupied = any(r is not None for r in self._slot_req)
            reserved = self._slots_reserved
            deps = set(self._cycle_tokens)
            free_slots = len(self._free_slots) - reserved
        waiting = self._scheduler.num_waiting
        if not waiting and not occupied and reserved == 0:
            # fully idle — nothing queued, no live rows, and no admitted
            # group still in flight toward its decode merge: drain so the
            # engine parks at zero cost; the next submit() re-arms the SAME
            # resident grid (no rebuild)
            pf.stop()
            return None
        group = None
        if self.paged:
            # phase 1 of two-phase admission: budget the PROMPT footprint
            # only; decode-time blocks are granted lazily by the decode
            # stage as rows grow
            popped = self._scheduler.try_admit(
                free_slots, self._pool.num_free, self._pool.blocks_for)
            if popped is not None:
                needs = [self._pool.blocks_for(r.prompt_len) for r in popped]
                ids = self._pool.alloc(sum(needs))  # atomic all-or-nothing
                if ids is None:
                    # raced a concurrent mid-decode grow: put the group back
                    # (id order preserved) and fall through to park/pump
                    self._scheduler.requeue_front(popped)
                else:
                    group, i = [], 0
                    for r, need in zip(popped, needs):
                        group.append((r, ids[i:i + need]))
                        i += need
        else:
            # slot-state pool: recurrent state is pre-allocated per slot, so
            # admission is bounded by free slots alone
            popped = self._scheduler.try_admit(free_slots, None)
            if popped is not None:
                group = [(r, None) for r in popped]
        if group is not None:
            now = time.perf_counter()
            for r, _ in group:
                r.state = "prefilling"
                if r.admitted_at is None:
                    r.admitted_at = now
            with self._state_lock:
                self._slots_reserved += len(group)
                self._inflight.update(r for r, _ in group)
                self._cycle_tokens.add(pf.token)
                self.stats["admitted"] += len(group)
            self._log("admit", pf.token, [r.id for r, _ in group])
            return ("admit", group)
        if waiting and deps:
            # deferred-token admission: the head request does not fit. Park
            # THIS cycle until the oldest in-flight cycle fully completes
            # (its complete stage frees retired blocks), instead of spinning
            # empty admissions; the in-flight cycles keep the decode pump
            # alive meanwhile.
            dep = min(deps)
            with self._state_lock:
                self.stats["admit_parks"] += 1
            self._log("park", pf.token, dep)
            pf.defer(dep)
            return None
        # nothing admittable but sequences are running (or their retirement
        # is still in flight): emit a pure decode-pump cycle
        with self._state_lock:
            self._cycle_tokens.add(pf.token)
            self.stats["pump_cycles"] += 1
        self._log("pump", pf.token, None)
        return ("pump", None)

    def _st_prefill(self, pf, msg):
        kind, payload = msg
        if kind != "admit":
            return msg
        group = payload
        reqs = [r for r, _ in group]
        if not self.paged:
            # SSM/hybrid: whole-prompt prefill per member (recurrent state
            # is O(1)/sequence — there is no per-token KV to chunk in; the
            # compiled shape keys on each prompt length, as the grouped
            # baseline's did)
            out = []
            for req in reqs:
                logits, cache = self._prefill(
                    self.params, jnp.asarray(req.prompt[None]), None,
                    max_len=req.prompt_len)
                first = int(np.asarray(jnp.argmax(logits, axis=-1))[0])
                out.append((req, cache, first))
            with self._state_lock:
                self.stats["prefills"] += len(out)
            self._log("prefill", pf.token, [r.id for r in reqs])
            return ("admit", out)
        # one launch for the group's FIRST prompt window: prompts are
        # right-padded to a single window shape (chunked prefill keys the
        # compiled program on the window size, never on prompt lengths, so
        # mixed-length groups ride together; pad rows repeat the last
        # request and scatter to the sink). Remaining windows stream through
        # the decode stage cycle by cycle. The window is rounded up to a
        # power of two (capped at prefill_chunk) so arbitrary prompt-length
        # mixes compile O(log prefill_chunk) shapes, not one per length.
        longest = max(r.prompt_len for r in reqs)
        C0 = min(self.prefill_chunk, 1 << max(0, longest - 1).bit_length())
        A = self._scheduler.max_admit
        toks = np.zeros((A, C0), np.int32)
        lastp = np.zeros((A,), np.int32)
        for i, r in enumerate(reqs):
            k = min(r.prompt_len, C0)
            toks[i, :k] = r.prompt[:k]
            lastp[i] = k - 1
        for i in range(len(reqs), A):
            toks[i] = toks[len(reqs) - 1]
            lastp[i] = lastp[len(reqs) - 1]
        logits, cache = self._prefill(self.params, jnp.asarray(toks),
                                      jnp.asarray(lastp), max_len=C0)
        first = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        with self._state_lock:
            self.stats["prefills"] += 1
        self._log("prefill", pf.token, [r.id for r in reqs])
        return ("admit", (group, C0, cache["k"], cache["v"], first))

    # ------------------------------------------------- decode-stage helpers
    def _merge_group(self, payload) -> None:
        """Seat an admitted group: assign slots, install block tables, and
        scatter the window-0 KV into the pool (single-writer: we are inside
        the SERIAL decode stage). Rows whose whole prompt fits window 0
        enter decode immediately; longer ones enter the prefill phase and
        stream their remaining windows in subsequent cycles."""
        group, C0, ck, cv, first = payload
        first = np.asarray(first)
        nb0 = self._pool.blocks_for(C0)
        rows_idx, rows_tab = [], []
        for i, (req, blocks) in enumerate(group):
            with self._state_lock:
                slot = self._free_slots.pop()
                self._slots_reserved -= 1
                self._slot_req[slot] = req
                self._slot_blocks[slot] = list(blocks)
                self._slot_out[slot] = []
            self._slot_prompt[slot] = req.prompt
            self._tables[slot] = 0
            self._tables[slot, :len(blocks)] = blocks
            self._pref_pos[slot] = min(req.prompt_len, C0)
            self._lengths[slot] = self._pref_pos[slot]
            if req.prompt_len <= C0:
                self._slot_phase[slot] = "decode"
                self._last[slot] = first[i]
                self._rem[slot] = req.max_new - 1
                self._slot_out[slot].append(int(first[i]))
                req.state = "decoding"
            else:
                self._slot_phase[slot] = "prefill"
                self._last[slot] = 0
                self._rem[slot] = 0   # masked out of decode until prefilled
            rows_idx.append(slot)
            rows_tab.append(self._tables[slot].copy())
        # pad the row-set scatter to the admission cap (duplicate writes of
        # the same row are idempotent): ONE compiled shape per engine, not
        # one per group size
        A = self._scheduler.max_admit
        while len(rows_idx) < A:
            rows_idx.append(rows_idx[-1])
            rows_tab.append(rows_tab[-1])
        self._tables_dev = self._set_rows(
            self._tables_dev, jnp.asarray(rows_idx, jnp.int32),
            jnp.asarray(np.stack(rows_tab)))
        # window-0 scatter: per-row block lists trimmed/padded to the window
        # footprint (sink-filled beyond a short prompt's own blocks and for
        # the group's pad rows), so the compiled shape keys on the window
        # size alone — never on group size, prompt lengths, or max_new
        blocks2d = np.zeros((ck.shape[1], nb0), np.int32)
        for i, (_, blocks) in enumerate(group):
            row = blocks[:nb0]
            blocks2d[i, :len(row)] = row
        self._pkv = self._scatter(self._pkv, jnp.asarray(blocks2d), ck, cv)

    def _merge_group_slots(self, payload) -> None:
        """Seat an admitted SSM/hybrid group: scatter each member's
        prefilled recurrent state (and zamba2 shared-KV span) into its
        slot of the fixed-slot state pool."""
        for req, cache, first in payload:
            with self._state_lock:
                slot = self._free_slots.pop()
                self._slots_reserved -= 1
                self._slot_req[slot] = req
                self._slot_out[slot] = [first]
                self._slot_phase[slot] = "decode"
            self._write_slot_state(slot, cache, req.prompt_len)
            self._lengths[slot] = req.prompt_len
            self._last[slot] = first
            self._rem[slot] = req.max_new - 1
            req.state = "decoding"

    def _write_slot_state(self, slot: int, cache, plen: int) -> None:
        cfg = self.cfg
        if cfg.hybrid_attn_every:
            conv, h = cache["g_ssm"]
            sc, sh = self._sstate["g_ssm"]
            self._sstate["g_ssm"] = (sc.at[:, :, slot].set(conv[:, :, 0]),
                                     sh.at[:, :, slot].set(h[:, :, 0]))
            if "tail_ssm" in self._sstate:
                tconv, th = cache["tail_ssm"]
                stc, sth = self._sstate["tail_ssm"]
                self._sstate["tail_ssm"] = (stc.at[:, slot].set(tconv[:, 0]),
                                            sth.at[:, slot].set(th[:, 0]))
            self._sstate["shared_k"] = self._sstate["shared_k"] \
                .at[:, slot, :, :plen].set(cache["shared_k"][:, 0])
            self._sstate["shared_v"] = self._sstate["shared_v"] \
                .at[:, slot, :, :plen].set(cache["shared_v"][:, 0])
        else:
            conv, h = cache["ssm"]
            sc, sh = self._sstate["ssm"]
            self._sstate["ssm"] = (sc.at[:, slot].set(conv[:, 0]),
                                   sh.at[:, slot].set(h[:, 0]))

    def _window_prefill_step(self, pf) -> None:
        """Stream ONE prefill window for every mid-prefill row: the window's
        KV is computed against the row's paged prefix and scattered straight
        into the pool (one fixed-shape launch however many rows are
        prefilling — resident rows keep decoding in the same cycle)."""
        B = len(self._slot_req)
        pref = [b for b in range(B) if self._slot_phase[b] == "prefill"]
        if not pref:
            return
        C = self.prefill_chunk
        toks = np.zeros((B, C), np.int32)
        valid = np.zeros((B, C), bool)
        start = np.zeros((B,), np.int32)
        last_idx = np.zeros((B,), np.int32)
        for b in pref:
            prompt = self._slot_prompt[b]
            s = int(self._pref_pos[b])
            k = min(C, len(prompt) - s)
            toks[b, :k] = prompt[s:s + k]
            valid[b, :k] = True
            start[b] = s
            last_idx[b] = min(len(prompt) - 1 - s, C - 1)
        first, pkv = self._prefill_window(
            self.params, self._pkv, self._tables_dev, jnp.asarray(toks),
            jnp.asarray(start), jnp.asarray(valid), jnp.asarray(last_idx))
        self._pkv = pkv
        first = np.asarray(first)
        for b in pref:
            prompt = self._slot_prompt[b]
            k = min(C, len(prompt) - int(self._pref_pos[b]))
            self._pref_pos[b] += k
            self._lengths[b] = self._pref_pos[b]
            if self._pref_pos[b] >= len(prompt):
                req = self._slot_req[b]
                self._slot_phase[b] = "decode"
                self._last[b] = first[b]
                self._rem[b] = req.max_new - 1
                self._slot_out[b].append(int(first[b]))
                req.state = "decoding"
        with self._state_lock:
            self.stats["prefill_windows"] += 1
        self._log("prefill_chunk", pf.token,
                  [(b, int(self._pref_pos[b])) for b in pref])

    def _grow_or_preempt(self, pf) -> None:
        """Phase 2 of two-phase admission: grant each decoding row the
        blocks the NEXT decode chunk will write into, oldest row first
        (lazy growth — a row crosses into a new block every ``block_size``
        tokens). Pool exhaustion preempts the YOUNGEST resident row back
        onto the wait queue instead of deadlocking: its blocks free
        immediately, the oldest rows keep decoding, and the preempted
        request re-runs from scratch later (greedy decode is deterministic,
        so its tokens are unchanged)."""
        bs = self._pool.block_size
        n = self.decode_chunk
        grow_rows: List[int] = []
        grow_cols: List[int] = []
        grow_ids: List[int] = []
        order = sorted((b for b in range(len(self._slot_req))
                        if self._slot_phase[b] == "decode"
                        and self._rem[b] > 0),
                       key=lambda b: self._slot_req[b].id)
        for b in order:
            if self._slot_req[b] is None:
                continue                    # preempted as a younger victim
            k = int(min(n, self._rem[b]))
            need = (int(self._lengths[b]) + k - 1) // bs + 1
            cur = len(self._slot_blocks[b])
            while need > cur:
                ids = self._pool.grow_table(self._slot_blocks[b], need - cur)
                if ids is not None:
                    self._tables[b, cur:need] = ids
                    grow_rows.extend([b] * len(ids))
                    grow_cols.extend(range(cur, need))
                    grow_ids.extend(ids)
                    with self._state_lock:
                        self.stats["grown_blocks"] += len(ids)
                    break
                victim = max((v for v in range(len(self._slot_req))
                              if self._slot_req[v] is not None),
                             key=lambda v: self._slot_req[v].id)
                self._preempt(victim, pf)
                if victim == b:
                    break                   # b itself was the youngest
        if grow_rows:
            # device-side per-row table extension: the resident table array
            # is updated in place, not re-uploaded. Padded with repeats
            # (idempotent duplicate writes) to the worst-case burst size so
            # the scatter compiles exactly ONE shape per engine.
            self._log("grow", pf.token, list(zip(grow_rows, grow_ids)))
            m = self._grow_burst_max
            while len(grow_rows) < m:
                grow_rows.append(grow_rows[-1])
                grow_cols.append(grow_cols[-1])
                grow_ids.append(grow_ids[-1])
            self._tables_dev = self._extend_tables(
                self._tables_dev, jnp.asarray(grow_rows, jnp.int32),
                jnp.asarray(grow_cols, jnp.int32),
                jnp.asarray(grow_ids, jnp.int32))

    def _preempt(self, slot: int, pf) -> None:
        req = self._slot_req[slot]
        with self._state_lock:
            self._slot_req[slot] = None
            self._slot_out[slot] = None
            self._slot_phase[slot] = None
            self._pool.free(self._slot_blocks[slot])
            self._slot_blocks[slot] = None
            self._free_slots.append(slot)
            self._inflight.discard(req)
            self.stats["preempted"] += 1
        self._slot_prompt[slot] = None
        self._tables[slot] = 0
        self._lengths[slot] = 0
        self._last[slot] = 0
        self._rem[slot] = 0
        self._pref_pos[slot] = 0
        self._tables_dev = self._set_rows(
            self._tables_dev, jnp.asarray([slot], jnp.int32),
            jnp.zeros((1, self._tables.shape[1]), jnp.int32))
        self._scheduler.requeue_front([req])
        self._log("preempt", pf.token, req.id)

    def _st_decode(self, pf, msg):
        kind, payload = msg
        if kind == "admit":
            if self.paged:
                self._merge_group(payload)
            else:
                self._merge_group_slots(payload)
        if self.paged:
            self._window_prefill_step(pf)
            self._grow_or_preempt(pf)
        rem_before = self._rem.copy()
        if not (rem_before > 0).any():
            self._log("decode", pf.token, 0)
            return ("cycle", self._collect_finished(rem_before))
        n = self.decode_chunk
        if self.paged:
            pkv, tok, ln, rm, toks = self._decode_paged(
                self.params, self._pkv, self._tables_dev,
                jnp.asarray(self._lengths), jnp.asarray(self._last),
                jnp.asarray(self._rem), n=n)
            self._pkv = pkv
        else:
            st, tok, ln, rm, toks = self._decode_slots(
                self.params, self._sstate, jnp.asarray(self._last),
                jnp.asarray(self._lengths), jnp.asarray(self._rem), n=n)
            self._sstate = st
        toks = np.asarray(toks)        # (B, n): the chunk's device sync
        # np.array (not asarray): device views are read-only and these
        # mirrors are mutated by the next cycle's merge
        self._last = np.array(tok)
        self._lengths = np.array(ln)
        self._rem = np.array(rm)
        emitted = 0
        for b in np.nonzero(rem_before > 0)[0]:
            k = int(min(n, rem_before[b]))
            self._slot_out[b].extend(toks[b, :k].tolist())
            emitted += k
        with self._state_lock:
            self.stats["decode_cycles"] += 1
            self.stats["tokens_out"] += emitted
        self._log("decode", pf.token, emitted)
        return ("cycle", self._collect_finished(rem_before))

    def _collect_finished(self, rem_before) -> List[tuple]:
        """Rows that just hit rem==0: detach them from the batch (their slot
        stays reserved until complete frees it)."""
        retire = []
        zero_rows = []
        for b in range(len(self._rem)):
            if self._slot_req[b] is not None \
                    and self._slot_phase[b] == "decode" \
                    and self._rem[b] == 0:
                req = self._slot_req[b]
                out = np.asarray(self._slot_out[b], np.int32)
                with self._state_lock:
                    self._slot_req[b] = None
                    self._slot_out[b] = None
                    self._slot_phase[b] = None
                # zero the detached row's mirrors (still inside the SERIAL
                # decode stage: single-writer): the gather-free read paths
                # bound their page loop by max(lengths), so a retired slot
                # must not keep advertising its old length
                self._lengths[b] = 0
                self._last[b] = 0
                if self.paged:
                    self._tables[b] = 0
                    self._pref_pos[b] = 0
                    self._slot_prompt[b] = None
                    zero_rows.append(b)
                retire.append((b, req, out))
        if zero_rows:
            # fixed-shape zeroing scatter (pad with repeats; idempotent)
            B = len(self._slot_req)
            zero_rows += [zero_rows[-1]] * (B - len(zero_rows))
            self._tables_dev = self._set_rows(
                self._tables_dev, jnp.asarray(zero_rows, jnp.int32),
                jnp.zeros((B, self._tables.shape[1]), jnp.int32))
        return retire

    def _st_complete(self, pf, msg):
        _, retire = msg
        now = time.perf_counter()
        for slot, req, out in retire:
            self._scheduler.finish(req, out, now)
            with self._state_lock:
                if self.paged:
                    self._pool.free(self._slot_blocks[slot])
                    self._slot_blocks[slot] = None
                self._free_slots.append(slot)
                self._inflight.discard(req)
                self.stats["retired"] += 1
        with self._state_lock:
            self._cycle_tokens.discard(pf.token)
        self._log("complete", pf.token, len(retire))
        return None

    # --------------------------------------------------------------- pumping
    def _pump(self) -> None:
        ex = self._ensure_executor()
        pl = self._ensure_pipeline(ex)
        with self._pump_lock:
            if self._broken is not None or not pl.idle():
                return
            with self._state_lock:
                occupied = any(r is not None for r in self._slot_req)
            if self._scheduler.num_waiting == 0 and not occupied:
                return
            self._topo = pl.run(ex, self._on_topo_done)

    def _on_topo_done(self, topo) -> None:
        if topo.exceptions:
            err = topo.exceptions[0]
            self._broken = err
            self._fail_outstanding(err)
            return
        if self._scheduler.num_waiting:
            self._pump()   # a submit raced the stop-drain: re-arm

    def _fail_outstanding(self, err: BaseException) -> None:
        self._scheduler.fail_all_waiting(err)
        with self._state_lock:
            live = list(self._inflight)  # admitted: slotted or pre-merge
            self._inflight.clear()
        for r in live:
            r.set_error(err)

    # ----------------------------------------------------------- client API
    def submit(self, prompt, max_new: int = 16) -> ServeRequest:
        """Enqueue one generation request on the resident pipeline and
        return its future. Thread-safe; callable while earlier requests are
        mid-decode — that is the point. All architectures: paged attention
        KV for dense/MoE models, the fixed-slot recurrent-state pool for
        SSM/hybrid ones."""
        if self._broken is not None:
            raise RuntimeError("serve pipeline is broken") from self._broken
        if self._closing:
            raise RuntimeError("engine is closed")
        req = ServeRequest(prompt, max_new)
        total = req.prompt_len + req.max_new
        if total > self._max_seq:
            raise ValueError(
                f"prompt+max_new = {total} exceeds max_seq_len "
                f"{self._max_seq}")
        req.submitted_at = time.perf_counter()
        self._scheduler.enqueue(req)
        self._pump()
        return req

    def result(self, req: ServeRequest,
               timeout: Optional[float] = 300.0) -> np.ndarray:
        return req.result(timeout)

    def generate(self, prompts: List[Any], max_new: int) -> List[Any]:
        """Compatibility shim: submit every prompt, gather results in input
        order. Greedy tokens are bit-identical to the per-call engine this
        replaces (same compiled prefill math, same argmax chain — verified
        against the contiguous reference in tests)."""
        if not prompts:
            return []
        reqs = [self.submit(p, max_new) for p in prompts]
        return [self.result(r, timeout=600.0) for r in reqs]

    # -------------------------------------------- per-call baseline (bench)
    def _generate_grouped(self, prompts: List[Any], max_new: int
                          ) -> List[Any]:
        """PR 1's per-call pipeline: length groups flow admit -> prefill ->
        chunked contiguous decode -> complete through a throwaway
        DataPipeline. No longer a serving fallback (submit()/result() covers
        every arch through the resident pipeline); kept as the per-call
        BASELINE the serve benchmark compares against and as a bit-identity
        reference in tests."""
        groups: "OrderedDict[int, List[int]]" = OrderedDict()
        arrs = [np.asarray(p, np.int32) for p in prompts]
        for i, a in enumerate(arrs):
            groups.setdefault(len(a), []).append(i)
        work = deque(groups.values())
        results: List[Any] = [None] * len(prompts)

        def admit(pf):
            if not work:
                pf.stop()
                return None
            return work.popleft()

        def prefill(pf, idxs):
            toks = np.stack([arrs[i] for i in idxs])
            max_len = toks.shape[1] + max_new + 1
            logits, cache = self._prefill(self.params, jnp.asarray(toks),
                                          None, max_len=max_len)
            cur = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            return idxs, cache, cur

        def decode(pf, state):
            idxs, cache, cur = state
            chunks = [cur[:, None]]
            remaining = max_new - 1
            while remaining > 0:
                n = min(self.decode_chunk, remaining)
                cache, chunk = self._decode_n(self.params, cache, cur, n)
                chunks.append(chunk)
                cur = chunk[:, -1]
                remaining -= n
            return idxs, chunks

        def complete(pf, state):
            idxs, chunks = state
            seqs = np.concatenate([np.asarray(c) for c in chunks], axis=1)
            for row, i in enumerate(idxs):  # rows scatter to disjoint slots
                results[i] = seqs[row]
            return None

        ex = self._ensure_executor()
        decode_domain = ACCEL if ex.has_domain(ACCEL) else HOST
        pl = DataPipeline(
            max(1, min(len(work), self.pipeline_lines)),
            DataPipe(PipeType.SERIAL, admit, name="admit"),
            DataPipe(PipeType.SERIAL, prefill, name="prefill"),
            DataPipe(PipeType.SERIAL, decode, name="decode",
                     domain=decode_domain),
            DataPipe(PipeType.PARALLEL, complete, name="complete"),
            name="serve-generate")
        pl.run(ex).wait()
        return results
