"""Batched serving engine: prefill + jitted decode loop with KV cache.

The engine packages the two compiled programs of the serving path —
``prefill`` (prompt -> cache) and a ``decode_chunk`` DeviceFlow program
that advances N tokens inside ONE ``lax.while_loop``-style XLA launch
(the cudaFlow single-launch effect: host dispatch once per chunk, not per
token) — and drives them from a request queue on the host domain.

Greedy sampling (argmax) keeps tests deterministic; temperature sampling is
a flag away.
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..distributed.sharding import ShardCtx, use_shard_ctx
from ..models import lm

__all__ = ["ServeEngine", "Request"]


@dataclass
class Request:
    prompt: Any                   # (S,) int32
    max_new: int = 16
    result: Optional[Any] = None


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params,
                 ctx: Optional[ShardCtx] = None,
                 decode_chunk: int = 8):
        self.cfg = cfg
        self.params = params
        self.ctx = ctx or ShardCtx(mesh=None)
        self.decode_chunk = decode_chunk
        self._prefill = jax.jit(self._prefill_impl,
                                static_argnames=("max_len",))
        self._decode_n = jax.jit(self._decode_n_impl,
                                 static_argnames=("n",),
                                 donate_argnums=(1,))

    # ---------------------------------------------------------- compiled fns
    def _prefill_impl(self, params, tokens, max_len: int):
        with use_shard_ctx(self.ctx):
            return lm.prefill(self.cfg, params, tokens, max_len=max_len)

    def _decode_n_impl(self, params, cache, token, n: int):
        """n decode steps in one XLA launch (single-launch graph)."""
        with use_shard_ctx(self.ctx):
            def body(carry, _):
                cache, tok = carry
                logits, cache = lm.decode_step(self.cfg, params, cache, tok)
                nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
                return (cache, nxt), nxt

            (cache, tok), toks = jax.lax.scan(body, (cache, token),
                                              None, length=n)
            return cache, toks.swapaxes(0, 1)  # (B, n)

    # ----------------------------------------------------------------- serve
    def generate(self, prompts: List[Any], max_new: int) -> List[Any]:
        """Batched greedy generation (equal-length prompts per batch; the
        continuous-batching scheduler groups requests by length upstream)."""
        import numpy as np

        B = len(prompts)
        S = len(prompts[0])
        assert all(len(p) == S for p in prompts), \
            "batch prompts must share a length (group upstream)"
        toks = np.stack([np.asarray(p, np.int32) for p in prompts])
        max_len = S + max_new + 1
        logits, cache = self._prefill(self.params, jnp.asarray(toks),
                                      max_len=max_len)
        cur = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        outs = [np.asarray(cur)[:, None]]
        remaining = max_new - 1
        while remaining > 0:
            n = min(self.decode_chunk, remaining)
            cache, chunk = self._decode_n(self.params, cache, cur, n)
            outs.append(np.asarray(chunk))
            cur = chunk[:, -1]
            remaining -= n
        seqs = np.concatenate(outs, axis=1)
        return [seqs[i] for i in range(B)]
