"""Batched serving engine: a 4-stage task-parallel generation pipeline.

The engine packages the two compiled programs of the serving path —
``prefill`` (prompt -> cache) and a ``decode_chunk`` program that advances N
tokens inside ONE ``lax.scan`` XLA launch (the cudaFlow single-launch
effect: host dispatch once per chunk, not per token) — and drives them
through a :class:`repro.pipeline.DataPipeline` over the work-stealing
executor:

    admit (SERIAL)  -> pop the next length-group of requests, or stop
    prefill (SERIAL)-> one compiled prefill launch for the group
    decode (SERIAL, accel domain) -> chunked greedy decode to completion
    complete (PARALLEL) -> host materialisation + scatter to request order

Stages are SERIAL where they contend for the same compiled program / device,
but *different length-groups occupy different stages simultaneously*: group
B prefills while group A decodes — the overlap the hand-rolled host loop
this replaces could not express. Greedy sampling (argmax) keeps tests
deterministic; temperature sampling is a flag away.
"""
from __future__ import annotations

from collections import OrderedDict, deque
from dataclasses import dataclass
from typing import Any, List, Optional

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..core import ACCEL, HOST, Executor
from ..distributed.sharding import ShardCtx, use_shard_ctx
from ..models import lm
from ..pipeline import DataPipe, DataPipeline, PipeType

__all__ = ["ServeEngine", "Request"]


@dataclass
class Request:
    prompt: Any                   # (S,) int32
    max_new: int = 16
    result: Optional[Any] = None


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params,
                 ctx: Optional[ShardCtx] = None,
                 decode_chunk: int = 8,
                 executor: Optional[Executor] = None,
                 pipeline_lines: int = 3):
        self.cfg = cfg
        self.params = params
        self.ctx = ctx or ShardCtx(mesh=None)
        self.decode_chunk = decode_chunk
        self.pipeline_lines = pipeline_lines
        self._executor = executor
        self._own_executor = False
        self._prefill = jax.jit(self._prefill_impl,
                                static_argnames=("max_len",))
        self._decode_n = jax.jit(self._decode_n_impl,
                                 static_argnames=("n",),
                                 donate_argnums=(1,))

    # ---------------------------------------------------------- compiled fns
    def _prefill_impl(self, params, tokens, max_len: int):
        with use_shard_ctx(self.ctx):
            return lm.prefill(self.cfg, params, tokens, max_len=max_len)

    def _decode_n_impl(self, params, cache, token, n: int):
        """n decode steps in one XLA launch (single-launch graph)."""
        with use_shard_ctx(self.ctx):
            def body(carry, _):
                cache, tok = carry
                logits, cache = lm.decode_step(self.cfg, params, cache, tok)
                nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
                return (cache, nxt), nxt

            (cache, tok), toks = jax.lax.scan(body, (cache, token),
                                              None, length=n)
            return cache, toks.swapaxes(0, 1)  # (B, n)

    # ------------------------------------------------------------- lifecycle
    def _ensure_executor(self) -> Executor:
        if self._executor is None:
            self._executor = Executor(domains={HOST: 2, ACCEL: 1})
            self._own_executor = True
        return self._executor

    def close(self) -> None:
        if self._own_executor and self._executor is not None:
            self._executor.shutdown()
            self._executor = None
            self._own_executor = False

    def __enter__(self) -> "ServeEngine":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # ----------------------------------------------------------------- serve
    def generate(self, prompts: List[Any], max_new: int) -> List[Any]:
        """Pipelined greedy generation. Prompts of mixed lengths are grouped
        by length (one compiled prefill shape per group) and the groups flow
        through the 4-stage pipeline as scheduling tokens, so prefill of one
        group overlaps decode of another. Results keep the input order."""
        import numpy as np

        if not prompts:
            return []
        groups: "OrderedDict[int, List[int]]" = OrderedDict()
        arrs = [np.asarray(p, np.int32) for p in prompts]
        for i, a in enumerate(arrs):
            groups.setdefault(len(a), []).append(i)
        work = deque(groups.values())
        results: List[Any] = [None] * len(prompts)

        def admit(pf):
            if not work:
                pf.stop()
                return None
            return work.popleft()

        def prefill(pf, idxs):
            toks = np.stack([arrs[i] for i in idxs])
            max_len = toks.shape[1] + max_new + 1
            logits, cache = self._prefill(self.params, jnp.asarray(toks),
                                          max_len=max_len)
            cur = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            return idxs, cache, cur

        def decode(pf, state):
            idxs, cache, cur = state
            chunks = [cur[:, None]]
            remaining = max_new - 1
            while remaining > 0:
                n = min(self.decode_chunk, remaining)
                cache, chunk = self._decode_n(self.params, cache, cur, n)
                chunks.append(chunk)
                cur = chunk[:, -1]
                remaining -= n
            return idxs, chunks

        def complete(pf, state):
            idxs, chunks = state
            seqs = np.concatenate([np.asarray(c) for c in chunks], axis=1)
            for row, i in enumerate(idxs):  # rows scatter to disjoint slots
                results[i] = seqs[row]
            return None

        ex = self._ensure_executor()
        decode_domain = ACCEL if ex.has_domain(ACCEL) else HOST
        pl = DataPipeline(
            max(1, min(len(work), self.pipeline_lines)),
            DataPipe(PipeType.SERIAL, admit, name="admit"),
            DataPipe(PipeType.SERIAL, prefill, name="prefill"),
            DataPipe(PipeType.SERIAL, decode, name="decode",
                     domain=decode_domain),
            DataPipe(PipeType.PARALLEL, complete, name="complete"),
            name="serve-generate")
        pl.run(ex).wait()
        return results
