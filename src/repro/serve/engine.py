"""Continuously-batched serving engine: a RESIDENT 4-stage pipeline fed by
a request queue, with TWO-PHASE memory admission.

PR 2 kept ONE cyclic :class:`repro.pipeline.DataPipeline` alive for the
life of the engine; PR 3 made the paged *read* path occupancy-proportional.
This revision makes the *write/admission* half follow live token counts
too — the Taskflow memory thesis (resources follow control flow
incrementally, not worst-case up front) applied to KV admission:

    admit (SERIAL)    -> pop an admission group from ONE FIFO (no length
                         buckets: chunked prefill makes per-window shapes
                         uniform, so mixed-length groups admit together) and
                         allocate its PROMPT-ONLY block footprint; park via
                         ``pf.defer(token)`` when the head does not fit, or
                         emit a plain decode-pump cycle
    prefill (SERIAL)  -> one compiled launch for the group's FIRST prompt
                         window (fixed window size, prompts right-padded);
                         SSM/hybrid archs prefill each member's whole prompt
                         here instead (recurrent state is O(1)/sequence)
    decode (SERIAL,   -> merge the group (scatter window-0 KV / recurrent
      accel domain)      state into the pool, assign slots), stream ONE more
                         prefill window for every mid-prefill row, grow
                         block tables lazily for rows about to cross a block
                         boundary (preempting the youngest row on pool
                         exhaustion), then advance every decoding row by one
                         compiled chunk of ``decode_chunk`` steps
    complete (PARALLEL)-> retire rows that just finished: fulfil their
                         request futures, free their blocks/slots — per
                         sequence, WITHOUT draining the pipeline

Two-phase admission
-------------------
*Phase 1 (admit):* a request is admitted when the pool covers its PROMPT
KV footprint — not ``prompt + max_new``. *Phase 2 (grow):* every
``block_size`` decode tokens, the decode stage grants the row one more
block (``BlockPool.grow_table`` + a device-side table-extension scatter);
on pool exhaustion it preempts the YOUNGEST resident row back onto the
wait queue (its blocks freed, its request re-queued at the head) instead
of deadlocking. Long prompts are *chunked*: window 0 lands via the prefill
stage, the rest stream through the decode stage one fixed-size window per
cycle, scattered straight into the paged pool — resident rows keep
decoding in the overlapped cycles.

The KV pool and the block-table array are written ONLY by the SERIAL
decode stage, so pool updates stay single-writer by construction; the
block table is device-resident across cycles (growth is an in-place
scatter, not a re-upload). The compiled chunk reads the pool gather-free
(``paged_impl``: the Pallas kernel or its XLA page-loop lowering, see
:mod:`repro.serve`).

Async decode lookahead (``async_decode=`` / ``REPRO_ASYNC_DECODE``)
-------------------------------------------------------------------
The synchronous decode stage blocks on every chunk's tokens and runs all
grow/preempt/retire/admit bookkeeping while the device idles. With
``async_decode=True`` the stage is split into **dispatch -> sync** at a
pipeline depth of 2:

* the decode carry (``lengths``, ``last``, ``rem``) is DEVICE-RESIDENT
  across cycles, alongside the block tables: chunk N+1 consumes chunk N's
  output carry directly, so the device-side dependency chain never waits
  on the host. Merge/retire/preempt mutate the carry through the same
  fixed-shape padded scatters the table array uses
  (:func:`repro.serve.kvcache.set_carry_rows`); the host keeps exact
  ``lengths``/``rem`` mirrors by pure arithmetic (chunk advance is
  token-independent) while ``last`` lives only on device.
* each cycle dispatches chunk N+1 FIRST (JAX async dispatch queues it
  behind N), then syncs chunk N's tokens and does every piece of host
  bookkeeping — emit tokens, collect finished rows, stream prefill
  windows, grow tables — while N+1 runs on device. Admission scatters,
  window launches and growth scatters are sequenced BEFORE the dispatch.

The new scheduling hazards this opens are closed explicitly:

* **retirement is one chunk late**: a row that exhausts ``rem`` during
  chunk N stays seated through N+1 — masked on device by ``rem == 0``
  (KV writes go to the sink) — and detaches after N's sync; tokens a
  chunk computed for a row whose seat changed since dispatch (preempted,
  retired, re-seated) are discarded host-side via a per-slot seat
  generation.
* **deferred-free fence**: a preempted row's blocks may still be written
  by the chunk in flight at preemption time (and by the prefill window
  launched the same cycle), so :meth:`repro.serve.kvcache.BlockPool
  .free_deferred` parks them — invisible to allocation — until the
  engine has synced past that device work (two fence advances).
* **prefill-window completion is deferred one cycle**: the window launch
  precedes the next chunk on the pool's dependency chain, so reading its
  first-token logits a cycle later never stalls the loop behind the
  in-flight chunk.

Greedy tokens are bit-identical to the synchronous engine (same compiled
chunk program, same carry values — asserted on the ``gather`` oracle in
``tests/test_serve_async.py``); the synchronous path remains the
reference. ``self.overlap_stats`` tracks the per-cycle dispatch / wait /
bookkeeping / host-gap breakdown that
``benchmarks/decode_overlap_microbench.py`` reports.

SSM / hybrid architectures (mamba, zamba2) serve through the SAME
resident pipeline via a fixed-slot recurrent-state pool: prefilled
``(conv, h)`` states (plus zamba2's shared-block KV span) are scattered
into a per-slot pool, rows decode side by side at per-row positions
(:func:`repro.models.lm.decode_step_slots`), and slots free at
retirement. The old per-call grouped fallback is retired from
``submit()``/``generate()`` and survives only as the benchmark baseline
(:meth:`ServeEngine._generate_grouped`).

Client API: :meth:`submit` returns a :class:`ServeRequest` future;
:meth:`ServeRequest.result` blocks for the tokens. :meth:`generate` remains
as a thin compatibility shim over submit/result (greedy tokens bit-identical
to the per-call engine it replaces).

The pipeline goes idle (stop-drain) when no requests are waiting or
running; ``submit()`` re-arms it without rebuilding the task graph
(:meth:`repro.pipeline.Pipeline.run` on the same resident grid). A failure
inside any stage cancels the topology, fails every outstanding request
future (``result()`` raises instead of deadlocking) and marks the engine
broken.
"""
from __future__ import annotations

import os
import threading
import time
from collections import OrderedDict, deque
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..core import ACCEL, HOST, Executor
from ..distributed.sharding import ShardCtx, use_shard_ctx
from ..models import lm
from ..obs import TRACK_ENGINE
from ..obs import from_env as _obs_from_env
from ..pipeline import DataPipe, DataPipeline, PipeType
from .kvcache import (SINK_BLOCK, BlockPool, copy_blocks,
                      extend_block_tables, init_kv_pool,
                      scatter_prefill_rows, set_carry_rows, set_table_rows)
from .prefix import PrefixCache
from .scheduler import Scheduler, ServeRequest

__all__ = ["ServeEngine", "ServeRequest"]


class ServeEngine:
    """Resident continuous-batching engine (see module docstring).

    Parameters
    ----------
    decode_chunk:
        decode steps per compiled chunk launch — also the admission
        granularity (sequences join/leave at chunk boundaries).
    prefill_chunk:
        prompt tokens per prefill window. A prompt longer than this
        prefills across multiple pipeline cycles (window 0 in the prefill
        stage, the rest streamed by the decode stage) while resident rows
        keep decoding. Defaults to ``decode_chunk * block_size``. Paged
        (attention) archs only; SSM/hybrid prompts prefill whole.
    max_batch:
        decode slot count; the compiled chunk program always runs this many
        rows (inactive rows are masked), so batch composition changes never
        recompile.
    kv_blocks / block_size:
        paged KV pool geometry. Block 0 is the reserved sink.
    max_admit:
        cap on requests admitted per cycle (one prefill launch).
    max_seq_len:
        per-sequence cap on ``prompt + max_new`` (sets the block-table
        width; for zamba2 it sizes the shared-block KV span per slot).
        Defaults to 32 blocks worth, clamped to the pool size (512 for
        SSM/hybrid).
    paged_impl:
        attention read path of the compiled decode chunk: ``"pallas"``
        (gather-free Pallas kernel, Mosaic on TPU), ``"xla"`` (gather-free
        traced-bound page loop), or ``"gather"`` (materializing reference
        oracle). None resolves via
        :func:`repro.kernels.ops.default_paged_impl` (honors the
        ``REPRO_PAGED_IMPL`` env var; pallas on TPU, xla elsewhere).
    async_decode:
        pipeline the decode loop one chunk deep: the carry stays
        device-resident, chunk N+1 is dispatched before chunk N's tokens
        are synced, and all host bookkeeping overlaps device compute (see
        the module docstring). None resolves via the ``REPRO_ASYNC_DECODE``
        env var (default off — the synchronous path is the reference).
    prefix_cache:
        share KV blocks across requests with a common prompt prefix: full
        prompt chunks are indexed in a :class:`repro.serve.prefix
        .PrefixCache` trie, cache-hit admissions seed their block table
        with the shared (refcount-pinned) blocks and budget/prefill only
        their uncached suffix, a shared tail block is copy-on-write forked
        before the first divergent write, and under pool pressure cold
        PARKED prefix blocks are evicted by reuse score before any
        resident row is preempted (see ``docs/prefix_caching.md``). None
        resolves via the ``REPRO_PREFIX_CACHE`` env var (default off —
        the uncached path is the bit-exact reference). Paged
        (attention) archs only; ignored for SSM/hybrid models.
    record_stages:
        keep an in-memory (stage, cycle-token, info, t) event log — the
        observer hook the overlap tests read.
    obs:
        a :class:`repro.obs.Observability` (tracer + metrics registry).
        The engine records request lifecycle spans on per-slot tracks,
        engine-cycle phase spans on the ``"engine"`` track, and the
        counters/gauges/histograms listed in :mod:`repro.serve`'s
        observability section. None resolves via the ``REPRO_OBS`` env
        var (default off — the disabled path costs attribute checks
        only). Rebindable at idle via :meth:`set_obs`.
    """

    def __init__(self, cfg: ModelConfig, params,
                 ctx: Optional[ShardCtx] = None,
                 decode_chunk: int = 8,
                 prefill_chunk: Optional[int] = None,
                 executor: Optional[Executor] = None,
                 pipeline_lines: int = 3,
                 max_batch: int = 8,
                 kv_blocks: int = 128,
                 block_size: int = 16,
                 max_admit: int = 4,
                 max_seq_len: Optional[int] = None,
                 paged_impl: Optional[str] = None,
                 async_decode: Optional[bool] = None,
                 prefix_cache: Optional[bool] = None,
                 record_stages: bool = False,
                 obs=None):
        self.cfg = cfg
        self.params = params
        self.ctx = ctx or ShardCtx(mesh=None)
        self.decode_chunk = decode_chunk
        self.pipeline_lines = pipeline_lines
        self._executor = executor
        self._own_executor = False
        self._prefill = jax.jit(self._prefill_impl,
                                static_argnames=("max_len",))
        self._decode_n = jax.jit(self._decode_n_impl,
                                 static_argnames=("n",),
                                 donate_argnums=(1,))

        #: continuous batching pages the attention KV cache; SSM/hybrid
        #: recurrent state is O(1)/seq and lives in a fixed-slot state pool
        self.paged = not (cfg.ssm or cfg.hybrid_attn_every)
        from ..kernels.ops import PAGED_IMPLS, default_paged_impl
        if paged_impl is not None and paged_impl not in PAGED_IMPLS:
            raise ValueError(f"paged_impl={paged_impl!r}: expected one of "
                             f"{PAGED_IMPLS} (or None for the default)")
        #: read path of the compiled decode chunk; None on non-paged archs
        self.paged_impl = (paged_impl or default_paged_impl()) \
            if self.paged else None
        if async_decode is None:
            async_decode = os.environ.get("REPRO_ASYNC_DECODE", "") \
                .strip().lower() in ("1", "true", "yes", "on")
        #: dispatch->sync pipelined decode loop (depth 2); False = the
        #: synchronous reference path
        self.async_decode = bool(async_decode)
        if prefix_cache is None:
            prefix_cache = os.environ.get("REPRO_PREFIX_CACHE", "") \
                .strip().lower() in ("1", "true", "yes", "on")
        #: cross-request KV block sharing (paged archs only); False = the
        #: uncached bit-exact reference path
        self.prefix_cache = bool(prefix_cache) and self.paged
        self._closing = False
        self._broken: Optional[BaseException] = None
        self._stage_log = [] if record_stages else None
        self._log_lock = threading.Lock()

        B = max_batch
        self._scheduler = Scheduler(max_admit=max_admit)
        # slot state: written by the SERIAL decode stage (merge/window/grow/
        # step) and the complete stage (free) under _state_lock; admit only
        # reads counts
        self._lengths = np.zeros((B,), np.int32)   # KV/state tokens written
        self._rem = np.zeros((B,), np.int32)       # decode steps remaining
        self._last = np.zeros((B,), np.int32)      # last emitted token
        # DEVICE-RESIDENT decode carry (lengths, last, rem): in async mode
        # chunk N+1 consumes chunk N's output carry directly (merge/grow/
        # retire/preempt mutate it via fixed-shape scatters) and the host
        # mirrors above are maintained deterministically — lengths/rem
        # arithmetic is token-independent, `last` is refreshed lazily from
        # synced chunk outputs. The sync path uploads the mirrors instead.
        self._carry = (jnp.zeros((B,), jnp.int32),
                       jnp.zeros((B,), jnp.int32),
                       jnp.zeros((B,), jnp.int32))
        self._set_carry = jax.jit(set_carry_rows)
        # seat generation per slot, bumped on every seat/retire/preempt:
        # guards late token emission in async mode (a synced chunk's tokens
        # only land on the seat they were computed for)
        self._slot_gen = np.zeros((B,), np.int64)
        self._pending: Optional[Dict[str, Any]] = None   # in-flight chunk
        self._window_pending: Optional[Dict[str, Any]] = None
        #: per-decode-cycle wall-time breakdown (all modes): dispatch_s =
        #: chunk launch, wait_s = blocking device sync, book_s = host
        #: bookkeeping, gap_s = host time with NO device work in flight
        #: (the host gap the async mode exists to close)
        #: ``min_chunk_s`` is the cleanest observed upload+launch+block
        #: interval of a sync-mode cycle — the microbench's device-time
        #: calibration constant (0 until a sync chunk has run)
        self.overlap_stats = {"cycles": 0, "dispatch_s": 0.0, "wait_s": 0.0,
                              "book_s": 0.0, "gap_s": 0.0, "total_s": 0.0,
                              "min_chunk_s": 0.0}
        self._slot_req: List[Optional[ServeRequest]] = [None] * B
        self._slot_out: List[Optional[List[int]]] = [None] * B
        self._slot_phase: List[Optional[str]] = [None] * B  # prefill|decode
        self._free_slots = list(range(B - 1, -1, -1))
        self._slots_reserved = 0       # admitted but not yet merged
        self._inflight: set = set()    # admitted, not yet retired (failure
        #                                cleanup: these must see set_error)
        self._cycle_tokens: set = set()  # cycles minted and not yet completed
        self._state_lock = threading.Lock()
        self._pump_lock = threading.Lock()
        self._topo = None
        self._pipeline: Optional[DataPipeline] = None
        self.stats = {"admitted": 0, "admit_parks": 0, "pump_cycles": 0,
                      "decode_cycles": 0, "prefills": 0,
                      "prefill_windows": 0, "tokens_out": 0, "retired": 0,
                      "grown_blocks": 0, "preempted": 0, "stalls": 0,
                      "prefix_hits": 0, "prefix_tokens_saved": 0,
                      "cow_forks": 0}

        self._prefix: Optional[PrefixCache] = None
        if self.paged:
            self._pool = BlockPool(kv_blocks, block_size)
            self._pkv = init_kv_pool(cfg, kv_blocks, block_size)
            if self.prefix_cache:
                self._prefix = PrefixCache(self._pool)
            self._cow_copy = jax.jit(copy_blocks, donate_argnums=(0,))
            self._max_seq = min(max_seq_len or 32 * block_size,
                                (kv_blocks - 1) * block_size)
            self.prefill_chunk = prefill_chunk or decode_chunk * block_size
            if self.prefill_chunk < 1:
                raise ValueError("prefill_chunk must be >= 1")
            mb = self._pool.blocks_for(self._max_seq)
            # block tables: host mirror for growth decisions + a DEVICE-
            # resident array the compiled programs read; growth/merge/retire
            # update the device copy with in-place scatters
            self._tables = np.zeros((B, mb), np.int32)
            self._tables_dev = jnp.zeros((B, mb), jnp.int32)
            self._pref_pos = np.zeros((B,), np.int32)  # prompt tokens done
            self._slot_blocks: List[Optional[List[int]]] = [None] * B
            self._slot_prompt: List[Optional[np.ndarray]] = [None] * B
            # preallocated chunked-prefill window buffers: each cycle only
            # the rows actually mid-prefill are (re)written — invariant: a
            # row's `valid` entries are False unless it is mid-prefill
            # (cleared on decode transition and preemption)
            C = self.prefill_chunk
            self._wp_toks = np.zeros((B, C), np.int32)
            self._wp_valid = np.zeros((B, C), bool)
            self._wp_start = np.zeros((B,), np.int32)
            self._wp_last_idx = np.zeros((B,), np.int32)
            # worst-case blocks granted in one cycle: every row crosses into
            # ceil(decode_chunk / block_size) new blocks plus one boundary
            # block — the fixed width of the growth scatter
            self._grow_burst_max = B * (-(-decode_chunk // block_size) + 1)
            # async stall ledger: a row whose growth failed ONLY because the
            # needed blocks sit behind the deferred-free fence is masked on
            # device (rem -> 0) instead of preempted; its remaining steps
            # park here until the fence releases and growth succeeds
            self._stall_rem = np.zeros((B,), np.int32)
            self._set_rem = jax.jit(
                lambda rem, rows, vals: rem.at[rows].set(vals))
            self._decode_paged = jax.jit(self._decode_paged_impl,
                                         static_argnames=("n",),
                                         donate_argnums=(1,))
            self._scatter = jax.jit(self._scatter_impl, donate_argnums=(0,))
            self._prefill_window = jax.jit(self._prefill_window_impl,
                                           donate_argnums=(1,))
            self._extend_tables = jax.jit(extend_block_tables)
            self._set_rows = jax.jit(set_table_rows)
        else:
            self._max_seq = max_seq_len or 512
            self.prefill_chunk = None
            # fixed-slot recurrent-state pool: init_cache's pytree with the
            # scalar pos replaced by the per-row _lengths mirror
            self._sstate = {k: v
                            for k, v in lm.init_cache(cfg, B,
                                                      self._max_seq).items()
                            if k != "pos"}
            self._decode_slots = jax.jit(self._decode_slots_impl,
                                         static_argnames=("n",),
                                         donate_argnums=(1,))

        # observability: one open phase span per seated slot (name, t0);
        # None obs = fully disabled (hot paths guard on self._tr/_mh)
        self._slot_span: List[Optional[tuple]] = [None] * B
        self.set_obs(obs if obs is not None else _obs_from_env())

    # ---------------------------------------------------------- observability
    def set_obs(self, obs) -> None:
        """Attach (or detach, with None) a :class:`repro.obs.Observability`.

        Binding caches every metric handle once (``self._mh``) and hands the
        metrics registry to the scheduler and block pool and the tracer to
        the resident pipeline, so an instrumented event costs one cached-
        handle call and a disabled one a single ``None`` check. Rebindable
        while the engine is idle — the overhead-gate benchmark toggles obs
        on ONE engine instead of paying a second jit warm-up.
        """
        self.obs = obs
        self._tr = obs.tracer if obs is not None else None
        metrics = obs.metrics if obs is not None else None
        self._scheduler.set_metrics(metrics)
        if self.paged:
            self._pool.set_metrics(metrics)
        if self._prefix is not None:
            self._prefix.set_metrics(metrics)
        if self._pipeline is not None:
            self._pipeline.tracer = self._tr
        if metrics is None:
            self._mh = None
            return
        self._mh = {
            "tokens_out": metrics.counter("serve.tokens_out"),
            "admitted": metrics.counter("serve.requests.admitted"),
            "retired": metrics.counter("serve.requests.retired"),
            "preempted": metrics.counter("serve.requests.preempted"),
            "stalled": metrics.counter("serve.requests.stalled"),
            "grown_blocks": metrics.counter("pool.grown_blocks"),
            "prefill_saved": metrics.counter("serve.prefill_tokens_saved"),
            "resident": metrics.gauge("serve.resident_rows"),
            "ttft": metrics.histogram("serve.ttft_s"),
            "qwait": metrics.histogram("serve.queue_wait_s"),
            "cycle": metrics.histogram("engine.cycle_s"),
            "dispatch": metrics.histogram("engine.dispatch_s"),
            "sync": metrics.histogram("engine.chunk_sync_s"),
            "book": metrics.histogram("engine.book_s"),
            "gap": metrics.histogram("engine.gap_s"),
            "chunk": metrics.histogram("engine.chunk_s"),
        }

    def _phase_begin(self, slot: int, name: str, t: float) -> None:
        self._slot_span[slot] = (name, t)

    def _phase_end(self, slot: int, t: float, req=None) -> None:
        cur = self._slot_span[slot]
        self._slot_span[slot] = None
        if cur is not None and self._tr is not None:
            args = {"req": req.id} if req is not None else None
            self._tr.add(cur[0], f"slot{slot}", cur[1], t, args)

    def _note_seated(self, slot: int, req, now: float) -> None:
        """Retroactive lifecycle spans, emitted at seat time (the slot a
        request will occupy is unknown until the decode-stage merge):
        ``queued`` [enqueue -> admission pop], ``admitted`` [pop -> merge],
        then the open ``prefill``/``decode`` phase span. A preempted
        request re-enters here on its NEXT admission, so its track shows
        every queued/admitted/decode re-entry."""
        tr = self._tr
        track = f"slot{slot}"
        adm = req.last_admitted_at or now
        if req.queued_since is not None:
            tr.add("queued", track, req.queued_since, adm,
                   {"req": req.id, "preempted": req.preempted_count})
        tr.add("admitted", track, adm, now, {"req": req.id})
        self._phase_begin(slot, self._slot_phase[slot], now)

    def _note_first_token(self, req, now: float) -> None:
        if req.first_token_at is None:
            req.first_token_at = now
            if self._mh is not None and req.submitted_at is not None:
                self._mh["ttft"].record(now - req.submitted_at)

    def _note_resident(self) -> None:
        if self._mh is not None:
            self._mh["resident"].set(
                sum(r is not None for r in self._slot_req))

    # ---------------------------------------------------------- compiled fns
    def _prefill_impl(self, params, tokens, last_positions, max_len: int):
        with use_shard_ctx(self.ctx):
            return lm.prefill(self.cfg, params, tokens, max_len=max_len,
                              last_positions=last_positions)

    def _decode_n_impl(self, params, cache, token, n: int):
        """n contiguous decode steps in one XLA launch (per-call baseline)."""
        with use_shard_ctx(self.ctx):
            def body(carry, _):
                cache, tok = carry
                logits, cache = lm.decode_step(self.cfg, params, cache, tok)
                nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
                return (cache, nxt), nxt

            (cache, tok), toks = jax.lax.scan(body, (cache, token),
                                              None, length=n)
            return cache, toks.swapaxes(0, 1)  # (B, n)

    def _decode_paged_impl(self, params, pkv, tables, lengths, last,
                           rem, n: int):
        """One chunk: ``n`` paged decode steps over the resident batch in a
        single XLA launch (:func:`repro.models.lm.decode_chunk_paged` — the
        shared device-carry chunk program; the sync path feeds it uploaded
        host mirrors, the async path feeds it the previous chunk's output
        carry directly). Rows with ``rem == 0`` are inactive: their KV
        writes go to the sink block and their emitted tokens are discarded
        host-side. The attention read path is ``self.paged_impl``.
        Returns the advanced state + (B, n) greedy tokens."""
        with use_shard_ctx(self.ctx):
            pkv, (ln, tok, rm), toks = lm.decode_chunk_paged(
                self.cfg, params, pkv, tables, (lengths, last, rem), n,
                impl=self.paged_impl)
            return pkv, tok, ln, rm, toks

    def _decode_slots_impl(self, params, state, last, lengths, rem, n: int):
        """One chunk over the SSM/hybrid slot-state pool
        (:func:`repro.models.lm.decode_chunk_slots` at per-row positions).
        Inactive slots step on stale state harmlessly (row-wise math; their
        tokens are discarded host-side and their slot is overwritten at the
        next admission)."""
        with use_shard_ctx(self.ctx):
            st, (ln, tok, rm), toks = lm.decode_chunk_slots(
                self.cfg, params, state, (lengths, last, rem), n)
            return st, tok, ln, rm, toks

    def _prefill_window_impl(self, params, pkv, tables, tokens, start,
                             valid, last_idx):
        with use_shard_ctx(self.ctx):
            return lm.prefill_window_paged(self.cfg, params, pkv, tables,
                                           tokens, start, valid, last_idx)

    def _scatter_impl(self, pkv, blocks, krows, vrows):
        return scatter_prefill_rows(pkv, blocks, krows, vrows)

    # ------------------------------------------------------------- lifecycle
    def _ensure_executor(self) -> Executor:
        if self._executor is None:
            self._executor = Executor(domains={HOST: 2, ACCEL: 1})
            self._own_executor = True
        return self._executor

    def _ensure_pipeline(self, ex: Executor) -> DataPipeline:
        if self._pipeline is None:
            decode_domain = ACCEL if ex.has_domain(ACCEL) else HOST
            self._pipeline = DataPipeline(
                self.pipeline_lines,
                DataPipe(PipeType.SERIAL, self._st_admit, name="admit"),
                DataPipe(PipeType.SERIAL, self._st_prefill, name="prefill"),
                DataPipe(PipeType.SERIAL, self._st_decode, name="decode",
                         domain=decode_domain),
                DataPipe(PipeType.PARALLEL, self._st_complete,
                         name="complete"),
                name="serve-continuous")
            # promote stage_times into per-line spans when tracing is on
            self._pipeline.tracer = self._tr
        return self._pipeline

    def close(self, timeout: float = 300.0) -> None:
        """Drain outstanding requests, then release the executor. Idempotent."""
        self._closing = True
        if self._pipeline is not None:
            deadline = time.perf_counter() + timeout
            while time.perf_counter() < deadline:
                if self._broken is not None:
                    break
                if self._pipeline.idle() and \
                        self._scheduler.num_waiting == 0:
                    break
                time.sleep(0.005)
        if self.paged and self._pending is None:
            # drained: no chunk in flight, every deferred block is past the
            # device work that fenced it — flush the fence
            while self._pool.num_deferred:
                self._pool.release_deferred()
        if self._own_executor and self._executor is not None:
            self._executor.shutdown()
            self._executor = None
            self._own_executor = False

    def __enter__(self) -> "ServeEngine":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # ------------------------------------------------------- stage callables
    def _log(self, stage: str, token: int, info: Any) -> None:
        if self._stage_log is not None:
            with self._log_lock:
                self._stage_log.append((stage, token, info,
                                        time.perf_counter()))

    @property
    def stage_log(self) -> List[tuple]:
        """(stage, cycle-token, info, timestamp) events (record_stages=True)."""
        with self._log_lock:
            return list(self._stage_log or [])

    def _st_admit(self, pf):
        t_adm = time.perf_counter()
        with self._state_lock:
            occupied = any(r is not None for r in self._slot_req)
            reserved = self._slots_reserved
            deps = set(self._cycle_tokens)
            free_slots = len(self._free_slots) - reserved
        waiting = self._scheduler.num_waiting
        if not waiting and not occupied and reserved == 0:
            # fully idle — nothing queued, no live rows, and no admitted
            # group still in flight toward its decode merge: drain so the
            # engine parks at zero cost; the next submit() re-arms the SAME
            # resident grid (no rebuild)
            pf.stop()
            return None
        # async back-pressure gate: a STALLED resident row is starving for
        # blocks that are (or will be) released by the deferred-free fence.
        # Admitting here would hand those blocks to a new request, which the
        # grow pass then preempts to feed the older stalled row — an
        # admit/preempt livelock. Stalled residents claim released blocks
        # first; admission resumes once no row is stalled. (Benign race: a
        # one-cycle-stale read costs at most one wasted admission, which the
        # next cycle's gate stops.)
        stalled = self.paged and self.async_decode \
            and bool((self._stall_rem > 0).any())
        group = None
        if stalled:
            pass                        # fall through to park / decode pump
        elif self.paged:
            # phase 1 of two-phase admission: budget the PROMPT footprint
            # only — minus any prompt blocks the prefix cache already holds
            # (peek is conservative: registration can only grow a match
            # between the peek and the pin below) — and count PARKED cached
            # blocks toward the budget, since they are evictable on demand;
            # decode-time blocks are granted lazily by the decode stage
            px = self._prefix
            if px is not None:
                bs = self._pool.block_size

                def need_for(r):
                    return self._pool.blocks_for(r.prompt_len) \
                        - px.peek(r.prompt) // bs
                budget = self._pool.num_free + px.num_parked
            else:
                def need_for(r):
                    return self._pool.blocks_for(r.prompt_len)
                budget = self._pool.num_free
            popped = self._scheduler.try_admit(free_slots, budget, need_for)
            if popped is not None:
                # pin the longest cached prefix per member (ref++ on every
                # matched block) and allocate only the uncached suffixes
                hits = [px.match_and_pin(r.prompt) if px is not None
                        else None for r in popped]
                needs = [self._pool.blocks_for(r.prompt_len)
                         - (len(h.blocks) if h is not None else 0)
                         for r, h in zip(popped, hits)]
                ids = self._pool.alloc(sum(needs))  # atomic all-or-nothing
                if ids is None and px is not None:
                    # reuse-aware back-pressure: release cold PARKED prefix
                    # blocks (leaf-first, coldest score first) before giving
                    # up on the group — and long before the grow pass would
                    # preempt any resident row
                    short = sum(needs) - self._pool.num_free
                    if short > 0:
                        px.evict(short)
                    ids = self._pool.alloc(sum(needs))
                if ids is None:
                    # raced a concurrent mid-decode grow: unpin, put the
                    # group back (id order preserved), fall through to
                    # park/pump
                    for h in hits:
                        if h is None:
                            continue
                        pins = list(h.blocks)
                        if h.partial_block is not None:
                            pins.append(h.partial_block)
                        if pins:
                            px.unpin(pins)
                    self._scheduler.requeue_front(popped)
                else:
                    group, i, saved, nhit = [], 0, 0, 0
                    for r, h, need in zip(popped, hits, needs):
                        group.append((r, ids[i:i + need], h))
                        i += need
                        if h is not None and h.tokens > 0:
                            nhit += 1
                            saved += h.tokens
                    if nhit:
                        with self._state_lock:
                            self.stats["prefix_hits"] += nhit
                            self.stats["prefix_tokens_saved"] += saved
                        if self._mh is not None:
                            self._mh["prefill_saved"].inc(saved)
        else:
            # slot-state pool: recurrent state is pre-allocated per slot, so
            # admission is bounded by free slots alone
            popped = self._scheduler.try_admit(free_slots, None)
            if popped is not None:
                group = [(r, None) for r in popped]
        if group is not None:
            now = time.perf_counter()
            for g in group:
                r = g[0]
                r.state = "prefilling"
                if r.admitted_at is None:
                    r.admitted_at = now
                    if self._mh is not None and r.submitted_at is not None:
                        self._mh["qwait"].record(now - r.submitted_at)
            with self._state_lock:
                self._slots_reserved += len(group)
                self._inflight.update(g[0] for g in group)
                self._cycle_tokens.add(pf.token)
                self.stats["admitted"] += len(group)
            if self._mh is not None:
                self._mh["admitted"].inc(len(group))
            if self._tr is not None:
                self._tr.add("admission", TRACK_ENGINE, t_adm, now,
                             {"reqs": [g[0].id for g in group]})
            self._log("admit", pf.token, [g[0].id for g in group])
            return ("admit", group)
        if waiting and deps:
            # deferred-token admission: the head request does not fit. Park
            # THIS cycle until the oldest in-flight cycle fully completes
            # (its complete stage frees retired blocks), instead of spinning
            # empty admissions; the in-flight cycles keep the decode pump
            # alive meanwhile.
            dep = min(deps)
            with self._state_lock:
                self.stats["admit_parks"] += 1
            self._log("park", pf.token, dep)
            pf.defer(dep)
            return None
        # nothing admittable but sequences are running (or their retirement
        # is still in flight): emit a pure decode-pump cycle
        with self._state_lock:
            self._cycle_tokens.add(pf.token)
            self.stats["pump_cycles"] += 1
        if self._tr is not None:
            self._tr.add("admission", TRACK_ENGINE, t_adm,
                         time.perf_counter(), {"pump": True})
        self._log("pump", pf.token, None)
        return ("pump", None)

    def _st_prefill(self, pf, msg):
        kind, payload = msg
        if kind != "admit":
            return msg
        group = payload
        reqs = [g[0] for g in group]
        if not self.paged:
            # SSM/hybrid: whole-prompt prefill per member (recurrent state
            # is O(1)/sequence — there is no per-token KV to chunk in; the
            # compiled shape keys on each prompt length, as the grouped
            # baseline's did)
            out = []
            for req in reqs:
                logits, cache = self._prefill(
                    self.params, jnp.asarray(req.prompt[None]), None,
                    max_len=req.prompt_len)
                first = int(np.asarray(jnp.argmax(logits, axis=-1))[0])
                out.append((req, cache, first))
            with self._state_lock:
                self.stats["prefills"] += len(out)
            self._log("prefill", pf.token, [r.id for r in reqs])
            return ("admit", out)
        # one launch for the group's FIRST prompt window: prompts are
        # right-padded to a single window shape (chunked prefill keys the
        # compiled program on the window size, never on prompt lengths, so
        # mixed-length groups ride together; pad rows repeat the last
        # request and scatter to the sink). Remaining windows stream through
        # the decode stage cycle by cycle. The window is rounded up to a
        # power of two (capped at prefill_chunk) so arbitrary prompt-length
        # mixes compile O(log prefill_chunk) shapes, not one per length.
        # Prefix-cache HIT rows skip this launch entirely: their cached
        # tokens never re-prefill — the decode stage seats them with the
        # shared blocks and streams windows from the first uncached token
        # (the group is reordered miss-first so launch row i is group
        # member i for every window-0 participant).
        miss = [g for g in group if g[2] is None or g[2].tokens == 0]
        hitg = [g for g in group if not (g[2] is None or g[2].tokens == 0)]
        group = miss + hitg
        if not miss:
            self._log("prefill", pf.token, [r.id for r in reqs])
            return ("admit", (group, 0, None, None, None, 0))
        longest = max(g[0].prompt_len for g in miss)
        C0 = min(self.prefill_chunk, 1 << max(0, longest - 1).bit_length())
        A = self._scheduler.max_admit
        toks = np.zeros((A, C0), np.int32)
        lastp = np.zeros((A,), np.int32)
        for i, g in enumerate(miss):
            r = g[0]
            k = min(r.prompt_len, C0)
            toks[i, :k] = r.prompt[:k]
            lastp[i] = k - 1
        for i in range(len(miss), A):
            toks[i] = toks[len(miss) - 1]
            lastp[i] = lastp[len(miss) - 1]
        logits, cache = self._prefill(self.params, jnp.asarray(toks),
                                      jnp.asarray(lastp), max_len=C0)
        first = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        with self._state_lock:
            self.stats["prefills"] += 1
        self._log("prefill", pf.token, [r.id for r in reqs])
        return ("admit", (group, C0, cache["k"], cache["v"], first,
                          len(miss)))

    # ------------------------------------------------- decode-stage helpers
    def _scatter_carry(self, rows, lens, lasts, rems, pad_to: int) -> None:
        """Fixed-shape scatter onto the device-resident carry: pad every
        list with repeats of its last element (duplicate writes of the same
        row are idempotent) so each call site compiles exactly ONE shape
        regardless of how many rows it touches. Async mode only — the sync
        path re-uploads the host mirrors each cycle instead."""
        rows, lens = list(rows), list(lens)
        lasts, rems = list(lasts), list(rems)
        while len(rows) < pad_to:
            rows.append(rows[-1])
            lens.append(lens[-1])
            lasts.append(lasts[-1])
            rems.append(rems[-1])
        self._carry = self._set_carry(
            *self._carry, jnp.asarray(rows, jnp.int32),
            jnp.asarray(lens, jnp.int32), jnp.asarray(lasts, jnp.int32),
            jnp.asarray(rems, jnp.int32))

    def _merge_group(self, payload) -> None:
        """Seat an admitted group: assign slots, install block tables, and
        scatter the window-0 KV into the pool (single-writer: we are inside
        the SERIAL decode stage). Rows whose whole prompt fits window 0
        enter decode immediately; longer ones enter the prefill phase and
        stream their remaining windows in subsequent cycles.

        Prefix-cache HIT rows (group members past ``n_miss`` — they took no
        window-0 launch row) seed their table with the pinned SHARED prefix
        blocks followed by their own suffix blocks and enter the prefill
        phase at the first uncached token; a partially-matched tail block
        is copy-on-write FORKED here (device block copy into the row's
        first suffix block, which the table already points at) so the
        row's own writes never touch the shared original."""
        group, C0, ck, cv, first, n_miss = payload
        first = np.asarray(first) if first is not None else None
        nb0 = self._pool.blocks_for(C0) if C0 else 0
        now = time.perf_counter()
        rows_idx, rows_tab = [], []
        c_len, c_last, c_rem = [], [], []
        fork_src, fork_dst = [], []
        reg_slots = []
        for i, (req, blocks, hit) in enumerate(group):
            shared = list(hit.blocks) if (hit is not None and i >= n_miss) \
                else []
            tab = shared + list(blocks)
            with self._state_lock:
                slot = self._free_slots.pop()
                self._slots_reserved -= 1
                self._slot_req[slot] = req
                self._slot_blocks[slot] = tab
                self._slot_out[slot] = []
            self._slot_gen[slot] += 1
            self._slot_prompt[slot] = req.prompt
            self._wp_valid[slot] = False
            self._stall_rem[slot] = 0
            self._tables[slot] = 0
            self._tables[slot, :len(tab)] = tab
            if shared or (hit is not None and i >= n_miss):
                # cache hit: cached tokens are already in the pool — start
                # the window walk at the first uncached token
                self._pref_pos[slot] = hit.tokens
                if hit.partial_block is not None:
                    # CoW fork of the partially-matched tail block into the
                    # row's first suffix block (table column len(shared)):
                    # its cached leading tokens come along, the row's own
                    # writes land past them
                    fork_src.append(hit.partial_block)
                    fork_dst.append(blocks[0])
                    with self._state_lock:
                        self.stats["cow_forks"] += 1
                    if self._tr is not None:
                        self._tr.instant(
                            "cow_fork", f"slot{slot}", now,
                            {"req": req.id, "src": int(hit.partial_block),
                             "dst": int(blocks[0])})
            else:
                self._pref_pos[slot] = min(req.prompt_len, C0)
            self._lengths[slot] = self._pref_pos[slot]
            if i < n_miss and req.prompt_len <= C0:
                self._slot_phase[slot] = "decode"
                self._last[slot] = first[i]
                self._rem[slot] = req.max_new - 1
                self._slot_out[slot].append(int(first[i]))
                req.state = "decoding"
                self._note_first_token(req, now)
                reg_slots.append(slot)
            else:
                self._slot_phase[slot] = "prefill"
                self._last[slot] = 0
                self._rem[slot] = 0   # masked out of decode until prefilled
            if self._tr is not None:
                self._note_seated(slot, req, now)
            rows_idx.append(slot)
            rows_tab.append(self._tables[slot].copy())
            c_len.append(int(self._lengths[slot]))
            c_last.append(int(self._last[slot]))
            c_rem.append(int(self._rem[slot]))
        # pad the row-set scatters to the admission cap (duplicate writes of
        # the same row are idempotent): ONE compiled shape per engine, not
        # one per group size
        A = self._scheduler.max_admit
        while len(rows_idx) < A:
            rows_idx.append(rows_idx[-1])
            rows_tab.append(rows_tab[-1])
        self._tables_dev = self._set_rows(
            self._tables_dev, jnp.asarray(rows_idx, jnp.int32),
            jnp.asarray(np.stack(rows_tab)))
        if self.async_decode:
            # admission scatter onto the device carry, sequenced BEFORE the
            # next chunk dispatch: the seated rows were inactive (rem==0) in
            # the chunk still in flight, so scattering onto its output carry
            # is exact
            self._scatter_carry(rows_idx[:len(group)], c_len, c_last, c_rem,
                                pad_to=A)
        if fork_src:
            # partial-tail forks: one padded device copy for the whole
            # group, sequenced on the pool chain before any window launch
            # that reads the forked blocks
            self._copy_blocks_padded(fork_src, fork_dst)
            self._prefix.unpin(fork_src)   # fork done: drop the tail pins
        if n_miss:
            # window-0 scatter: per-row block lists trimmed/padded to the
            # window footprint (sink-filled beyond a short prompt's own
            # blocks and for the group's pad rows), so the compiled shape
            # keys on the window size alone — never on group size, prompt
            # lengths, or max_new
            blocks2d = np.zeros((ck.shape[1], nb0), np.int32)
            for i, (_, blocks, _) in enumerate(group[:n_miss]):
                row = blocks[:nb0]
                blocks2d[i, :len(row)] = row
            self._pkv = self._scatter(self._pkv, jnp.asarray(blocks2d),
                                      ck, cv)
        for slot in reg_slots:
            self._register_prefix(slot)
        self._note_resident()

    def _copy_blocks_padded(self, srcs: List[int], dsts: List[int]) -> None:
        """One :func:`repro.serve.kvcache.copy_blocks` launch, padded with
        ``SINK -> SINK`` repeats to the next power of two so arbitrary fork
        counts compile O(log max_batch) shapes."""
        m = 1 << max(0, len(srcs) - 1).bit_length()
        srcs = list(srcs) + [SINK_BLOCK] * (m - len(srcs))
        dsts = list(dsts) + [SINK_BLOCK] * (m - len(dsts))
        self._pkv = self._cow_copy(self._pkv, jnp.asarray(srcs, jnp.int32),
                                   jnp.asarray(dsts, jnp.int32))

    def _register_prefix(self, slot: int) -> None:
        """Index a just-prefilled row's FULL prompt chunks in the prefix
        trie (decode entry is the registration point: every full prompt
        block is final — decode writes land strictly past the prompt)."""
        if self._prefix is None:
            return
        prompt = self._slot_prompt[slot]
        blocks = self._slot_blocks[slot]
        if prompt is not None and blocks is not None:
            self._prefix.register(prompt, blocks)

    def _merge_group_slots(self, payload) -> None:
        """Seat an admitted SSM/hybrid group: scatter each member's
        prefilled recurrent state (and zamba2 shared-KV span) into its
        slot of the fixed-slot state pool."""
        now = time.perf_counter()
        rows_idx, c_len, c_last, c_rem = [], [], [], []
        for req, cache, first in payload:
            with self._state_lock:
                slot = self._free_slots.pop()
                self._slots_reserved -= 1
                self._slot_req[slot] = req
                self._slot_out[slot] = [first]
                self._slot_phase[slot] = "decode"
            self._slot_gen[slot] += 1
            self._write_slot_state(slot, cache, req.prompt_len)
            self._lengths[slot] = req.prompt_len
            self._last[slot] = first
            self._rem[slot] = req.max_new - 1
            req.state = "decoding"
            self._note_first_token(req, now)
            if self._tr is not None:
                self._note_seated(slot, req, now)
            rows_idx.append(slot)
            c_len.append(req.prompt_len)
            c_last.append(first)
            c_rem.append(req.max_new - 1)
        if self.async_decode:
            self._scatter_carry(rows_idx, c_len, c_last, c_rem,
                                pad_to=self._scheduler.max_admit)
        self._note_resident()

    def _write_slot_state(self, slot: int, cache, plen: int) -> None:
        cfg = self.cfg
        if cfg.hybrid_attn_every:
            conv, h = cache["g_ssm"]
            sc, sh = self._sstate["g_ssm"]
            self._sstate["g_ssm"] = (sc.at[:, :, slot].set(conv[:, :, 0]),
                                     sh.at[:, :, slot].set(h[:, :, 0]))
            if "tail_ssm" in self._sstate:
                tconv, th = cache["tail_ssm"]
                stc, sth = self._sstate["tail_ssm"]
                self._sstate["tail_ssm"] = (stc.at[:, slot].set(tconv[:, 0]),
                                            sth.at[:, slot].set(th[:, 0]))
            self._sstate["shared_k"] = self._sstate["shared_k"] \
                .at[:, slot, :, :plen].set(cache["shared_k"][:, 0])
            self._sstate["shared_v"] = self._sstate["shared_v"] \
                .at[:, slot, :, :plen].set(cache["shared_v"][:, 0])
        else:
            conv, h = cache["ssm"]
            sc, sh = self._sstate["ssm"]
            self._sstate["ssm"] = (sc.at[:, slot].set(conv[:, 0]),
                                   sh.at[:, slot].set(h[:, 0]))

    def _window_prefill_step(self, pf) -> None:
        """Synchronous chunked prefill: build, launch and complete ONE
        prefill window for every mid-prefill row in the same cycle. The
        async path instead calls :meth:`_dispatch_window_prefill` directly
        and completes the window next cycle (:meth:`_finish_window`), so
        reading its first-token logits never blocks behind the in-flight
        decode chunk."""
        pend = self._dispatch_window_prefill(pf)
        if pend is not None:
            self._finish_window(pend)

    def _dispatch_window_prefill(self, pf) -> Optional[Dict[str, Any]]:
        """Launch ONE prefill window for every mid-prefill row: the window's
        KV is computed against the row's paged prefix and scattered straight
        into the pool (one fixed-shape launch however many rows are
        prefilling — resident rows keep decoding in the same cycle). Only
        the prefilling rows are written into the preallocated window
        buffers; everyone else's ``valid`` entries are invariantly False.
        Returns the pending-window descriptor (or None if no row is
        prefilling); completion is :meth:`_finish_window`."""
        B = len(self._slot_req)
        pref = [b for b in range(B) if self._slot_phase[b] == "prefill"]
        if not pref:
            return None
        C = self.prefill_chunk
        toks, valid = self._wp_toks, self._wp_valid
        start, last_idx = self._wp_start, self._wp_last_idx
        ks = {}
        for b in pref:
            prompt = self._slot_prompt[b]
            s = int(self._pref_pos[b])
            k = min(C, len(prompt) - s)
            toks[b, :k] = prompt[s:s + k]
            valid[b, :k] = True
            valid[b, k:] = False
            start[b] = s
            last_idx[b] = min(len(prompt) - 1 - s, C - 1)
            ks[b] = k
        first, pkv = self._prefill_window(
            self.params, self._pkv, self._tables_dev, jnp.asarray(toks),
            jnp.asarray(start), jnp.asarray(valid), jnp.asarray(last_idx))
        self._pkv = pkv
        with self._state_lock:
            self.stats["prefill_windows"] += 1
        return {"first": first, "rows": pref, "k": ks, "token": pf.token,
                "gen": {b: self._slot_gen[b] for b in pref},
                "t_disp": time.perf_counter()}

    def _finish_window(self, pend: Dict[str, Any]) -> None:
        """Complete a dispatched prefill window: advance per-row prompt
        positions and flip rows whose prompt just finished into decode
        (their first-token logits seed the stream). Async mode runs this
        one cycle AFTER the dispatch — the window launch precedes the next
        chunk on the pool's dependency chain, so by then its outputs are
        ready and the ``np.asarray`` below does not stall the loop — and
        scatters the transitions onto the device carry."""
        first = np.asarray(pend["first"])
        now = time.perf_counter()
        t_rows, t_len, t_last, t_rem = [], [], [], []
        done = []
        for b in pend["rows"]:
            if self._slot_gen[b] != pend["gen"][b] \
                    or self._slot_phase[b] != "prefill":
                continue                    # preempted since the dispatch
            prompt = self._slot_prompt[b]
            self._pref_pos[b] += pend["k"][b]
            self._lengths[b] = self._pref_pos[b]
            done.append(b)
            if self._tr is not None:
                self._tr.add("prefill_window", f"slot{b}",
                             pend["t_disp"], now,
                             {"req": self._slot_req[b].id,
                              "pos": int(self._pref_pos[b])})
            if self._pref_pos[b] >= len(prompt):
                req = self._slot_req[b]
                self._slot_phase[b] = "decode"
                self._last[b] = first[b]
                self._rem[b] = req.max_new - 1
                self._slot_out[b].append(int(first[b]))
                req.state = "decoding"
                self._note_first_token(req, now)
                if self._tr is not None:
                    self._phase_end(b, now, req)     # close "prefill"
                    self._phase_begin(b, "decode", now)
                self._wp_valid[b] = False
                self._register_prefix(b)
                t_rows.append(b)
                t_len.append(int(self._lengths[b]))
                t_last.append(int(first[b]))
                t_rem.append(req.max_new - 1)
        if self.async_decode and t_rows:
            self._scatter_carry(t_rows, t_len, t_last, t_rem,
                                pad_to=len(self._slot_req))
        self._log("prefill_chunk", pend["token"],
                  [(b, int(self._pref_pos[b])) for b in done])

    def _grow_or_preempt(self, pf) -> None:
        """Phase 2 of two-phase admission: grant each decoding row the
        blocks the NEXT decode chunk will write into, oldest row first
        (lazy growth — a row crosses into a new block every ``block_size``
        tokens). Pool exhaustion preempts the YOUNGEST resident row back
        onto the wait queue instead of deadlocking: its blocks free
        immediately, the oldest rows keep decoding, and the preempted
        request re-runs from scratch later (greedy decode is deterministic,
        so its tokens are unchanged).

        Async refinements: a growth failure while blocks sit behind the
        deferred-free fence STALLS the row (``rem`` masked to 0 on device,
        the balance parked in ``_stall_rem``) instead of preempting —
        preempting on in-transit memory could cascade into the oldest row
        evicting itself and replaying forever. Stalled rows retry here
        every cycle and resume the moment growth succeeds."""
        bs = self._pool.block_size
        n = self.decode_chunk
        grow_rows: List[int] = []
        grow_cols: List[int] = []
        grow_ids: List[int] = []
        stall_rows: List[int] = []
        stall_vals: List[int] = []
        order = sorted((b for b in range(len(self._slot_req))
                        if self._slot_phase[b] == "decode"
                        and (self._rem[b] > 0 or self._stall_rem[b] > 0)),
                       key=lambda b: self._slot_req[b].id)
        # youngest-first victim order, computed ONCE per cycle (the old
        # code re-ran a max() over all slots on every failed grow attempt);
        # slots preempted along the way are skipped by the slot_req check
        victims = sorted((v for v in range(len(self._slot_req))
                          if self._slot_req[v] is not None),
                         key=lambda v: self._slot_req[v].id, reverse=True)
        vi = 0
        for b in order:
            if self._slot_req[b] is None:
                continue                    # preempted as a younger victim
            rem_b = int(self._rem[b]) + int(self._stall_rem[b])
            k = int(min(n, rem_b))
            need = (int(self._lengths[b]) + k - 1) // bs + 1
            cur = len(self._slot_blocks[b])
            covered = need <= cur
            while need > cur:
                ids = self._pool.grow_table(self._slot_blocks[b], need - cur)
                if ids is not None:
                    self._tables[b, cur:need] = ids
                    grow_rows.extend([b] * len(ids))
                    grow_cols.extend(range(cur, need))
                    grow_ids.extend(ids)
                    with self._state_lock:
                        self.stats["grown_blocks"] += len(ids)
                    if self._mh is not None:
                        self._mh["grown_blocks"].inc(len(ids))
                    covered = True
                    break
                if self._prefix is not None \
                        and self._prefix.evict(need - cur) > 0:
                    continue    # cold parked prefix blocks released: retry
                    # growth before stalling or preempting ANY resident row
                if self.async_decode and self._pool.num_deferred > 0:
                    break       # blocks in transit behind the fence: stall
                while vi < len(victims) \
                        and self._slot_req[victims[vi]] is None:
                    vi += 1
                if vi == len(victims):
                    break                   # nothing left to preempt
                victim = victims[vi]
                vi += 1
                self._preempt(victim, pf)
                if victim == b:
                    break                   # b itself was the youngest
            if self._slot_req[b] is None:
                continue                    # b preempted itself
            if covered:
                if self._stall_rem[b]:      # fence released: resume the row
                    self._rem[b] += self._stall_rem[b]
                    self._stall_rem[b] = 0
                    stall_rows.append(b)
                    stall_vals.append(int(self._rem[b]))
                    if self._tr is not None:
                        _t = time.perf_counter()
                        self._phase_end(b, _t, self._slot_req[b])  # stalled
                        self._phase_begin(b, "decode", _t)
                    self._log("resume", pf.token, b)
            elif self._rem[b] > 0:
                # newly stalled: mask the row out of the next dispatch
                self._stall_rem[b] = int(self._rem[b])
                self._rem[b] = 0
                stall_rows.append(b)
                stall_vals.append(0)
                with self._state_lock:
                    self.stats["stalls"] += 1
                if self._mh is not None:
                    self._mh["stalled"].inc()
                if self._tr is not None:
                    _t = time.perf_counter()
                    self._phase_end(b, _t, self._slot_req[b])  # close decode
                    self._phase_begin(b, "stalled", _t)
                self._log("stall", pf.token, b)
        if stall_rows and self.async_decode:
            # fixed-shape rem-only carry scatter (lengths/last unchanged —
            # `last` is device-only in async mode; pad with repeats)
            B = len(self._slot_req)
            rows = stall_rows + [stall_rows[-1]] * (B - len(stall_rows))
            vals = stall_vals + [stall_vals[-1]] * (B - len(stall_vals))
            ln, la, rm = self._carry
            self._carry = (ln, la, self._set_rem(
                rm, jnp.asarray(rows, jnp.int32),
                jnp.asarray(vals, jnp.int32)))
        if grow_rows:
            # device-side per-row table extension: the resident table array
            # is updated in place, not re-uploaded. Padded with repeats
            # (idempotent duplicate writes) to the worst-case burst size so
            # the scatter compiles exactly ONE shape per engine.
            self._log("grow", pf.token, list(zip(grow_rows, grow_ids)))
            m = self._grow_burst_max
            while len(grow_rows) < m:
                grow_rows.append(grow_rows[-1])
                grow_cols.append(grow_cols[-1])
                grow_ids.append(grow_ids[-1])
            self._tables_dev = self._extend_tables(
                self._tables_dev, jnp.asarray(grow_rows, jnp.int32),
                jnp.asarray(grow_cols, jnp.int32),
                jnp.asarray(grow_ids, jnp.int32))

    def _cow_guard(self, pf) -> None:
        """Copy-on-write safety net, run BEFORE the window-prefill and
        decode-chunk dispatches each cycle: any row about to WRITE into a
        block that is still shared (refcount > 1) forks it first — device
        block copy, table repoint (host mirror + device scatter), one
        reference dropped on the original. Structurally this never fires
        on the engine's own flows (admission forks partial tail blocks
        eagerly at the merge, and FULL shared prefix blocks are never
        written again by construction — decode appends land strictly past
        the prompt), but ``append_kv`` into a shared block corrupting a
        co-holder would be silent and unbounded, so the invariant is
        enforced here unconditionally (tests trigger it via an artificial
        ``incref``)."""
        if self._prefix is None:
            return
        bs = self._pool.block_size
        srcs, dsts, rows, cols = [], [], [], []
        for b in range(len(self._slot_req)):
            if self._slot_req[b] is None or self._slot_blocks[b] is None:
                continue
            if self._slot_phase[b] == "decode":
                lo = int(self._lengths[b])
                k = int(min(self.decode_chunk,
                            int(self._rem[b]) + int(self._stall_rem[b])))
            elif self._slot_phase[b] == "prefill":
                lo = int(self._pref_pos[b])
                k = int(min(self.prefill_chunk,
                            len(self._slot_prompt[b]) - lo))
            else:
                continue
            if k <= 0:
                continue
            blocks = self._slot_blocks[b]
            hi = min((lo + k - 1) // bs + 1, len(blocks))
            for col in range(lo // bs, hi):
                old = blocks[col]
                if self._pool.refcount(old) <= 1:
                    continue
                ids = self._pool.alloc(1)
                if ids is None:
                    self._prefix.evict(1)
                    ids = self._pool.alloc(1)
                if ids is None:
                    # cannot fork and must not write the shared block:
                    # requeue the row, it replays later (deterministic)
                    self._preempt(b, pf)
                    break
                new = ids[0]
                blocks[col] = new
                self._tables[b, col] = new
                srcs.append(old)
                dsts.append(new)
                rows.append(b)
                cols.append(col)
                # drop OUR reference on the original (co-holders keep it
                # alive; refcount stays >= 1 so nothing is released here)
                if self.async_decode:
                    self._pool.free_deferred([old])
                else:
                    self._pool.free([old])
                with self._state_lock:
                    self.stats["cow_forks"] += 1
                if self._tr is not None:
                    self._tr.instant("cow_fork", f"slot{b}",
                                     time.perf_counter(),
                                     {"req": self._slot_req[b].id,
                                      "src": int(old), "dst": int(new)})
        # a row preempted mid-pass (fork allocation failure) zeroed its
        # table and freed its blocks — drop its queued forks
        live = [j for j in range(len(rows))
                if self._slot_req[rows[j]] is not None]
        if len(live) < len(rows):
            srcs = [srcs[j] for j in live]
            dsts = [dsts[j] for j in live]
            rows = [rows[j] for j in live]
            cols = [cols[j] for j in live]
        if srcs:
            self._copy_blocks_padded(srcs, dsts)
            # device table repoint, padded with repeats (idempotent) to a
            # power of two like the copy
            m = 1 << max(0, len(rows) - 1).bit_length()
            ids2 = list(dsts)
            while len(rows) < m:
                rows.append(rows[-1])
                cols.append(cols[-1])
                ids2.append(ids2[-1])
            self._tables_dev = self._extend_tables(
                self._tables_dev, jnp.asarray(rows, jnp.int32),
                jnp.asarray(cols, jnp.int32), jnp.asarray(ids2, jnp.int32))

    def _preempt(self, slot: int, pf) -> None:
        req = self._slot_req[slot]
        with self._state_lock:
            self._slot_req[slot] = None
            self._slot_out[slot] = None
            self._slot_phase[slot] = None
            if self.async_decode:
                # deferred-free FENCE: the chunk in flight at preemption
                # time (and any prefill window launched this cycle) may
                # still write these blocks — they return to the pool only
                # after the engine has synced past that device work
                self._pool.free_deferred(self._slot_blocks[slot])
            else:
                self._pool.free(self._slot_blocks[slot])
            self._slot_blocks[slot] = None
            self._free_slots.append(slot)
            self._inflight.discard(req)
            self.stats["preempted"] += 1
        self._slot_gen[slot] += 1      # in-flight tokens become surplus
        req.preempted_count += 1
        self._slot_prompt[slot] = None
        self._wp_valid[slot] = False
        self._tables[slot] = 0
        self._lengths[slot] = 0
        self._last[slot] = 0
        self._rem[slot] = 0
        self._stall_rem[slot] = 0
        self._pref_pos[slot] = 0
        self._tables_dev = self._set_rows(
            self._tables_dev, jnp.asarray([slot], jnp.int32),
            jnp.zeros((1, self._tables.shape[1]), jnp.int32))
        if self.async_decode:
            self._scatter_carry([slot], [0], [0], [0], pad_to=1)
        if self._mh is not None:
            self._mh["preempted"].inc()
            self._note_resident()
        if self._tr is not None:
            _t = time.perf_counter()
            self._phase_end(slot, _t, req)
            self._tr.instant("preempted", f"slot{slot}", _t, {"req": req.id})
        self._scheduler.requeue_front([req])
        self._log("preempt", pf.token, req.id)

    def _st_decode(self, pf, msg):
        if self.async_decode:
            return self._st_decode_async(pf, msg)
        t0 = time.perf_counter()
        kind, payload = msg
        if kind == "admit":
            if self.paged:
                self._merge_group(payload)
            else:
                self._merge_group_slots(payload)
        if self.paged:
            tg0 = time.perf_counter()
            self._cow_guard(pf)
            self._window_prefill_step(pf)
            self._grow_or_preempt(pf)
            if self._tr is not None:
                self._tr.add("growth", TRACK_ENGINE, tg0,
                             time.perf_counter())
        rem_before = self._rem.copy()
        if not (rem_before > 0).any():
            self._log("decode", pf.token, 0)
            return ("cycle", self._collect_finished())
        n = self.decode_chunk
        t1 = time.perf_counter()
        if self.paged:
            pkv, tok, ln, rm, toks = self._decode_paged(
                self.params, self._pkv, self._tables_dev,
                jnp.asarray(self._lengths), jnp.asarray(self._last),
                jnp.asarray(self._rem), n=n)
            self._pkv = pkv
        else:
            st, tok, ln, rm, toks = self._decode_slots(
                self.params, self._sstate, jnp.asarray(self._last),
                jnp.asarray(self._lengths), jnp.asarray(self._rem), n=n)
            self._sstate = st
        t1b = time.perf_counter()      # carry uploads + launch: device idle
        toks = np.asarray(toks)        # (B, n): the chunk's device sync
        t2a = time.perf_counter()
        # np.array (not asarray): device views are read-only and these
        # mirrors are mutated by the next cycle's merge
        self._last = np.array(tok)
        self._lengths = np.array(ln)
        self._rem = np.array(rm)
        t2 = time.perf_counter()
        emitted = 0
        for b in np.nonzero(rem_before > 0)[0]:
            k = int(min(n, rem_before[b]))
            self._slot_out[b].extend(toks[b, :k].tolist())
            emitted += k
        with self._state_lock:
            self.stats["decode_cycles"] += 1
            self.stats["tokens_out"] += emitted
        retire = self._collect_finished()
        t3 = time.perf_counter()
        o = self.overlap_stats
        o["cycles"] += 1
        # dispatch_s here = mirror uploads + launch; under CPU contention
        # the chunk starts computing mid-interval, so it is EXCLUDED from
        # the gap (conservative: the true sync gap is larger)
        o["dispatch_s"] += t1b - t1
        o["wait_s"] += t2a - t1b
        o["book_s"] += (t1 - t0) + (t2 - t2a) + (t3 - t2)
        # sync-mode host gap: pre-work, the mirror download copies and all
        # bookkeeping run with nothing queued on the device — the gap the
        # async mode exists to close
        o["gap_s"] += (t1 - t0) + (t2 - t2a) + (t3 - t2)
        o["total_s"] += t3 - t0
        chunk_s = t2a - t1             # upload + launch + block: the device
        if o["min_chunk_s"] == 0.0 or chunk_s < o["min_chunk_s"]:
            o["min_chunk_s"] = chunk_s  # cleanest (least contended) sample
        if self._mh is not None:
            mh = self._mh
            mh["cycle"].record(t3 - t0)
            mh["dispatch"].record(t1b - t1)
            mh["sync"].record(t2a - t1b)
            mh["book"].record((t1 - t0) + (t2 - t2a) + (t3 - t2))
            mh["gap"].record((t1 - t0) + (t2 - t2a) + (t3 - t2))
            mh["chunk"].record(chunk_s)
            mh["tokens_out"].inc(emitted)
        if self._tr is not None:
            tr = self._tr
            tr.add("cycle", TRACK_ENGINE, t0, t3, {"emitted": emitted})
            tr.add("dispatch", TRACK_ENGINE, t1, t1b)
            tr.add("sync", TRACK_ENGINE, t1b, t2a)
            tr.add("bookkeeping", TRACK_ENGINE, t2a, t3)
        self._log("decode", pf.token, emitted)
        return ("cycle", retire)

    def _st_decode_async(self, pf, msg):
        """Async decode lookahead (pipeline depth 2): dispatch chunk N+1
        FIRST — JAX async dispatch queues it behind the in-flight chunk N,
        so the device-side dependency chain never drains — then sync chunk
        N's tokens and do all host bookkeeping (emit tokens, retire
        finished rows, advance the deferred-free fence) while N+1 runs.
        Admission merges, streamed prefill windows and table growth are
        sequenced BEFORE the dispatch; retirement takes effect one chunk
        late (already masked on device by ``rem == 0``); a preempted row's
        in-flight tokens are discarded via the seat-generation guard."""
        t0 = time.perf_counter()
        kind, payload = msg
        pend = self._pending
        device_idle = (pend is None or bool(pend["toks"].is_ready())) \
            and self._window_pending is None
        # ---- pre-dispatch: everything chunk N+1 must observe ----
        wpend, self._window_pending = self._window_pending, None
        if wpend is not None:
            self._finish_window(wpend)
        if kind == "admit":
            if self.paged:
                self._merge_group(payload)
            else:
                self._merge_group_slots(payload)
        if self.paged:
            tg0 = time.perf_counter()
            self._cow_guard(pf)
            self._window_pending = self._dispatch_window_prefill(pf)
            self._grow_or_preempt(pf)
            if self._tr is not None:
                self._tr.add("growth", TRACK_ENGINE, tg0,
                             time.perf_counter())
        # ---- dispatch chunk N+1 (the device never waits on the host
        # bookkeeping below) ----
        n = self.decode_chunk
        new_pend = None
        t1 = time.perf_counter()
        if (self._rem > 0).any():
            rem_before = self._rem.copy()
            if self.paged:
                pkv, tok, ln, rm, toks = self._decode_paged(
                    self.params, self._pkv, self._tables_dev,
                    *self._carry, n=n)
                self._pkv = pkv
            else:
                lengths, last, rem = self._carry
                st, tok, ln, rm, toks = self._decode_slots(
                    self.params, self._sstate, last, lengths, rem, n=n)
                self._sstate = st
            self._carry = (ln, tok, rm)
            # advance the host lengths/rem mirrors deterministically (the
            # chunk's length/rem arithmetic is token-independent); the
            # host `last` mirror stays stale — it is never read in async
            # mode, the device carry is authoritative
            adv = np.minimum(n, rem_before)
            self._lengths += adv
            self._rem -= adv
            new_pend = {"toks": toks, "rem_before": rem_before,
                        "gen": self._slot_gen.copy(), "token": pf.token}
            with self._state_lock:
                self.stats["decode_cycles"] += 1
            self._log("dispatch", pf.token, int((rem_before > 0).sum()))
        t2 = time.perf_counter()
        # ---- sync chunk N + host bookkeeping (overlaps N+1 on device) ----
        emitted = 0
        wait_s = 0.0
        if pend is not None:
            ts = time.perf_counter()
            toks = np.asarray(pend["toks"])
            wait_s = time.perf_counter() - ts
            for b in np.nonzero(pend["rem_before"] > 0)[0]:
                if self._slot_gen[b] != pend["gen"][b]:
                    continue    # seat changed since dispatch: surplus tokens
                k = int(min(n, pend["rem_before"][b]))
                self._slot_out[b].extend(toks[b, :k].tolist())
                emitted += k
            with self._state_lock:
                self.stats["tokens_out"] += emitted
            self._log("sync", pf.token, (pend["token"], emitted))
        self._pending = new_pend
        retire = self._collect_finished()
        if self.paged and (pend is not None or (
                new_pend is None and self._window_pending is None)):
            # fence advance: a chunk was synced (or nothing is in flight
            # at all) — blocks deferred two advances ago are now provably
            # past every device write that could touch them
            self._pool.release_deferred()
        t3 = time.perf_counter()
        o = self.overlap_stats
        o["cycles"] += 1
        o["dispatch_s"] += t2 - t1
        o["wait_s"] += wait_s
        o["book_s"] += (t1 - t0) + (t3 - t2 - wait_s)
        gap = 0.0
        if device_idle:
            gap += t1 - t0          # nothing in flight during pre-dispatch
        if new_pend is None:
            gap += t3 - t2 - wait_s  # nothing in flight during bookkeeping
        o["gap_s"] += gap
        o["total_s"] += t3 - t0
        if self._mh is not None:
            mh = self._mh
            mh["cycle"].record(t3 - t0)
            mh["dispatch"].record(t2 - t1)
            mh["sync"].record(wait_s)
            mh["book"].record((t1 - t0) + (t3 - t2 - wait_s))
            mh["gap"].record(gap)
            mh["tokens_out"].inc(emitted)
        if self._tr is not None:
            tr = self._tr
            tr.add("cycle", TRACK_ENGINE, t0, t3, {"emitted": emitted})
            if new_pend is not None:
                tr.add("dispatch", TRACK_ENGINE, t1, t2)
            if pend is not None:
                tr.add("sync", TRACK_ENGINE, ts, ts + wait_s)
            tr.add("bookkeeping", TRACK_ENGINE, t2, t3)
        self._log("decode", pf.token, emitted)
        return ("cycle", retire)

    def _collect_finished(self) -> List[tuple]:
        """Rows that hit rem==0: detach them from the batch (their slot
        stays reserved until complete frees it) and zero their mirrors —
        still inside the SERIAL decode stage (single-writer); the
        gather-free read paths bound their page loop by max(lengths), so a
        retired slot must not keep advertising its old length.

        Async mode retires one chunk LATE by construction: a row that hit
        ``rem == 0`` during chunk N is collected only after N's sync —
        rows still finishing inside the freshly dispatched chunk (or
        stalled behind the deferred-free fence) are skipped, and the
        zeroing scatters land on the in-flight chunk's OUTPUT carry/
        tables (the retired rows are already inactive in that chunk), so
        the detach never races device work."""
        pend = self._pending
        retire = []
        zero_rows = []
        for b in range(len(self._rem)):
            if self._slot_req[b] is None or self._slot_phase[b] != "decode" \
                    or self._rem[b] != 0:
                continue
            if self.paged and self.async_decode and self._stall_rem[b] > 0:
                continue        # stalled behind the fence, not finished
            if pend is not None and pend["rem_before"][b] > 0:
                continue        # active in the in-flight chunk: next cycle
            req = self._slot_req[b]
            out = np.asarray(self._slot_out[b], np.int32)
            with self._state_lock:
                self._slot_req[b] = None
                self._slot_out[b] = None
                self._slot_phase[b] = None
            self._slot_gen[b] += 1
            self._lengths[b] = 0
            self._last[b] = 0
            if self.paged:
                self._tables[b] = 0
                self._pref_pos[b] = 0
                self._slot_prompt[b] = None
            zero_rows.append(b)
            retire.append((b, req, out))
            if self._tr is not None:
                _t = time.perf_counter()
                self._phase_end(b, _t, req)
                self._tr.instant("retired", f"slot{b}", _t,
                                 {"req": req.id, "tokens": len(out)})
        if zero_rows:
            # fixed-shape zeroing scatters (pad with repeats; idempotent)
            B = len(self._slot_req)
            z = [0] * len(zero_rows)
            if self.async_decode:
                self._scatter_carry(zero_rows, z, z, z, pad_to=B)
            if self.paged:
                rows = zero_rows + [zero_rows[-1]] * (B - len(zero_rows))
                self._tables_dev = self._set_rows(
                    self._tables_dev, jnp.asarray(rows, jnp.int32),
                    jnp.zeros((B, self._tables.shape[1]), jnp.int32))
        return retire

    def _st_complete(self, pf, msg):
        _, retire = msg
        now = time.perf_counter()
        for slot, req, out in retire:
            self._scheduler.finish(req, out, now)
            with self._state_lock:
                if self.paged:
                    self._pool.free(self._slot_blocks[slot])
                    self._slot_blocks[slot] = None
                self._free_slots.append(slot)
                self._inflight.discard(req)
                self.stats["retired"] += 1
        with self._state_lock:
            self._cycle_tokens.discard(pf.token)
        if retire and self._mh is not None:
            self._mh["retired"].inc(len(retire))
            self._note_resident()
        self._log("complete", pf.token, len(retire))
        return None

    # --------------------------------------------------------------- pumping
    def _pump(self) -> None:
        ex = self._ensure_executor()
        pl = self._ensure_pipeline(ex)
        with self._pump_lock:
            if self._broken is not None or not pl.idle():
                return
            with self._state_lock:
                occupied = any(r is not None for r in self._slot_req)
            if self._scheduler.num_waiting == 0 and not occupied:
                return
            self._topo = pl.run(ex, self._on_topo_done)

    def _on_topo_done(self, topo) -> None:
        if topo.exceptions:
            err = topo.exceptions[0]
            self._broken = err
            self._fail_outstanding(err)
            return
        if self._scheduler.num_waiting:
            self._pump()   # a submit raced the stop-drain: re-arm

    def _fail_outstanding(self, err: BaseException) -> None:
        self._scheduler.fail_all_waiting(err)
        with self._state_lock:
            live = list(self._inflight)  # admitted: slotted or pre-merge
            self._inflight.clear()
        for r in live:
            r.set_error(err)

    # ----------------------------------------------------------- client API
    def submit(self, prompt, max_new: int = 16) -> ServeRequest:
        """Enqueue one generation request on the resident pipeline and
        return its future. Thread-safe; callable while earlier requests are
        mid-decode — that is the point. All architectures: paged attention
        KV for dense/MoE models, the fixed-slot recurrent-state pool for
        SSM/hybrid ones."""
        if self._broken is not None:
            raise RuntimeError("serve pipeline is broken") from self._broken
        if self._closing:
            raise RuntimeError("engine is closed")
        req = ServeRequest(prompt, max_new)
        total = req.prompt_len + req.max_new
        if total > self._max_seq:
            raise ValueError(
                f"prompt+max_new = {total} exceeds max_seq_len "
                f"{self._max_seq}")
        req.submitted_at = time.perf_counter()
        self._scheduler.enqueue(req)
        self._pump()
        return req

    def result(self, req: ServeRequest,
               timeout: Optional[float] = 300.0) -> np.ndarray:
        return req.result(timeout)

    def generate(self, prompts: List[Any], max_new: int) -> List[Any]:
        """Compatibility shim: submit every prompt, gather results in input
        order. Greedy tokens are bit-identical to the per-call engine this
        replaces (same compiled prefill math, same argmax chain — verified
        against the contiguous reference in tests)."""
        if not prompts:
            return []
        reqs = [self.submit(p, max_new) for p in prompts]
        return [self.result(r, timeout=600.0) for r in reqs]

    # -------------------------------------------- per-call baseline (bench)
    def _generate_grouped(self, prompts: List[Any], max_new: int
                          ) -> List[Any]:
        """PR 1's per-call pipeline: length groups flow admit -> prefill ->
        chunked contiguous decode -> complete through a throwaway
        DataPipeline. No longer a serving fallback (submit()/result() covers
        every arch through the resident pipeline); kept as the per-call
        BASELINE the serve benchmark compares against and as a bit-identity
        reference in tests."""
        groups: "OrderedDict[int, List[int]]" = OrderedDict()
        arrs = [np.asarray(p, np.int32) for p in prompts]
        for i, a in enumerate(arrs):
            groups.setdefault(len(a), []).append(i)
        work = deque(groups.values())
        results: List[Any] = [None] * len(prompts)

        def admit(pf):
            if not work:
                pf.stop()
                return None
            return work.popleft()

        def prefill(pf, idxs):
            toks = np.stack([arrs[i] for i in idxs])
            max_len = toks.shape[1] + max_new + 1
            logits, cache = self._prefill(self.params, jnp.asarray(toks),
                                          None, max_len=max_len)
            cur = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            return idxs, cache, cur

        def decode(pf, state):
            idxs, cache, cur = state
            chunks = [cur[:, None]]
            remaining = max_new - 1
            while remaining > 0:
                n = min(self.decode_chunk, remaining)
                cache, chunk = self._decode_n(self.params, cache, cur, n)
                chunks.append(chunk)
                cur = chunk[:, -1]
                remaining -= n
            return idxs, chunks

        def complete(pf, state):
            idxs, chunks = state
            seqs = np.concatenate([np.asarray(c) for c in chunks], axis=1)
            for row, i in enumerate(idxs):  # rows scatter to disjoint slots
                results[i] = seqs[row]
            return None

        ex = self._ensure_executor()
        decode_domain = ACCEL if ex.has_domain(ACCEL) else HOST
        pl = DataPipeline(
            max(1, min(len(work), self.pipeline_lines)),
            DataPipe(PipeType.SERIAL, admit, name="admit"),
            DataPipe(PipeType.SERIAL, prefill, name="prefill"),
            DataPipe(PipeType.SERIAL, decode, name="decode",
                     domain=decode_domain),
            DataPipe(PipeType.PARALLEL, complete, name="complete"),
            name="serve-generate")
        pl.run(ex).wait()
        return results
