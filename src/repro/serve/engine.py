"""Continuously-batched serving engine: a RESIDENT 4-stage pipeline fed by
a request queue, with TWO-PHASE memory admission.

PR 2 kept ONE cyclic :class:`repro.pipeline.DataPipeline` alive for the
life of the engine; PR 3 made the paged *read* path occupancy-proportional.
This revision makes the *write/admission* half follow live token counts
too — the Taskflow memory thesis (resources follow control flow
incrementally, not worst-case up front) applied to KV admission:

    admit (SERIAL)    -> pop an admission group from ONE FIFO (no length
                         buckets: chunked prefill makes per-window shapes
                         uniform, so mixed-length groups admit together) and
                         allocate its PROMPT-ONLY block footprint; park via
                         ``pf.defer(token)`` when the head does not fit, or
                         emit a plain decode-pump cycle
    prefill (SERIAL)  -> one compiled launch for the group's FIRST prompt
                         window (fixed window size, prompts right-padded);
                         SSM/hybrid archs prefill each member's whole prompt
                         here instead (recurrent state is O(1)/sequence)
    decode (SERIAL,   -> merge the group (scatter window-0 KV / recurrent
      accel domain)      state into the pool, assign slots), stream ONE more
                         prefill window for every mid-prefill row, grow
                         block tables lazily for rows about to cross a block
                         boundary (preempting the youngest row on pool
                         exhaustion), then advance every decoding row by one
                         compiled chunk of ``decode_chunk`` steps
    complete (PARALLEL)-> retire rows that just finished: fulfil their
                         request futures, free their blocks/slots — per
                         sequence, WITHOUT draining the pipeline

Two-phase admission
-------------------
*Phase 1 (admit):* a request is admitted when the pool covers its PROMPT
KV footprint — not ``prompt + max_new``. *Phase 2 (grow):* every
``block_size`` decode tokens, the decode stage grants the row one more
block (``BlockPool.grow_table`` + a device-side table-extension scatter);
on pool exhaustion it preempts the YOUNGEST resident row back onto the
wait queue (its blocks freed, its request re-queued at the head) instead
of deadlocking. Long prompts are *chunked*: window 0 lands via the prefill
stage, the rest stream through the decode stage one fixed-size window per
cycle, scattered straight into the paged pool — resident rows keep
decoding in the overlapped cycles.

The KV pool and the block-table array are written ONLY by the SERIAL
decode stage, so pool updates stay single-writer by construction; the
block table is device-resident across cycles (growth is an in-place
scatter, not a re-upload). The compiled chunk reads the pool gather-free
(``paged_impl``: the Pallas kernel or its XLA page-loop lowering, see
:mod:`repro.serve`).

Async decode lookahead (``async_decode=`` / ``REPRO_ASYNC_DECODE``)
-------------------------------------------------------------------
The synchronous decode stage blocks on every chunk's tokens and runs all
grow/preempt/retire/admit bookkeeping while the device idles. With
``async_decode=True`` the stage is split into **dispatch -> sync** at a
pipeline depth of 2:

* the decode carry (``lengths``, ``last``, ``rem``) is DEVICE-RESIDENT
  across cycles, alongside the block tables: chunk N+1 consumes chunk N's
  output carry directly, so the device-side dependency chain never waits
  on the host. Merge/retire/preempt mutate the carry through the same
  fixed-shape padded scatters the table array uses
  (:func:`repro.serve.kvcache.set_carry_rows`); the host keeps exact
  ``lengths``/``rem`` mirrors by pure arithmetic (chunk advance is
  token-independent) while ``last`` lives only on device.
* each cycle dispatches chunk N+1 FIRST (JAX async dispatch queues it
  behind N), then syncs chunk N's tokens and does every piece of host
  bookkeeping — emit tokens, collect finished rows, stream prefill
  windows, grow tables — while N+1 runs on device. Admission scatters,
  window launches and growth scatters are sequenced BEFORE the dispatch.

The new scheduling hazards this opens are closed explicitly:

* **retirement is one chunk late**: a row that exhausts ``rem`` during
  chunk N stays seated through N+1 — masked on device by ``rem == 0``
  (KV writes go to the sink) — and detaches after N's sync; tokens a
  chunk computed for a row whose seat changed since dispatch (preempted,
  retired, re-seated) are discarded host-side via a per-slot seat
  generation.
* **deferred-free fence**: a preempted row's blocks may still be written
  by the chunk in flight at preemption time (and by the prefill window
  launched the same cycle), so :meth:`repro.serve.kvcache.BlockPool
  .free_deferred` parks them — invisible to allocation — until the
  engine has synced past that device work (two fence advances).
* **prefill-window completion is deferred one cycle**: the window launch
  precedes the next chunk on the pool's dependency chain, so reading its
  first-token logits a cycle later never stalls the loop behind the
  in-flight chunk.

Greedy tokens are bit-identical to the synchronous engine (same compiled
chunk program, same carry values — asserted on the ``gather`` oracle in
``tests/test_serve_async.py``); the synchronous path remains the
reference. ``self.overlap_stats`` tracks the per-cycle dispatch / wait /
bookkeeping / host-gap breakdown that
``benchmarks/decode_overlap_microbench.py`` reports.

SSM / hybrid architectures (mamba, zamba2) serve through the SAME
resident pipeline via a fixed-slot recurrent-state pool: prefilled
``(conv, h)`` states (plus zamba2's shared-block KV span) are scattered
into a per-slot pool, rows decode side by side at per-row positions
(:func:`repro.models.lm.decode_step_slots`), and slots free at
retirement. The old per-call grouped fallback is retired from
``submit()``/``generate()`` and survives only as the benchmark baseline
(:meth:`ServeEngine._generate_grouped`).

Client API: :meth:`submit` returns a :class:`ServeRequest` future;
:meth:`ServeRequest.result` blocks for the tokens. :meth:`generate` remains
as a thin compatibility shim over submit/result (greedy tokens bit-identical
to the per-call engine it replaces).

The pipeline goes idle (stop-drain) when no requests are waiting or
running; ``submit()`` re-arms it without rebuilding the task graph
(:meth:`repro.pipeline.Pipeline.run` on the same resident grid). A failure
inside any stage cancels the topology, fails every outstanding request
future (``result()`` raises instead of deadlocking) and marks the engine
broken.
"""
from __future__ import annotations

import os
import threading
import time
from collections import OrderedDict, deque
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from ..configs.base import ModelConfig
from ..core import ACCEL, HOST, Executor
from ..distributed.sharding import (ShardCtx, manual_serve_map,
                                    serve_attn_sharded, serve_kv_cache_spec,
                                    serve_param_shardings, serve_pool_spec,
                                    serve_tp_size, use_shard_ctx,
                                    validate_serve_mesh)
from ..models import lm
from ..obs import TRACK_ENGINE
from ..obs import from_env as _obs_from_env
from ..pipeline import DataPipe, DataPipeline, PipeType
from .errors import (DeadlineExceeded, EngineClosed, Overloaded,
                     RequestCancelled, RowFailed, SnapshotCorrupt,
                     WatchdogTimeout)
from .faultinject import FaultInjected, FaultInjector
from .journal import Journal, replay as replay_journal
from .kvcache import (SINK_BLOCK, BlockPool, copy_blocks,
                      extend_block_tables, init_kv_pool,
                      scatter_prefill_rows, set_carry_rows, set_table_rows)
from .prefix import PrefixCache
from .scheduler import Scheduler, ServeRequest
from .snapshot import corrupt_snapshot, read_snapshot, write_snapshot

__all__ = ["ServeEngine", "ServeRequest", "JOURNAL_FILE", "SNAPSHOT_FILE"]

#: File names ``recover()`` / ``launch.serve --state-dir`` use inside a
#: state directory.
JOURNAL_FILE = "journal.wal"
SNAPSHOT_FILE = "engine.snap"


def _env_mesh_ctx(cfg: ModelConfig) -> Optional[ShardCtx]:
    """Resolve ``REPRO_MESH_MODEL=N`` into a serve :class:`ShardCtx` (or
    None for single-device). The requested model-axis size is CLAMPED —
    first to the local device count, then down to the largest size that
    divides the config's KV-head/head/feature counts — so the env knob is
    safe to export across a whole test matrix of configs. An explicit
    ``ctx=`` argument to :class:`ServeEngine` is never clamped: an
    indivisible mesh there raises
    :class:`repro.distributed.sharding.MeshDivisibilityError`.

    SSM/hybrid configs have no KV heads to partition, so the env path
    leaves them on a single device (their slot state is replicated by
    construction anyway)."""
    env = os.environ.get("REPRO_MESH_MODEL", "").strip()
    if not env:
        return None
    mp = min(int(env), jax.device_count())
    if cfg.ssm or cfg.hybrid_attn_every:
        return None
    while mp > 1 and not serve_attn_sharded(cfg, mp):
        mp -= 1
    if mp <= 1:
        return None
    from ..launch.mesh import make_ctx, small_mesh
    return make_ctx(small_mesh(data=1, model=mp))


class ServeEngine:
    """Resident continuous-batching engine (see module docstring).

    Parameters
    ----------
    ctx:
        a :class:`repro.distributed.sharding.ShardCtx` over a device mesh
        with a ``model`` axis: the paged KV pool and the attention/MLP
        projection weights are partitioned by KV head / output column
        across it and every compiled step runs under ``shard_map``
        (greedy tokens stay BIT-IDENTICAL to the single-device engine —
        the tensor-parallel decomposition only ever concatenates
        column slices, see ``docs/sharded_serving.md``). A model-axis
        size that does not divide the config's KV-head/head/feature
        counts raises a typed :class:`repro.distributed.sharding
        .MeshDivisibilityError`. None resolves via the
        ``REPRO_MESH_MODEL`` env var (clamped to the device count and
        the largest divisible size; default single-device).
    decode_chunk:
        decode steps per compiled chunk launch — also the admission
        granularity (sequences join/leave at chunk boundaries).
    prefill_chunk:
        prompt tokens per prefill window. A prompt longer than this
        prefills across multiple pipeline cycles (window 0 in the prefill
        stage, the rest streamed by the decode stage) while resident rows
        keep decoding. Defaults to ``decode_chunk * block_size``. Paged
        (attention) archs only; SSM/hybrid prompts prefill whole.
    max_batch:
        decode slot count; the compiled chunk program always runs this many
        rows (inactive rows are masked), so batch composition changes never
        recompile.
    kv_blocks / block_size:
        paged KV pool geometry. Block 0 is the reserved sink.
    max_admit:
        cap on requests admitted per cycle (one prefill launch).
    max_seq_len:
        per-sequence cap on ``prompt + max_new`` (sets the block-table
        width; for zamba2 it sizes the shared-block KV span per slot).
        Defaults to 32 blocks worth, clamped to the pool size (512 for
        SSM/hybrid).
    paged_impl:
        attention read path of the compiled decode chunk: ``"pallas"``
        (gather-free Pallas kernel, Mosaic on TPU), ``"xla"`` (gather-free
        traced-bound page loop), or ``"gather"`` (materializing reference
        oracle). None resolves via
        :func:`repro.kernels.ops.default_paged_impl` (honors the
        ``REPRO_PAGED_IMPL`` env var; pallas on TPU, xla elsewhere).
    async_decode:
        pipeline the decode loop one chunk deep: the carry stays
        device-resident, chunk N+1 is dispatched before chunk N's tokens
        are synced, and all host bookkeeping overlaps device compute (see
        the module docstring). None resolves via the ``REPRO_ASYNC_DECODE``
        env var (default off — the synchronous path is the reference).
    prefix_cache:
        share KV blocks across requests with a common prompt prefix: full
        prompt chunks are indexed in a :class:`repro.serve.prefix
        .PrefixCache` trie, cache-hit admissions seed their block table
        with the shared (refcount-pinned) blocks and budget/prefill only
        their uncached suffix, a shared tail block is copy-on-write forked
        before the first divergent write, and under pool pressure cold
        PARKED prefix blocks are evicted by reuse score before any
        resident row is preempted (see ``docs/prefix_caching.md``). None
        resolves via the ``REPRO_PREFIX_CACHE`` env var (default off —
        the uncached path is the bit-exact reference). Paged
        (attention) archs only; ignored for SSM/hybrid models.
    tier_targets:
        per-priority-tier guaranteed minimum share of each admission
        cycle (``{tier: share}``, see :class:`repro.serve.scheduler
        .Scheduler`) — the anti-starvation floor for best-effort tiers
        under sustained SLO-tier load.
    shed_budget_s:
        load-shedding latency budget: a float applies one queue-wait
        budget to every tier, a dict maps ``{tier: budget_s}`` (tiers
        absent from the dict are never shed). ``submit()`` rejects with
        a typed :class:`repro.serve.errors.Overloaded` when the
        estimated queue wait exceeds the budget (or the request's own
        ``deadline_s``, making it unreachable before it ever queues).
        The estimate is a SERVICE-RATE model: observed decode
        throughput (EWMA tokens/s over engine cycles) divides the
        resident rows' remaining decode work plus the tier-visible
        waiting ``max_new`` backlog. Until the engine has emitted its
        first tokens it falls back to the p90 of the live
        ``serve.queue_wait_s`` histogram scaled by the backlog (armed
        after 8 recorded admissions — a cold-start engine never
        sheds); the fallback needs ``obs``, the rate model does not.
        None resolves via the ``REPRO_SHED_BUDGET_S`` env var (a
        float; default off).
    watchdog_s:
        engine watchdog budget in seconds: a daemon thread fails every
        in-flight/waiting future with a diagnostic
        :class:`repro.serve.errors.WatchdogTimeout` when a busy engine
        makes no cycle progress for this long (a wedged device sync, a
        deadlocked stage). 0/None = off; None resolves via the
        ``REPRO_WATCHDOG_S`` env var.
    fault_inject:
        a :class:`repro.serve.faultinject.FaultInjector` (or its spec
        string) injecting deterministic seeded faults at named engine
        sites — see :mod:`repro.serve.faultinject` for the grammar and
        sites. None resolves via the ``REPRO_FAULT_INJECT`` env var
        (default off).
    record_stages:
        keep an in-memory (stage, cycle-token, info, t) event log — the
        observer hook the overlap tests read.
    obs:
        a :class:`repro.obs.Observability` (tracer + metrics registry).
        The engine records request lifecycle spans on per-slot tracks,
        engine-cycle phase spans on the ``"engine"`` track, and the
        counters/gauges/histograms listed in :mod:`repro.serve`'s
        observability section. None resolves via the ``REPRO_OBS`` env
        var (default off — the disabled path costs attribute checks
        only). Rebindable at idle via :meth:`set_obs`.
    """

    def __init__(self, cfg: ModelConfig, params,
                 ctx: Optional[ShardCtx] = None,
                 decode_chunk: int = 8,
                 prefill_chunk: Optional[int] = None,
                 executor: Optional[Executor] = None,
                 pipeline_lines: int = 3,
                 max_batch: int = 8,
                 kv_blocks: int = 128,
                 block_size: int = 16,
                 max_admit: int = 4,
                 max_seq_len: Optional[int] = None,
                 paged_impl: Optional[str] = None,
                 async_decode: Optional[bool] = None,
                 prefix_cache: Optional[bool] = None,
                 tier_targets: Optional[Dict[int, float]] = None,
                 shed_budget_s=None,
                 watchdog_s: Optional[float] = None,
                 fault_inject=None,
                 journal=None,
                 record_stages: bool = False,
                 obs=None):
        self.cfg = cfg
        if ctx is None:
            ctx = _env_mesh_ctx(cfg)       # REPRO_MESH_MODEL, clamped
        self.ctx = ctx or ShardCtx(mesh=None)
        #: model-axis (tensor-parallel) size of the serve mesh; 1 = the
        #: single-device reference engine
        self._tp = serve_tp_size(self.ctx)
        if self._tp > 1:
            # an explicit indivisible mesh is a typed error, not a clamp
            validate_serve_mesh(cfg, self._tp)
        #: True when the paged KV pool is partitioned over the model axis
        #: (attention archs on a >1 mesh); SSM/hybrid state is replicated
        self._pool_sharded = self.ctx.mesh is not None \
            and serve_attn_sharded(cfg, self._tp)
        if self.ctx.mesh is not None:
            self._repl_ns = NamedSharding(self.ctx.mesh, P())
            # KV-head-partitioned attention + column-sharded MLP weights;
            # every other leaf (embeddings, norms, router, ...) replicated
            self.params = jax.device_put(
                params, serve_param_shardings(cfg, params, self.ctx))
        else:
            self._repl_ns = None
            self.params = params
        self.decode_chunk = decode_chunk
        self.pipeline_lines = pipeline_lines
        self._executor = executor
        self._own_executor = False
        self._prefill = jax.jit(self._prefill_impl,
                                static_argnames=("max_len",))
        self._decode_n = jax.jit(self._decode_n_impl,
                                 static_argnames=("n",),
                                 donate_argnums=(1,))

        #: continuous batching pages the attention KV cache; SSM/hybrid
        #: recurrent state is O(1)/seq and lives in a fixed-slot state pool
        self.paged = not (cfg.ssm or cfg.hybrid_attn_every)
        from ..kernels.ops import PAGED_IMPLS, default_paged_impl
        if paged_impl is not None and paged_impl not in PAGED_IMPLS:
            raise ValueError(f"paged_impl={paged_impl!r}: expected one of "
                             f"{PAGED_IMPLS} (or None for the default)")
        #: read path of the compiled decode chunk; None on non-paged archs
        self.paged_impl = (paged_impl or default_paged_impl()) \
            if self.paged else None
        if async_decode is None:
            async_decode = os.environ.get("REPRO_ASYNC_DECODE", "") \
                .strip().lower() in ("1", "true", "yes", "on")
        #: dispatch->sync pipelined decode loop (depth 2); False = the
        #: synchronous reference path
        self.async_decode = bool(async_decode)
        if prefix_cache is None:
            prefix_cache = os.environ.get("REPRO_PREFIX_CACHE", "") \
                .strip().lower() in ("1", "true", "yes", "on")
        #: cross-request KV block sharing (paged archs only); False = the
        #: uncached bit-exact reference path
        self.prefix_cache = bool(prefix_cache) and self.paged
        self._closing = False
        # graceful drain: set by drain() — admission stops, residents run
        # to completion; past _drain_deadline_at the decode stage
        # checkpoint-preempts every resident so close() can fail the
        # requeued work typed instead of hanging on it
        self._draining = False
        self._drain_deadline_at: Optional[float] = None
        # request WAL (durability boundary #1, off by default): a
        # repro.serve.journal.Journal or a path string; every lifecycle
        # transition appends one checksummed record. The None path is one
        # `is None` check per transition — bit-exact unchanged.
        if isinstance(journal, str):
            journal = Journal(journal)
        self._journal: Optional[Journal] = journal
        self._broken: Optional[BaseException] = None
        self._stage_log = [] if record_stages else None
        self._log_lock = threading.Lock()

        # deterministic fault injection (param > env; see faultinject.py)
        if fault_inject is None:
            fault_inject = os.environ.get("REPRO_FAULT_INJECT") or None
        if isinstance(fault_inject, str):
            fault_inject = FaultInjector.parse(fault_inject)
        self._fi: Optional[FaultInjector] = fault_inject
        # load-shedding budget: float (all tiers) or {tier: budget_s}
        if shed_budget_s is None:
            env = os.environ.get("REPRO_SHED_BUDGET_S", "").strip()
            shed_budget_s = float(env) if env else None
        self._shed_budget = shed_budget_s
        if watchdog_s is None:
            env = os.environ.get("REPRO_WATCHDOG_S", "").strip()
            watchdog_s = float(env) if env else 0.0
        self._watchdog_s = float(watchdog_s or 0.0)
        # service-rate load-shed model: EWMA of observed decode throughput
        # (emitted tokens per engine-cycle wall second), updated at every
        # chunk sync. 0.0 until the first tokens are emitted — the shed
        # estimator falls back to the p90-queue-wait heuristic until then.
        self._decode_rate = 0.0
        self._rate_alpha = 0.3

        B = max_batch
        self._scheduler = Scheduler(max_admit=max_admit,
                                    tier_targets=tier_targets)
        self._scheduler.on_event = self._sched_event
        # slot state: written by the SERIAL decode stage (merge/window/grow/
        # step) and the complete stage (free) under _state_lock; admit only
        # reads counts
        self._lengths = np.zeros((B,), np.int32)   # KV/state tokens written
        self._rem = np.zeros((B,), np.int32)       # decode steps remaining
        self._last = np.zeros((B,), np.int32)      # last emitted token
        # DEVICE-RESIDENT decode carry (lengths, last, rem): in async mode
        # chunk N+1 consumes chunk N's output carry directly (merge/grow/
        # retire/preempt mutate it via fixed-shape scatters) and the host
        # mirrors above are maintained deterministically — lengths/rem
        # arithmetic is token-independent, `last` is refreshed lazily from
        # synced chunk outputs. The sync path uploads the mirrors instead.
        self._carry = (self._dev(np.zeros((B,), np.int32)),
                       self._dev(np.zeros((B,), np.int32)),
                       self._dev(np.zeros((B,), np.int32)))
        self._set_carry = jax.jit(set_carry_rows)
        # seat generation per slot, bumped on every seat/retire/preempt:
        # guards late token emission in async mode (a synced chunk's tokens
        # only land on the seat they were computed for)
        self._slot_gen = np.zeros((B,), np.int64)
        self._pending: Optional[Dict[str, Any]] = None   # in-flight chunk
        self._window_pending: Optional[Dict[str, Any]] = None
        #: per-decode-cycle wall-time breakdown (all modes): dispatch_s =
        #: chunk launch, wait_s = blocking device sync, book_s = host
        #: bookkeeping, gap_s = host time with NO device work in flight
        #: (the host gap the async mode exists to close)
        #: ``min_chunk_s`` is the cleanest observed upload+launch+block
        #: interval of a sync-mode cycle — the microbench's device-time
        #: calibration constant (0 until a sync chunk has run)
        self.overlap_stats = {"cycles": 0, "dispatch_s": 0.0, "wait_s": 0.0,
                              "book_s": 0.0, "gap_s": 0.0, "total_s": 0.0,
                              "min_chunk_s": 0.0}
        self._slot_req: List[Optional[ServeRequest]] = [None] * B
        self._slot_out: List[Optional[List[int]]] = [None] * B
        self._slot_phase: List[Optional[str]] = [None] * B  # prefill|decode
        self._free_slots = list(range(B - 1, -1, -1))
        self._slots_reserved = 0       # admitted but not yet merged
        self._inflight: set = set()    # admitted, not yet retired (failure
        #                                cleanup: these must see set_error)
        self._cycle_tokens: set = set()  # cycles minted and not yet completed
        # admitted groups not yet seated, keyed by cycle token: failure
        # isolation clears this so a stale group (admitted against the
        # pre-reset pool) is dropped at the merge instead of seating with
        # dead block ids
        self._premerge: Dict[int, List[ServeRequest]] = {}
        # bumped by every failure-isolation reset: retire payloads from an
        # older epoch must not free blocks / slots against the fresh state
        self._reset_epoch = 0
        self._state_lock = threading.Lock()
        self._pump_lock = threading.Lock()
        self._topo = None
        self._pipeline: Optional[DataPipeline] = None
        self.stats = {"admitted": 0, "admit_parks": 0, "pump_cycles": 0,
                      "decode_cycles": 0, "prefills": 0,
                      "prefill_windows": 0, "tokens_out": 0, "retired": 0,
                      "grown_blocks": 0, "preempted": 0, "stalls": 0,
                      "prefix_hits": 0, "prefix_tokens_saved": 0,
                      "cow_forks": 0, "shed": 0, "expired": 0,
                      "cancelled": 0, "watchdog_fires": 0,
                      "row_failures": 0, "recovered": 0,
                      "replayed_tokens": 0, "drain_preempted": 0,
                      "warm_started": 0}

        self._prefix: Optional[PrefixCache] = None
        self._kv_geom = (kv_blocks, block_size)   # failure-isolation reinit
        if self.paged:
            self._pool = BlockPool(kv_blocks, block_size)
            self._pkv = self._place_pool(
                init_kv_pool(cfg, kv_blocks, block_size))
            if self.prefix_cache:
                self._prefix = PrefixCache(self._pool)
            if self._pool_sharded:
                # pool-touching mutators run per-shard under shard_map so
                # their donated in/out pool buffers keep the KV-head
                # sharding — a plain jit would let GSPMD re-lay them out
                pool_s = serve_pool_spec(cfg, self.ctx)
                kv_s = serve_kv_cache_spec(cfg, self.ctx)
                self._cow_copy = jax.jit(
                    manual_serve_map(copy_blocks, self.ctx,
                                     in_specs=(pool_s, P(), P()),
                                     out_specs=pool_s),
                    donate_argnums=(0,))
                self._scatter = jax.jit(
                    manual_serve_map(scatter_prefill_rows, self.ctx,
                                     in_specs=(pool_s, P(), kv_s, kv_s),
                                     out_specs=pool_s),
                    donate_argnums=(0,))
            else:
                self._cow_copy = jax.jit(copy_blocks, donate_argnums=(0,))
                self._scatter = jax.jit(self._scatter_impl,
                                        donate_argnums=(0,))
            self._max_seq = min(max_seq_len or 32 * block_size,
                                (kv_blocks - 1) * block_size)
            self.prefill_chunk = prefill_chunk or decode_chunk * block_size
            if self.prefill_chunk < 1:
                raise ValueError("prefill_chunk must be >= 1")
            mb = self._pool.blocks_for(self._max_seq)
            # block tables: host mirror for growth decisions + a DEVICE-
            # resident array the compiled programs read; growth/merge/retire
            # update the device copy with in-place scatters
            self._tables = np.zeros((B, mb), np.int32)
            self._tables_dev = self._dev(np.zeros((B, mb), np.int32))
            self._pref_pos = np.zeros((B,), np.int32)  # prompt tokens done
            self._slot_blocks: List[Optional[List[int]]] = [None] * B
            self._slot_prompt: List[Optional[np.ndarray]] = [None] * B
            # preallocated chunked-prefill window buffers: each cycle only
            # the rows actually mid-prefill are (re)written — invariant: a
            # row's `valid` entries are False unless it is mid-prefill
            # (cleared on decode transition and preemption)
            C = self.prefill_chunk
            self._wp_toks = np.zeros((B, C), np.int32)
            self._wp_valid = np.zeros((B, C), bool)
            self._wp_start = np.zeros((B,), np.int32)
            self._wp_last_idx = np.zeros((B,), np.int32)
            # worst-case blocks granted in one cycle: every row crosses into
            # ceil(decode_chunk / block_size) new blocks plus one boundary
            # block — the fixed width of the growth scatter
            self._grow_burst_max = B * (-(-decode_chunk // block_size) + 1)
            # async stall ledger: a row whose growth failed ONLY because the
            # needed blocks sit behind the deferred-free fence is masked on
            # device (rem -> 0) instead of preempted; its remaining steps
            # park here until the fence releases and growth succeeds
            self._stall_rem = np.zeros((B,), np.int32)
            self._set_rem = jax.jit(
                lambda rem, rows, vals: rem.at[rows].set(vals))
            self._decode_paged = jax.jit(self._decode_paged_impl,
                                         static_argnames=("n",),
                                         donate_argnums=(1,))
            self._prefill_window = jax.jit(self._prefill_window_impl,
                                           donate_argnums=(1,))
            self._extend_tables = jax.jit(extend_block_tables)
            self._set_rows = jax.jit(set_table_rows)
        else:
            self._max_seq = max_seq_len or 512
            self.prefill_chunk = None
            # fixed-slot recurrent-state pool: init_cache's pytree with the
            # scalar pos replaced by the per-row _lengths mirror
            self._sstate = {k: v
                            for k, v in lm.init_cache(cfg, B,
                                                      self._max_seq).items()
                            if k != "pos"}
            if self._repl_ns is not None:
                # SSM/hybrid slot state is replicated over the serve mesh
                self._sstate = jax.device_put(self._sstate, self._repl_ns)
            self._decode_slots = jax.jit(self._decode_slots_impl,
                                         static_argnames=("n",),
                                         donate_argnums=(1,))

        # observability: one open phase span per seated slot (name, t0);
        # None obs = fully disabled (hot paths guard on self._tr/_mh)
        self._slot_span: List[Optional[tuple]] = [None] * B
        self.set_obs(obs if obs is not None else _obs_from_env())

        # watchdog: a daemon thread that fails every outstanding future
        # when a BUSY engine makes no cycle progress within the budget
        # (stuck device sync, wedged stage) — result() raises a diagnostic
        # WatchdogTimeout instead of hanging
        self._wd_beat = time.perf_counter()
        self._wd_stop = threading.Event()
        self._wd_thread: Optional[threading.Thread] = None
        if self._watchdog_s > 0:
            self._wd_thread = threading.Thread(
                target=self._watchdog_loop, name="serve-watchdog",
                daemon=True)
            self._wd_thread.start()

    # ---------------------------------------------------------- observability
    def set_obs(self, obs) -> None:
        """Attach (or detach, with None) a :class:`repro.obs.Observability`.

        Binding caches every metric handle once (``self._mh``) and hands the
        metrics registry to the scheduler and block pool and the tracer to
        the resident pipeline, so an instrumented event costs one cached-
        handle call and a disabled one a single ``None`` check. Rebindable
        while the engine is idle — the overhead-gate benchmark toggles obs
        on ONE engine instead of paying a second jit warm-up.
        """
        self.obs = obs
        self._tr = obs.tracer if obs is not None else None
        metrics = obs.metrics if obs is not None else None
        self._scheduler.set_metrics(metrics)
        if self.paged:
            self._pool.set_metrics(metrics)
        if self._prefix is not None:
            self._prefix.set_metrics(metrics)
        if self._journal is not None:
            self._journal.set_metrics(metrics)
        if self._pipeline is not None:
            self._pipeline.tracer = self._tr
        #: per-tier TTFT histograms, keyed by priority — populated lazily
        #: at first token time (serve.ttft_s.tier<N>)
        self._mh_tier: Dict[int, Any] = {}
        if metrics is None:
            self._mh = None
            return
        self._mh = {
            "tokens_out": metrics.counter("serve.tokens_out"),
            "admitted": metrics.counter("serve.requests.admitted"),
            "retired": metrics.counter("serve.requests.retired"),
            "preempted": metrics.counter("serve.requests.preempted"),
            "stalled": metrics.counter("serve.requests.stalled"),
            "grown_blocks": metrics.counter("pool.grown_blocks"),
            "prefill_saved": metrics.counter("serve.prefill_tokens_saved"),
            "resident": metrics.gauge("serve.resident_rows"),
            "ttft": metrics.histogram("serve.ttft_s"),
            "qwait": metrics.histogram("serve.queue_wait_s"),
            "cycle": metrics.histogram("engine.cycle_s"),
            "dispatch": metrics.histogram("engine.dispatch_s"),
            "sync": metrics.histogram("engine.chunk_sync_s"),
            "book": metrics.histogram("engine.book_s"),
            "gap": metrics.histogram("engine.gap_s"),
            "chunk": metrics.histogram("engine.chunk_s"),
            "shed": metrics.counter("serve.shed"),
            "expired": metrics.counter("serve.expired"),
            "cancelled": metrics.counter("serve.cancelled"),
            "watchdog": metrics.counter("serve.watchdog_fires"),
            "row_failed": metrics.counter("serve.row_failures"),
            "recovered": metrics.counter("serve.recovered"),
            "replayed": metrics.counter("serve.replayed_tokens"),
        }

    def set_journal(self, journal) -> None:
        """Attach (or detach, with None) a request :class:`~repro.serve
        .journal.Journal`. Rebindable while the engine is idle — the
        journal-overhead gate toggles the WAL on ONE engine the same way
        :meth:`set_obs` toggles observability. Accepts a path string."""
        if isinstance(journal, str):
            journal = Journal(journal)
        old = self._journal
        self._journal = journal
        if journal is not None and self.obs is not None:
            journal.set_metrics(self.obs.metrics)
        if old is not None and old is not journal:
            old.close()

    def _phase_begin(self, slot: int, name: str, t: float) -> None:
        self._slot_span[slot] = (name, t)

    def _phase_end(self, slot: int, t: float, req=None) -> None:
        cur = self._slot_span[slot]
        self._slot_span[slot] = None
        if cur is not None and self._tr is not None:
            args = {"req": req.id} if req is not None else None
            self._tr.add(cur[0], f"slot{slot}", cur[1], t, args)

    def _note_seated(self, slot: int, req, now: float) -> None:
        """Retroactive lifecycle spans, emitted at seat time (the slot a
        request will occupy is unknown until the decode-stage merge):
        ``queued`` [enqueue -> admission pop], ``admitted`` [pop -> merge],
        then the open ``prefill``/``decode`` phase span. A preempted
        request re-enters here on its NEXT admission, so its track shows
        every queued/admitted/decode re-entry."""
        tr = self._tr
        track = f"slot{slot}"
        adm = req.last_admitted_at or now
        if req.queued_since is not None:
            tr.add("queued", track, req.queued_since, adm,
                   {"req": req.id, "preempted": req.preempted_count})
        tr.add("admitted", track, adm, now, {"req": req.id})
        self._phase_begin(slot, self._slot_phase[slot], now)

    def _note_first_token(self, req, now: float) -> None:
        if req.first_token_at is None:
            req.first_token_at = now
            if self._journal is not None:
                self._journal.first_token(req)
            if self._mh is not None and req.submitted_at is not None:
                ttft = now - req.submitted_at
                self._mh["ttft"].record(ttft)
                h = self._mh_tier.get(req.priority)
                if h is None:
                    h = self.obs.metrics.histogram(
                        f"serve.ttft_s.tier{req.priority}")
                    self._mh_tier[req.priority] = h
                h.record(ttft)

    def _sched_event(self, kind: str, req) -> None:
        """Scheduler sweep callback (outside the scheduler lock): a waiting
        request was dropped — ``kind`` in ``("expired", "cancelled")``."""
        with self._state_lock:
            self.stats[kind] += 1
        if self._journal is not None:
            self._journal.cancel(req, kind)
        if self._mh is not None:
            self._mh[kind].inc()
        if self._tr is not None:
            self._tr.instant(kind, TRACK_ENGINE, time.perf_counter(),
                             {"req": req.id, "state": "waiting"})

    def _note_resident(self) -> None:
        if self._mh is not None:
            self._mh["resident"].set(
                sum(r is not None for r in self._slot_req))

    # ------------------------------------------------------- mesh placement
    def _dev(self, x):
        """Upload a host array REPLICATED over the serve mesh (plain
        ``jnp.asarray`` off-mesh). Used for every device-resident array the
        compiled programs treat as replicated — block tables, the decode
        carry, slot state — so no launch ever sees an unexpectedly
        device-0-committed operand."""
        a = jnp.asarray(x)
        if self._repl_ns is not None:
            a = jax.device_put(a, self._repl_ns)
        return a

    def _place_pool(self, pkv):
        """Commit a freshly built KV pool to its mesh sharding: partitioned
        on the KV-head axis when the model axis shards attention, else
        replicated-equivalent single-device placement. Keeping the pool
        committed is what makes the per-device footprint 1/N and lets the
        donated chunk in/out buffers alias without a relayout."""
        if self._pool_sharded:
            pkv = jax.device_put(
                pkv, NamedSharding(self.ctx.mesh,
                                   serve_pool_spec(self.cfg, self.ctx)))
        elif self._repl_ns is not None:
            pkv = jax.device_put(pkv, self._repl_ns)
        return pkv

    # ---------------------------------------------------------- compiled fns
    def _prefill_impl(self, params, tokens, last_positions, max_len: int):
        with use_shard_ctx(self.ctx):
            return lm.prefill(self.cfg, params, tokens, max_len=max_len,
                              last_positions=last_positions, ctx=self.ctx)

    def _decode_n_impl(self, params, cache, token, n: int):
        """n contiguous decode steps in one XLA launch (per-call baseline)."""
        with use_shard_ctx(self.ctx):
            def body(carry, _):
                cache, tok = carry
                logits, cache = lm.decode_step(self.cfg, params, cache, tok)
                nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
                return (cache, nxt), nxt

            (cache, tok), toks = jax.lax.scan(body, (cache, token),
                                              None, length=n)
            return cache, toks.swapaxes(0, 1)  # (B, n)

    def _decode_paged_impl(self, params, pkv, tables, lengths, last,
                           rem, n: int):
        """One chunk: ``n`` paged decode steps over the resident batch in a
        single XLA launch (:func:`repro.models.lm.decode_chunk_paged` — the
        shared device-carry chunk program; the sync path feeds it uploaded
        host mirrors, the async path feeds it the previous chunk's output
        carry directly). Rows with ``rem == 0`` are inactive: their KV
        writes go to the sink block and their emitted tokens are discarded
        host-side. The attention read path is ``self.paged_impl``.
        Returns the advanced state + (B, n) greedy tokens."""
        with use_shard_ctx(self.ctx):
            pkv, (ln, tok, rm), toks = lm.decode_chunk_paged(
                self.cfg, params, pkv, tables, (lengths, last, rem), n,
                impl=self.paged_impl, ctx=self.ctx)
            return pkv, tok, ln, rm, toks

    def _decode_slots_impl(self, params, state, last, lengths, rem, n: int):
        """One chunk over the SSM/hybrid slot-state pool
        (:func:`repro.models.lm.decode_chunk_slots` at per-row positions).
        Inactive slots step on stale state harmlessly (row-wise math; their
        tokens are discarded host-side and their slot is overwritten at the
        next admission)."""
        with use_shard_ctx(self.ctx):
            st, (ln, tok, rm), toks = lm.decode_chunk_slots(
                self.cfg, params, state, (lengths, last, rem), n,
                ctx=self.ctx)
            return st, tok, ln, rm, toks

    def _prefill_window_impl(self, params, pkv, tables, tokens, start,
                             valid, last_idx):
        with use_shard_ctx(self.ctx):
            return lm.prefill_window_paged(self.cfg, params, pkv, tables,
                                           tokens, start, valid, last_idx,
                                           ctx=self.ctx)

    def _scatter_impl(self, pkv, blocks, krows, vrows):
        return scatter_prefill_rows(pkv, blocks, krows, vrows)

    # ------------------------------------------------------------- lifecycle
    def _ensure_executor(self) -> Executor:
        if self._executor is None:
            self._executor = Executor(domains={HOST: 2, ACCEL: 1})
            self._own_executor = True
        return self._executor

    def _ensure_pipeline(self, ex: Executor) -> DataPipeline:
        if self._pipeline is None:
            decode_domain = ACCEL if ex.has_domain(ACCEL) else HOST
            self._pipeline = DataPipeline(
                self.pipeline_lines,
                DataPipe(PipeType.SERIAL, self._st_admit, name="admit"),
                DataPipe(PipeType.SERIAL, self._st_prefill, name="prefill"),
                DataPipe(PipeType.SERIAL, self._st_decode, name="decode",
                         domain=decode_domain),
                DataPipe(PipeType.PARALLEL, self._st_complete,
                         name="complete"),
                name="serve-continuous")
            # promote stage_times into per-line spans when tracing is on
            self._pipeline.tracer = self._tr
        return self._pipeline

    # --------------------------------------------------------------- watchdog
    def _watchdog_busy(self) -> bool:
        """Lock-free busy probe (container truthiness is atomic enough for
        a heuristic; the watchdog must never block on a lock a wedged stage
        might hold)."""
        return bool(self._inflight) or bool(self._cycle_tokens) \
            or self._scheduler.num_waiting > 0

    def _watchdog_loop(self) -> None:
        """Daemon thread: fail every outstanding future with a diagnostic
        :class:`WatchdogTimeout` when a BUSY engine makes no stage progress
        (heartbeat ``_wd_beat``, touched by every admit/decode/complete
        entry and by ``submit``) for ``watchdog_s`` seconds. The stuck
        device call itself cannot be interrupted — the point is that
        ``result()`` raises a diagnostic instead of hanging forever."""
        period = max(0.01, self._watchdog_s / 4.0)
        while not self._wd_stop.wait(period):
            if self._broken is not None:
                return
            stale = time.perf_counter() - self._wd_beat
            if stale <= self._watchdog_s or not self._watchdog_busy():
                continue
            err = WatchdogTimeout(
                f"engine made no cycle progress for {stale:.3f}s "
                f"(budget {self._watchdog_s:.3f}s; "
                f"inflight={len(self._inflight)} "
                f"waiting={self._scheduler.num_waiting} "
                f"cycles={sorted(self._cycle_tokens)}; a stuck device "
                f"sync or a deadlocked stage — failing all futures)")
            self._broken = err
            with self._state_lock:
                self.stats["watchdog_fires"] += 1
            if self._mh is not None:
                self._mh["watchdog"].inc()
            if self._tr is not None:
                self._tr.instant("watchdog_fire", TRACK_ENGINE,
                                 time.perf_counter(), {"stale_s": stale})
            self._fail_outstanding(err)
            return

    def close(self, timeout: float = 300.0) -> None:
        """Drain outstanding requests, then release the executor. Anything
        still outstanding after the drain budget (or after a breakage)
        fails typed :class:`EngineClosed` — ``result()`` never hangs on a
        torn-down engine. Idempotent."""
        self._closing = True
        if self._pipeline is not None:
            deadline = time.perf_counter() + timeout
            while time.perf_counter() < deadline:
                if self._broken is not None:
                    break
                if self._pipeline.idle() and \
                        (self._scheduler.num_waiting == 0
                         or self._draining):
                    # a draining engine never admits its backlog — stop
                    # waiting on it; the typed fail below settles it
                    break
                time.sleep(0.005)
        self._wd_stop.set()
        if self._wd_thread is not None:
            self._wd_thread.join(timeout=1.0)
            self._wd_thread = None
        if self._watchdog_busy():
            # drain gave up (or the pipeline broke): propagate a typed
            # error into every pending future instead of letting result()
            # time out slot by slot
            self._fail_outstanding(EngineClosed(
                "engine closed with requests outstanding "
                "(drain timeout or prior failure)"))
        if self.paged and self._pending is None:
            # drained: no chunk in flight, every deferred block is past the
            # device work that fenced it — flush the fence
            while self._pool.num_deferred:
                self._pool.release_deferred()
        if self._journal is not None:
            self._journal.close()
        if self._own_executor and self._executor is not None:
            self._executor.shutdown()
            self._executor = None
            self._own_executor = False

    def __enter__(self) -> "ServeEngine":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -------------------------------------------------- durability (drain /
    # snapshot / restore / recover — see docs/robustness.md)
    def drain(self, deadline_s: Optional[float] = None,
              timeout: float = 300.0) -> bool:
        """Gracefully drain the engine: stop admitting (``submit()`` and
        the admission stage both gate typed), let resident rows run to
        completion, and — once ``deadline_s`` elapses — have the decode
        stage CHECKPOINT-PREEMPT every remaining resident (SSM sync rows
        capture their exact recurrent state; paged/async rows replay
        bit-identically later) so the engine settles instead of riding
        out its longest resident. The drain thread only sets flags and
        polls: all slot-state mutation stays on the SERIAL decode stage,
        the single writer. Flushes the journal once settled. Returns
        True when the engine reached idle within ``timeout``; waiting
        and preempted requests stay queued — snapshot them, then
        ``close()`` fails them typed :class:`EngineClosed`. Idempotent."""
        self._draining = True
        if deadline_s is not None and self._drain_deadline_at is None:
            self._drain_deadline_at = time.perf_counter() + deadline_s
        settled = True
        if self._pipeline is not None:
            limit = time.perf_counter() + timeout
            settled = False
            while time.perf_counter() < limit:
                if self._broken is not None:
                    settled = True
                    break
                with self._state_lock:
                    occupied = any(r is not None for r in self._slot_req)
                    reserved = self._slots_reserved
                if self._pipeline.idle() and not occupied \
                        and reserved == 0:
                    settled = True
                    break
                time.sleep(0.005)
        if self._journal is not None:
            self._journal.flush()
        return settled

    def snapshot(self, path: str) -> int:
        """Serialize warm state to ``path`` (atomic, checksummed — see
        :mod:`repro.serve.snapshot`): the prefix trie with its stable
        blake2b chunk keys and every indexed block's KV page, plus
        waiting-queue request descriptors. Call at idle (typically right
        after :meth:`drain`): resident rows are NOT captured — the
        journal covers them by replay. Returns bytes written. The
        ``snapshot_corrupt`` fault site flips a payload byte right after
        the write, for the typed cold-fallback tests."""
        if self._journal is not None:
            self._journal.flush()
        meta: Dict[str, Any] = {"paged": self.paged}
        arrays: Dict[str, np.ndarray] = {}
        qdesc = []
        qtoks: List[np.ndarray] = []
        for r in self._scheduler.export_waiting():
            qdesc.append({"id": int(r.id), "max_new": int(r.max_new),
                          "priority": int(r.priority),
                          "deadline_s": r.deadline_s})
            qtoks.append(np.asarray(r.prompt, np.int32))
        meta["queue"] = qdesc
        arrays["queue_tokens"] = (np.concatenate(qtoks) if qtoks
                                  else np.zeros((0,), np.int32))
        arrays["queue_lens"] = np.asarray([len(t) for t in qtoks],
                                          np.int32)
        if self.paged:
            meta["block_size"] = int(self._pool.block_size)
        if self._prefix is not None:
            nodes = self._prefix.export_nodes()
            meta["prefix"] = [{"parent": n["parent"], "key": n["key"],
                               "depth": n["depth"], "hits": n["hits"]}
                              for n in nodes]
            ptoks = [n["tokens"].astype(np.int32) for n in nodes]
            arrays["prefix_tokens"] = (np.concatenate(ptoks) if ptoks
                                       else np.zeros((0,), np.int32))
            arrays["prefix_lens"] = np.asarray(
                [len(t) for t in ptoks], np.int32)
            ids = [n["block"] for n in nodes]
            hp = np.asarray(jax.device_get(self._pkv))
            # (L, 2, N, KV, bs, hd): page i on axis 2 is node i's block.
            # Stored as RAW BYTES (uint8 view): npz round-trips bfloat16
            # only as opaque void, so the restore side re-views with the
            # live pool dtype (recorded below for the compat check)
            pg = np.ascontiguousarray(hp[:, :, ids] if ids
                                      else hp[:, :, :0])
            arrays["prefix_pages"] = pg.view(np.uint8)
            meta["pool_dtype"] = str(hp.dtype)
        n = write_snapshot(path, meta, arrays)
        if self._fi is not None and self._fi.fire("snapshot_corrupt"):
            corrupt_snapshot(path)
        return n

    def restore(self, path: str) -> List[Dict[str, Any]]:
        """Warm-start THIS (freshly constructed, idle) engine from a
        :meth:`snapshot` file: rebuild the prefix trie — fresh pool
        blocks are allocated, the saved KV pages written into them, and
        the nodes adopted PARKED and flagged warm, so a known system
        prompt hits the cache on the first post-restart request
        (``prefix.warm_hits``) — and return the waiting-queue
        descriptors for the caller (:meth:`recover` re-submits them when
        no journal supersedes the snapshot). Raises typed
        :class:`SnapshotCorrupt` on any integrity or geometry mismatch
        BEFORE mutating engine state, so callers fall back to a cold
        start: a snapshot can lose warmth, never serve wrong tokens."""
        meta, arrays = read_snapshot(path)
        if bool(meta.get("paged")) != self.paged:
            raise SnapshotCorrupt(
                f"snapshot arch mismatch: paged={meta.get('paged')} vs "
                f"engine paged={self.paged}")
        queue: List[Dict[str, Any]] = []
        qlens = arrays.get("queue_lens")
        qtoks = arrays.get("queue_tokens")
        if qlens is not None and qtoks is not None:
            off = 0
            for d, ln in zip(meta.get("queue", []),
                             [int(x) for x in qlens]):
                d = dict(d)
                d["prompt"] = np.asarray(qtoks[off:off + ln], np.int32)
                off += ln
                queue.append(d)
        entries = meta.get("prefix") or []
        if entries and self._prefix is not None:
            pages = arrays["prefix_pages"]
            plens = [int(x) for x in arrays["prefix_lens"]]
            # np.array (not asarray): device views are read-only and the
            # page import writes into this host copy before re-upload
            hp = np.array(jax.device_get(self._pkv))
            if meta.get("pool_dtype") != str(hp.dtype):
                raise SnapshotCorrupt(
                    f"snapshot pool dtype mismatch: "
                    f"{meta.get('pool_dtype')!r} vs engine {hp.dtype}")
            pages = pages.view(hp.dtype)     # stored as raw uint8 bytes
            want = hp.shape[:2] + (pages.shape[2],) + hp.shape[3:]
            if int(meta.get("block_size", -1)) != self._pool.block_size \
                    or pages.shape != want:
                raise SnapshotCorrupt(
                    f"snapshot pool geometry mismatch: pages "
                    f"{pages.shape} / block_size "
                    f"{meta.get('block_size')} vs engine "
                    f"{want} / {self._pool.block_size}")
            off, toks = 0, []
            for ln in plens:
                toks.append(np.asarray(
                    arrays["prefix_tokens"][off:off + ln], np.int32))
                off += ln
            for e, t in zip(entries, toks):
                e["tokens"] = t
            # leave headroom: warmth must never consume the whole pool
            n = min(len(entries),
                    max(0, self._pool.num_free_unreserved - 1))
            ids = self._pool.alloc(n) if n > 0 else []
            if ids:
                hp[:, :, ids] = pages[:, :, :len(ids)]
                self._pkv = self._place_pool(jnp.asarray(hp))
                created = self._prefix.import_nodes(entries[:len(ids)],
                                                    ids)
                with self._state_lock:
                    self.stats["warm_started"] += created
        return queue

    def recover(self, state_dir: str, *, fsync_every: int = 1
                ) -> Dict[int, ServeRequest]:
        """Crash/restart recovery against a ``--state-dir``: restore the
        snapshot if one exists (typed :class:`SnapshotCorrupt` falls
        back to a cold start — warmth lost, correctness kept), replay
        the journal and RE-SUBMIT every incomplete request (greedy
        decode makes the replay bit-identical; deadlines re-arm in
        full), rotate the consumed journal aside and attach a fresh one
        at the same path. The snapshot's queue descriptors are used only
        when no journal exists — with one, its submit records are a
        superset. Returns ``{old request id: new future}`` so the
        caller can hand back or verify the replayed results."""
        os.makedirs(state_dir, exist_ok=True)
        spath = os.path.join(state_dir, SNAPSHOT_FILE)
        jpath = os.path.join(state_dir, JOURNAL_FILE)
        queue: List[Dict[str, Any]] = []
        if os.path.exists(spath):
            try:
                queue = self.restore(spath)
            except SnapshotCorrupt:
                queue = []    # cold start; the journal still replays
        rep = replay_journal(jpath)
        pending = rep.incomplete if rep.submits else queue
        if os.path.exists(jpath):
            os.replace(jpath, jpath + ".replayed")
        self.set_journal(Journal(jpath, fsync_every=fsync_every))
        out: Dict[int, ServeRequest] = {}
        ntok = 0
        for rec in pending:
            prompt = np.asarray(rec["prompt"], np.int32)
            req = self.submit(prompt, int(rec["max_new"]),
                              priority=int(rec.get("priority", 0)),
                              deadline_s=rec.get("deadline_s"))
            out[int(rec["id"])] = req
            ntok += len(prompt)
        with self._state_lock:
            self.stats["recovered"] += len(out)
            self.stats["replayed_tokens"] += ntok
        if self._mh is not None and out:
            self._mh["recovered"].inc(len(out))
            self._mh["replayed"].inc(ntok)
        return out

    # ------------------------------------------------------- stage callables
    def _log(self, stage: str, token: int, info: Any) -> None:
        if self._stage_log is not None:
            with self._log_lock:
                self._stage_log.append((stage, token, info,
                                        time.perf_counter()))

    @property
    def stage_log(self) -> List[tuple]:
        """(stage, cycle-token, info, timestamp) events (record_stages=True)."""
        with self._log_lock:
            return list(self._stage_log or [])

    def _st_admit(self, pf):
        t_adm = time.perf_counter()
        self._wd_beat = t_adm
        epoch = self._reset_epoch
        with self._state_lock:
            occupied = any(r is not None for r in self._slot_req)
            reserved = self._slots_reserved
            deps = set(self._cycle_tokens)
            free_slots = len(self._free_slots) - reserved
        waiting = self._scheduler.num_waiting
        draining = self._draining
        if draining:
            # graceful drain: admission is closed. Residents keep decoding
            # via pump cycles below; anything still waiting is failed typed
            # by close() after the drain settles. Forcing `waiting` to 0
            # here lets the idle-stop fire the moment the last resident
            # retires even with a backlog queued behind the gate.
            waiting = 0
        if not waiting and not occupied and reserved == 0:
            # fully idle — nothing queued, no live rows, and no admitted
            # group still in flight toward its decode merge: drain so the
            # engine parks at zero cost; the next submit() re-arms the SAME
            # resident grid (no rebuild)
            pf.stop()
            return None
        group = None
        if draining:
            popped = None
        elif self.paged:
            # phase 1 of two-phase admission: budget the PROMPT footprint
            # only — minus any prompt blocks the prefix cache already holds
            # (peek is conservative: registration can only grow a match
            # between the peek and the pin below) — and count PARKED cached
            # blocks toward the budget, since they are evictable on demand;
            # decode-time blocks are granted lazily by the decode stage.
            # The budget sees free blocks MINUS the stalled-row reservation
            # floor: a stalled resident row is starving for blocks that are
            # (or will be) released by the deferred-free fence, and handing
            # them to a new request would make the grow pass preempt that
            # request right back — an admit/preempt livelock. The grow pass
            # reserves each stalled row's unmet demand
            # (:meth:`BlockPool.set_reserved`, drained oldest-stalled-first
            # by the grow pass's age order), so admission proceeds on the
            # surplus instead of halting outright while anything is stalled.
            px = self._prefix
            if px is not None:
                bs = self._pool.block_size

                def need_for(r):
                    return self._pool.blocks_for(r.prompt_len) \
                        - px.peek(r.prompt) // bs
                budget = self._pool.num_free_unreserved + px.num_parked
            else:
                def need_for(r):
                    return self._pool.blocks_for(r.prompt_len)
                budget = self._pool.num_free_unreserved
            popped = self._scheduler.try_admit(free_slots, budget, need_for,
                                               hopeless=self._hopeless_why)
            if popped is not None:
                # pin the longest cached prefix per member (ref++ on every
                # matched block) and allocate only the uncached suffixes
                hits = [px.match_and_pin(r.prompt) if px is not None
                        else None for r in popped]
                needs = [self._pool.blocks_for(r.prompt_len)
                         - (len(h.blocks) if h is not None else 0)
                         for r, h in zip(popped, hits)]
                if self._fi is not None and self._fi.fire("alloc_fail"):
                    ids = None          # injected admission-alloc failure
                else:
                    ids = self._pool.alloc(sum(needs))  # all-or-nothing
                if ids is None and px is not None:
                    # reuse-aware back-pressure: release cold PARKED prefix
                    # blocks (leaf-first, coldest score first) before giving
                    # up on the group — and long before the grow pass would
                    # preempt any resident row
                    short = sum(needs) - self._pool.num_free_unreserved
                    if short > 0:
                        px.evict(short)
                    ids = self._pool.alloc(sum(needs))
                if ids is None:
                    # raced a concurrent mid-decode grow: unpin, put the
                    # group back (id order preserved), fall through to
                    # park/pump
                    for h in hits:
                        if h is None:
                            continue
                        pins = list(h.blocks)
                        if h.partial_block is not None:
                            pins.append(h.partial_block)
                        if pins:
                            px.unpin(pins)
                    self._scheduler.requeue_front(popped)
                else:
                    group, i, saved, nhit = [], 0, 0, 0
                    for r, h, need in zip(popped, hits, needs):
                        group.append((r, ids[i:i + need], h))
                        i += need
                        if h is not None and h.tokens > 0:
                            nhit += 1
                            saved += h.tokens
                    if nhit:
                        with self._state_lock:
                            self.stats["prefix_hits"] += nhit
                            self.stats["prefix_tokens_saved"] += saved
                        if self._mh is not None:
                            self._mh["prefill_saved"].inc(saved)
        else:
            # slot-state pool: recurrent state is pre-allocated per slot, so
            # admission is bounded by free slots alone
            popped = self._scheduler.try_admit(free_slots, None,
                                               hopeless=self._hopeless_why)
            if popped is not None:
                group = [(r, None) for r in popped]
        if group is not None:
            now = time.perf_counter()
            for g in group:
                r = g[0]
                r.state = "prefilling"
                if r.admitted_at is None:
                    r.admitted_at = now
                    if self._mh is not None and r.submitted_at is not None:
                        self._mh["qwait"].record(now - r.submitted_at)
            with self._state_lock:
                stale = epoch != self._reset_epoch
                if not stale:
                    self._slots_reserved += len(group)
                    self._inflight.update(g[0] for g in group)
                    self._cycle_tokens.add(pf.token)
                    self._premerge[pf.token] = (epoch,
                                                [g[0] for g in group])
                    self.stats["admitted"] += len(group)
            if stale:
                # a failure-isolation reset raced this admission: the block
                # ids above came from the pre-reset pool and are dead. Fail
                # the group typed (re-submit is safe and deterministic)
                # instead of seating it on a fresh pool it never allocated
                # from.
                err = RowFailed(
                    "admission raced an engine failure-isolation reset")
                for g in group:
                    g[0].set_error(err)
                return ("pump", None)
            if self._journal is not None:
                for g in group:
                    self._journal.admit(g[0])
            if self._mh is not None:
                self._mh["admitted"].inc(len(group))
            if self._tr is not None:
                self._tr.add("admission", TRACK_ENGINE, t_adm, now,
                             {"reqs": [g[0].id for g in group]})
            self._log("admit", pf.token, [g[0].id for g in group])
            return ("admit", group)
        if waiting and deps:
            # deferred-token admission: the head request does not fit. Park
            # THIS cycle until the oldest in-flight cycle fully completes
            # (its complete stage frees retired blocks), instead of spinning
            # empty admissions; the in-flight cycles keep the decode pump
            # alive meanwhile.
            dep = min(deps)
            with self._state_lock:
                self.stats["admit_parks"] += 1
            self._log("park", pf.token, dep)
            pf.defer(dep)
            return None
        # nothing admittable but sequences are running (or their retirement
        # is still in flight): emit a pure decode-pump cycle
        with self._state_lock:
            self._cycle_tokens.add(pf.token)
            self.stats["pump_cycles"] += 1
        if self._tr is not None:
            self._tr.add("admission", TRACK_ENGINE, t_adm,
                         time.perf_counter(), {"pump": True})
        self._log("pump", pf.token, None)
        return ("pump", None)

    def _st_prefill(self, pf, msg):
        kind, payload = msg
        if kind != "admit":
            return msg
        try:
            return self._prefill_group(pf, payload)
        except Exception as exc:           # per-group failure isolation
            return self._prefill_failed(pf, payload, exc)

    def _prefill_failed(self, pf, group, exc):
        """A raising prefill launch fails ONLY the admitted group (typed
        :class:`RowFailed`), releases its untouched resources, and the
        engine keeps serving — prefill never donates the KV pool, so no
        device-state reset is needed (contrast :meth:`_isolate_failure`)."""
        err = RowFailed(
            f"prefill launch failed for group "
            f"{[g[0].id for g in group]}: {exc!r}")
        err.__cause__ = exc
        with self._state_lock:
            info = self._premerge.pop(pf.token, None)
            live = info is not None and info[0] == self._reset_epoch
            if live:
                self._slots_reserved -= len(group)
                for g in group:
                    self._inflight.discard(g[0])
            self.stats["row_failures"] += len(group)
        if live and self.paged:
            for g in group:
                blocks, hit = g[1], g[2]
                if blocks:
                    # allocated at admit, never scattered: no device work
                    # references them, a plain free is safe even in async
                    self._pool.free(list(blocks))
                if hit is not None:
                    pins = list(hit.blocks)
                    if hit.partial_block is not None:
                        pins.append(hit.partial_block)
                    if pins:
                        self._prefix.unpin(pins)
        for g in group:
            g[0].set_error(err)
        if self._mh is not None:
            self._mh["row_failed"].inc(len(group))
        if self._tr is not None:
            self._tr.instant("prefill_failed", TRACK_ENGINE,
                             time.perf_counter(),
                             {"reqs": [g[0].id for g in group]})
        self._log("prefill_failed", pf.token, [g[0].id for g in group])
        return ("pump", None)

    def _prefill_group(self, pf, group):
        reqs = [g[0] for g in group]
        if not self.paged:
            # SSM/hybrid: whole-prompt prefill per member (recurrent state
            # is O(1)/sequence — there is no per-token KV to chunk in; the
            # compiled shape keys on each prompt length, as the grouped
            # baseline's did)
            out = []
            n_pref = 0
            for req in reqs:
                if getattr(req, "_ssm_ckpt", None) is not None:
                    # checkpoint-preempted row (drain deadline / boost in
                    # sync mode): the exact recurrent state was captured at
                    # preemption — re-seat it directly, no prefill replay
                    out.append((req, None, None))
                    continue
                logits, cache = self._prefill(
                    self.params, jnp.asarray(req.prompt[None]), None,
                    max_len=req.prompt_len)
                first = int(np.asarray(jnp.argmax(logits, axis=-1))[0])
                out.append((req, cache, first))
                n_pref += 1
            with self._state_lock:
                self.stats["prefills"] += n_pref
            self._log("prefill", pf.token, [r.id for r in reqs])
            return ("admit", out)
        # one launch for the group's FIRST prompt window: prompts are
        # right-padded to a single window shape (chunked prefill keys the
        # compiled program on the window size, never on prompt lengths, so
        # mixed-length groups ride together; pad rows repeat the last
        # request and scatter to the sink). Remaining windows stream through
        # the decode stage cycle by cycle. The window is rounded up to a
        # power of two (capped at prefill_chunk) so arbitrary prompt-length
        # mixes compile O(log prefill_chunk) shapes, not one per length.
        # Prefix-cache HIT rows skip this launch entirely: their cached
        # tokens never re-prefill — the decode stage seats them with the
        # shared blocks and streams windows from the first uncached token
        # (the group is reordered miss-first so launch row i is group
        # member i for every window-0 participant).
        miss = [g for g in group if g[2] is None or g[2].tokens == 0]
        hitg = [g for g in group if not (g[2] is None or g[2].tokens == 0)]
        group = miss + hitg
        if not miss:
            self._log("prefill", pf.token, [r.id for r in reqs])
            return ("admit", (group, 0, None, None, None, 0))
        longest = max(g[0].prompt_len for g in miss)
        C0 = min(self.prefill_chunk, 1 << max(0, longest - 1).bit_length())
        A = self._scheduler.max_admit
        toks = np.zeros((A, C0), np.int32)
        lastp = np.zeros((A,), np.int32)
        for i, g in enumerate(miss):
            r = g[0]
            k = min(r.prompt_len, C0)
            toks[i, :k] = r.prompt[:k]
            lastp[i] = k - 1
        for i in range(len(miss), A):
            toks[i] = toks[len(miss) - 1]
            lastp[i] = lastp[len(miss) - 1]
        logits, cache = self._prefill(self.params, jnp.asarray(toks),
                                      jnp.asarray(lastp), max_len=C0)
        first = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        with self._state_lock:
            self.stats["prefills"] += 1
        self._log("prefill", pf.token, [r.id for r in reqs])
        return ("admit", (group, C0, cache["k"], cache["v"], first,
                          len(miss)))

    # ------------------------------------------------- decode-stage helpers
    def _scatter_carry(self, rows, lens, lasts, rems, pad_to: int) -> None:
        """Fixed-shape scatter onto the device-resident carry: pad every
        list with repeats of its last element (duplicate writes of the same
        row are idempotent) so each call site compiles exactly ONE shape
        regardless of how many rows it touches. Async mode only — the sync
        path re-uploads the host mirrors each cycle instead."""
        rows, lens = list(rows), list(lens)
        lasts, rems = list(lasts), list(rems)
        while len(rows) < pad_to:
            rows.append(rows[-1])
            lens.append(lens[-1])
            lasts.append(lasts[-1])
            rems.append(rems[-1])
        self._carry = self._set_carry(
            *self._carry, jnp.asarray(rows, jnp.int32),
            jnp.asarray(lens, jnp.int32), jnp.asarray(lasts, jnp.int32),
            jnp.asarray(rems, jnp.int32))

    def _premerge_live(self, pf, n: int) -> bool:
        """Epoch guard at the decode-stage merge: an admitted group that
        predates a failure-isolation reset must NOT seat — its block ids
        came from the torn-down pool, and its requests were already failed
        by the reset. PEEKS (the record is popped at the END of a merge, so
        a crash mid-merge still finds every group member in the pre-merge
        table and fails it — double ``set_error`` is a no-op)."""
        with self._state_lock:
            info = self._premerge.get(pf.token)
            if info is None or info[0] != self._reset_epoch:
                return False
        return True

    def _merge_group(self, pf, payload) -> None:
        """Seat an admitted group: assign slots, install block tables, and
        scatter the window-0 KV into the pool (single-writer: we are inside
        the SERIAL decode stage). Rows whose whole prompt fits window 0
        enter decode immediately; longer ones enter the prefill phase and
        stream their remaining windows in subsequent cycles.

        Prefix-cache HIT rows (group members past ``n_miss`` — they took no
        window-0 launch row) seed their table with the pinned SHARED prefix
        blocks followed by their own suffix blocks and enter the prefill
        phase at the first uncached token; a partially-matched tail block
        is copy-on-write FORKED here (device block copy into the row's
        first suffix block, which the table already points at) so the
        row's own writes never touch the shared original."""
        group, C0, ck, cv, first, n_miss = payload
        if not self._premerge_live(pf, len(group)):
            return
        first = np.asarray(first) if first is not None else None
        nb0 = self._pool.blocks_for(C0) if C0 else 0
        now = time.perf_counter()
        rows_idx, rows_tab = [], []
        c_len, c_last, c_rem = [], [], []
        fork_src, fork_dst = [], []
        reg_slots = []
        for i, (req, blocks, hit) in enumerate(group):
            shared = list(hit.blocks) if (hit is not None and i >= n_miss) \
                else []
            tab = shared + list(blocks)
            with self._state_lock:
                slot = self._free_slots.pop()
                self._slots_reserved -= 1
                self._slot_req[slot] = req
                self._slot_blocks[slot] = tab
                self._slot_out[slot] = []
            self._slot_gen[slot] += 1
            self._slot_prompt[slot] = req.prompt
            self._wp_valid[slot] = False
            self._stall_rem[slot] = 0
            self._tables[slot] = 0
            self._tables[slot, :len(tab)] = tab
            if shared or (hit is not None and i >= n_miss):
                # cache hit: cached tokens are already in the pool — start
                # the window walk at the first uncached token
                self._pref_pos[slot] = hit.tokens
                if hit.partial_block is not None:
                    # CoW fork of the partially-matched tail block into the
                    # row's first suffix block (table column len(shared)):
                    # its cached leading tokens come along, the row's own
                    # writes land past them
                    fork_src.append(hit.partial_block)
                    fork_dst.append(blocks[0])
                    with self._state_lock:
                        self.stats["cow_forks"] += 1
                    if self._tr is not None:
                        self._tr.instant(
                            "cow_fork", f"slot{slot}", now,
                            {"req": req.id, "src": int(hit.partial_block),
                             "dst": int(blocks[0])})
            else:
                self._pref_pos[slot] = min(req.prompt_len, C0)
            self._lengths[slot] = self._pref_pos[slot]
            if i < n_miss and req.prompt_len <= C0:
                self._slot_phase[slot] = "decode"
                self._last[slot] = first[i]
                self._rem[slot] = req.max_new - 1
                self._slot_out[slot].append(int(first[i]))
                req.state = "decoding"
                self._note_first_token(req, now)
                reg_slots.append(slot)
            else:
                self._slot_phase[slot] = "prefill"
                self._last[slot] = 0
                self._rem[slot] = 0   # masked out of decode until prefilled
            if self._tr is not None:
                self._note_seated(slot, req, now)
            rows_idx.append(slot)
            rows_tab.append(self._tables[slot].copy())
            c_len.append(int(self._lengths[slot]))
            c_last.append(int(self._last[slot]))
            c_rem.append(int(self._rem[slot]))
        # pad the row-set scatters to the admission cap (duplicate writes of
        # the same row are idempotent): ONE compiled shape per engine, not
        # one per group size
        A = self._scheduler.max_admit
        while len(rows_idx) < A:
            rows_idx.append(rows_idx[-1])
            rows_tab.append(rows_tab[-1])
        self._tables_dev = self._set_rows(
            self._tables_dev, jnp.asarray(rows_idx, jnp.int32),
            jnp.asarray(np.stack(rows_tab)))
        if self.async_decode:
            # admission scatter onto the device carry, sequenced BEFORE the
            # next chunk dispatch: the seated rows were inactive (rem==0) in
            # the chunk still in flight, so scattering onto its output carry
            # is exact
            self._scatter_carry(rows_idx[:len(group)], c_len, c_last, c_rem,
                                pad_to=A)
        if fork_src:
            # partial-tail forks: one padded device copy for the whole
            # group, sequenced on the pool chain before any window launch
            # that reads the forked blocks
            self._copy_blocks_padded(fork_src, fork_dst)
            self._prefix.unpin(fork_src)   # fork done: drop the tail pins
        if n_miss:
            # window-0 scatter: per-row block lists trimmed/padded to the
            # window footprint (sink-filled beyond a short prompt's own
            # blocks and for the group's pad rows), so the compiled shape
            # keys on the window size alone — never on group size, prompt
            # lengths, or max_new
            blocks2d = np.zeros((ck.shape[1], nb0), np.int32)
            for i, (_, blocks, _) in enumerate(group[:n_miss]):
                row = blocks[:nb0]
                blocks2d[i, :len(row)] = row
            self._pkv = self._scatter(self._pkv, jnp.asarray(blocks2d),
                                      ck, cv)
        for slot in reg_slots:
            self._register_prefix(slot)
        with self._state_lock:
            self._premerge.pop(pf.token, None)   # fully seated
        self._note_resident()

    def _copy_blocks_padded(self, srcs: List[int], dsts: List[int]) -> None:
        """One :func:`repro.serve.kvcache.copy_blocks` launch, padded with
        ``SINK -> SINK`` repeats to the next power of two so arbitrary fork
        counts compile O(log max_batch) shapes."""
        m = 1 << max(0, len(srcs) - 1).bit_length()
        srcs = list(srcs) + [SINK_BLOCK] * (m - len(srcs))
        dsts = list(dsts) + [SINK_BLOCK] * (m - len(dsts))
        self._pkv = self._cow_copy(self._pkv, jnp.asarray(srcs, jnp.int32),
                                   jnp.asarray(dsts, jnp.int32))

    def _register_prefix(self, slot: int) -> None:
        """Index a just-prefilled row's FULL prompt chunks in the prefix
        trie (decode entry is the registration point: every full prompt
        block is final — decode writes land strictly past the prompt)."""
        if self._prefix is None:
            return
        prompt = self._slot_prompt[slot]
        blocks = self._slot_blocks[slot]
        if prompt is not None and blocks is not None:
            self._prefix.register(prompt, blocks)

    def _merge_group_slots(self, pf, payload) -> None:
        """Seat an admitted SSM/hybrid group: scatter each member's
        prefilled recurrent state (and zamba2 shared-KV span) into its
        slot of the fixed-slot state pool."""
        if not self._premerge_live(pf, len(payload)):
            return
        now = time.perf_counter()
        rows_idx, c_len, c_last, c_rem = [], [], [], []
        for req, cache, first in payload:
            ckpt = getattr(req, "_ssm_ckpt", None)
            with self._state_lock:
                slot = self._free_slots.pop()
                self._slots_reserved -= 1
                self._slot_req[slot] = req
                self._slot_phase[slot] = "decode"
            self._slot_gen[slot] += 1
            if ckpt is not None:
                # checkpoint-preempted row: re-seat the exact recurrent
                # state captured at preemption and resume mid-stream —
                # no prefill, no token re-emission (out already holds
                # everything emitted before the preemption)
                state, length, last, rem, out = ckpt
                req._ssm_ckpt = None
                self._restore_slot_state(slot, state)
                self._slot_out[slot] = list(out)
                self._lengths[slot] = length
                self._last[slot] = last
                self._rem[slot] = rem
            else:
                self._write_slot_state(slot, cache, req.prompt_len)
                self._slot_out[slot] = [first]
                self._lengths[slot] = req.prompt_len
                self._last[slot] = first
                self._rem[slot] = req.max_new - 1
                self._note_first_token(req, now)
            req.state = "decoding"
            if self._tr is not None:
                self._note_seated(slot, req, now)
            rows_idx.append(slot)
            c_len.append(int(self._lengths[slot]))
            c_last.append(int(self._last[slot]))
            c_rem.append(int(self._rem[slot]))
        if self.async_decode:
            self._scatter_carry(rows_idx, c_len, c_last, c_rem,
                                pad_to=self._scheduler.max_admit)
        with self._state_lock:
            self._premerge.pop(pf.token, None)   # fully seated
        self._note_resident()

    def _write_slot_state(self, slot: int, cache, plen: int) -> None:
        cfg = self.cfg
        if cfg.hybrid_attn_every:
            conv, h = cache["g_ssm"]
            sc, sh = self._sstate["g_ssm"]
            self._sstate["g_ssm"] = (sc.at[:, :, slot].set(conv[:, :, 0]),
                                     sh.at[:, :, slot].set(h[:, :, 0]))
            if "tail_ssm" in self._sstate:
                tconv, th = cache["tail_ssm"]
                stc, sth = self._sstate["tail_ssm"]
                self._sstate["tail_ssm"] = (stc.at[:, slot].set(tconv[:, 0]),
                                            sth.at[:, slot].set(th[:, 0]))
            self._sstate["shared_k"] = self._sstate["shared_k"] \
                .at[:, slot, :, :plen].set(cache["shared_k"][:, 0])
            self._sstate["shared_v"] = self._sstate["shared_v"] \
                .at[:, slot, :, :plen].set(cache["shared_v"][:, 0])
        else:
            conv, h = cache["ssm"]
            sc, sh = self._sstate["ssm"]
            self._sstate["ssm"] = (sc.at[:, slot].set(conv[:, 0]),
                                   sh.at[:, slot].set(h[:, 0]))

    def _save_slot_state(self, slot: int) -> Dict[str, Any]:
        """Capture one slot's recurrent state (and zamba2 shared-KV span)
        to HOST memory — the SSM/hybrid checkpoint-preemption path.
        Sliced copies, not aliases: the donated ``_sstate`` buffers can be
        consumed by the next chunk without invalidating the checkpoint.
        Sync mode only — async's in-flight chunk has already advanced the
        device state past the host mirrors, so its preemptions replay
        from the prompt instead (bit-identical either way)."""
        g = jax.device_get
        st: Dict[str, Any] = {}
        if self.cfg.hybrid_attn_every:
            sc, sh = self._sstate["g_ssm"]
            st["g_ssm"] = (g(sc[:, :, slot]), g(sh[:, :, slot]))
            if "tail_ssm" in self._sstate:
                stc, sth = self._sstate["tail_ssm"]
                st["tail_ssm"] = (g(stc[:, slot]), g(sth[:, slot]))
            st["shared_k"] = g(self._sstate["shared_k"][:, slot])
            st["shared_v"] = g(self._sstate["shared_v"][:, slot])
        else:
            sc, sh = self._sstate["ssm"]
            st["ssm"] = (g(sc[:, slot]), g(sh[:, slot]))
        return st

    def _restore_slot_state(self, slot: int, st: Dict[str, Any]) -> None:
        """Scatter a :meth:`_save_slot_state` checkpoint back into a
        (possibly different) slot of the fixed-slot state pool."""
        if self.cfg.hybrid_attn_every:
            conv, h = st["g_ssm"]
            sc, sh = self._sstate["g_ssm"]
            self._sstate["g_ssm"] = (
                sc.at[:, :, slot].set(jnp.asarray(conv)),
                sh.at[:, :, slot].set(jnp.asarray(h)))
            if "tail_ssm" in st:
                tconv, th = st["tail_ssm"]
                stc, sth = self._sstate["tail_ssm"]
                self._sstate["tail_ssm"] = (
                    stc.at[:, slot].set(jnp.asarray(tconv)),
                    sth.at[:, slot].set(jnp.asarray(th)))
            self._sstate["shared_k"] = self._sstate["shared_k"] \
                .at[:, slot].set(jnp.asarray(st["shared_k"]))
            self._sstate["shared_v"] = self._sstate["shared_v"] \
                .at[:, slot].set(jnp.asarray(st["shared_v"]))
        else:
            conv, h = st["ssm"]
            sc, sh = self._sstate["ssm"]
            self._sstate["ssm"] = (sc.at[:, slot].set(jnp.asarray(conv)),
                                   sh.at[:, slot].set(jnp.asarray(h)))

    def _window_prefill_step(self, pf) -> None:
        """Synchronous chunked prefill: build, launch and complete ONE
        prefill window for every mid-prefill row in the same cycle. The
        async path instead calls :meth:`_dispatch_window_prefill` directly
        and completes the window next cycle (:meth:`_finish_window`), so
        reading its first-token logits never blocks behind the in-flight
        decode chunk."""
        pend = self._dispatch_window_prefill(pf)
        if pend is not None:
            self._finish_window(pend)

    def _dispatch_window_prefill(self, pf) -> Optional[Dict[str, Any]]:
        """Launch ONE prefill window for every mid-prefill row: the window's
        KV is computed against the row's paged prefix and scattered straight
        into the pool (one fixed-shape launch however many rows are
        prefilling — resident rows keep decoding in the same cycle). Only
        the prefilling rows are written into the preallocated window
        buffers; everyone else's ``valid`` entries are invariantly False.
        Returns the pending-window descriptor (or None if no row is
        prefilling); completion is :meth:`_finish_window`."""
        B = len(self._slot_req)
        pref = [b for b in range(B) if self._slot_phase[b] == "prefill"]
        if not pref:
            return None
        C = self.prefill_chunk
        toks, valid = self._wp_toks, self._wp_valid
        start, last_idx = self._wp_start, self._wp_last_idx
        ks = {}
        for b in pref:
            prompt = self._slot_prompt[b]
            s = int(self._pref_pos[b])
            k = min(C, len(prompt) - s)
            toks[b, :k] = prompt[s:s + k]
            valid[b, :k] = True
            valid[b, k:] = False
            start[b] = s
            last_idx[b] = min(len(prompt) - 1 - s, C - 1)
            ks[b] = k
        first, pkv = self._prefill_window(
            self.params, self._pkv, self._tables_dev, jnp.asarray(toks),
            jnp.asarray(start), jnp.asarray(valid), jnp.asarray(last_idx))
        self._pkv = pkv
        with self._state_lock:
            self.stats["prefill_windows"] += 1
        return {"first": first, "rows": pref, "k": ks, "token": pf.token,
                "gen": {b: self._slot_gen[b] for b in pref},
                "t_disp": time.perf_counter()}

    def _finish_window(self, pend: Dict[str, Any]) -> None:
        """Complete a dispatched prefill window: advance per-row prompt
        positions and flip rows whose prompt just finished into decode
        (their first-token logits seed the stream). Async mode runs this
        one cycle AFTER the dispatch — the window launch precedes the next
        chunk on the pool's dependency chain, so by then its outputs are
        ready and the ``np.asarray`` below does not stall the loop — and
        scatters the transitions onto the device carry."""
        first = np.asarray(pend["first"])
        now = time.perf_counter()
        t_rows, t_len, t_last, t_rem = [], [], [], []
        done = []
        for b in pend["rows"]:
            if self._slot_gen[b] != pend["gen"][b] \
                    or self._slot_phase[b] != "prefill":
                continue                    # preempted since the dispatch
            prompt = self._slot_prompt[b]
            self._pref_pos[b] += pend["k"][b]
            self._lengths[b] = self._pref_pos[b]
            done.append(b)
            if self._tr is not None:
                self._tr.add("prefill_window", f"slot{b}",
                             pend["t_disp"], now,
                             {"req": self._slot_req[b].id,
                              "pos": int(self._pref_pos[b])})
            if self._pref_pos[b] >= len(prompt):
                req = self._slot_req[b]
                self._slot_phase[b] = "decode"
                self._last[b] = first[b]
                self._rem[b] = req.max_new - 1
                self._slot_out[b].append(int(first[b]))
                req.state = "decoding"
                self._note_first_token(req, now)
                if self._tr is not None:
                    self._phase_end(b, now, req)     # close "prefill"
                    self._phase_begin(b, "decode", now)
                self._wp_valid[b] = False
                self._register_prefix(b)
                t_rows.append(b)
                t_len.append(int(self._lengths[b]))
                t_last.append(int(first[b]))
                t_rem.append(req.max_new - 1)
        if self.async_decode and t_rows:
            self._scatter_carry(t_rows, t_len, t_last, t_rem,
                                pad_to=len(self._slot_req))
        self._log("prefill_chunk", pend["token"],
                  [(b, int(self._pref_pos[b])) for b in done])

    def _victim_score(self, v: int):
        """Cost-model preemption order (ascending = preempt FIRST). A
        victim is scored ``(tier, work lost net of blocks reclaimed,
        prior preemptions, age)``: best-effort tiers are always victimized
        before SLO tiers (tier-0 residents survive mixed-tier overload),
        then the row losing the least generated work per block reclaimed
        goes first, prior preemptions and youngest id as deterministic
        tiebreaks. Replaces the pure youngest-first rule, which happily
        evicted a tier-0 resident to feed a best-effort grow.

        Work-lost MUST outrank prior-preemption count: two same-tier
        residents contending for the same blocks alternate preemptions,
        so their counts leapfrog (c vs c+1) and a count-first order makes
        the established row score itself cheapest every time it grows —
        both rows self-evict forever (admit/replay livelock, zero
        retirements). Work-lost-first protects whichever row is furthest
        along, which is exactly the monotonic-progress guarantee the old
        youngest-first rule provided within a tier."""
        req = self._slot_req[v]
        out = self._slot_out[v]
        produced = len(out) if out is not None else 0
        blocks = self._slot_blocks[v] if self.paged else None
        held = len(blocks) if blocks is not None else 0
        return (-req.priority, produced - held, req.preempted_count,
                -req.id)

    def _grow_or_preempt(self, pf) -> None:
        """Phase 2 of two-phase admission: grant each decoding row the
        blocks the NEXT decode chunk will write into, oldest row first
        (lazy growth — a row crosses into a new block every ``block_size``
        tokens). Pool exhaustion preempts the best COST-MODEL victim
        (:meth:`_victim_score`: best-effort tier first, then least work
        lost per block reclaimed) back onto the wait queue instead of
        deadlocking: its blocks free immediately, the surviving rows keep
        decoding, and the preempted request re-runs from scratch later
        (greedy decode is deterministic, so its tokens are unchanged). A
        row never preempts a victim of a STRICTLY better (lower) tier —
        it stalls instead, so tier-0 residents are never evicted by
        best-effort growth.

        Async refinements: a growth failure while blocks sit behind the
        deferred-free fence STALLS the row (``rem`` masked to 0 on device,
        the balance parked in ``_stall_rem``) instead of preempting —
        preempting on in-transit memory could cascade into the oldest row
        evicting itself and replaying forever. Stalled rows retry here
        every cycle and resume the moment growth succeeds; their unmet
        block demand is RESERVED in the pool (oldest-stalled-first, since
        this pass runs in age order) so concurrent admissions cannot
        snatch the blocks the fence releases."""
        bs = self._pool.block_size
        n = self.decode_chunk
        fi = self._fi
        if fi is not None:
            if fi.fire("evict") and self._prefix is not None:
                self._prefix.evict(1)      # forced parked-prefix eviction
            if fi.fire("preempt"):
                live = [v for v in range(len(self._slot_req))
                        if self._slot_req[v] is not None]
                if live:
                    self._preempt(min(live, key=self._victim_score), pf)
        grow_rows: List[int] = []
        grow_cols: List[int] = []
        grow_ids: List[int] = []
        stall_rows: List[int] = []
        stall_vals: List[int] = []
        order = sorted((b for b in range(len(self._slot_req))
                        if self._slot_phase[b] == "decode"
                        and (self._rem[b] > 0 or self._stall_rem[b] > 0)),
                       key=lambda b: self._slot_req[b].id)
        # cost-model victim order, computed ONCE per cycle; slots preempted
        # along the way are skipped by the slot_req check
        victims = sorted((v for v in range(len(self._slot_req))
                          if self._slot_req[v] is not None),
                         key=self._victim_score)
        vi = 0
        for b in order:
            if self._slot_req[b] is None:
                continue                    # preempted as a victim already
            rem_b = int(self._rem[b]) + int(self._stall_rem[b])
            k = int(min(n, rem_b))
            need = (int(self._lengths[b]) + k - 1) // bs + 1
            cur = len(self._slot_blocks[b])
            covered = need <= cur
            while need > cur:
                if fi is not None and fi.fire("grow_fail"):
                    ids = None             # injected growth failure
                else:
                    # stalled/starved rows drain the reservation floor here
                    # (use_reserved): the pass runs oldest-first, so the
                    # oldest stalled row gets first claim on fence releases
                    ids = self._pool.grow_table(self._slot_blocks[b],
                                                need - cur,
                                                use_reserved=True)
                if ids is not None:
                    self._tables[b, cur:need] = ids
                    grow_rows.extend([b] * len(ids))
                    grow_cols.extend(range(cur, need))
                    grow_ids.extend(ids)
                    with self._state_lock:
                        self.stats["grown_blocks"] += len(ids)
                    if self._mh is not None:
                        self._mh["grown_blocks"].inc(len(ids))
                    covered = True
                    break
                if self._prefix is not None \
                        and self._prefix.evict(need - cur) > 0:
                    continue    # cold parked prefix blocks released: retry
                    # growth before stalling or preempting ANY resident row
                if self.async_decode and self._pool.num_deferred > 0:
                    break       # blocks in transit behind the fence: stall
                while vi < len(victims) \
                        and self._slot_req[victims[vi]] is None:
                    vi += 1
                if vi == len(victims):
                    break                   # nothing left to preempt
                victim = victims[vi]
                if self._slot_req[victim].priority \
                        < self._slot_req[b].priority:
                    # every remaining victim is of a strictly better tier
                    # than the grower (victims are ordered best-effort
                    # first): stall b rather than evict an SLO resident
                    break
                vi += 1
                self._preempt(victim, pf)
                if victim == b:
                    break                   # b itself was the best victim
            if self._slot_req[b] is None:
                continue                    # b preempted itself
            if covered:
                if self._stall_rem[b]:      # fence released: resume the row
                    self._rem[b] += self._stall_rem[b]
                    self._stall_rem[b] = 0
                    stall_rows.append(b)
                    stall_vals.append(int(self._rem[b]))
                    if self._tr is not None:
                        _t = time.perf_counter()
                        self._phase_end(b, _t, self._slot_req[b])  # stalled
                        self._phase_begin(b, "decode", _t)
                    self._log("resume", pf.token, b)
            elif self._rem[b] > 0:
                # newly stalled: mask the row out of the next dispatch
                self._stall_rem[b] = int(self._rem[b])
                self._rem[b] = 0
                stall_rows.append(b)
                stall_vals.append(0)
                with self._state_lock:
                    self.stats["stalls"] += 1
                if self._mh is not None:
                    self._mh["stalled"].inc()
                if self._tr is not None:
                    _t = time.perf_counter()
                    self._phase_end(b, _t, self._slot_req[b])  # close decode
                    self._phase_begin(b, "stalled", _t)
                self._log("stall", pf.token, b)
        # stalled-row reservation floor: the total block demand the pass
        # could not meet stays invisible to the admit stage until the
        # stalled rows (served oldest-first above) have been fed — the
        # structural fix for the admit-vs-stalled-row race
        unmet = 0
        for b in range(len(self._slot_req)):
            if self._stall_rem[b] > 0 and self._slot_req[b] is not None:
                k = int(min(n, self._stall_rem[b]))
                need = (int(self._lengths[b]) + k - 1) // bs + 1
                unmet += max(0, need - len(self._slot_blocks[b]))
        self._pool.set_reserved(unmet)
        if stall_rows and self.async_decode:
            # fixed-shape rem-only carry scatter (lengths/last unchanged —
            # `last` is device-only in async mode; pad with repeats)
            B = len(self._slot_req)
            rows = stall_rows + [stall_rows[-1]] * (B - len(stall_rows))
            vals = stall_vals + [stall_vals[-1]] * (B - len(stall_vals))
            ln, la, rm = self._carry
            self._carry = (ln, la, self._set_rem(
                rm, jnp.asarray(rows, jnp.int32),
                jnp.asarray(vals, jnp.int32)))
        if grow_rows:
            # device-side per-row table extension: the resident table array
            # is updated in place, not re-uploaded. Padded with repeats
            # (idempotent duplicate writes) to the worst-case burst size so
            # the scatter compiles exactly ONE shape per engine.
            self._log("grow", pf.token, list(zip(grow_rows, grow_ids)))
            m = self._grow_burst_max
            while len(grow_rows) < m:
                grow_rows.append(grow_rows[-1])
                grow_cols.append(grow_cols[-1])
                grow_ids.append(grow_ids[-1])
            self._tables_dev = self._extend_tables(
                self._tables_dev, jnp.asarray(grow_rows, jnp.int32),
                jnp.asarray(grow_cols, jnp.int32),
                jnp.asarray(grow_ids, jnp.int32))

    def _cow_guard(self, pf) -> None:
        """Copy-on-write safety net, run BEFORE the window-prefill and
        decode-chunk dispatches each cycle: any row about to WRITE into a
        block that is still shared (refcount > 1) forks it first — device
        block copy, table repoint (host mirror + device scatter), one
        reference dropped on the original. Structurally this never fires
        on the engine's own flows (admission forks partial tail blocks
        eagerly at the merge, and FULL shared prefix blocks are never
        written again by construction — decode appends land strictly past
        the prompt), but ``append_kv`` into a shared block corrupting a
        co-holder would be silent and unbounded, so the invariant is
        enforced here unconditionally (tests trigger it via an artificial
        ``incref``)."""
        if self._prefix is None:
            return
        bs = self._pool.block_size
        srcs, dsts, rows, cols = [], [], [], []
        for b in range(len(self._slot_req)):
            if self._slot_req[b] is None or self._slot_blocks[b] is None:
                continue
            if self._slot_phase[b] == "decode":
                lo = int(self._lengths[b])
                k = int(min(self.decode_chunk,
                            int(self._rem[b]) + int(self._stall_rem[b])))
            elif self._slot_phase[b] == "prefill":
                lo = int(self._pref_pos[b])
                k = int(min(self.prefill_chunk,
                            len(self._slot_prompt[b]) - lo))
            else:
                continue
            if k <= 0:
                continue
            blocks = self._slot_blocks[b]
            hi = min((lo + k - 1) // bs + 1, len(blocks))
            for col in range(lo // bs, hi):
                old = blocks[col]
                if self._pool.refcount(old) <= 1:
                    continue
                ids = self._pool.alloc(1)
                if ids is None:
                    self._prefix.evict(1)
                    ids = self._pool.alloc(1)
                if ids is None:
                    # cannot fork and must not write the shared block:
                    # requeue the row, it replays later (deterministic)
                    self._preempt(b, pf)
                    break
                new = ids[0]
                blocks[col] = new
                self._tables[b, col] = new
                srcs.append(old)
                dsts.append(new)
                rows.append(b)
                cols.append(col)
                # drop OUR reference on the original (co-holders keep it
                # alive; refcount stays >= 1 so nothing is released here)
                if self.async_decode:
                    self._pool.free_deferred([old])
                else:
                    self._pool.free([old])
                with self._state_lock:
                    self.stats["cow_forks"] += 1
                if self._tr is not None:
                    self._tr.instant("cow_fork", f"slot{b}",
                                     time.perf_counter(),
                                     {"req": self._slot_req[b].id,
                                      "src": int(old), "dst": int(new)})
        # a row preempted mid-pass (fork allocation failure) zeroed its
        # table and freed its blocks — drop its queued forks
        live = [j for j in range(len(rows))
                if self._slot_req[rows[j]] is not None]
        if len(live) < len(rows):
            srcs = [srcs[j] for j in live]
            dsts = [dsts[j] for j in live]
            rows = [rows[j] for j in live]
            cols = [cols[j] for j in live]
        if srcs:
            self._copy_blocks_padded(srcs, dsts)
            # device table repoint, padded with repeats (idempotent) to a
            # power of two like the copy
            m = 1 << max(0, len(rows) - 1).bit_length()
            ids2 = list(dsts)
            while len(rows) < m:
                rows.append(rows[-1])
                cols.append(cols[-1])
                ids2.append(ids2[-1])
            self._tables_dev = self._extend_tables(
                self._tables_dev, jnp.asarray(rows, jnp.int32),
                jnp.asarray(cols, jnp.int32), jnp.asarray(ids2, jnp.int32))

    def _clear_row_dev(self, slot: int) -> None:
        """Zero one vacated seat's device state: its block-table row
        (paged) and its carry row (async). Both scatters are PADDED to
        the admission cap with duplicate rows (idempotent writes) so
        they reuse the merge's compiled shapes — a 1-row scatter here
        would JIT-compile on the engine's FIRST preemption/eviction,
        a ~100ms+ stall landing exactly in the overloaded decode cycle
        the preemption was meant to relieve (it showed up as a 10x+
        tier-0 TTFT outlier in ``benchmarks/serve_slo.py``)."""
        A = self._scheduler.max_admit
        if self.paged:
            self._tables_dev = self._set_rows(
                self._tables_dev, jnp.asarray([slot] * A, jnp.int32),
                jnp.zeros((A, self._tables.shape[1]), jnp.int32))
        if self.async_decode:
            self._scatter_carry([slot], [0], [0], [0], pad_to=A)

    def _preempt(self, slot: int, pf) -> None:
        req = self._slot_req[slot]
        if not self.paged and not self.async_decode \
                and self._slot_phase[slot] == "decode":
            # SSM/hybrid sync mode: recurrent state is O(1)/seq, so a
            # CHECKPOINT preemption is cheap — capture the slot's exact
            # state + progress and re-seat it at the next admission with
            # no prefill replay. Async falls through to plain replay (the
            # in-flight chunk already advanced the donated state past the
            # host mirrors, so a capture here would be stale).
            req._ssm_ckpt = (self._save_slot_state(slot),
                             int(self._lengths[slot]),
                             int(self._last[slot]), int(self._rem[slot]),
                             list(self._slot_out[slot] or []))
        with self._state_lock:
            self._slot_req[slot] = None
            self._slot_out[slot] = None
            self._slot_phase[slot] = None
            if self.paged:
                if self.async_decode:
                    # deferred-free FENCE: the chunk in flight at
                    # preemption time (and any prefill window launched
                    # this cycle) may still write these blocks — they
                    # return to the pool only after the engine has synced
                    # past that device work
                    self._pool.free_deferred(self._slot_blocks[slot])
                else:
                    self._pool.free(self._slot_blocks[slot])
                self._slot_blocks[slot] = None
            self._free_slots.append(slot)
            self._inflight.discard(req)
            self.stats["preempted"] += 1
        self._slot_gen[slot] += 1      # in-flight tokens become surplus
        req.preempted_count += 1
        self._lengths[slot] = 0
        self._last[slot] = 0
        self._rem[slot] = 0
        if self.paged:
            self._slot_prompt[slot] = None
            self._wp_valid[slot] = False
            self._tables[slot] = 0
            self._stall_rem[slot] = 0
            self._pref_pos[slot] = 0
        self._clear_row_dev(slot)
        if self._mh is not None:
            self._mh["preempted"].inc()
            self._note_resident()
        if self._tr is not None:
            _t = time.perf_counter()
            self._phase_end(slot, _t, req)
            self._tr.instant("preempted", f"slot{slot}", _t, {"req": req.id})
        self._scheduler.requeue_front([req])
        self._log("preempt", pf.token, req.id)

    def _evict_row(self, slot: int, pf, err: BaseException,
                   kind: str) -> None:
        """Cancel/expire a SEATED row mid-flight: release its blocks/slot
        through the same paths preemption uses (deferred-free fence in
        async mode, seat-generation bump so in-flight chunk tokens are
        discarded) but fail the request typed instead of re-queueing it.
        ``kind`` is the stats/counter key (``"cancelled"``/``"expired"``).
        Works for both the paged and the SSM slot-state pools."""
        req = self._slot_req[slot]
        with self._state_lock:
            self._slot_req[slot] = None
            self._slot_out[slot] = None
            self._slot_phase[slot] = None
            if self.paged:
                if self.async_decode:
                    self._pool.free_deferred(self._slot_blocks[slot])
                else:
                    self._pool.free(self._slot_blocks[slot])
                self._slot_blocks[slot] = None
            self._free_slots.append(slot)
            self._inflight.discard(req)
            self.stats[kind] += 1
        self._slot_gen[slot] += 1      # in-flight tokens become surplus
        self._lengths[slot] = 0
        self._last[slot] = 0
        self._rem[slot] = 0
        if self.paged:
            self._slot_prompt[slot] = None
            self._wp_valid[slot] = False
            self._tables[slot] = 0
            self._stall_rem[slot] = 0
            self._pref_pos[slot] = 0
        self._clear_row_dev(slot)
        req.set_error(err)
        if self._journal is not None:
            self._journal.cancel(req, kind)
        if self._mh is not None:
            self._mh[kind].inc()
            self._note_resident()
        if self._tr is not None:
            _t = time.perf_counter()
            self._phase_end(slot, _t, req)
            self._tr.instant(kind, f"slot{slot}", _t, {"req": req.id})
        self._log(kind, pf.token, req.id)

    def _sweep_seated(self, pf) -> None:
        """Per-cycle SLO sweep, run in the SERIAL decode stage BEFORE the
        chunk dispatch (so the eviction scatters are sequenced ahead of
        it): cancel-requested rows and rows whose deadline elapsed
        mid-prefill/mid-decode are evicted — blocks and slot reclaimed
        through the normal (fence-aware) path, future failed typed. Also
        sweeps the WAITING queues so queued deadlines fire promptly even
        while admission is parked, and runs the admission-BOOST pass: a
        waiting head of a strictly better tier than the worst seated row
        must not wait out that row's whole decode when the batch is full,
        so the cost-model victim is preempted now and the next admit
        cycle seats the head (the victim replays later, bit-identically
        — greedy decode is deterministic)."""
        now = time.perf_counter()
        for b in range(len(self._slot_req)):
            req = self._slot_req[b]
            if req is None:
                continue
            if req._cancel_requested:
                self._evict_row(b, pf, RequestCancelled(
                    f"request {req.id} cancelled while {req.state}"),
                    "cancelled")
            elif req.expired(now):
                self._evict_row(b, pf, DeadlineExceeded(
                    f"request {req.id} deadline ({req.deadline_s:.3f}s) "
                    f"expired while {req.state} "
                    f"({now - (req.submitted_at or now):.3f}s after "
                    f"submit)"), "expired")
        self._scheduler.expire_waiting(now)
        if self._draining and self._drain_deadline_at is not None \
                and now >= self._drain_deadline_at:
            # drain deadline: checkpoint-preempt every resident (SSM sync
            # rows capture exact state; paged/async rows will replay) so
            # drain() can settle and the snapshot captures them as
            # waiting-queue descriptors. Runs here — the SERIAL decode
            # stage is the single writer of slot state — never on the
            # drain() caller thread.
            n = 0
            for b in range(len(self._slot_req)):
                if self._slot_req[b] is None:
                    continue
                self._preempt(b, pf)
                n += 1
            if n:
                with self._state_lock:
                    self.stats["drain_preempted"] += n
            return
        head = self._scheduler.peek_head()
        if head is None:
            return
        with self._state_lock:
            full = len(self._free_slots) <= self._slots_reserved
        if not full:
            return
        live = [v for v in range(len(self._slot_req))
                if self._slot_req[v] is not None]
        if not live:
            return
        victim = min(live, key=self._victim_score)
        if self._slot_req[victim].priority > head.priority:
            # one victim per cycle: enough to keep the SLO tier's TTFT
            # bounded by a cycle, without churning the whole batch
            self._preempt(victim, pf)

    def _isolate_failure(self, pf, exc: BaseException):
        """Per-row failure isolation: a raising decode/merge/sync step
        fails ONLY the rows it could have corrupted — every SEATED row and
        every admitted-but-unmerged group — with a typed
        :class:`RowFailed` (``__cause__`` carries the original exception),
        then rebuilds the device-resident state from scratch and keeps the
        engine serving: the WAITING queues survive untouched and re-run
        bit-identically (greedy decode is deterministic).

        The full rebuild (fresh block pool + zeroed KV pool) is not
        pessimism: the failed chunk call DONATED ``self._pkv``, so the old
        pool buffer is invalid whether or not the failure touched it. The
        reset epoch is bumped under the state lock — in-flight retire
        payloads and admitted groups from the old epoch are dropped at
        their epoch checks instead of freeing dead block ids into the
        fresh pool."""
        err = RowFailed(
            f"model step failed ({exc!r}); this row's seat was torn down "
            f"and the engine kept serving")
        err.__cause__ = exc
        B = len(self._slot_gen)
        now = time.perf_counter()
        # fresh device state FIRST, outside the lock (big allocations);
        # swapped in atomically below
        if self.paged:
            kv_blocks, block_size = self._kv_geom
            new_pool = BlockPool(kv_blocks, block_size)
            new_pkv = init_kv_pool(self.cfg, kv_blocks, block_size)
        else:
            new_state = {k: v
                         for k, v in lm.init_cache(self.cfg, B,
                                                   self._max_seq).items()
                         if k != "pos"}
        with self._state_lock:
            self._reset_epoch += 1
            seated = [(b, r) for b, r in enumerate(self._slot_req)
                      if r is not None]
            pre = [r for _, reqs in self._premerge.values() for r in reqs]
            self._premerge.clear()
            victims = {r.id: r for _, r in seated}
            victims.update((r.id, r) for r in pre)
            for r in victims.values():
                self._inflight.discard(r)
            self._slot_req = [None] * B
            self._slot_out = [None] * B
            self._slot_phase = [None] * B
            self._free_slots = list(range(B - 1, -1, -1))
            self._slots_reserved = 0
            if self.paged:
                self._pool = new_pool
                self._slot_blocks = [None] * B
            self.stats["row_failures"] += len(victims)
        # host mirrors + device arrays: decode-stage-owned, safe unlocked
        self._slot_gen += 1            # all in-flight tokens are surplus
        self._lengths[:] = 0
        self._last[:] = 0
        self._rem[:] = 0
        self._pending = None
        self._window_pending = None
        metrics = self.obs.metrics if self.obs is not None else None
        if self.paged:
            self._pkv = self._place_pool(new_pkv)
            self._stall_rem[:] = 0
            self._pref_pos[:] = 0
            self._wp_valid[:] = False
            self._tables[:] = 0
            self._tables_dev = self._dev(
                np.zeros(self._tables.shape, np.int32))
            self._slot_prompt = [None] * B
            self._pool.set_metrics(metrics)
            if self.prefix_cache:
                self._prefix = PrefixCache(self._pool)
                self._prefix.set_metrics(metrics)
        else:
            self._sstate = new_state if self._repl_ns is None \
                else jax.device_put(new_state, self._repl_ns)
        if self.async_decode:
            self._carry = (self._dev(np.zeros((B,), np.int32)),
                           self._dev(np.zeros((B,), np.int32)),
                           self._dev(np.zeros((B,), np.int32)))
        for b, r in seated:
            if self._tr is not None:
                self._phase_end(b, now, r)
        for r in victims.values():
            r.set_error(err)
        if self._mh is not None and victims:
            self._mh["row_failed"].inc(len(victims))
            self._note_resident()
        if self._tr is not None:
            self._tr.instant("row_failure_reset", TRACK_ENGINE, now,
                             {"failed": sorted(victims),
                              "epoch": self._reset_epoch,
                              "cause": repr(exc)})
        self._log("row_failure", pf.token,
                  {"failed": sorted(victims), "cause": repr(exc)})
        return ("cycle", (self._reset_epoch, []))

    def _st_decode(self, pf, msg):
        self._wd_beat = time.perf_counter()
        try:
            if self.async_decode:
                out = self._st_decode_async(pf, msg)
            else:
                out = self._st_decode_sync(pf, msg)
        except Exception as exc:       # per-row failure isolation
            out = self._isolate_failure(pf, exc)
        self._wd_beat = time.perf_counter()
        return out

    def _st_decode_sync(self, pf, msg):
        t0 = time.perf_counter()
        kind, payload = msg
        if kind == "admit":
            if self.paged:
                self._merge_group(pf, payload)
            else:
                self._merge_group_slots(pf, payload)
        self._sweep_seated(pf)
        if self.paged:
            tg0 = time.perf_counter()
            self._cow_guard(pf)
            self._window_prefill_step(pf)
            self._grow_or_preempt(pf)
            if self._tr is not None:
                self._tr.add("growth", TRACK_ENGINE, tg0,
                             time.perf_counter())
        rem_before = self._rem.copy()
        if not (rem_before > 0).any():
            self._log("decode", pf.token, 0)
            return ("cycle", (self._reset_epoch, self._collect_finished()))
        n = self.decode_chunk
        t1 = time.perf_counter()
        if self.paged:
            pkv, tok, ln, rm, toks = self._decode_paged(
                self.params, self._pkv, self._tables_dev,
                jnp.asarray(self._lengths), jnp.asarray(self._last),
                jnp.asarray(self._rem), n=n)
            self._pkv = pkv
        else:
            st, tok, ln, rm, toks = self._decode_slots(
                self.params, self._sstate, jnp.asarray(self._last),
                jnp.asarray(self._lengths), jnp.asarray(self._rem), n=n)
            self._sstate = st
        t1b = time.perf_counter()      # carry uploads + launch: device idle
        if self._fi is not None:       # chunk-sync fault sites
            if self._fi.fire("chunk_latency"):
                time.sleep(self._fi.latency_s("chunk_latency"))
            if self._fi.fire("chunk_sync_exc"):
                raise FaultInjected("chunk_sync_exc")
            if self._fi.fire("crash_at"):
                os._exit(137)          # hard mid-stream death, no cleanup
        toks = np.asarray(toks)        # (B, n): the chunk's device sync
        t2a = time.perf_counter()
        # np.array (not asarray): device views are read-only and these
        # mirrors are mutated by the next cycle's merge
        self._last = np.array(tok)
        self._lengths = np.array(ln)
        self._rem = np.array(rm)
        t2 = time.perf_counter()
        emitted = 0
        for b in np.nonzero(rem_before > 0)[0]:
            k = int(min(n, rem_before[b]))
            self._slot_out[b].extend(toks[b, :k].tolist())
            emitted += k
        with self._state_lock:
            self.stats["decode_cycles"] += 1
            self.stats["tokens_out"] += emitted
        retire = self._collect_finished()
        t3 = time.perf_counter()
        self._note_rate(emitted, t3 - t0)
        o = self.overlap_stats
        o["cycles"] += 1
        # dispatch_s here = mirror uploads + launch; under CPU contention
        # the chunk starts computing mid-interval, so it is EXCLUDED from
        # the gap (conservative: the true sync gap is larger)
        o["dispatch_s"] += t1b - t1
        o["wait_s"] += t2a - t1b
        o["book_s"] += (t1 - t0) + (t2 - t2a) + (t3 - t2)
        # sync-mode host gap: pre-work, the mirror download copies and all
        # bookkeeping run with nothing queued on the device — the gap the
        # async mode exists to close
        o["gap_s"] += (t1 - t0) + (t2 - t2a) + (t3 - t2)
        o["total_s"] += t3 - t0
        chunk_s = t2a - t1             # upload + launch + block: the device
        if o["min_chunk_s"] == 0.0 or chunk_s < o["min_chunk_s"]:
            o["min_chunk_s"] = chunk_s  # cleanest (least contended) sample
        if self._mh is not None:
            mh = self._mh
            mh["cycle"].record(t3 - t0)
            mh["dispatch"].record(t1b - t1)
            mh["sync"].record(t2a - t1b)
            mh["book"].record((t1 - t0) + (t2 - t2a) + (t3 - t2))
            mh["gap"].record((t1 - t0) + (t2 - t2a) + (t3 - t2))
            mh["chunk"].record(chunk_s)
            mh["tokens_out"].inc(emitted)
        if self._tr is not None:
            tr = self._tr
            tr.add("cycle", TRACK_ENGINE, t0, t3, {"emitted": emitted})
            tr.add("dispatch", TRACK_ENGINE, t1, t1b)
            tr.add("sync", TRACK_ENGINE, t1b, t2a)
            tr.add("bookkeeping", TRACK_ENGINE, t2a, t3)
        self._log("decode", pf.token, emitted)
        return ("cycle", (self._reset_epoch, retire))

    def _st_decode_async(self, pf, msg):
        """Async decode lookahead (pipeline depth 2): dispatch chunk N+1
        FIRST — JAX async dispatch queues it behind the in-flight chunk N,
        so the device-side dependency chain never drains — then sync chunk
        N's tokens and do all host bookkeeping (emit tokens, retire
        finished rows, advance the deferred-free fence) while N+1 runs.
        Admission merges, streamed prefill windows and table growth are
        sequenced BEFORE the dispatch; retirement takes effect one chunk
        late (already masked on device by ``rem == 0``); a preempted row's
        in-flight tokens are discarded via the seat-generation guard."""
        t0 = time.perf_counter()
        kind, payload = msg
        pend = self._pending
        device_idle = (pend is None or bool(pend["toks"].is_ready())) \
            and self._window_pending is None
        # ---- pre-dispatch: everything chunk N+1 must observe ----
        wpend, self._window_pending = self._window_pending, None
        if wpend is not None:
            self._finish_window(wpend)
        if kind == "admit":
            if self.paged:
                self._merge_group(pf, payload)
            else:
                self._merge_group_slots(pf, payload)
        self._sweep_seated(pf)
        if self.paged:
            tg0 = time.perf_counter()
            self._cow_guard(pf)
            self._window_pending = self._dispatch_window_prefill(pf)
            self._grow_or_preempt(pf)
            if self._tr is not None:
                self._tr.add("growth", TRACK_ENGINE, tg0,
                             time.perf_counter())
        # ---- dispatch chunk N+1 (the device never waits on the host
        # bookkeeping below) ----
        n = self.decode_chunk
        new_pend = None
        t1 = time.perf_counter()
        if (self._rem > 0).any():
            rem_before = self._rem.copy()
            if self.paged:
                pkv, tok, ln, rm, toks = self._decode_paged(
                    self.params, self._pkv, self._tables_dev,
                    *self._carry, n=n)
                self._pkv = pkv
            else:
                lengths, last, rem = self._carry
                st, tok, ln, rm, toks = self._decode_slots(
                    self.params, self._sstate, last, lengths, rem, n=n)
                self._sstate = st
            self._carry = (ln, tok, rm)
            # advance the host lengths/rem mirrors deterministically (the
            # chunk's length/rem arithmetic is token-independent); the
            # host `last` mirror stays stale — it is never read in async
            # mode, the device carry is authoritative
            adv = np.minimum(n, rem_before)
            self._lengths += adv
            self._rem -= adv
            new_pend = {"toks": toks, "rem_before": rem_before,
                        "gen": self._slot_gen.copy(), "token": pf.token}
            with self._state_lock:
                self.stats["decode_cycles"] += 1
            self._log("dispatch", pf.token, int((rem_before > 0).sum()))
        t2 = time.perf_counter()
        # ---- sync chunk N + host bookkeeping (overlaps N+1 on device) ----
        emitted = 0
        wait_s = 0.0
        if pend is not None:
            ts = time.perf_counter()
            if self._fi is not None:   # chunk-sync fault sites
                if self._fi.fire("chunk_latency"):
                    time.sleep(self._fi.latency_s("chunk_latency"))
                if self._fi.fire("chunk_sync_exc"):
                    raise FaultInjected("chunk_sync_exc")
                if self._fi.fire("crash_at"):
                    os._exit(137)      # hard mid-stream death, no cleanup
            toks = np.asarray(pend["toks"])
            wait_s = time.perf_counter() - ts
            for b in np.nonzero(pend["rem_before"] > 0)[0]:
                if self._slot_gen[b] != pend["gen"][b]:
                    continue    # seat changed since dispatch: surplus tokens
                k = int(min(n, pend["rem_before"][b]))
                self._slot_out[b].extend(toks[b, :k].tolist())
                emitted += k
            with self._state_lock:
                self.stats["tokens_out"] += emitted
            self._log("sync", pf.token, (pend["token"], emitted))
        self._pending = new_pend
        retire = self._collect_finished()
        if self.paged and (pend is not None or (
                new_pend is None and self._window_pending is None)):
            # fence advance: a chunk was synced (or nothing is in flight
            # at all) — blocks deferred two advances ago are now provably
            # past every device write that could touch them
            self._pool.release_deferred()
        t3 = time.perf_counter()
        self._note_rate(emitted, t3 - t0)
        o = self.overlap_stats
        o["cycles"] += 1
        o["dispatch_s"] += t2 - t1
        o["wait_s"] += wait_s
        o["book_s"] += (t1 - t0) + (t3 - t2 - wait_s)
        gap = 0.0
        if device_idle:
            gap += t1 - t0          # nothing in flight during pre-dispatch
        if new_pend is None:
            gap += t3 - t2 - wait_s  # nothing in flight during bookkeeping
        o["gap_s"] += gap
        o["total_s"] += t3 - t0
        if self._mh is not None:
            mh = self._mh
            mh["cycle"].record(t3 - t0)
            mh["dispatch"].record(t2 - t1)
            mh["sync"].record(wait_s)
            mh["book"].record((t1 - t0) + (t3 - t2 - wait_s))
            mh["gap"].record(gap)
            mh["tokens_out"].inc(emitted)
        if self._tr is not None:
            tr = self._tr
            tr.add("cycle", TRACK_ENGINE, t0, t3, {"emitted": emitted})
            if new_pend is not None:
                tr.add("dispatch", TRACK_ENGINE, t1, t2)
            if pend is not None:
                tr.add("sync", TRACK_ENGINE, ts, ts + wait_s)
            tr.add("bookkeeping", TRACK_ENGINE, t2, t3)
        self._log("decode", pf.token, emitted)
        return ("cycle", (self._reset_epoch, retire))

    def _collect_finished(self) -> List[tuple]:
        """Rows that hit rem==0: detach them from the batch (their slot
        stays reserved until complete frees it) and zero their mirrors —
        still inside the SERIAL decode stage (single-writer); the
        gather-free read paths bound their page loop by max(lengths), so a
        retired slot must not keep advertising its old length.

        Async mode retires one chunk LATE by construction: a row that hit
        ``rem == 0`` during chunk N is collected only after N's sync —
        rows still finishing inside the freshly dispatched chunk (or
        stalled behind the deferred-free fence) are skipped, and the
        zeroing scatters land on the in-flight chunk's OUTPUT carry/
        tables (the retired rows are already inactive in that chunk), so
        the detach never races device work."""
        pend = self._pending
        retire = []
        zero_rows = []
        for b in range(len(self._rem)):
            if self._slot_req[b] is None or self._slot_phase[b] != "decode" \
                    or self._rem[b] != 0:
                continue
            if self.paged and self._stall_rem[b] > 0:
                continue        # stalled (fence or tier guard), not finished
            if pend is not None and pend["rem_before"][b] > 0:
                continue        # active in the in-flight chunk: next cycle
            req = self._slot_req[b]
            out = np.asarray(self._slot_out[b], np.int32)
            with self._state_lock:
                self._slot_req[b] = None
                self._slot_out[b] = None
                self._slot_phase[b] = None
            self._slot_gen[b] += 1
            self._lengths[b] = 0
            self._last[b] = 0
            if self.paged:
                self._tables[b] = 0
                self._pref_pos[b] = 0
                self._slot_prompt[b] = None
            zero_rows.append(b)
            retire.append((b, req, out))
            if self._tr is not None:
                _t = time.perf_counter()
                self._phase_end(b, _t, req)
                self._tr.instant("retired", f"slot{b}", _t,
                                 {"req": req.id, "tokens": len(out)})
        if zero_rows:
            # fixed-shape zeroing scatters (pad with repeats; idempotent)
            B = len(self._slot_req)
            z = [0] * len(zero_rows)
            if self.async_decode:
                self._scatter_carry(zero_rows, z, z, z, pad_to=B)
            if self.paged:
                rows = zero_rows + [zero_rows[-1]] * (B - len(zero_rows))
                self._tables_dev = self._set_rows(
                    self._tables_dev, jnp.asarray(rows, jnp.int32),
                    jnp.zeros((B, self._tables.shape[1]), jnp.int32))
        return retire

    def _st_complete(self, pf, msg):
        _, (epoch, retire) = msg
        now = time.perf_counter()
        for slot, req, out in retire:
            # a retiree's TOKENS are always valid (it finished before any
            # failure), so its future is fulfilled unconditionally; its
            # blocks/slot are reclaimed only if no failure-isolation reset
            # rebuilt the pool since the decode stage collected it (the
            # epoch check and the frees are atomic against the reset, which
            # swaps the pool under the same lock)
            self._scheduler.finish(req, out, now)
            if self._journal is not None:
                self._journal.finish(req, out)
            with self._state_lock:
                self._inflight.discard(req)
                self.stats["retired"] += 1
                if epoch == self._reset_epoch:
                    if self.paged:
                        self._pool.free(self._slot_blocks[slot])
                        self._slot_blocks[slot] = None
                    self._free_slots.append(slot)
        self._wd_beat = now
        with self._state_lock:
            self._cycle_tokens.discard(pf.token)
        if retire and self._mh is not None:
            self._mh["retired"].inc(len(retire))
            self._note_resident()
        self._log("complete", pf.token, len(retire))
        return None

    # --------------------------------------------------------------- pumping
    def _pump(self) -> None:
        ex = self._ensure_executor()
        pl = self._ensure_pipeline(ex)
        with self._pump_lock:
            if self._broken is not None or not pl.idle():
                return
            with self._state_lock:
                occupied = any(r is not None for r in self._slot_req)
            if self._scheduler.num_waiting == 0 and not occupied:
                return
            self._topo = pl.run(ex, self._on_topo_done)

    def _on_topo_done(self, topo) -> None:
        if topo.exceptions:
            err = topo.exceptions[0]
            self._broken = err
            self._fail_outstanding(err)
            return
        if self._scheduler.num_waiting:
            self._pump()   # a submit raced the stop-drain: re-arm

    def _fail_outstanding(self, err: BaseException) -> None:
        self._scheduler.fail_all_waiting(err)
        with self._state_lock:
            live = list(self._inflight)  # admitted: slotted or pre-merge
            self._inflight.clear()
        for r in live:
            r.set_error(err)

    # ----------------------------------------------------------- client API
    def _shed_budget_for(self, tier: int) -> Optional[float]:
        """Resolve the load-shed latency budget for a tier: a scalar
        budget applies to every tier, a dict only to its listed tiers
        (absent tiers are never shed)."""
        b = self._shed_budget
        if b is None:
            return None
        if isinstance(b, dict):
            v = b.get(tier)
            return float(v) if v is not None else None
        return float(b)

    def _note_rate(self, emitted: int, dt: float) -> None:
        """Fold one decode cycle into the observed service rate: emitted
        tokens over the cycle's WALL time (device chunk + host
        bookkeeping — the rate the backlog actually drains at). Cycles
        that emitted nothing (pure prefill/admission cycles) are skipped
        rather than averaged in as zero: they stall emission but their
        cost is already inside the neighbouring cycles' wall time."""
        if emitted <= 0 or dt <= 0.0:
            return
        r = emitted / dt
        a = self._rate_alpha
        self._decode_rate = r if self._decode_rate == 0.0 \
            else (1.0 - a) * self._decode_rate + a * r

    def _estimated_wait_s(self, priority: int) -> Optional[float]:
        """Admission-wait estimate for a NEW request at ``priority``.

        Primary model — SERVICE RATE: the engine's observed decode
        throughput (EWMA tokens/s over whole cycles, :meth:`_note_rate`)
        divides the work queued ahead of the request: every resident
        row's remaining decode steps (including fence-stalled balances)
        plus the ``max_new`` of everything waiting at tiers <= the
        request's. This tracks load directly — it rises the moment the
        backlog grows, rather than waiting for slow admissions to feed
        the queue-wait histogram.

        Fallback — the pre-existing p90-queue-wait heuristic (the p90 of
        ``serve.queue_wait_s`` scaled by the tier-visible backlog in
        admission waves), used only until the engine has emitted its
        first tokens. It still arms only after 8 recorded admissions, so
        a cold-start engine never sheds. Returns None when neither model
        has a signal."""
        rate = self._decode_rate
        if rate > 0.0:
            resident = 0
            # lock-free mirror reads (heuristic: same policy as the
            # watchdog's busy probe — at worst one cycle stale)
            for b in range(len(self._rem)):
                if self._slot_req[b] is None:
                    continue
                resident += int(self._rem[b])
                if self.paged:
                    resident += int(self._stall_rem[b])
            backlog = self._scheduler.waiting_tokens_upto(priority)
            return (resident + backlog) / rate
        if self._mh is None:
            return None
        h = self._mh["qwait"]
        if h.count < 8:
            return None
        base = h.percentile(90.0)
        backlog = self._scheduler.num_waiting_upto(priority)
        waves = 1.0 + backlog / float(self._scheduler.max_admit)
        return base * waves

    def _hopeless_why(self, r: ServeRequest) -> Optional[str]:
        """Preemption-aware deadline check at the admission head: a
        deadline request whose remaining budget cannot cover its
        estimated prefill + decode at the observed service rate is
        failed typed :class:`DeadlineExceeded` NOW, before it steals a
        slot (and possibly preempts a resident via the admission boost)
        only to expire mid-decode anyway. Conservative: with no rate
        signal yet (cold engine) nothing is ever hopeless."""
        if r.deadline_at is None:
            return None
        rate = self._decode_rate
        if rate <= 0.0:
            return None
        remaining = r.deadline_at - time.perf_counter()
        est = (r.prompt_len + r.max_new) / rate
        if est <= remaining:
            return None
        return (f"hopeless at admission: estimated prefill+decode "
                f"{est:.3f}s exceeds the remaining deadline budget "
                f"{remaining:.3f}s at the observed service rate "
                f"{rate:.1f} tok/s")

    def submit(self, prompt, max_new: int = 16, *,
               priority: int = 0,
               deadline_s: Optional[float] = None) -> ServeRequest:
        """Enqueue one generation request on the resident pipeline and
        return its future. Thread-safe; callable while earlier requests are
        mid-decode — that is the point. All architectures: paged attention
        KV for dense/MoE models, the fixed-slot recurrent-state pool for
        SSM/hybrid ones.

        ``priority`` is the scheduling tier (0 = highest; admission scans
        tiers in order, the preemption cost model victimizes the highest
        tier first). ``deadline_s`` is an optional latency bound from now:
        a request that has not completed within it fails typed
        :class:`DeadlineExceeded` whether it is queued or mid-decode, and
        its resources are reclaimed. When a shed budget is configured for
        the tier (``shed_budget_s``), an over-budget estimated queue wait
        raises :class:`Overloaded` HERE — synchronously, before the
        request ever queues — so callers can back off or retry elsewhere."""
        if self._broken is not None:
            raise RuntimeError("serve pipeline is broken") from self._broken
        if self._closing:
            raise EngineClosed("engine is closed")
        if self._draining:
            raise EngineClosed(
                "engine is draining: admission stopped; submit to another "
                "replica (residents run to the drain deadline)")
        req = ServeRequest(prompt, max_new, priority=priority,
                           deadline_s=deadline_s)
        total = req.prompt_len + req.max_new
        if total > self._max_seq:
            raise ValueError(
                f"prompt+max_new = {total} exceeds max_seq_len "
                f"{self._max_seq}")
        budget = self._shed_budget_for(req.priority)
        if budget is not None:
            est = self._estimated_wait_s(req.priority)
            limit = budget if deadline_s is None \
                else min(budget, deadline_s)
            if est is not None and est > limit:
                with self._state_lock:
                    self.stats["shed"] += 1
                if self._mh is not None:
                    self._mh["shed"].inc()
                depth = self._scheduler.num_waiting_upto(req.priority)
                raise Overloaded(
                    f"request shed at submit: estimated queue wait "
                    f"{est:.3f}s exceeds the tier-{req.priority} budget "
                    f"{limit:.3f}s (backlog {depth} at tiers <= "
                    f"{req.priority})",
                    tier=req.priority, est_wait_s=est, budget_s=limit,
                    queue_depth=depth)
        now = time.perf_counter()
        req.submitted_at = now
        if req.deadline_s is not None:
            req.deadline_at = now + req.deadline_s
        if self._journal is not None:
            self._journal.submit(req)
        self._wd_beat = now
        self._scheduler.enqueue(req)
        self._pump()
        return req

    def result(self, req: ServeRequest,
               timeout: Optional[float] = 300.0) -> np.ndarray:
        return req.result(timeout)

    def generate(self, prompts: List[Any], max_new: int) -> List[Any]:
        """Compatibility shim: submit every prompt, gather results in input
        order. Greedy tokens are bit-identical to the per-call engine this
        replaces (same compiled prefill math, same argmax chain — verified
        against the contiguous reference in tests)."""
        if not prompts:
            return []
        reqs = [self.submit(p, max_new) for p in prompts]
        return [self.result(r, timeout=600.0) for r in reqs]

    # -------------------------------------------- per-call baseline (bench)
    def _generate_grouped(self, prompts: List[Any], max_new: int
                          ) -> List[Any]:
        """PR 1's per-call pipeline: length groups flow admit -> prefill ->
        chunked contiguous decode -> complete through a throwaway
        DataPipeline. No longer a serving fallback (submit()/result() covers
        every arch through the resident pipeline); kept as the per-call
        BASELINE the serve benchmark compares against and as a bit-identity
        reference in tests."""
        groups: "OrderedDict[int, List[int]]" = OrderedDict()
        arrs = [np.asarray(p, np.int32) for p in prompts]
        for i, a in enumerate(arrs):
            groups.setdefault(len(a), []).append(i)
        work = deque(groups.values())
        results: List[Any] = [None] * len(prompts)

        def admit(pf):
            if not work:
                pf.stop()
                return None
            return work.popleft()

        def prefill(pf, idxs):
            toks = np.stack([arrs[i] for i in idxs])
            max_len = toks.shape[1] + max_new + 1
            logits, cache = self._prefill(self.params, jnp.asarray(toks),
                                          None, max_len=max_len)
            cur = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            return idxs, cache, cur

        def decode(pf, state):
            idxs, cache, cur = state
            chunks = [cur[:, None]]
            remaining = max_new - 1
            while remaining > 0:
                n = min(self.decode_chunk, remaining)
                cache, chunk = self._decode_n(self.params, cache, cur, n)
                chunks.append(chunk)
                cur = chunk[:, -1]
                remaining -= n
            return idxs, chunks

        def complete(pf, state):
            idxs, chunks = state
            seqs = np.concatenate([np.asarray(c) for c in chunks], axis=1)
            for row, i in enumerate(idxs):  # rows scatter to disjoint slots
                results[i] = seqs[row]
            return None

        ex = self._ensure_executor()
        decode_domain = ACCEL if ex.has_domain(ACCEL) else HOST
        pl = DataPipeline(
            max(1, min(len(work), self.pipeline_lines)),
            DataPipe(PipeType.SERIAL, admit, name="admit"),
            DataPipe(PipeType.SERIAL, prefill, name="prefill"),
            DataPipe(PipeType.SERIAL, decode, name="decode",
                     domain=decode_domain),
            DataPipe(PipeType.PARALLEL, complete, name="complete"),
            name="serve-generate")
        pl.run(ex).wait()
        return results
