"""Paged KV-cache pool for continuous-batching serve.

The decode cache stops being one contiguous ``(L, B, KV, S_max, hd)`` tensor
per call and becomes a *pool* of fixed-size blocks plus per-sequence block
tables — the paged-attention layout. Sequences of different lengths share
the pool, join and leave the running batch at chunk boundaries, and free
their blocks the moment they retire, so KV memory is bounded by the pool
size instead of ``max_batch * max_len``.

Two halves, deliberately separated:

* :class:`BlockPool` — the HOST-side allocator: a free list of block ids
  with ``alloc`` / ``free`` / ``fragmentation`` / ``defragment``. Thread-safe
  (admission allocates from the pipeline's SERIAL admit stage while
  retirement frees from the complete stage). Block id 0 is a reserved *sink*:
  it is never handed out, and jit-compiled decode redirects the KV writes of
  inactive batch rows into it, so masked rows can never corrupt a live
  sequence's blocks.
* pure jit-able helpers (``scatter_prefill_row`` / ``gather_pages`` /
  ``append_kv``) — the device-side gather/scatter through block tables, used
  by :func:`repro.models.lm.decode_step_paged` and the engine's compiled
  chunk program. They close over nothing and take/return arrays only, so
  they trace cleanly under ``jax.jit``/``lax.scan``.
"""
from __future__ import annotations

import threading
from typing import List, Optional, Sequence, Tuple

import jax.numpy as jnp

from ..configs.base import ModelConfig

__all__ = ["BlockPool", "init_kv_pool", "scatter_prefill_row",
           "scatter_prefill_rows", "gather_pages", "append_kv",
           "SINK_BLOCK"]

#: Block id 0 is reserved: never allocated, target of masked-row KV writes.
SINK_BLOCK = 0


class BlockPool:
    """Free-list allocator over ``num_blocks`` KV blocks of ``block_size``
    token slots each.

    Invariants (exercised by ``tests/test_kvcache.py``):

    * ``num_free + allocated == num_blocks - 1`` (the sink is neither);
    * a block id is never handed out twice without an intervening ``free``;
    * ``free`` of an unallocated (or sink) id raises;
    * ``alloc`` is all-or-nothing: it returns ``None`` rather than a partial
      allocation when the pool cannot cover the request (the admission
      back-pressure signal).
    """

    def __init__(self, num_blocks: int, block_size: int) -> None:
        if num_blocks < 2:
            raise ValueError("pool needs >= 2 blocks (block 0 is the sink)")
        if block_size < 1:
            raise ValueError("block_size must be >= 1")
        self.num_blocks = num_blocks
        self.block_size = block_size
        self._lock = threading.Lock()
        # LIFO free list: recently freed blocks are re-used first (warm)
        self._free: List[int] = list(range(num_blocks - 1, SINK_BLOCK, -1))
        self._allocated: set = set()

    # ------------------------------------------------------------- accounting
    @property
    def num_free(self) -> int:
        with self._lock:
            return len(self._free)

    @property
    def num_allocated(self) -> int:
        with self._lock:
            return len(self._allocated)

    def blocks_for(self, num_tokens: int) -> int:
        """Blocks needed to hold ``num_tokens`` KV entries."""
        return -(-num_tokens // self.block_size)

    def can_alloc(self, n: int) -> bool:
        with self._lock:
            return n <= len(self._free)

    # ------------------------------------------------------------- alloc/free
    def alloc(self, n: int) -> Optional[List[int]]:
        """Take ``n`` blocks, or None (and take nothing) if fewer are free."""
        if n < 0:
            raise ValueError("alloc of negative block count")
        with self._lock:
            if n > len(self._free):
                return None
            ids = [self._free.pop() for _ in range(n)]
            self._allocated.update(ids)
            return ids

    def free(self, ids: Sequence[int]) -> None:
        with self._lock:
            for b in ids:
                if b not in self._allocated:
                    raise ValueError(
                        f"free of block {b} that is not allocated "
                        f"(double free, or the reserved sink)")
                self._allocated.discard(b)
                self._free.append(b)

    # ---------------------------------------------------------- fragmentation
    def fragmentation(self) -> float:
        """1 - (longest contiguous free run / free blocks): 0.0 when the
        free ids form one contiguous range, approaching 1.0 as the free set
        shatters. Paged attention gathers through the table so this is a
        locality metric, not a correctness one."""
        with self._lock:
            free = sorted(self._free)
        if not free:
            return 0.0
        longest = run = 1
        for a, b in zip(free, free[1:]):
            run = run + 1 if b == a + 1 else 1
            longest = max(longest, run)
        return 1.0 - longest / len(free)

    def defragment(self) -> float:
        """Order the free list so future allocations hand out ascending,
        contiguous-when-possible id runs; returns the fragmentation metric
        after the compaction. Safe while sequences run: allocated blocks are
        never moved (tables keep pointing at the same ids)."""
        with self._lock:
            self._free.sort(reverse=True)  # LIFO pop() yields ascending ids
        return self.fragmentation()


# ---------------------------------------------------------------- device side
def init_kv_pool(cfg: ModelConfig, num_blocks: int, block_size: int
                 ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Allocate the pooled KV storage: ``(L, num_blocks, KV, block, hd)``
    for k and v (same layout as the contiguous cache with the sequence dim
    split into pages)."""
    if cfg.ssm or cfg.hybrid_attn_every:
        raise ValueError(
            f"{cfg.name}: paged KV applies to attention caches only "
            "(SSM state is O(1) per sequence)")
    # lazy: keeps this module import-light (attention.py imports the
    # gather/scatter helpers above, so a models import here would cycle)
    from ..models.layers import dtype_of
    cdt = dtype_of(cfg.compute_dtype)
    shape = (cfg.num_layers, num_blocks, cfg.num_kv_heads, block_size,
             cfg.hd)
    return jnp.zeros(shape, cdt), jnp.zeros(shape, cdt)


def scatter_prefill_row(pool: jnp.ndarray, blocks: jnp.ndarray,
                        row: jnp.ndarray) -> jnp.ndarray:
    """Write one prefilled sequence into its blocks.

    pool: (L, N, KV, bs, hd); blocks: (nb,) int32; row: (L, KV, S, hd) with
    ``S <= nb * bs``. Returns the updated pool. Jit-safe: ``nb`` and ``S``
    are static shapes.
    """
    return scatter_prefill_rows(pool, blocks[None], row[:, None])


def scatter_prefill_rows(pool: jnp.ndarray, blocks: jnp.ndarray,
                         rows: jnp.ndarray) -> jnp.ndarray:
    """Write a whole admitted GROUP's prefilled sequences in one scatter.

    pool: (L, N, KV, bs, hd); blocks: (Bg, nb) int32 — every row uses the
    same block count (the group shares one prompt length, and ``nb`` covers
    the PROMPT footprint only, so the compiled shape keys on the admission
    bucket, not on per-request ``max_new``); rows: (L, Bg, KV, S, hd) with
    ``S <= nb * bs``. Rows own disjoint blocks, so the scatter indices
    never collide.
    """
    L, _, KV, bs, hd = pool.shape
    Bg, nb = blocks.shape
    S = rows.shape[3]
    pad = nb * bs - S
    if pad:
        rows = jnp.pad(rows, ((0, 0), (0, 0), (0, 0), (0, pad), (0, 0)))
    # (L, Bg, KV, nb*bs, hd) -> (L, Bg, nb, KV, bs, hd): page-major
    paged = rows.reshape(L, Bg, KV, nb, bs, hd).transpose(0, 1, 3, 2, 4, 5)
    return pool.at[:, blocks].set(paged)


def gather_pages(pool_l: jnp.ndarray, tables: jnp.ndarray) -> jnp.ndarray:
    """Gather one layer's pages for a batch of sequences.

    pool_l: (N, KV, bs, hd); tables: (B, max_blocks) int32 (unused tail
    entries point at the sink). Returns (B, KV, max_blocks * bs, hd) with
    token position ``j`` at gathered index ``j`` — the contiguous view the
    attention kernel reads, masked by each row's length.
    """
    B, mb = tables.shape
    _, KV, bs, hd = pool_l.shape
    pages = pool_l[tables]                       # (B, mb, KV, bs, hd)
    return pages.transpose(0, 2, 1, 3, 4).reshape(B, KV, mb * bs, hd)


def append_kv(pool_l: jnp.ndarray, new: jnp.ndarray, tables: jnp.ndarray,
              pos: jnp.ndarray, active: jnp.ndarray) -> jnp.ndarray:
    """Write one decode step's K (or V) for every batch row through the
    block table.

    pool_l: (N, KV, bs, hd); new: (B, KV, hd); tables: (B, max_blocks);
    pos: (B,) int32 write position per row; active: (B,) bool. Inactive
    rows are redirected to the sink block so they cannot touch live pages.
    """
    _, _, bs, _ = pool_l.shape
    B, mb = tables.shape
    idx = jnp.clip(pos // bs, 0, mb - 1)
    blk = jnp.where(active, jnp.take_along_axis(
        tables, idx[:, None], axis=1)[:, 0], SINK_BLOCK)
    off = jnp.where(active, pos % bs, 0)
    return pool_l.at[blk, :, off].set(new.astype(pool_l.dtype))
