"""Paged KV-cache pool for continuous-batching serve.

The decode cache stops being one contiguous ``(L, B, KV, S_max, hd)`` tensor
per call and becomes a *pool* of fixed-size blocks plus per-sequence block
tables — the paged-attention layout. Sequences of different lengths share
the pool, join and leave the running batch at chunk boundaries, and free
their blocks the moment they retire, so KV memory is bounded by the pool
size instead of ``max_batch * max_len``.

K and V are STACKED along a leading axis of one pool array
``(L, 2, N, KV, block, hd)`` rather than held as two tensors: the decode
write path appends a token's K *and* V with a single scatter launch and the
read paths fetch page pairs with a single gather (previously two separate
``.at[].set`` / gather launches per layer per token).

Two halves, deliberately separated:

* :class:`BlockPool` — the HOST-side allocator: a free list of block ids
  with ``alloc`` / ``free`` / ``grow_table`` (mid-decode extension of a live
  sequence's allocation — phase 2 of two-phase admission) /
  ``fragmentation`` / ``defragment``, plus the async-decode DEFERRED-FREE
  FENCE (``free_deferred`` / ``release_deferred``): a preempted row's
  blocks may still be written by the compiled chunk in flight at preemption
  time, so they return to the pool only after the engine has synced past
  that chunk. Thread-safe (admission allocates from the pipeline's SERIAL
  admit stage while retirement frees from the complete stage and the decode
  stage grows). Block id 0 is a reserved *sink*: it is never handed out,
  and jit-compiled decode redirects the KV writes of inactive batch rows
  into it, so masked rows can never corrupt a live sequence's blocks.

  Blocks are REFCOUNTED (prefix caching): ``alloc``/``grow_table`` hand a
  block out at refcount 1, ``incref`` pins it for another holder (a second
  request sharing a cached prompt prefix, or the prefix index itself), and
  ``free``/``free_deferred`` DECREMENT — a block only returns to the free
  list (or enters the deferred fence) when its last reference drops.
  ``defragment`` never relocates anything (tables keep pointing at the
  same ids), and a block with live references is by construction not in
  the free list, so shared (refcount > 1) and index-parked blocks are
  neither free nor movable; ``alloc`` can never hand out a block with
  live refs because only the zero-ref transition re-enters the free list.
* pure jit-able helpers (``scatter_prefill_rows`` / ``scatter_token_window``
  / ``gather_pages`` / ``append_kv`` / ``extend_block_tables`` /
  ``set_table_rows`` / ``set_carry_rows``) — the device-side gather/scatter
  through block tables, used by :func:`repro.models.lm.decode_step_paged`,
  :func:`repro.models.lm.prefill_window_paged` (chunked prefill) and the
  engine's compiled chunk program; ``extend_block_tables`` keeps the
  block-table array device-resident across cycles (growth is an in-place
  scatter, not a re-upload). They close over nothing and take/return
  arrays only, so they trace cleanly under ``jax.jit``/``lax.scan``.
  ``gather_pages`` is the *reference oracle* read path: the serve hot path
  reads pages in place via :mod:`repro.kernels.paged_attention` instead of
  materializing a gathered copy.
"""
from __future__ import annotations

import threading
from typing import List, Optional, Sequence

import jax.numpy as jnp

from ..configs.base import ModelConfig

__all__ = ["BlockPool", "init_kv_pool", "scatter_prefill_row",
           "scatter_prefill_rows", "scatter_token_window", "gather_pages",
           "gather_read_attention", "append_kv", "extend_block_tables",
           "set_table_rows", "set_carry_rows", "copy_blocks", "SINK_BLOCK"]

#: Block id 0 is reserved: never allocated, target of masked-row KV writes.
SINK_BLOCK = 0

_NEG_INF = -2.0 ** 30  # matches models.attention / kernels (bf16-safe)


class BlockPool:
    """Free-list allocator over ``num_blocks`` KV blocks of ``block_size``
    token slots each.

    Invariants (exercised by ``tests/test_kvcache.py`` and
    ``tests/test_prefix_cache.py``):

    * ``num_free + allocated == num_blocks - 1`` (the sink is neither;
      each allocated id counts ONCE however many references hold it);
    * a block id is never handed out twice without its refcount dropping
      to zero through ``free``/``free_deferred`` first;
    * ``free`` of an unallocated (or sink) id raises — including a second
      ``free`` after a shared block's LAST reference already dropped;
    * ``alloc`` is all-or-nothing: it returns ``None`` rather than a partial
      allocation when the pool cannot cover the request (the admission
      back-pressure signal).
    """

    def __init__(self, num_blocks: int, block_size: int) -> None:
        if num_blocks < 2:
            raise ValueError("pool needs >= 2 blocks (block 0 is the sink)")
        if block_size < 1:
            raise ValueError("block_size must be >= 1")
        self.num_blocks = num_blocks
        self.block_size = block_size
        self._lock = threading.Lock()
        # LIFO free list: recently freed blocks are re-used first (warm)
        self._free: List[int] = list(range(num_blocks - 1, SINK_BLOCK, -1))
        self._allocated: set = set()
        #: live reference count per allocated block (prefix sharing): the
        #: free paths DECREMENT and only release at zero
        self._refs: dict = {}
        # deferred-free fence (async decode lookahead): blocks whose owner
        # row may still be WRITTEN by an in-flight compiled chunk sit here —
        # still accounted as allocated, invisible to alloc — until the
        # engine advances the fence (see free_deferred / release_deferred)
        self._deferred_young: List[int] = []
        self._deferred_old: List[int] = []
        self._deferred_set: set = set()
        # reservation floor (admit-vs-stalled-row fairness): the engine
        # reserves the unmet block demand of fenced/stalled resident rows;
        # plain alloc (admission) cannot dip below it, while grow calls
        # pass use_reserved=True and drain it oldest-stalled-first
        self._reserved = 0
        self._g_free = self._g_used = self._g_deferred = None
        self._g_shared = None
        self._g_reserved = None

    def set_metrics(self, metrics) -> None:
        """Bind (or unbind with None) a :class:`repro.obs.MetricsRegistry`:
        the pool keeps ``pool.blocks_free`` / ``pool.blocks_used`` /
        ``pool.blocks_deferred`` gauges current at every alloc, free,
        deferred-free and fence advance. Pool mutations are per-block-batch
        (a handful per engine cycle), so three gauge writes are noise."""
        if metrics is None:
            self._g_free = self._g_used = self._g_deferred = None
            self._g_shared = None
            self._g_reserved = None
            return
        self._g_free = metrics.gauge("pool.blocks_free")
        self._g_used = metrics.gauge("pool.blocks_used")
        self._g_deferred = metrics.gauge("pool.blocks_deferred")
        self._g_shared = metrics.gauge("pool.blocks_shared")
        self._g_reserved = metrics.gauge("pool.blocks_reserved")
        with self._lock:
            self._note_locked()

    def _note_locked(self) -> None:
        if self._g_free is not None:
            self._g_free.set(len(self._free))
            self._g_used.set(len(self._allocated))
            self._g_deferred.set(len(self._deferred_young)
                                 + len(self._deferred_old))
            self._g_shared.set(sum(1 for c in self._refs.values() if c > 1))
        if self._g_reserved is not None:
            self._g_reserved.set(self._reserved)

    # ------------------------------------------------------------- accounting
    @property
    def num_free(self) -> int:
        with self._lock:
            return len(self._free)

    @property
    def num_allocated(self) -> int:
        with self._lock:
            return len(self._allocated)

    def blocks_for(self, num_tokens: int) -> int:
        """Blocks needed to hold ``num_tokens`` KV entries."""
        return -(-num_tokens // self.block_size)

    def can_alloc(self, n: int, *, use_reserved: bool = False) -> bool:
        with self._lock:
            return n <= self._avail_locked(use_reserved)

    # ------------------------------------------------- stalled-row reservation
    def set_reserved(self, n: int) -> None:
        """Set the reservation floor: ``n`` free blocks are held back from
        plain :meth:`alloc`/:meth:`grow_table` and only reachable with
        ``use_reserved=True``. The engine sets this to the unmet growth
        demand of stalled resident rows (oldest-stalled-first), so fresh
        admissions cannot indefinitely snipe the blocks a fenced-growth
        row is waiting for. The floor is advisory against what is
        CURRENTLY free — it never blocks frees or fence releases, it just
        earmarks them as they arrive."""
        if n < 0:
            raise ValueError("reservation must be >= 0")
        with self._lock:
            self._reserved = n
            self._note_locked()

    @property
    def reserved(self) -> int:
        with self._lock:
            return self._reserved

    @property
    def num_free_unreserved(self) -> int:
        """Free blocks visible to plain (admission) allocation."""
        with self._lock:
            return self._avail_locked(False)

    def _avail_locked(self, use_reserved: bool) -> int:
        if use_reserved:
            return len(self._free)
        return max(0, len(self._free) - self._reserved)

    # ------------------------------------------------------------- alloc/free
    def alloc(self, n: int, *, use_reserved: bool = False
              ) -> Optional[List[int]]:
        """Take ``n`` blocks at refcount 1, or None (and take nothing) if
        fewer are free. Only the zero-ref transition of ``free`` /
        ``release_deferred`` re-enters the free list, so a block with live
        references can never be handed out here. Plain calls respect the
        stalled-row reservation floor (:meth:`set_reserved`); resident-row
        growth passes ``use_reserved=True`` to drain it."""
        if n < 0:
            raise ValueError("alloc of negative block count")
        with self._lock:
            if n > self._avail_locked(use_reserved):
                return None
            ids = [self._free.pop() for _ in range(n)]
            self._allocated.update(ids)
            for b in ids:
                self._refs[b] = 1
            self._note_locked()
            return ids

    def incref(self, ids: Sequence[int]) -> None:
        """Pin blocks for an additional holder (prefix sharing: a second
        request's table pointing at cached prompt blocks, or the prefix
        index parking a completed request's prefix). Deferred blocks are
        un-pinnable — they are already fenced for release."""
        with self._lock:
            for b in ids:
                if b not in self._allocated or b in self._deferred_set:
                    raise ValueError(
                        f"incref of block {b} that is not live "
                        f"(unallocated, deferred, or the sink)")
                self._refs[b] += 1
            self._note_locked()

    def refcount(self, b: int) -> int:
        """Live references on ``b`` (0 when free/deferred) — the engine's
        copy-on-write trigger: a write into a block with refcount > 1 must
        fork it first."""
        with self._lock:
            return self._refs.get(b, 0)

    @property
    def num_shared(self) -> int:
        """Blocks held by more than one reference."""
        with self._lock:
            return sum(1 for c in self._refs.values() if c > 1)

    def free(self, ids: Sequence[int]) -> None:
        """Drop ONE reference per id; a block returns to the free list only
        when its last reference drops (shared prefix blocks survive their
        co-holders' retirements)."""
        with self._lock:
            for b in ids:
                if b not in self._allocated or b in self._deferred_set:
                    raise ValueError(
                        f"free of block {b} that is not allocated "
                        f"(double free, a deferred block, or the sink)")
                self._refs[b] -= 1
                if self._refs[b] == 0:
                    del self._refs[b]
                    self._allocated.discard(b)
                    self._free.append(b)
            self._note_locked()

    # ------------------------------------------------- deferred-free fence
    def free_deferred(self, ids: Sequence[int]) -> None:
        """Queue blocks for return to the pool behind the async-decode
        FENCE. A preempted row may still be written by the chunk program in
        flight at preemption time (and by a chunked-prefill window enqueued
        the same cycle), so its blocks must not be handed back out until
        that device work has provably retired. Deferred blocks stay
        accounted as allocated (the ``num_free + num_allocated`` invariant
        holds) but are invisible to :meth:`alloc` / :meth:`grow_table`
        until TWO :meth:`release_deferred` calls later.

        Like :meth:`free` this drops ONE reference per id: a SHARED block
        (live refs remain — e.g. a preempted row's prefix blocks still
        held by the prefix index or a co-resident row) is merely
        unpinned, never fenced — the surviving holders' tables still read
        it, and nothing in flight can write a shared prefix block (the
        engine forks before any such write)."""
        with self._lock:
            fenced = []
            for b in ids:
                if b not in self._allocated or b in self._deferred_set:
                    raise ValueError(
                        f"deferred free of block {b} that is not allocated "
                        f"(double free, or the reserved sink)")
                self._refs[b] -= 1
                if self._refs[b] == 0:
                    del self._refs[b]
                    self._deferred_set.add(b)
                    fenced.append(b)
            self._deferred_young.extend(fenced)
            self._note_locked()

    def release_deferred(self) -> int:
        """Advance the fence by one chunk sync: blocks deferred before the
        PREVIOUS advance return to the free list; blocks deferred since then
        age one stage. The engine calls this each time it has synced a
        compiled chunk (every device write enqueued when the blocks were
        deferred precedes the NEXT chunk on the pool's data-dependency
        chain, so two syncs bound all of them). Returns the number of
        blocks released."""
        with self._lock:
            old = self._deferred_old
            self._deferred_old = self._deferred_young
            self._deferred_young = []
            for b in old:
                self._deferred_set.discard(b)
                self._allocated.discard(b)
                self._free.append(b)
            if old:
                self._note_locked()
            return len(old)

    @property
    def num_deferred(self) -> int:
        """Blocks parked behind the deferred-free fence."""
        with self._lock:
            return len(self._deferred_young) + len(self._deferred_old)

    def grow_table(self, blocks: List[int], n: int, *,
                   use_reserved: bool = False) -> Optional[List[int]]:
        """Extend a sequence's existing allocation by ``n`` blocks — the
        mid-decode growth primitive of two-phase admission. All-or-nothing
        like :meth:`alloc`: returns the new ids (also appended to ``blocks``
        in place, keeping the caller's table mirror authoritative) or None
        (taking nothing) when the pool cannot cover the growth — the
        engine's preemption signal. Resident rows grow with
        ``use_reserved=True`` so the stalled-row reservation floor is
        theirs to drain."""
        ids = self.alloc(n, use_reserved=use_reserved)
        if ids is None:
            return None
        blocks.extend(ids)
        return ids

    # ---------------------------------------------------------- fragmentation
    def fragmentation(self) -> float:
        """1 - (longest contiguous free run / free blocks): 0.0 when the
        free ids form one contiguous range, approaching 1.0 as the free set
        shatters. Only genuinely FREE blocks count: deferred (fenced) and
        referenced/parked blocks are excluded — they are neither free nor
        movable. Paged attention reads through the table so this is a
        locality metric, not a correctness one."""
        with self._lock:
            free = sorted(self._free)
        if not free:
            return 0.0
        longest = run = 1
        for a, b in zip(free, free[1:]):
            run = run + 1 if b == a + 1 else 1
            longest = max(longest, run)
        return 1.0 - longest / len(free)

    def defragment(self) -> float:
        """Order the free list so future allocations hand out ascending,
        contiguous-when-possible id runs; returns the fragmentation metric
        after the compaction. Safe while sequences run: allocated blocks are
        never moved (tables keep pointing at the same ids), and blocks with
        live references — shared prefixes, index-parked blocks — or sitting
        behind the deferred-free fence are by invariant not in the free
        list, so the sort cannot disturb them (guarded below: a violation
        means a refcount bug upstream, better loud than silent)."""
        with self._lock:
            bad = [b for b in self._free
                   if b in self._refs or b in self._deferred_set
                   or b == SINK_BLOCK]
            if bad:
                raise RuntimeError(
                    f"free list holds live/deferred/sink blocks {bad}: "
                    "refcount accounting is corrupt")
            self._free.sort(reverse=True)  # LIFO pop() yields ascending ids
        return self.fragmentation()


# ---------------------------------------------------------------- device side
def init_kv_pool(cfg: ModelConfig, num_blocks: int, block_size: int
                 ) -> jnp.ndarray:
    """Allocate the pooled KV storage: one ``(L, 2, num_blocks, KV, block,
    hd)`` array — axis 1 stacks K (0) and V (1) so appends/gathers touch
    both halves in a single launch. Same layout as the contiguous cache
    with the sequence dim split into pages."""
    if cfg.ssm or cfg.hybrid_attn_every:
        raise ValueError(
            f"{cfg.name}: paged KV applies to attention caches only "
            "(SSM state is O(1) per sequence)")
    # lazy: keeps this module import-light (attention.py imports the
    # gather/scatter helpers above, so a models import here would cycle)
    from ..models.layers import dtype_of
    cdt = dtype_of(cfg.compute_dtype)
    shape = (cfg.num_layers, 2, num_blocks, cfg.num_kv_heads, block_size,
             cfg.hd)
    return jnp.zeros(shape, cdt)


def scatter_prefill_row(pool: jnp.ndarray, blocks: jnp.ndarray,
                        krow: jnp.ndarray, vrow: jnp.ndarray) -> jnp.ndarray:
    """Write one prefilled sequence into its blocks.

    pool: (L, 2, N, KV, bs, hd); blocks: (nb,) int32; krow/vrow:
    (L, KV, S, hd) with ``S <= nb * bs``. Returns the updated pool.
    Jit-safe: ``nb`` and ``S`` are static shapes.
    """
    return scatter_prefill_rows(pool, blocks[None], krow[:, None],
                                vrow[:, None])


def scatter_prefill_rows(pool: jnp.ndarray, blocks: jnp.ndarray,
                         krows: jnp.ndarray, vrows: jnp.ndarray
                         ) -> jnp.ndarray:
    """Write a whole admitted GROUP's prefilled K and V in one scatter.

    pool: (L, 2, N, KV, bs, hd); blocks: (Bg, nb) int32 — every row uses the
    same block count (the group shares one prompt length, and ``nb`` covers
    the PROMPT footprint only, so the compiled shape keys on the admission
    bucket, not on per-request ``max_new``); krows/vrows: (L, Bg, KV, S, hd)
    with ``S <= nb * bs``. Rows own disjoint blocks, so the scatter indices
    never collide.
    """
    L, _, _, KV, bs, hd = pool.shape
    Bg, nb = blocks.shape
    rows = jnp.stack([krows, vrows], axis=1)     # (L, 2, Bg, KV, S, hd)
    S = rows.shape[4]
    pad = nb * bs - S
    if pad:
        rows = jnp.pad(rows, ((0, 0), (0, 0), (0, 0), (0, 0), (0, pad),
                              (0, 0)))
    # (L, 2, Bg, KV, nb*bs, hd) -> (L, 2, Bg, nb, KV, bs, hd): page-major
    paged = rows.reshape(L, 2, Bg, KV, nb, bs, hd).transpose(
        0, 1, 2, 4, 3, 5, 6)
    return pool.at[:, :, blocks].set(paged)


def scatter_token_window(pool_l: jnp.ndarray, new_k: jnp.ndarray,
                         new_v: jnp.ndarray, tables: jnp.ndarray,
                         start: jnp.ndarray, valid: jnp.ndarray
                         ) -> jnp.ndarray:
    """Write a WINDOW of ``C`` consecutive tokens per batch row through the
    block tables — the chunked-prefill scatter (one launch per layer per
    window, however many rows are mid-prefill).

    pool_l: (2, N, KV, bs, hd) one layer's stacked pages; new_k/new_v:
    (B, C, KV, hd); tables: (B, max_blocks) int32; start: (B,) int32 first
    write position per row (token ``c`` lands at ``start[b] + c``); valid:
    (B, C) bool — invalid entries (rows not prefilling, window tail past the
    prompt) are redirected to the sink block. Valid entries of different
    rows go through disjoint blocks, so the scatter indices never collide.
    """
    _, _, _, bs, _ = pool_l.shape
    B, mb = tables.shape
    C = new_k.shape[1]
    pos = start[:, None] + jnp.arange(C, dtype=jnp.int32)[None, :]   # (B, C)
    idx = jnp.clip(pos // bs, 0, mb - 1)
    blk = jnp.where(valid, jnp.take_along_axis(tables, idx, axis=1),
                    SINK_BLOCK)
    off = jnp.where(valid, pos % bs, 0)
    new = jnp.stack([new_k, new_v], axis=2)          # (B, C, 2, KV, hd)
    return pool_l.at[:, blk, :, off].set(new.astype(pool_l.dtype))


def extend_block_tables(tables: jnp.ndarray, rows: jnp.ndarray,
                        cols: jnp.ndarray, blocks: jnp.ndarray
                        ) -> jnp.ndarray:
    """Device-side per-row table-extension scatter: write newly granted
    block ids into the resident block-table array at ``(rows[i], cols[i])``.
    The engine keeps the table array device-resident across cycles; growth
    updates it in place (one tiny scatter) instead of re-uploading the whole
    ``(B, max_blocks)`` table every time a row crosses a block boundary.

    tables: (B, max_blocks) int32; rows/cols/blocks: (M,) int32.
    """
    return tables.at[rows, cols].set(blocks)


def set_table_rows(tables: jnp.ndarray, rows: jnp.ndarray,
                   new_rows: jnp.ndarray) -> jnp.ndarray:
    """Replace whole block-table rows (admission merge writes a sequence's
    prompt blocks; retirement/preemption zeroes the row so the length-bound
    page loops stop advertising it). tables: (B, mb); rows: (M,) int32;
    new_rows: (M, mb) int32."""
    return tables.at[rows].set(new_rows)


def set_carry_rows(lengths: jnp.ndarray, last: jnp.ndarray, rem: jnp.ndarray,
                   rows: jnp.ndarray, new_lengths: jnp.ndarray,
                   new_last: jnp.ndarray, new_rem: jnp.ndarray):
    """Scatter per-row values into the DEVICE-RESIDENT decode carry
    ``(lengths, last, rem)`` — the async-lookahead counterpart of
    :func:`set_table_rows` for the carry arrays. Admission merge seats new
    rows, chunked-prefill completion flips a row into decode, and
    retirement/preemption zeroes a row, all without re-uploading the whole
    mirrors (chunk N+1 consumes chunk N's carry plus these scatters
    directly, so the device dependency chain never waits on the host).

    lengths/last/rem: (B,) int32; rows: (M,) int32 (pad with repeats —
    duplicate writes of the same row are idempotent, keeping the compiled
    shape fixed); new_lengths/new_last/new_rem: (M,) int32.
    """
    return (lengths.at[rows].set(new_lengths),
            last.at[rows].set(new_last),
            rem.at[rows].set(new_rem))


def copy_blocks(pool: jnp.ndarray, srcs: jnp.ndarray, dsts: jnp.ndarray
                ) -> jnp.ndarray:
    """Copy whole KV blocks ``srcs[i] -> dsts[i]`` across every layer in ONE
    gather+scatter launch — the copy-on-write FORK primitive of prefix
    caching: before a row's first divergent write into a shared block, the
    engine clones the block and repoints the row's table at the clone, so
    co-holders keep reading the original bits.

    pool: (L, 2, N, KV, bs, hd); srcs/dsts: (M,) int32. Call sites pad with
    ``SINK_BLOCK -> SINK_BLOCK`` repeats (the sink's contents are garbage by
    contract, and a self-copy is idempotent) to keep compiled shapes fixed.
    """
    return pool.at[:, :, dsts].set(pool[:, :, srcs])


def gather_pages(pool_l: jnp.ndarray, tables: jnp.ndarray):
    """Gather one layer's K and V pages for a batch of sequences.

    pool_l: (2, N, KV, bs, hd); tables: (B, max_blocks) int32 (unused tail
    entries point at the sink). Returns ``(ks, vs)``, each (B, KV,
    max_blocks * bs, hd) with token position ``j`` at gathered index ``j``
    — the contiguous view the reference attention path reads, masked by
    each row's length. This materializes O(max_blocks) per row regardless
    of its true length: the oracle the gather-free kernels are tested
    against, not the serve hot path.
    """
    B, mb = tables.shape
    _, _, KV, bs, hd = pool_l.shape
    pages = pool_l[:, tables]                    # (2, B, mb, KV, bs, hd)
    pages = pages.transpose(0, 1, 3, 2, 4, 5).reshape(2, B, KV, mb * bs, hd)
    return pages[0], pages[1]


def gather_read_attention(q: jnp.ndarray, pool_l: jnp.ndarray,
                          tables: jnp.ndarray, lengths: jnp.ndarray
                          ) -> jnp.ndarray:
    """The reference (oracle) paged read path: gather the fully padded
    span via :func:`gather_pages`, mask by each row's length, softmax.

    q: (B, H, hd) current-token queries; pool_l: (2, N, KV, bs, hd);
    tables: (B, max_blocks) int32; lengths: (B,) int32 per-row position
    ``pos`` (key positions ``0..pos`` attend). Returns (B, H, hd) in the
    pool dtype. O(max_blocks) per row regardless of true length — the
    single definition the gather-free kernels are tested and benchmarked
    against (``tests/test_paged_attention.py``,
    ``benchmarks/paged_decode_microbench.py``) and the ``impl="gather"``
    branch of :func:`repro.models.attention.paged_decode_attention`.
    """
    B, H, hd = q.shape
    KV = pool_l.shape[2]
    G = H // KV
    ks, vs = gather_pages(pool_l, tables)        # (B, KV, T, hd), T=mb*bs
    T = ks.shape[2]
    qg = q.reshape(B, KV, G, hd)
    s = jnp.einsum("bkgh,bksh->bkgs", qg, ks,
                   preferred_element_type=jnp.float32) * (hd ** -0.5)
    kpos = jnp.arange(T, dtype=jnp.int32)
    s = jnp.where((kpos[None, :] <= lengths[:, None])[:, None, None, :],
                  s, _NEG_INF)
    pmax = jnp.max(s, axis=-1, keepdims=True)
    e = jnp.exp(s - pmax)
    probs = (e / jnp.sum(e, axis=-1, keepdims=True)).astype(vs.dtype)
    out = jnp.einsum("bkgs,bksh->bkgh", probs, vs)
    return out.reshape(B, H, hd)


def append_kv(pool_l: jnp.ndarray, new_k: jnp.ndarray, new_v: jnp.ndarray,
              tables: jnp.ndarray, pos: jnp.ndarray, active: jnp.ndarray
              ) -> jnp.ndarray:
    """Write one decode step's K AND V for every batch row through the
    block table — one fused scatter launch.

    pool_l: (2, N, KV, bs, hd); new_k/new_v: (B, KV, hd); tables:
    (B, max_blocks); pos: (B,) int32 write position per row; active: (B,)
    bool. Inactive rows are redirected to the sink block so they cannot
    touch live pages.
    """
    _, _, _, bs, _ = pool_l.shape
    B, mb = tables.shape
    idx = jnp.clip(pos // bs, 0, mb - 1)
    blk = jnp.where(active, jnp.take_along_axis(
        tables, idx[:, None], axis=1)[:, 0], SINK_BLOCK)
    off = jnp.where(active, pos % bs, 0)
    new = jnp.stack([new_k, new_v], axis=1)      # (B, 2, KV, hd)
    return pool_l.at[:, blk, :, off].set(new.astype(pool_l.dtype))
