"""Request queue + admission control for the continuous-batching engine.

The scheduler is pure host-side bookkeeping (no jax): it owns the waiting
queue and decides, at every chunk boundary, which requests join the running
batch. The engine's SERIAL admit stage calls :meth:`Scheduler.try_admit`
with the currently free resources; retirement calls :meth:`finish` /
:meth:`fail` to fulfil the request futures.

Admission policy — *length-bucketed FIFO*:

* requests are grouped by prompt length (one compiled prefill shape per
  admitted group — no re-padding, no shape churn);
* the bucket of the OLDEST waiting request goes first (no starvation), and
  up to ``max_admit`` same-length requests ride along with it;
* a group is admitted only if the block pool can cover every member's full
  ``prompt + max_new`` KV footprint AND free decode slots exist — admission
  is all-or-nothing per request, so a running sequence can never hit KV
  exhaustion mid-decode (back-pressure happens at admission, where the
  pipeline can defer, not in the compiled chunk).
"""
from __future__ import annotations

import itertools
import threading
from collections import OrderedDict
from typing import Any, Callable, List, Optional

import numpy as np

__all__ = ["ServeRequest", "Scheduler"]

_REQ_IDS = itertools.count()


class ServeRequest:
    """One generation request: a prompt plus a future for its output.

    ``submit()`` hands these out; :meth:`result` blocks until the engine's
    complete stage retires the sequence (or the resident pipeline fails, in
    which case the failure re-raises here instead of deadlocking).
    """

    def __init__(self, prompt: Any, max_new: int) -> None:
        self.id = next(_REQ_IDS)
        self.prompt = np.asarray(prompt, np.int32)
        if self.prompt.ndim != 1 or self.prompt.size == 0:
            raise ValueError("prompt must be a non-empty 1-D token array")
        if max_new < 1:
            raise ValueError("max_new must be >= 1")
        self.max_new = int(max_new)
        self.submitted_at: Optional[float] = None   # set by the engine
        self.finished_at: Optional[float] = None
        self._done = threading.Event()
        self._tokens: Optional[np.ndarray] = None
        self._error: Optional[BaseException] = None

    # ------------------------------------------------------------ future API
    def done(self) -> bool:
        return self._done.is_set()

    def set_result(self, tokens: np.ndarray) -> None:
        self._tokens = tokens
        self._done.set()

    def set_error(self, err: BaseException) -> None:
        if not self._done.is_set():
            self._error = err
            self._done.set()

    def result(self, timeout: Optional[float] = 120.0) -> np.ndarray:
        if not self._done.wait(timeout):
            raise TimeoutError(f"request {self.id} did not complete in time")
        if self._error is not None:
            raise RuntimeError(
                f"request {self.id} failed in the serve pipeline"
            ) from self._error
        return self._tokens

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.shape[0])


class Scheduler:
    """Waiting-queue + admission-control policy (host side, thread-safe)."""

    def __init__(self, max_admit: int = 8) -> None:
        if max_admit < 1:
            raise ValueError("max_admit must be >= 1")
        self.max_admit = max_admit
        self._lock = threading.Lock()
        # prompt_len -> FIFO of ServeRequest; OrderedDict keeps bucket
        # creation order, but admission order follows the oldest REQUEST
        self._buckets: "OrderedDict[int, List[ServeRequest]]" = OrderedDict()
        self._num_waiting = 0

    # -------------------------------------------------------------- enqueue
    def enqueue(self, req: ServeRequest) -> None:
        with self._lock:
            self._buckets.setdefault(req.prompt_len, []).append(req)
            self._num_waiting += 1

    @property
    def num_waiting(self) -> int:
        with self._lock:
            return self._num_waiting

    def oldest(self) -> Optional[ServeRequest]:
        with self._lock:
            heads = [b[0] for b in self._buckets.values() if b]
            if not heads:
                return None
            return min(heads, key=lambda r: r.id)

    # ------------------------------------------------------------- admission
    def try_admit(self, free_slots: int,
                  blocks_free: int,
                  blocks_for: Callable[[int], int]
                  ) -> Optional[List[ServeRequest]]:
        """Pop the next admission group, or None (taking nothing) when the
        oldest waiting request cannot be covered — the engine turns that
        into either a deferred-token park or a plain decode-pump cycle.

        ``blocks_for(num_tokens)`` converts a KV footprint to block count
        (comes from the engine's :class:`~repro.serve.kvcache.BlockPool`).
        """
        with self._lock:
            heads = [b[0] for b in self._buckets.values() if b]
            if not heads or free_slots < 1:
                return None
            head = min(heads, key=lambda r: r.id)
            bucket = self._buckets[head.prompt_len]
            group: List[ServeRequest] = []
            budget = blocks_free
            for req in bucket:
                if len(group) >= min(self.max_admit, free_slots):
                    break
                need = blocks_for(req.prompt_len + req.max_new)
                if need > budget:
                    break
                budget -= need
                group.append(req)
            if not group:
                return None  # head of line does not fit: back-pressure
            del bucket[:len(group)]
            if not bucket:
                del self._buckets[head.prompt_len]
            self._num_waiting -= len(group)
            return group

    # ------------------------------------------------------------ retirement
    def finish(self, req: ServeRequest, tokens: np.ndarray, now: float
               ) -> None:
        req.finished_at = now
        req.set_result(tokens)

    def fail_all_waiting(self, err: BaseException) -> None:
        """Resident pipeline died: fail queued requests so result() raises
        instead of timing out."""
        with self._lock:
            waiting = [r for b in self._buckets.values() for r in b]
            self._buckets.clear()
            self._num_waiting = 0
        for r in waiting:
            r.set_error(err)
