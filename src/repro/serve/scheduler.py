"""Request queue + admission control for the continuous-batching engine.

The scheduler is pure host-side bookkeeping (no jax): it owns the waiting
queues and decides, at every chunk boundary, which requests join the
running batch. The engine's SERIAL admit stage calls
:meth:`Scheduler.try_admit` with the currently free resources; retirement
calls :meth:`finish` / :meth:`fail_all_waiting` to fulfil the request
futures.

Admission policy — *tiered FIFO on prompt-only footprint*:

* requests carry a **priority tier** (``ServeRequest(priority=...)``,
  0 = highest/SLO tier, larger = more best-effort). Each tier is one queue
  ordered **earliest-deadline-first**: requests with a ``deadline_s`` sort
  by their absolute deadline ahead of deadline-less ones, which keep plain
  FIFO (request-id) order among themselves — a pure-FIFO workload is
  byte-identical to the pre-EDF scheduler. Admission scans tiers in strict
  priority order, EDF-then-FIFO within a tier;
* a group is admitted when the block pool covers every member's **prompt**
  KV footprint (not ``prompt + max_new``) and free decode slots exist.
  Decode-time KV is allocated lazily, block by block, as sequences grow
  (:meth:`repro.serve.kvcache.BlockPool.grow_table`); pool exhaustion
  mid-decode preempts a cost-model-selected victim back onto this queue
  (:meth:`requeue_front`) instead of deadlocking;
* the strict scan stops at the first request that does not fit —
  head-of-line order is preserved within and across tiers (a lower tier
  never leapfrogs a blocked higher-tier head). **Per-tier admission
  targets** (``tier_targets={tier: share}``) are the anti-starvation
  escape hatch: ``floor(share * cap)`` seats of every admission cycle are
  reserved for a backlogged tier and filled even when a higher-tier head
  is blocked, so best-effort traffic keeps a guaranteed minimum share
  under sustained SLO load (choose ``share >= 1/max_admit`` for at least
  one seat);
* requests with a **deadline** (``deadline_s``) are swept on every
  admission attempt (and by the engine's per-cycle
  :meth:`expire_waiting`): an expired waiting request fails typed
  (:class:`repro.serve.errors.DeadlineExceeded`) and leaves the queue
  without ever seating. Cancelled requests
  (:meth:`ServeRequest.cancel`) are dropped the same way.
"""
from __future__ import annotations

import itertools
import threading
import time
from collections import deque
from typing import Any, Callable, Deque, Dict, Iterable, List, Optional

import numpy as np

from .errors import (DeadlineExceeded, RequestCancelled, ServeError,
                     WatchdogTimeout)

__all__ = ["ServeRequest", "Scheduler"]

_REQ_IDS = itertools.count()


class ServeRequest:
    """One generation request: a prompt plus a future for its output.

    ``submit()`` hands these out; :meth:`result` blocks until the engine's
    complete stage retires the sequence (or the request fails, in which
    case the failure re-raises here instead of deadlocking — typed
    :class:`repro.serve.errors.ServeError` subclasses re-raise directly,
    anything else wraps in a ``RuntimeError``).

    SLO fields: ``priority`` is the scheduling tier (0 = highest;
    admission scans tiers in order, preemption victimizes the highest
    tier number first), ``deadline_s`` an optional per-request latency
    bound measured from submit — an expired request fails
    :class:`DeadlineExceeded` whether it is still queued or mid-decode.
    :meth:`cancel` withdraws the request from any state.

    :attr:`state` tracks the request through the engine — ``"created"`` →
    ``"waiting"`` (queued) → ``"prefilling"`` (admitted, prompt KV being
    chunked in) → ``"decoding"`` → ``"done"``/``"failed"``; a mid-decode
    preemption moves it back to ``"waiting"`` and bumps
    :attr:`preempted_count` (under the async-lookahead engine the tokens
    the in-flight chunk computed for the preempted seat are discarded, and
    the re-run emits an identical stream — greedy decode is
    deterministic). Purely informational (the timeout message below
    reports it); transitions are made by the single SERIAL writer stages,
    so torn reads can at worst be one step stale.
    """

    def __init__(self, prompt: Any, max_new: int, *,
                 priority: int = 0,
                 deadline_s: Optional[float] = None) -> None:
        self.id = next(_REQ_IDS)
        self.prompt = np.asarray(prompt, np.int32)
        if self.prompt.ndim != 1 or self.prompt.size == 0:
            raise ValueError("prompt must be a non-empty 1-D token array")
        if max_new < 1:
            raise ValueError("max_new must be >= 1")
        if priority < 0:
            raise ValueError("priority must be >= 0 (0 = highest tier)")
        if deadline_s is not None and deadline_s <= 0:
            raise ValueError("deadline_s must be positive (or None)")
        self.max_new = int(max_new)
        self.priority = int(priority)
        self.deadline_s = float(deadline_s) if deadline_s is not None \
            else None
        #: absolute perf_counter deadline, stamped by the engine at submit
        self.deadline_at: Optional[float] = None
        self.state = "created"
        self.preempted_count = 0       # mid-decode evictions (see above)
        self._cancel_requested = False
        # SSM/hybrid checkpoint-preemption payload (sync engines): the
        # slot's exact recurrent state + progress, captured at preemption
        # and consumed (re-seated, no prefill replay) at re-admission
        self._ssm_ckpt: Optional[tuple] = None
        # Lifecycle timestamps, all on the time.perf_counter clock (the
        # same clock the tracer uses, so spans and these agree):
        self.submitted_at: Optional[float] = None   # set by the engine
        self.admitted_at: Optional[float] = None    # FIRST admission
        self.first_token_at: Optional[float] = None
        self.finished_at: Optional[float] = None
        # re-set on every (re-)enqueue / admission — a preempted request's
        # current wait, vs the *_at fields which keep first-occurrence
        self.queued_since: Optional[float] = None
        self.last_admitted_at: Optional[float] = None
        self._done = threading.Event()
        self._tokens: Optional[np.ndarray] = None
        self._error: Optional[BaseException] = None

    # ------------------------------------------------------------ future API
    def done(self) -> bool:
        return self._done.is_set()

    def set_result(self, tokens: np.ndarray) -> None:
        self._tokens = tokens
        self.state = "done"
        self._done.set()

    def set_error(self, err: BaseException) -> None:
        if not self._done.is_set():
            self._error = err
            self.state = "failed"
            self._done.set()

    def cancel(self) -> bool:
        """Withdraw the request. Returns False if it already completed
        (result or failure), True otherwise. A still-waiting request fails
        :class:`RequestCancelled` immediately; a seated one is reclaimed
        at the engine's next cycle boundary (blocks/slot released through
        the normal eviction path) and then fails the same way."""
        if self._done.is_set():
            return False
        self._cancel_requested = True
        if self.state in ("created", "waiting"):
            # unblock the caller now; the scheduler drops the queue entry
            # lazily on its next sweep
            self.set_error(RequestCancelled(
                f"request {self.id} cancelled while {self.state}"))
        return True

    def result(self, timeout: Optional[float] = 120.0) -> np.ndarray:
        if not self._done.wait(timeout):
            raise TimeoutError(
                f"request {self.id} did not complete within {timeout}s "
                f"(state: {self.state}, preempted {self.preempted_count}x; "
                f"submitted_at={self._fmt(self.submitted_at)} "
                f"admitted_at={self._fmt(self.admitted_at)} "
                f"first_token_at={self._fmt(self.first_token_at)} "
                f"finished_at={self._fmt(self.finished_at)})")
        if self._error is not None:
            if isinstance(self._error, ServeError):
                raise self._error        # typed: callers branch on policy
            raise RuntimeError(
                f"request {self.id} failed in the serve pipeline"
            ) from self._error
        return self._tokens

    @staticmethod
    def _fmt(t: Optional[float]) -> str:
        return f"{t:.3f}" if t is not None else "unset"

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.shape[0])

    def expired(self, now: Optional[float] = None) -> bool:
        """True once the absolute deadline (if any) has passed."""
        if self.deadline_at is None:
            return False
        return (now if now is not None else time.perf_counter()) \
            > self.deadline_at

    # -------------------------------------------------- derived lifecycle SLOs
    @property
    def ttft(self) -> Optional[float]:
        """Time to first token (submit -> first decode token), or None
        until one exists."""
        if self.first_token_at is None or self.submitted_at is None:
            return None
        return self.first_token_at - self.submitted_at

    @property
    def queue_wait(self) -> Optional[float]:
        """Submit -> first admission wait, or None while still queued."""
        if self.admitted_at is None or self.submitted_at is None:
            return None
        return self.admitted_at - self.submitted_at


class Scheduler:
    """Tiered waiting queue + admission-control policy (host side,
    thread-safe). ``tier_targets`` maps a priority tier to its guaranteed
    minimum share of each admission cycle (see module docstring);
    ``on_event(kind, req)`` — kind in ``("expired", "cancelled")`` — is
    called (outside the scheduler lock) whenever a sweep drops a waiting
    request, so the engine can keep its stats/counters current."""

    def __init__(self, max_admit: int = 8,
                 tier_targets: Optional[Dict[int, float]] = None) -> None:
        if max_admit < 1:
            raise ValueError("max_admit must be >= 1")
        self.max_admit = max_admit
        self.tier_targets = {int(t): float(s)
                             for t, s in (tier_targets or {}).items()}
        for t, s in self.tier_targets.items():
            if not 0.0 < s <= 1.0:
                raise ValueError(
                    f"tier_targets[{t}] = {s}: share must be in (0, 1]")
        self.on_event: Optional[Callable[[str, ServeRequest], None]] = None
        self._lock = threading.Lock()
        # one queue per tier, each kept sorted by the EDF key (deadline-or-
        # infinity, then request id): deadline requests admit earliest-
        # deadline-first, deadline-less ones keep FIFO order after them.
        # Enqueue of a deadline-less request is still an O(1) append —
        # its key (inf, monotone id) always sorts last.
        self._queues: Dict[int, Deque[ServeRequest]] = {}
        self._g_depth = None           # serve.queue_depth gauge when bound

    @staticmethod
    def _edf_key(r: ServeRequest) -> tuple:
        """Within-tier admission order: earliest absolute deadline first,
        deadline-less requests after every deadline one in FIFO (id)
        order. Ids are monotone, so the id tiebreak preserves submission
        order among equal deadlines too."""
        d = r.deadline_at
        return (d if d is not None else float("inf"), r.id)

    def set_metrics(self, metrics) -> None:
        """Bind (or unbind with None) a :class:`repro.obs.MetricsRegistry`:
        the scheduler keeps a ``serve.queue_depth`` gauge current at every
        queue mutation. Cheap enough to leave on: queue ops are per-request,
        not per-token."""
        self._g_depth = metrics.gauge("serve.queue_depth") \
            if metrics is not None else None

    def _note_depth_locked(self) -> None:
        if self._g_depth is not None:
            self._g_depth.set(sum(len(q) for q in self._queues.values()))

    def _q_locked(self, tier: int) -> Deque[ServeRequest]:
        q = self._queues.get(tier)
        if q is None:
            q = self._queues[tier] = deque()
        return q

    def _tiers_locked(self) -> List[int]:
        return sorted(t for t, q in self._queues.items() if q)

    # -------------------------------------------------------------- enqueue
    def enqueue(self, req: ServeRequest) -> None:
        req.state = "waiting"
        req.queued_since = time.perf_counter()
        key = self._edf_key(req)
        with self._lock:
            q = self._q_locked(req.priority)
            if not q or key >= self._edf_key(q[-1]):
                q.append(req)    # deadline-less fast path: always lands here
            else:
                self._queues[req.priority] = deque(
                    sorted(list(q) + [req], key=self._edf_key))
            self._note_depth_locked()

    def requeue_front(self, reqs: Iterable[ServeRequest]) -> None:
        """Put preempted (or admission-race-unwound) requests back into
        their tier's line at their EDF-key positions. A plain extendleft
        would suffice from ONE caller, but the decode stage (preemption)
        and the admit stage (alloc-race unwind) can both re-queue
        concurrently — merging by key keeps each tier's EDF/no-starvation
        invariant under that race (for deadline-less requests the key is
        their id, so this is the old FIFO merge)."""
        reqs = sorted(reqs, key=self._edf_key)
        now = time.perf_counter()
        for r in reqs:
            r.state = "waiting"
            r.queued_since = now
        with self._lock:
            for r in reqs:
                q = self._q_locked(r.priority)
                merged = sorted(list(q) + [r], key=self._edf_key)
                self._queues[r.priority] = deque(merged)
            self._note_depth_locked()

    @property
    def num_waiting(self) -> int:
        with self._lock:
            return sum(len(q) for q in self._queues.values())

    def num_waiting_upto(self, priority: int) -> int:
        """Waiting requests at tiers <= ``priority`` — everything that
        would be admitted ahead of (or alongside) a new request at that
        tier; the load-shed estimator's backlog term."""
        with self._lock:
            return sum(len(q) for t, q in self._queues.items()
                       if t <= priority)

    def waiting_tokens_upto(self, priority: int) -> int:
        """Total decode work (``max_new`` tokens) waiting at tiers <=
        ``priority`` — the backlog term of the service-rate load-shed
        estimator (everything that drains ahead of, or alongside, a new
        request at that tier)."""
        with self._lock:
            return sum(r.max_new for t, q in self._queues.items()
                       if t <= priority for r in q)

    def peek_head(self) -> Optional[ServeRequest]:
        """The request the strict-priority scan would admit next (no pop,
        no sweep): the oldest waiting request of the best backlogged tier.
        The engine's admission-boost pass compares seated rows against
        this head."""
        with self._lock:
            for t in self._tiers_locked():
                for r in self._queues[t]:
                    if not r.done() and not r._cancel_requested:
                        return r
            return None

    def oldest(self) -> Optional[ServeRequest]:
        return self.peek_head()

    # ----------------------------------------------------------------- sweep
    def _sweep_locked(self, now: float) -> List[tuple]:
        """Drop cancelled requests and fail+drop expired ones from every
        tier queue. Returns ``(kind, req)`` events for the caller to emit
        OUTSIDE the lock."""
        events: List[tuple] = []
        for t, q in self._queues.items():
            if not q:
                continue
            kept: Deque[ServeRequest] = deque()
            for r in q:
                if r._cancel_requested or r.done():
                    # cancel() already failed the future (or a racing
                    # cancel landed between state flips) — just drop
                    r.set_error(RequestCancelled(
                        f"request {r.id} cancelled while waiting"))
                    events.append(("cancelled", r))
                elif r.expired(now):
                    r.set_error(DeadlineExceeded(
                        f"request {r.id} deadline "
                        f"({r.deadline_s:.3f}s) expired after "
                        f"{now - (r.submitted_at or now):.3f}s in queue"))
                    events.append(("expired", r))
                else:
                    kept.append(r)
            self._queues[t] = kept
        if events:
            self._note_depth_locked()
        return events

    def _emit(self, events: List[tuple]) -> None:
        cb = self.on_event
        if cb is None:
            return
        for kind, req in events:
            cb(kind, req)

    def expire_waiting(self, now: Optional[float] = None) -> int:
        """Sweep the queues for expired/cancelled waiting requests (the
        engine calls this every decode cycle so deadlines fire promptly
        even while admission is parked). Returns the number dropped."""
        with self._lock:
            events = self._sweep_locked(
                now if now is not None else time.perf_counter())
        self._emit(events)
        return len(events)

    def export_waiting(self) -> List[ServeRequest]:
        """Snapshot copy of every waiting request in admission-scan order
        (tier, then EDF position) — the engine's snapshot writer persists
        these so a drained engine's queue survives a restart even without
        a journal. Pure read; the queues are untouched."""
        with self._lock:
            return [r for t in self._tiers_locked()
                    for r in self._queues[t]
                    if not r.done() and not r._cancel_requested]

    # ------------------------------------------------------------- admission
    def try_admit(self, free_slots: int,
                  blocks_free: Optional[int],
                  need_for: Optional[Callable[[ServeRequest], int]] = None,
                  hopeless: Optional[Callable[[ServeRequest],
                                              Optional[str]]] = None
                  ) -> Optional[List[ServeRequest]]:
        """Pop the next admission group, or None (taking nothing) when no
        waiting request can be covered — the engine turns that into either
        a deferred-token park or a plain decode-pump cycle.

        The block budget charges each member ``need_for(req)`` blocks — the
        request's PROMPT footprint only, minus any prompt blocks the
        engine's prefix cache already holds (a cache-hit admission budgets
        just its uncached suffix, which is exactly why shared-prefix
        traffic admits earlier under load). Decode-time blocks are granted
        lazily by the engine as rows grow. ``blocks_free=None`` skips block
        budgeting entirely (the SSM/hybrid slot-pool path, whose recurrent
        state is pre-allocated per slot). The engine allocates the group's
        blocks AFTER this pop (one all-or-nothing ``BlockPool.alloc``); if
        that races with a concurrent grow it re-queues via
        :meth:`requeue_front`.

        Selection: a strict-priority pass (tiers in order, EDF-then-FIFO
        within — see :meth:`_edf_key`,
        the whole pass stops at the first member that does not fit), then
        the per-tier reserved seats (``tier_targets``) fill for backlogged
        tiers even when the strict pass was blocked. Expired/cancelled
        entries are swept first.

        ``hopeless(req) -> reason | None`` is the engine's preemption-aware
        deadline check: a head whose remaining deadline budget cannot cover
        its estimated remaining prefill+decode at the current service rate
        fails typed :class:`DeadlineExceeded` HERE — popped and failed, no
        blocks charged, the scan continues past it — instead of seating,
        decoding for a while, and expiring mid-stream anyway (wasted pool
        and a doomed preemption). Only consulted for requests the scan is
        about to admit, so an estimate that later improves (service rate
        recovers) never pre-fails deep queue entries.
        """
        with self._lock:
            events = self._sweep_locked(time.perf_counter())
            group: List[ServeRequest] = []
            taken: Dict[int, int] = {}
            tiers = self._tiers_locked()
            if tiers and free_slots >= 1:
                cap = min(self.max_admit, free_slots)
                reserve = {t: min(len(self._queues[t]),
                                  int(self.tier_targets[t] * cap))
                           for t in tiers if t in self.tier_targets}
                # always leave >=1 strict-priority seat: reserved shares
                # that floor-round up to the whole cap must not lock the
                # top tier out of its own admission cycle
                strict_cap = max(1, cap - sum(reserve.values()))
                budget = blocks_free
                # pass 1 — strict priority, global head-of-line
                blocked = False
                for t in tiers:
                    for r in self._queues[t]:
                        if len(group) >= strict_cap:
                            break
                        why = hopeless(r) if hopeless is not None else None
                        if why is not None:
                            r.set_error(DeadlineExceeded(why))
                            events.append(("expired", r))
                            taken[t] = taken.get(t, 0) + 1
                            continue
                        if budget is not None:
                            need = need_for(r)
                            if need > budget:
                                blocked = True
                                break
                            budget -= need
                        group.append(r)
                        taken[t] = taken.get(t, 0) + 1
                    if blocked or len(group) >= strict_cap:
                        break
                # pass 2 — reserved seats: a backlogged target tier admits
                # its guaranteed share even when a higher-tier head blocked
                # the strict pass
                for t in sorted(reserve):
                    want = reserve[t]
                    q = self._queues[t]
                    while want > 0 and taken.get(t, 0) < len(q) \
                            and len(group) < cap:
                        r = q[taken.get(t, 0)]
                        why = hopeless(r) if hopeless is not None else None
                        if why is not None:
                            r.set_error(DeadlineExceeded(why))
                            events.append(("expired", r))
                            taken[t] = taken.get(t, 0) + 1
                            continue
                        if budget is not None:
                            need = need_for(r)
                            if need > budget:
                                break
                            budget -= need
                        group.append(r)
                        taken[t] = taken.get(t, 0) + 1
                        want -= 1
            for t, k in taken.items():
                q = self._queues[t]
                for _ in range(k):
                    q.popleft()
            if taken:
                self._note_depth_locked()
            if group:
                now = time.perf_counter()
                for req in group:
                    req.last_admitted_at = now
        self._emit(events)
        return group or None

    # ------------------------------------------------------------ retirement
    def finish(self, req: ServeRequest, tokens: np.ndarray, now: float
               ) -> None:
        req.finished_at = now
        req.set_result(tokens)

    def fail_all_waiting(self, err: BaseException) -> None:
        """Resident pipeline died: fail queued requests so result() raises
        instead of timing out."""
        with self._lock:
            waiting = [r for q in self._queues.values() for r in q]
            self._queues.clear()
            self._note_depth_locked()
        for r in waiting:
            r.set_error(err)
