"""Request queue + admission control for the continuous-batching engine.

The scheduler is pure host-side bookkeeping (no jax): it owns the waiting
queue and decides, at every chunk boundary, which requests join the running
batch. The engine's SERIAL admit stage calls :meth:`Scheduler.try_admit`
with the currently free resources; retirement calls :meth:`finish` /
:meth:`fail` to fulfil the request futures.

Admission policy — *FIFO on prompt-only footprint* (two-phase admission):

* requests admit strictly oldest-first from ONE queue. There are no prompt
  length buckets any more: chunked prefill processes every prompt in
  fixed-size windows, so an admission group's compiled shapes no longer
  depend on its members' prompt lengths and mixed-length groups ride one
  prefill launch together;
* a group is admitted when the block pool covers every member's **prompt**
  KV footprint (not ``prompt + max_new``) and free decode slots exist.
  Decode-time KV is allocated lazily, block by block, as sequences grow
  (:meth:`repro.serve.kvcache.BlockPool.grow_table`); pool exhaustion
  mid-decode preempts the youngest running row back onto this queue
  (:meth:`requeue_front`) instead of deadlocking;
* admission stops at the first request that does not fit — head-of-line
  order is preserved (no starvation via younger requests skipping ahead).
"""
from __future__ import annotations

import itertools
import threading
import time
from collections import deque
from typing import Any, Callable, Deque, Iterable, List, Optional

import numpy as np

__all__ = ["ServeRequest", "Scheduler"]

_REQ_IDS = itertools.count()


class ServeRequest:
    """One generation request: a prompt plus a future for its output.

    ``submit()`` hands these out; :meth:`result` blocks until the engine's
    complete stage retires the sequence (or the resident pipeline fails, in
    which case the failure re-raises here instead of deadlocking).

    :attr:`state` tracks the request through the engine — ``"created"`` →
    ``"waiting"`` (queued) → ``"prefilling"`` (admitted, prompt KV being
    chunked in) → ``"decoding"`` → ``"done"``/``"failed"``; a mid-decode
    preemption moves it back to ``"waiting"`` and bumps
    :attr:`preempted_count` (under the async-lookahead engine the tokens
    the in-flight chunk computed for the preempted seat are discarded, and
    the re-run emits an identical stream — greedy decode is
    deterministic). Purely informational (the timeout message below
    reports it); transitions are made by the single SERIAL writer stages,
    so torn reads can at worst be one step stale.
    """

    def __init__(self, prompt: Any, max_new: int) -> None:
        self.id = next(_REQ_IDS)
        self.prompt = np.asarray(prompt, np.int32)
        if self.prompt.ndim != 1 or self.prompt.size == 0:
            raise ValueError("prompt must be a non-empty 1-D token array")
        if max_new < 1:
            raise ValueError("max_new must be >= 1")
        self.max_new = int(max_new)
        self.state = "created"
        self.preempted_count = 0       # mid-decode evictions (see above)
        # Lifecycle timestamps, all on the time.perf_counter clock (the
        # same clock the tracer uses, so spans and these agree):
        self.submitted_at: Optional[float] = None   # set by the engine
        self.admitted_at: Optional[float] = None    # FIRST admission
        self.first_token_at: Optional[float] = None
        self.finished_at: Optional[float] = None
        # re-set on every (re-)enqueue / admission — a preempted request's
        # current wait, vs the *_at fields which keep first-occurrence
        self.queued_since: Optional[float] = None
        self.last_admitted_at: Optional[float] = None
        self._done = threading.Event()
        self._tokens: Optional[np.ndarray] = None
        self._error: Optional[BaseException] = None

    # ------------------------------------------------------------ future API
    def done(self) -> bool:
        return self._done.is_set()

    def set_result(self, tokens: np.ndarray) -> None:
        self._tokens = tokens
        self.state = "done"
        self._done.set()

    def set_error(self, err: BaseException) -> None:
        if not self._done.is_set():
            self._error = err
            self.state = "failed"
            self._done.set()

    def result(self, timeout: Optional[float] = 120.0) -> np.ndarray:
        if not self._done.wait(timeout):
            raise TimeoutError(
                f"request {self.id} did not complete within {timeout}s "
                f"(state: {self.state}, preempted {self.preempted_count}x; "
                f"submitted_at={self._fmt(self.submitted_at)} "
                f"admitted_at={self._fmt(self.admitted_at)} "
                f"first_token_at={self._fmt(self.first_token_at)} "
                f"finished_at={self._fmt(self.finished_at)})")
        if self._error is not None:
            raise RuntimeError(
                f"request {self.id} failed in the serve pipeline"
            ) from self._error
        return self._tokens

    @staticmethod
    def _fmt(t: Optional[float]) -> str:
        return f"{t:.3f}" if t is not None else "unset"

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.shape[0])

    # -------------------------------------------------- derived lifecycle SLOs
    @property
    def ttft(self) -> Optional[float]:
        """Time to first token (submit -> first decode token), or None
        until one exists."""
        if self.first_token_at is None or self.submitted_at is None:
            return None
        return self.first_token_at - self.submitted_at

    @property
    def queue_wait(self) -> Optional[float]:
        """Submit -> first admission wait, or None while still queued."""
        if self.admitted_at is None or self.submitted_at is None:
            return None
        return self.admitted_at - self.submitted_at


class Scheduler:
    """Waiting-queue + admission-control policy (host side, thread-safe)."""

    def __init__(self, max_admit: int = 8) -> None:
        if max_admit < 1:
            raise ValueError("max_admit must be >= 1")
        self.max_admit = max_admit
        self._lock = threading.Lock()
        # ONE FIFO ordered by request id (enqueue appends, preemption
        # re-inserts at the front — preempted requests are older than
        # anything still waiting, so id order is preserved)
        self._queue: Deque[ServeRequest] = deque()
        self._g_depth = None           # serve.queue_depth gauge when bound

    def set_metrics(self, metrics) -> None:
        """Bind (or unbind with None) a :class:`repro.obs.MetricsRegistry`:
        the scheduler keeps a ``serve.queue_depth`` gauge current at every
        queue mutation. Cheap enough to leave on: queue ops are per-request,
        not per-token."""
        self._g_depth = metrics.gauge("serve.queue_depth") \
            if metrics is not None else None

    def _note_depth_locked(self) -> None:
        if self._g_depth is not None:
            self._g_depth.set(len(self._queue))

    # -------------------------------------------------------------- enqueue
    def enqueue(self, req: ServeRequest) -> None:
        req.state = "waiting"
        req.queued_since = time.perf_counter()
        with self._lock:
            self._queue.append(req)
            self._note_depth_locked()

    def requeue_front(self, reqs: Iterable[ServeRequest]) -> None:
        """Put preempted (or admission-race-unwound) requests back into the
        line at their id positions. A plain extendleft would suffice from
        ONE caller, but the decode stage (preemption) and the admit stage
        (alloc-race unwind) can both re-queue concurrently — merging by id
        keeps the queue's FIFO/no-starvation invariant under that race."""
        reqs = sorted(reqs, key=lambda r: r.id)
        now = time.perf_counter()
        for r in reqs:
            r.state = "waiting"
            r.queued_since = now
        with self._lock:
            merged = sorted(list(self._queue) + list(reqs),
                            key=lambda r: r.id)
            self._queue = deque(merged)
            self._note_depth_locked()

    @property
    def num_waiting(self) -> int:
        with self._lock:
            return len(self._queue)

    def _head_locked(self) -> Optional[ServeRequest]:
        """The single head-of-line rule: the oldest waiting request leads.
        Shared by :meth:`oldest` and :meth:`try_admit` so the two can never
        disagree about who goes first. Caller holds ``_lock``."""
        return self._queue[0] if self._queue else None

    def oldest(self) -> Optional[ServeRequest]:
        with self._lock:
            return self._head_locked()

    # ------------------------------------------------------------- admission
    def try_admit(self, free_slots: int,
                  blocks_free: Optional[int],
                  need_for: Optional[Callable[[ServeRequest], int]] = None
                  ) -> Optional[List[ServeRequest]]:
        """Pop the next admission group, or None (taking nothing) when the
        oldest waiting request cannot be covered — the engine turns that
        into either a deferred-token park or a plain decode-pump cycle.

        The block budget charges each member ``need_for(req)`` blocks — the
        request's PROMPT footprint only, minus any prompt blocks the
        engine's prefix cache already holds (a cache-hit admission budgets
        just its uncached suffix, which is exactly why shared-prefix
        traffic admits earlier under load). Decode-time blocks are granted
        lazily by the engine as rows grow. ``blocks_free=None`` skips block
        budgeting entirely (the SSM/hybrid slot-pool path, whose recurrent
        state is pre-allocated per slot). The engine allocates the group's
        blocks AFTER this pop (one all-or-nothing ``BlockPool.alloc``); if
        that races with a concurrent grow it re-queues via
        :meth:`requeue_front`.
        """
        with self._lock:
            if self._head_locked() is None or free_slots < 1:
                return None
            group: List[ServeRequest] = []
            budget = blocks_free
            cap = min(self.max_admit, free_slots)
            for req in itertools.islice(self._queue, cap):
                if budget is not None:
                    need = need_for(req)
                    if need > budget:
                        break
                    budget -= need
                group.append(req)
            if not group:
                return None  # head of line does not fit: back-pressure
            for _ in group:
                self._queue.popleft()
            self._note_depth_locked()
            now = time.perf_counter()
            for req in group:
                req.last_admitted_at = now
            return group

    # ------------------------------------------------------------ retirement
    def finish(self, req: ServeRequest, tokens: np.ndarray, now: float
               ) -> None:
        req.finished_at = now
        req.set_result(tokens)

    def fail_all_waiting(self, err: BaseException) -> None:
        """Resident pipeline died: fail queued requests so result() raises
        instead of timing out."""
        with self._lock:
            waiting = list(self._queue)
            self._queue.clear()
            self._note_depth_locked()
        for r in waiting:
            r.set_error(err)
