"""Prefix cache: a hash trie over block-aligned prompt chunks.

At production scale most traffic shares long system/few-shot prompt
prefixes; re-prefilling them on every admission wastes the hottest device
path AND duplicates their KV in the paged pool. This module is the host
side of copy-on-write KV block sharing:

* prompts are hashed in ``block_size``-token CHUNKS, each chunk keyed on
  ``(parent chain hash, chunk tokens)`` — a trie whose nodes map one full
  prompt chunk to the pool block already holding its KV. Chained hashing
  means a node can only match when its ENTIRE token prefix matches; hash
  collisions are disambiguated by comparing the stored chunk tokens
  (``tests/test_prefix_cache.py`` forces collisions through an injected
  hash function).
* :meth:`PrefixCache.match_and_pin` walks the longest cached prefix for an
  admitted prompt and pins every matched block
  (:meth:`repro.serve.kvcache.BlockPool.incref`) so the admitting row can
  seed its block table with SHARED blocks and budget only its suffix.
  Beyond the last full-chunk match it also offers the best PARTIAL tail
  match — a cached block whose leading tokens extend the match — which the
  engine consumes by copy-on-write fork (clone then continue writing).
* :meth:`PrefixCache.register` inserts a freshly prefilled row's full
  prompt chunks, taking one index reference per block. When the owning
  request later retires and drops its own reference, the block is PARKED:
  alive, invisible to allocation, free to be shared by future admissions.
* :meth:`PrefixCache.evict` is reuse-aware back-pressure: under pool
  pressure the engine releases cold parked blocks by a reuse score
  (hit count x recency) LEAF-FIRST, so a parent chunk is never evicted
  while a cached child still chains through it (the
  parent-before-child trie invariant) — and hot shared prefixes outlive
  cold tails, which is the whole point (arXiv:1502.07451's cost-model
  thesis: victim selection must weigh reuse value, not just age).

The cache never touches device memory itself: it is pure host bookkeeping
over block IDS, thread-safe (admit-stage lookup/evict vs decode-stage
register), with the pool's refcounts as the single source of liveness
truth. A matched prefix is bit-identical KV by construction: chunk KV
depends only on the token prefix and absolute positions, both of which the
chained hash + token comparison pin exactly.
"""
from __future__ import annotations

import hashlib
import threading
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

__all__ = ["PrefixCache", "PrefixHit"]


def _default_hash(parent_key: int, chunk: bytes) -> int:
    """Chunk-hash chained on the parent chain hash. STABLE content hash
    (blake2b over the parent key + token bytes), not Python's ``hash()``:
    chunk identity must survive ``PYTHONHASHSEED`` changes and process
    restarts so a persisted/cross-process prefix index keys the same
    prompt to the same chain (the cross-tier pinning prerequisite).
    Collisions are still disambiguated by token comparison downstream."""
    h = hashlib.blake2b(digest_size=8)
    h.update(parent_key.to_bytes(8, "little", signed=True))
    h.update(chunk)
    return int.from_bytes(h.digest(), "little", signed=True)


class _Node:
    """One cached prompt chunk: ``block`` holds the KV of ``tokens`` at
    absolute positions ``[depth*bs, (depth+1)*bs)`` given the parent
    chain's token prefix."""

    __slots__ = ("key", "parent", "children", "block", "tokens", "hits",
                 "last_used", "depth", "warm")

    def __init__(self, key: int, parent: Optional["_Node"], block: int,
                 tokens: np.ndarray, depth: int, now: float,
                 warm: bool = False) -> None:
        self.key = key
        self.parent = parent
        # hash -> list of nodes (collision chain, disambiguated by tokens)
        self.children: Dict[int, List["_Node"]] = {}
        self.block = block
        self.tokens = tokens
        self.hits = 0
        self.last_used = now
        self.depth = depth
        self.warm = warm        # restored from a snapshot, not prefilled here


class PrefixHit:
    """Result of :meth:`PrefixCache.match_and_pin`: ``blocks`` are the
    pinned FULL shared prefix blocks (one per cached chunk, table-order),
    ``tokens`` the total cached token count (``partial_len`` of which sit
    in ``partial_block`` — a pinned shared block the engine must
    copy-on-write fork before writing the row's own suffix into it)."""

    __slots__ = ("blocks", "tokens", "partial_block", "partial_len")

    def __init__(self, blocks: List[int], tokens: int,
                 partial_block: Optional[int], partial_len: int) -> None:
        self.blocks = blocks
        self.tokens = tokens
        self.partial_block = partial_block
        self.partial_len = partial_len


class PrefixCache:
    """Block-granular prompt prefix index over a :class:`BlockPool`.

    ``hash_fn(parent_key, chunk_bytes) -> int`` is injectable so tests can
    force collisions; the default chains Python's bytes hash.
    """

    def __init__(self, pool, hash_fn: Optional[Callable[[int, bytes], int]]
                 = None) -> None:
        self._pool = pool
        self._bs = pool.block_size
        self._hash = hash_fn or _default_hash
        self._lock = threading.Lock()
        self._root: Dict[int, List[_Node]] = {}   # depth-0 collision chains
        self._nodes = 0
        self.stats = {"hits": 0, "misses": 0, "evicted": 0, "warm_hits": 0}
        self._c_hits = self._c_misses = self._c_evicted = None
        self._c_warm = None
        self._g_parked = None

    # ---------------------------------------------------------- observability
    def set_metrics(self, metrics) -> None:
        """Bind (or unbind with None) a metrics registry: ``prefix.hits`` /
        ``prefix.misses`` / ``prefix.evicted`` counters plus the
        ``pool.blocks_parked`` gauge (blocks whose ONLY reference is this
        index — cached capacity reclaimable without touching any row)."""
        if metrics is None:
            self._c_hits = self._c_misses = self._c_evicted = None
            self._c_warm = None
            self._g_parked = None
            return
        self._c_hits = metrics.counter("prefix.hits")
        self._c_misses = metrics.counter("prefix.misses")
        self._c_evicted = metrics.counter("prefix.evicted")
        self._c_warm = metrics.counter("prefix.warm_hits")
        self._g_parked = metrics.gauge("pool.blocks_parked")
        with self._lock:
            self._note_parked_locked()

    def _iter_nodes_locked(self):
        stack = [n for chain in self._root.values() for n in chain]
        while stack:
            node = stack.pop()
            yield node
            for chain in node.children.values():
                stack.extend(chain)

    def _note_parked_locked(self) -> None:
        if self._g_parked is not None:
            self._g_parked.set(sum(
                1 for n in self._iter_nodes_locked()
                if self._pool.refcount(n.block) == 1))

    # -------------------------------------------------------------- accounting
    @property
    def num_nodes(self) -> int:
        with self._lock:
            return self._nodes

    @property
    def num_parked(self) -> int:
        """Cached blocks held ONLY by this index — evictable on pressure
        without touching any resident row."""
        with self._lock:
            return sum(1 for n in self._iter_nodes_locked()
                       if self._pool.refcount(n.block) == 1)

    # ------------------------------------------------------------------ lookup
    def _walk_locked(self, prompt: np.ndarray
                     ) -> Tuple[List[_Node], Optional[_Node], int]:
        """Longest cached prefix of ``prompt``: the matched full-chunk node
        chain, plus the best PARTIAL tail child (a node whose leading
        ``partial_len`` tokens extend the match). The total cached token
        count is capped at ``len(prompt) - 1`` — at least one prompt token
        must be computed so its logits can seed the first output token."""
        bs = self._bs
        chain: List[_Node] = []
        children, parent_key = self._root, 0
        limit = len(prompt) - 1            # leave >= 1 token to compute
        while (len(chain) + 1) * bs <= limit:
            lo = len(chain) * bs
            chunk = prompt[lo:lo + bs]
            h = self._hash(parent_key, chunk.tobytes())
            node = None
            for cand in children.get(h, ()):
                if np.array_equal(cand.tokens, chunk):  # collision guard
                    node = cand
                    break
            if node is None:
                break
            chain.append(node)
            children, parent_key = node.children, node.key
        # partial tail: the best child whose leading tokens extend the match
        lo = len(chain) * bs
        best, best_len = None, 0
        tail = prompt[lo:limit]
        if len(tail) > 0:
            for cands in children.values():
                for cand in cands:
                    m = int(min(len(tail), len(cand.tokens)))
                    eq = np.flatnonzero(cand.tokens[:m] != tail[:m])
                    k = m if eq.size == 0 else int(eq[0])
                    if k > best_len:
                        best, best_len = cand, k
        return chain, best, best_len

    def peek(self, prompt: np.ndarray) -> int:
        """Cached token count for ``prompt`` WITHOUT pinning — the
        admission budgeter (suffix blocks only = ``blocks_for(prompt_len)
        - len(full chain)``). Registration can only grow the match between
        peek and pin, so the budget is conservative."""
        with self._lock:
            chain, _, partial_len = self._walk_locked(np.asarray(prompt))
            return len(chain) * self._bs + partial_len

    def match_and_pin(self, prompt: np.ndarray) -> PrefixHit:
        """Longest-prefix match that PINS every matched block (full chain
        + partial tail) against eviction and release, and bumps the
        chain's reuse statistics. The caller owns one reference per
        returned block: table-seeded full blocks release through the row's
        normal retirement/preemption ``free``; the partial block must be
        released right after its copy-on-write fork."""
        prompt = np.asarray(prompt)
        now = time.perf_counter()
        with self._lock:
            chain, partial, partial_len = self._walk_locked(prompt)
            blocks = [n.block for n in chain]
            for n in chain:
                n.hits += 1
                n.last_used = now
            if partial is not None and partial_len > 0:
                partial.hits += 1
                partial.last_used = now
                self._pool.incref([partial.block])
            else:
                partial, partial_len = None, 0
            if blocks:
                self._pool.incref(blocks)
            hit = bool(blocks) or partial is not None
            self.stats["hits" if hit else "misses"] += 1
            c = self._c_hits if hit else self._c_misses
            if c is not None:
                c.inc()
            if hit and (any(n.warm for n in chain)
                        or (partial is not None and partial.warm)):
                # the match was served (at least partly) by chunks restored
                # from a snapshot — warm start paid off on a live request
                self.stats["warm_hits"] += 1
                if self._c_warm is not None:
                    self._c_warm.inc()
            self._note_parked_locked()
            return PrefixHit(blocks, len(blocks) * self._bs + partial_len,
                             partial.block if partial else None, partial_len)

    def unpin(self, blocks: Sequence[int]) -> None:
        """Release pins taken by :meth:`match_and_pin` (admission unwound,
        or a partial block's fork completed)."""
        if blocks:
            self._pool.free(list(blocks))
            with self._lock:
                self._note_parked_locked()

    # ---------------------------------------------------------------- register
    def register(self, prompt: np.ndarray, blocks: Sequence[int]) -> int:
        """Index a freshly prefilled row's FULL prompt chunks: chunk ``i``
        lives in ``blocks[i]``. Each newly created node takes one index
        reference on its block, so the block survives its owner's
        retirement (parked) and later admissions can share it. Chunks whose
        node already exists are skipped — the canonical block stays, the
        row's duplicate simply retires with the row. Only FULL blocks are
        registerable (a partial block is still written by its owner; a full
        prompt block never is — decode writes land strictly past the
        prompt). Returns the number of nodes created."""
        prompt = np.asarray(prompt)
        bs = self._bs
        now = time.perf_counter()
        created = 0
        with self._lock:
            children, parent_key, parent = self._root, 0, None
            for i in range(len(prompt) // bs):
                chunk = prompt[i * bs:(i + 1) * bs]
                h = self._hash(parent_key, chunk.tobytes())
                node = None
                for cand in children.get(h, ()):
                    if np.array_equal(cand.tokens, chunk):
                        node = cand
                        break
                if node is None:
                    b = int(blocks[i])
                    if self._pool.refcount(b) < 1:
                        break              # owner raced a free: stop here
                    self._pool.incref([b])
                    node = _Node(self._hash(parent_key, chunk.tobytes()),
                                 parent, b, np.array(chunk), i, now)
                    node.key = h
                    children.setdefault(h, []).append(node)
                    self._nodes += 1
                    created += 1
                children, parent_key, parent = node.children, node.key, node
            if created:
                self._note_parked_locked()
        return created

    # ----------------------------------------------------------------- evict
    def evict(self, need: int) -> int:
        """Release up to ``need`` PARKED blocks (refcount 1 — held only by
        this index) back to the pool, coldest-first by reuse score
        ``hits x recency`` and strictly LEAF-FIRST: a node with cached
        children is not a candidate until its subtree is gone, so every
        surviving node's parent chain stays intact (longest-match never
        dangles). Pinned chains (any row holding a reference) are
        untouchable. Returns the number of blocks actually freed."""
        if need <= 0:
            return 0
        now = time.perf_counter()
        freed = 0
        with self._lock:
            while freed < need:
                leaves = [n for n in self._iter_nodes_locked()
                          if not any(n.children.values())
                          and self._pool.refcount(n.block) == 1]
                if not leaves:
                    break
                # reuse score: hit count x recency decay — evict the
                # coldest (low hits, long idle) first
                leaves.sort(key=lambda n: (1 + n.hits)
                            / (1.0 + now - n.last_used))
                take = leaves[:need - freed]
                for n in take:
                    self._remove_locked(n)
                    self._pool.free([n.block])
                    freed += 1
                    self.stats["evicted"] += 1
                    if self._c_evicted is not None:
                        self._c_evicted.inc()
            if freed:
                self._note_parked_locked()
        return freed

    def clear(self) -> int:
        """Drop the ENTIRE index: release every index-held reference and
        reset the trie. The failure-isolation path uses this — after a
        raising model step the pool's KV contents are reinitialized, so
        every cached chunk is stale garbage and must not match future
        admissions. Pinned blocks (rows still referencing them) merely
        lose the index reference; parked blocks return to the pool.
        Returns the number of nodes dropped."""
        with self._lock:
            nodes = list(self._iter_nodes_locked())
            for n in nodes:
                self._pool.free([n.block])
            self._root.clear()
            self._nodes = 0
            self._note_parked_locked()
        return len(nodes)

    # ------------------------------------------------------- persistence
    def export_nodes(self) -> List[Dict]:
        """Serialize the trie for a snapshot: a list of per-node dicts in
        parent-before-child (BFS) order, each carrying its parent's LIST
        INDEX (``-1`` for depth-0 nodes), the chained chunk key, the chunk
        tokens, the reuse hit count, and the pool block id whose KV page
        the snapshot writer must capture. Chained keys are stable blake2b
        content hashes, so the same entries re-key identically in a fresh
        process."""
        with self._lock:
            order: List[_Node] = []
            index: Dict[int, int] = {}
            queue = [n for chain in self._root.values() for n in chain]
            while queue:
                nxt: List[_Node] = []
                for n in queue:
                    index[id(n)] = len(order)
                    order.append(n)
                    for chain in n.children.values():
                        nxt.extend(chain)
                queue = nxt
            return [{"parent": -1 if n.parent is None
                     else index[id(n.parent)],
                     "key": int(n.key), "depth": int(n.depth),
                     "hits": int(n.hits), "tokens": np.array(n.tokens),
                     "block": int(n.block)} for n in order]

    def import_nodes(self, entries: Sequence[Dict],
                     blocks: Sequence[int]) -> int:
        """Rebuild trie nodes from :meth:`export_nodes` entries into an
        EMPTY index, adopting ``blocks[i]`` (freshly allocated by the
        restore path, refcount 1) as node *i*'s index reference — the
        block is born PARKED. Entries must be parent-before-child;
        entries whose parent was dropped (restore truncated to fit the
        pool) are skipped, keeping the parent-chain invariant. Restored
        nodes are flagged ``warm`` so their first live match counts into
        ``prefix.warm_hits``. Returns the number of nodes created."""
        now = time.perf_counter()
        created = 0
        with self._lock:
            nodes: Dict[int, _Node] = {}
            for i, e in enumerate(entries):
                if i >= len(blocks):
                    break
                parent = None
                if e["parent"] >= 0:
                    parent = nodes.get(e["parent"])
                    if parent is None:
                        continue            # parent dropped: skip subtree
                node = _Node(int(e["key"]), parent, int(blocks[i]),
                             np.array(e["tokens"]), int(e["depth"]), now,
                             warm=True)
                node.hits = int(e.get("hits", 0))
                siblings = (self._root if parent is None
                            else parent.children)
                siblings.setdefault(node.key, []).append(node)
                nodes[i] = node
                self._nodes += 1
                created += 1
            self._note_parked_locked()
        return created

    def _remove_locked(self, node: _Node) -> None:
        siblings = (self._root if node.parent is None
                    else node.parent.children)
        chain = siblings.get(node.key, [])
        if node in chain:
            chain.remove(node)
            if not chain:
                del siblings[node.key]
            self._nodes -= 1

    def check_parent_invariant(self) -> bool:
        """Every node's parent is still indexed (test hook): eviction must
        never orphan a child chain."""
        with self._lock:
            for n in self._iter_nodes_locked():
                p = n.parent
                if p is not None and n not in p.children.get(n.key, []):
                    return False
                if p is not None:
                    sibs = (self._root if p.parent is None
                            else p.parent.children)
                    if p not in sibs.get(p.key, []):
                        return False
            return True
