"""Typed serve-runtime errors (SLO-aware overload control).

Every failure mode the engine can impose on a request has a distinct
exception type, raised DIRECTLY from :meth:`ServeRequest.result` (no
``RuntimeError`` wrapping) so callers can branch on policy:

* :class:`Overloaded`       — rejected at ``submit()`` (load shedding):
  the estimated queue wait exceeds the tier's latency budget, or the
  request's own deadline is already unreachable. Synchronous — the
  request never enters the queue.
* :class:`DeadlineExceeded` — the request's ``deadline_s`` elapsed while
  waiting in the queue or mid-decode; its blocks/slot were reclaimed.
* :class:`RequestCancelled` — :meth:`ServeRequest.cancel` was honored.
* :class:`RowFailed`        — a raising decode/prefill step failed the
  seated rows; the engine itself kept serving (``__cause__`` carries
  the original exception).
* :class:`WatchdogTimeout`  — the engine watchdog detected a stuck cycle
  (no sync progress within ``watchdog_s``) and failed all in-flight
  futures with a diagnostic instead of letting ``result()`` hang.
* :class:`EngineClosed`     — ``close()`` gave up draining (or the
  engine was torn down / draining) with the request still outstanding.
* :class:`SnapshotCorrupt`  — a state snapshot failed integrity checks
  on restore (bad magic/length/checksum/version). Unlike the others
  this is raised to the *operator* path, not a request future: callers
  catch it and cold-start (durability can lose warmth, never serve
  wrong tokens).

All derive from :class:`ServeError` (a ``RuntimeError``); the
deadline/watchdog pair additionally subclass :class:`TimeoutError` so
generic timeout handling catches them.
"""
from __future__ import annotations

__all__ = ["ServeError", "Overloaded", "DeadlineExceeded",
           "RequestCancelled", "RowFailed", "WatchdogTimeout",
           "EngineClosed", "SnapshotCorrupt"]


class ServeError(RuntimeError):
    """Base class for typed serve-runtime request failures."""


class Overloaded(ServeError):
    """Load shed at submit: estimated queue wait exceeds the latency
    budget for this request's tier (or its deadline is unreachable)."""

    def __init__(self, msg: str, *, tier: int = 0,
                 est_wait_s: float = 0.0, budget_s: float = 0.0,
                 queue_depth: int = 0) -> None:
        super().__init__(msg)
        self.tier = tier
        self.est_wait_s = est_wait_s
        self.budget_s = budget_s
        self.queue_depth = queue_depth


class DeadlineExceeded(ServeError, TimeoutError):
    """The request's ``deadline_s`` elapsed before completion."""


class RequestCancelled(ServeError):
    """The request was cancelled via :meth:`ServeRequest.cancel`."""


class RowFailed(ServeError):
    """A raising model step failed this seated row; the engine kept
    serving (``__cause__`` carries the original exception)."""


class WatchdogTimeout(ServeError, TimeoutError):
    """The engine watchdog fired: no cycle progress within the budget."""


class EngineClosed(ServeError):
    """The engine was closed/torn down with this request outstanding."""


class SnapshotCorrupt(ServeError):
    """A state snapshot failed integrity verification on restore; the
    caller must fall back to a cold start."""
