"""Engine snapshot container: checksummed, versioned state files.

Durability boundary #2 (see ``docs/robustness.md``): where the journal
(:mod:`repro.serve.journal`) makes *requests* recoverable by replay,
the snapshot makes *state that is expensive to recompute* survive a
restart — the prefix trie with its stable blake2b chunk keys, every
parked block's KV page, and the waiting-queue descriptors captured at
drain time. A warm-started engine answers a known system prompt from
the prefix cache on the FIRST post-restart request (``prefix.warm_hits``).

File format — torn-write and corruption safe by construction::

    MAGIC "RSNAPv1\\n"  | 8-byte big-endian payload length
    16-byte blake2b digest of the payload | payload (npz, pickle-free)

The payload is a standard ``.npz`` archive (``meta`` is a JSON string
stored as a 0-d unicode array; every other entry is a plain ndarray —
``allow_pickle=False`` on load, so a corrupted file can never execute
anything). Writes go to a temp file + ``os.replace`` so a crash during
:func:`write_snapshot` leaves the previous snapshot intact; any
mismatch on read — short file, bad magic, bad length, digest mismatch,
bad JSON, wrong version — raises typed :class:`SnapshotCorrupt`, and
callers (``ServeEngine.recover``, ``launch.serve --state-dir``) fall
back to a cold start. A snapshot can lose warmth; it can never serve
wrong tokens.
"""
from __future__ import annotations

import hashlib
import io
import json
import os
from typing import Any, Dict, Tuple

import numpy as np

from .errors import SnapshotCorrupt

__all__ = ["MAGIC", "SNAPSHOT_VERSION", "write_snapshot", "read_snapshot",
           "corrupt_snapshot"]

MAGIC = b"RSNAPv1\n"
SNAPSHOT_VERSION = 1

_DIGEST_SIZE = 16


def _digest(payload: bytes) -> bytes:
    return hashlib.blake2b(payload, digest_size=_DIGEST_SIZE).digest()


def write_snapshot(path: str, meta: Dict[str, Any],
                   arrays: Dict[str, np.ndarray]) -> int:
    """Write ``meta`` + ``arrays`` atomically; returns bytes written."""
    meta = dict(meta)
    meta["version"] = SNAPSHOT_VERSION
    buf = io.BytesIO()
    np.savez(buf, meta=np.array(json.dumps(meta, sort_keys=True)),
             **{k: np.ascontiguousarray(v) for k, v in arrays.items()})
    payload = buf.getvalue()
    blob = MAGIC + len(payload).to_bytes(8, "big") + _digest(payload) \
        + payload
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(blob)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)
    return len(blob)


def read_snapshot(path: str) -> Tuple[Dict[str, Any],
                                      Dict[str, np.ndarray]]:
    """Load and verify a snapshot; raises :class:`SnapshotCorrupt` on
    any integrity failure (callers cold-start)."""
    try:
        with open(path, "rb") as f:
            blob = f.read()
    except OSError as e:
        raise SnapshotCorrupt(f"snapshot unreadable: {e}") from e
    head = len(MAGIC) + 8 + _DIGEST_SIZE
    if len(blob) < head or blob[:len(MAGIC)] != MAGIC:
        raise SnapshotCorrupt("snapshot missing or bad magic header")
    plen = int.from_bytes(blob[len(MAGIC):len(MAGIC) + 8], "big")
    digest = blob[len(MAGIC) + 8:head]
    payload = blob[head:]
    if len(payload) != plen:
        raise SnapshotCorrupt(
            f"snapshot truncated: payload {len(payload)} != header {plen}")
    if _digest(payload) != digest:
        raise SnapshotCorrupt("snapshot payload checksum mismatch")
    try:
        npz = np.load(io.BytesIO(payload), allow_pickle=False)
        arrays = {k: npz[k] for k in npz.files if k != "meta"}
        meta = json.loads(str(npz["meta"]))
    except Exception as e:
        raise SnapshotCorrupt(f"snapshot payload undecodable: {e}") from e
    if not isinstance(meta, dict) \
            or meta.get("version") != SNAPSHOT_VERSION:
        raise SnapshotCorrupt(
            f"snapshot version mismatch: {meta.get('version')!r} "
            f"!= {SNAPSHOT_VERSION}")
    return meta, arrays


def corrupt_snapshot(path: str) -> None:
    """Flip one payload byte in place — the ``snapshot_corrupt`` fault
    site and the recovery tests use this to prove the typed cold-start
    fallback (a real torn write corrupts less politely; the checksum
    catches both)."""
    with open(path, "r+b") as f:
        f.seek(0, os.SEEK_END)
        size = f.tell()
        pos = len(MAGIC) + 8 + _DIGEST_SIZE + max(0, (size - 32)) // 2
        pos = min(pos, size - 1)
        f.seek(pos)
        b = f.read(1)
        f.seek(pos)
        f.write(bytes([b[0] ^ 0xFF]))
