"""Continuous-batching serve subsystem.

* :mod:`.engine`    — the resident admit→prefill→decode→complete pipeline
  (``submit()`` / ``result()``; ``generate()`` compatibility shim), serving
  EVERY architecture: attention models through the paged KV pool, SSM and
  hybrid models (mamba, zamba2) through a fixed-slot recurrent-state pool;
* :mod:`.scheduler` — TIERED request queues + admission control (no
  length buckets) budgeted on prompt-only footprints (minus any
  cached-prefix blocks when prefix caching is on): strict priority
  across tiers, earliest-deadline-first within a tier (deadline-less
  requests keep FIFO order behind any deadlines), optional guaranteed
  best-effort admission shares (``tier_targets``), queue-deadline expiry
  and lazy cancellation sweeps;
* :mod:`.errors`    — the typed failure vocabulary (``ServeError`` and
  subclasses: ``Overloaded``, ``DeadlineExceeded``, ``RequestCancelled``,
  ``RowFailed``, ``WatchdogTimeout``, ``EngineClosed``) that
  ``result()`` re-raises directly;
* :mod:`.faultinject` — the deterministic fault-injection harness
  (``REPRO_FAULT_INJECT`` / ``ServeEngine(fault_inject=...)``; seeded
  per-site schedules, see ``docs/robustness.md``);
* :mod:`.journal`   — the durability WAL: checksummed request-transition
  records, torn-tail truncating :func:`~repro.serve.journal.replay`
  (crashed requests re-submit and replay bit-identically under greedy
  decode);
* :mod:`.snapshot`  — the checksummed, versioned, pickle-free engine
  snapshot container (prefix trie + parked KV pages + waiting-queue
  descriptors; ANY integrity failure is typed ``SnapshotCorrupt`` and
  recovery cold-starts);
* :mod:`.kvcache`   — paged KV-cache pool (REFCOUNTED block allocator with
  mid-decode ``grow_table`` + jit-able fused K/V scatters through
  per-sequence block tables, including the chunked-prefill
  ``scatter_token_window``, the device-side ``extend_block_tables`` growth
  scatter and the copy-on-write ``copy_blocks`` fork; the ``gather_pages``
  reference read path);
* :mod:`.prefix`    — the prefix cache: a hash trie over block-aligned
  prompt chunks mapping cached prefixes to live pool blocks, with
  pin/park/reuse-scored-evict semantics (see ``docs/prefix_caching.md``).

Two-phase admission semantics
-----------------------------
Memory admission is split into two phases so pool capacity follows LIVE
token counts instead of worst-case reservations:

* **Phase 1 — admit on the prompt footprint.** A request joins the running
  batch as soon as free decode slots exist and the pool covers
  ``blocks_for(prompt_len)`` — not ``prompt + max_new``. Admission is
  strictly FIFO from one queue; because chunked prefill fixes the compiled
  window shape, mixed prompt lengths admit together in one group / one
  prefill launch.
* **Chunked prefill.** A prompt longer than ``prefill_chunk`` lands window
  by window: window 0 through the prefill stage, the rest streamed by the
  decode stage one window per pipeline cycle, each scattered straight into
  the paged pool through the row's block table — resident rows keep
  decoding in the overlapped cycles, so a long prompt never stalls the
  batch behind one monolithic launch.
* **Phase 2 — grow mid-decode.** Every ``block_size`` generated tokens a
  row crosses into a new block; the decode stage grants it lazily
  (``BlockPool.grow_table`` + an in-place device-side table-extension
  scatter). If the pool is exhausted, the best COST-MODEL victim is
  preempted — best-effort tier first, then least generated work lost
  per block reclaimed, prior preemptions and age as tiebreaks (tier-0
  residents survive mixed-tier overload; a grower never evicts a
  strictly better-tier victim, it stalls instead). The victim's blocks free
  immediately, its request re-queues at its tier's line position
  (greedy decode is deterministic, so the re-run emits identical
  tokens) — back-pressure degrades to queueing, never deadlock.

SLO-aware overload control
--------------------------
``submit(prompt, max_new, priority=..., deadline_s=...)`` places a
request on a scheduling TIER (0 = highest) with an optional latency
bound; ``ServeRequest.cancel()`` withdraws it from any state. Engine
knobs (see ``docs/robustness.md`` for the full policy): ``tier_targets``
guarantees backlogged best-effort tiers a minimum admission share;
``shed_budget_s`` (scalar or per-tier dict; ``REPRO_SHED_BUDGET_S``)
makes ``submit()`` raise typed ``Overloaded`` when the live estimated
queue wait exceeds the tier's budget — estimated from a service-rate
model (observed decode tokens/s vs resident remaining work plus the
waiting backlog at or above the request's tier), falling back to the
p90-queue-wait heuristic only before any rate sample exists;
``watchdog_s``
(``REPRO_WATCHDOG_S``) arms a stuck-engine monitor that fails all
outstanding futures typed ``WatchdogTimeout``; ``fault_inject``
(``REPRO_FAULT_INJECT``) enables the deterministic fault-injection
harness. Expiry/cancellation of SEATED rows reclaims blocks and seats
through the normal fence-aware eviction path; a raising prefill/decode
step fails only its blast radius typed ``RowFailed`` and the engine
rebuilds device state and keeps serving (per-row failure isolation);
``close()`` fails anything still outstanding typed ``EngineClosed``.
``benchmarks/serve_slo.py`` measures the resulting tier-0 tail-TTFT
protection under a best-effort flood.

SSM/hybrid architectures have no per-token KV to page; their O(1)-per-
sequence recurrent state (and zamba2's shared-block KV span) lives in a
fixed-slot state pool indexed by decode slot, so ``submit()``/``result()``
continuous batching covers them through the same resident pipeline
(:func:`repro.models.lm.decode_step_slots`); admission for them is
bounded by free slots alone.

Prefix caching (copy-on-write KV block sharing)
-----------------------------------------------
``ServeEngine(prefix_cache=True)`` (or ``REPRO_PREFIX_CACHE=1``) indexes
every admitted prompt's full ``block_size``-token chunks in a hash trie
and lets later admissions SHARE the pool blocks already holding that
prefix's KV:

* a cache-hit admission budgets only its uncached suffix blocks, seeds
  its block table with the shared blocks, and starts its prefill window
  walk at the first uncached token (``serve.prefill_tokens_saved``);
* a hit ending mid-block is consumed by a copy-on-write FORK (one device
  block copy + table repoint) before the row's own writes land, so
  co-holders keep reading the original bits — and a ``_cow_guard`` pass
  enforces fork-before-write on every dispatch, sync and async;
* retired requests' prefix blocks stay PARKED (held only by the index);
  under pool pressure the engine evicts cold parked blocks by reuse
  score (hits x recency, leaf-first) BEFORE preempting any resident row.

Off by default; the uncached path is bit-exact unchanged, and cached
greedy streams are bit-identical to uncached on the gather oracle
(``tests/test_prefix_cache.py``). Attention/paged serving only — SSM
recurrent state has no block-granular prefix identity (follow-up).

Async decode lookahead
----------------------
``ServeEngine(async_decode=True)`` (or ``REPRO_ASYNC_DECODE=1``) pipelines
the decode loop one chunk deep so host scheduling overlaps device compute:

* the decode carry ``(lengths, last, rem)`` is DEVICE-RESIDENT across
  cycles — chunk N+1 consumes chunk N's output carry directly, and
  admission merges / prefill-window completions / retirement / preemption
  mutate it via the same fixed-shape padded scatters the block-table array
  uses (:func:`repro.serve.kvcache.set_carry_rows`);
* each cycle the SERIAL decode stage runs **dispatch -> sync**: chunk N+1
  is dispatched first (queued behind N by JAX async dispatch), then chunk
  N's tokens are synced and all host bookkeeping runs while N+1 computes.

Consequences, handled explicitly: retirement takes effect ONE CHUNK LATE
(the finished row stays masked on device by ``rem == 0``; its surplus
in-flight tokens are discarded host-side by a seat-generation guard), and
a preempted row's blocks re-enter the pool only after the engine has
synced past the device work that could still write them (the
``BlockPool.free_deferred`` / ``release_deferred`` fence). Greedy tokens
are bit-identical to the synchronous engine, which remains the reference
path (default off). ``ServeEngine.overlap_stats`` exposes the per-cycle
dispatch/wait/bookkeeping/host-gap breakdown; see
``benchmarks/decode_overlap_microbench.py``.

Tensor-parallel sharded serving
-------------------------------
``ServeEngine(cfg, params, ctx=make_ctx(small_mesh(data=1, model=N)))``
— or ``REPRO_MESH_MODEL=N`` — shards the serve data plane over the mesh
``model`` axis (see ``docs/sharded_serving.md``): the paged KV pool is
partitioned by KV HEAD (per-device footprint ~1/N), attention/MLP
weights are column-sharded on their output dim, and the compiled decode
chunk runs under ``shard_map`` with only activation-sized tiled
all-gathers — never a psum, so greedy decode stays BIT-IDENTICAL to the
single-device engine (sync and async, chunked prefill, growth/
preemption, prefix caching). Block tables, the decode carry and SSM slot
state stay replicated. An explicit mesh whose axis cannot divide the
model's head/feature counts is refused with typed
``MeshDivisibilityError``; the env knob clamps to the largest usable
divisor instead. ``tests/test_serve_mesh.py`` asserts both the parity
matrix and — via :mod:`repro.distributed.hlo_analysis` — that the
lowered decode HLO contains no all-reduce and no all-gather anywhere
near the pool-shard size (the no-accidental-gather invariant).

Paged read-path selection
-------------------------
The compiled decode chunk reads the KV pool through one of three
implementations, chosen by ``ServeEngine(paged_impl=...)`` (or the
``REPRO_PAGED_IMPL`` environment variable when left unset; see
:func:`repro.kernels.ops.default_paged_impl`):

* ``"pallas"`` — gather-free Pallas kernel
  (:mod:`repro.kernels.paged_attention`): pages are read in place through
  the scalar-prefetched block table, blocks past each row's length are
  skipped. Mosaic lowering on TPU; interpreter (correctness only)
  elsewhere. Default on TPU.
* ``"xla"``    — the same blockwise online-softmax algorithm as a
  traced-bound page loop: per-row cost follows batch occupancy, not pool
  capacity. Default off TPU.
* ``"gather"`` — the original materialize-then-mask path
  (``kvcache.gather_pages``): O(max_blocks) HBM traffic and FLOPs per row
  per layer per token regardless of true length. Kept as the reference
  oracle (``tests/test_paged_attention.py`` checks both gather-free paths
  against it).

Observability
-------------
``ServeEngine(obs=repro.obs.Observability())`` — or ``REPRO_OBS=1`` in the
environment — turns on the serve-layer observability stack
(:mod:`repro.obs`; see ``docs/observability.md`` for a quick-start):

* **Spans** (ring-buffer :class:`repro.obs.Tracer`): each decode SLOT is a
  track carrying its seated request's lifecycle — ``queued`` → ``admitted``
  → ``prefill``/``prefill_window`` → ``decode`` → ``stalled`` — plus
  ``retired``/``preempted`` instants; a preempted request re-enters with a
  fresh queued/admitted chain, so the track replays every re-entry. The
  ``"engine"`` track carries per-cycle phases (``admission``, ``growth``,
  ``cycle`` with its ``dispatch``/``sync``/``bookkeeping`` split), and
  ``lineN`` tracks carry the raw pipeline pipe-body intervals
  (``Pipeline.stage_times`` promoted to a timeline).
* **Metrics** (:class:`repro.obs.MetricsRegistry`): counters
  ``serve.tokens_out`` / ``serve.requests.{admitted,retired,preempted,
  stalled}`` / ``serve.{shed,expired,cancelled,watchdog_fires,
  row_failures}`` / ``pool.grown_blocks`` /
  ``prefix.{hits,misses,evicted}`` / ``serve.prefill_tokens_saved``;
  gauges ``serve.queue_depth`` / ``serve.resident_rows`` /
  ``pool.blocks_{free,used,deferred,shared,parked,reserved}``;
  histograms ``serve.ttft_s`` (plus lazy per-tier
  ``serve.ttft_s.tierN``) / ``serve.queue_wait_s`` /
  ``engine.{cycle,dispatch,chunk_sync,book,gap,chunk}_s``; per-slot
  ``cow_fork`` trace instants mark copy-on-write block forks.
* **Export**: ``obs.export(path)`` writes Chrome trace-event JSON that
  loads directly in Perfetto (https://ui.perfetto.dev) or
  ``chrome://tracing``; ``repro.launch.serve --stats-interval N --trace
  out.json`` prints a one-line stats summary per interval and writes the
  artifact on exit. Requests themselves carry lifecycle timestamps
  (:attr:`ServeRequest.submitted_at` / ``admitted_at`` /
  ``first_token_at`` / ``finished_at`` and the derived ``ttft`` /
  ``queue_wait``).

A ``None`` obs handle (the default) keeps every hot path to a single
attribute check; ``benchmarks/obs_overhead_gate.py`` enforces the
enabled-path budget (2% local, 5% CI).

Durable serving
---------------
Off by default, composable on (``docs/robustness.md`` "Durability &
recovery"): attach a :class:`~repro.serve.journal.Journal` (or pass
``--state-dir`` to ``repro.launch.serve``) and every request transition
lands in a checksummed WAL; ``ServeEngine.recover(state_dir)`` replays
a crashed engine's incomplete requests bit-identically and warm-starts
the prefix cache from the last ``ServeEngine.snapshot`` (corruption is
typed ``SnapshotCorrupt`` → cold start, never wrong tokens);
``ServeEngine.drain(deadline_s=...)`` gates admission and
checkpoint-preempts residents past the deadline (sync SSM/hybrid rows
capture recurrent slot state and resume without re-prefill). The
launcher turns SIGTERM into drain → snapshot → close.
``benchmarks/journal_overhead_gate.py`` enforces the journaled-path
budget; the no-journal path is one ``is None`` check per transition.
"""
from .engine import JOURNAL_FILE, SNAPSHOT_FILE, ServeEngine
from .errors import (DeadlineExceeded, EngineClosed, Overloaded,
                     RequestCancelled, RowFailed, ServeError,
                     SnapshotCorrupt, WatchdogTimeout)
from .faultinject import FaultInjected, FaultInjector
from .journal import Journal, JournalReplay, replay
from .kvcache import BlockPool, init_kv_pool
from .scheduler import Scheduler, ServeRequest
from .snapshot import read_snapshot, write_snapshot

__all__ = ["ServeEngine", "ServeRequest", "Scheduler", "BlockPool",
           "init_kv_pool", "ServeError", "Overloaded", "DeadlineExceeded",
           "RequestCancelled", "RowFailed", "WatchdogTimeout",
           "EngineClosed", "SnapshotCorrupt", "FaultInjector",
           "FaultInjected", "Journal", "JournalReplay", "replay",
           "read_snapshot", "write_snapshot", "JOURNAL_FILE",
           "SNAPSHOT_FILE"]
