"""Continuous-batching serve subsystem.

* :mod:`.engine`    — the resident admit→prefill→decode→complete pipeline
  (``submit()`` / ``result()``; ``generate()`` compatibility shim);
* :mod:`.scheduler` — request queue + length-bucketed admission control;
* :mod:`.kvcache`   — paged KV-cache pool (block allocator + jit-able
  gather/scatter through per-sequence block tables).
"""
from .engine import ServeEngine
from .kvcache import BlockPool, init_kv_pool
from .scheduler import Scheduler, ServeRequest

__all__ = ["ServeEngine", "ServeRequest", "Scheduler", "BlockPool",
           "init_kv_pool"]
