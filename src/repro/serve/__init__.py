"""Continuous-batching serve subsystem.

* :mod:`.engine`    — the resident admit→prefill→decode→complete pipeline
  (``submit()`` / ``result()``; ``generate()`` compatibility shim);
* :mod:`.scheduler` — request queue + length-bucketed admission control;
* :mod:`.kvcache`   — paged KV-cache pool (block allocator + jit-able
  fused K/V scatter through per-sequence block tables; the ``gather_pages``
  reference read path).

Paged read-path selection
-------------------------
The compiled decode chunk reads the KV pool through one of three
implementations, chosen by ``ServeEngine(paged_impl=...)`` (or the
``REPRO_PAGED_IMPL`` environment variable when left unset; see
:func:`repro.kernels.ops.default_paged_impl`):

* ``"pallas"`` — gather-free Pallas kernel
  (:mod:`repro.kernels.paged_attention`): pages are read in place through
  the scalar-prefetched block table, blocks past each row's length are
  skipped. Mosaic lowering on TPU; interpreter (correctness only)
  elsewhere. Default on TPU.
* ``"xla"``    — the same blockwise online-softmax algorithm as a
  traced-bound page loop: per-row cost follows batch occupancy, not pool
  capacity. Default off TPU.
* ``"gather"`` — the original materialize-then-mask path
  (``kvcache.gather_pages``): O(max_blocks) HBM traffic and FLOPs per row
  per layer per token regardless of true length. Kept as the reference
  oracle (``tests/test_paged_attention.py`` checks both gather-free paths
  against it).
"""
from .engine import ServeEngine
from .kvcache import BlockPool, init_kv_pool
from .scheduler import Scheduler, ServeRequest

__all__ = ["ServeEngine", "ServeRequest", "Scheduler", "BlockPool",
           "init_kv_pool"]
