"""Request journal: an append-only WAL for the serve engine.

Durability boundary #1 (see ``docs/robustness.md`` "Durability &
recovery"): every request lifecycle transition the engine performs —
``submit`` / ``admit`` / ``first_token`` / ``finish`` / ``cancel`` — is
appended to a checksummed, line-delimited journal file, with the full
prompt token ids recorded at submit. Greedy decode is deterministic, so
the journal alone is enough to recover from a hard crash: on restart,
:func:`replay` classifies every journaled request as *finished* (a
terminal ``finish``/``cancel`` record exists — the client already got
its result, nothing to do) or *incomplete* (no terminal record — the
process died while it was queued or mid-decode), and the engine
re-submits the incomplete ones, which replay **bit-identically** on the
gather oracle.

Record format — one record per line::

    <crc32-hex8> <compact-json>\\n

The CRC covers the JSON bytes. A hard kill can tear the final line
(partial write); replay detects this via the checksum and truncates at
the FIRST bad record — everything before it is trusted, everything at
and after it is dropped and reported (``JournalReplay.dropped``). This
is standard WAL tail-truncation: a dropped ``finish`` record merely
causes a benign bit-identical re-run of an already-answered request,
never a wrong answer.

Write path discipline: records are buffer-written and flushed on every
append; ``fsync`` runs on a configurable cadence (``fsync_every=N``
records; ``1`` = every record = maximal durability, ``0`` = only on
:meth:`Journal.flush`/:meth:`Journal.close`). The ``journal.lag_s``
gauge exposes how long un-fsynced records have been at risk.

Off by default: an engine without a journal attached takes a single
``is None`` check per transition — the no-journal path is bit-exact
unchanged.
"""
from __future__ import annotations

import json
import os
import threading
import time
import zlib
from typing import Any, Dict, List, Optional

__all__ = ["Journal", "JournalReplay", "replay",
           "TERMINAL_KINDS", "RECORD_KINDS"]

#: Lifecycle transitions the engine journals.
RECORD_KINDS = ("submit", "admit", "first_token", "finish", "cancel")

#: Kinds that mark a request as settled (never replayed).
TERMINAL_KINDS = ("finish", "cancel")


def _encode(rec: Dict[str, Any]) -> bytes:
    payload = json.dumps(rec, separators=(",", ":"),
                         sort_keys=True).encode("utf-8")
    crc = zlib.crc32(payload) & 0xFFFFFFFF
    return b"%08x %s\n" % (crc, payload)


def _decode(line: bytes) -> Optional[Dict[str, Any]]:
    """Parse one journal line; None when torn/corrupt."""
    line = line.rstrip(b"\n")
    if len(line) < 10 or line[8:9] != b" ":
        return None
    try:
        crc = int(line[:8], 16)
    except ValueError:
        return None
    payload = line[9:]
    if (zlib.crc32(payload) & 0xFFFFFFFF) != crc:
        return None
    try:
        rec = json.loads(payload)
    except ValueError:
        return None
    return rec if isinstance(rec, dict) and "k" in rec else None


class Journal:
    """Append-only, checksummed request WAL (see module docstring).

    Thread-safe: ``submit`` runs on client threads while ``finish`` runs
    on the engine's complete stage. One lock per append — journal
    records are per *request transition*, not per token, so this is far
    off the decode hot path (the ``journal_gate`` benchmark enforces
    the overhead budget).
    """

    def __init__(self, path: str, *, fsync_every: int = 1) -> None:
        if fsync_every < 0:
            raise ValueError("fsync_every must be >= 0")
        self.path = str(path)
        self.fsync_every = fsync_every
        self._lock = threading.Lock()
        self._f = open(self.path, "ab")
        self._since_sync = 0
        self._dirty_at: Optional[float] = None
        self._lag_gauge = None
        self._rec_counter = None
        self.records_written = 0

    def set_metrics(self, metrics: Any) -> None:
        """Bind ``journal.lag_s`` / ``journal.records`` to a registry."""
        if metrics is None:
            self._lag_gauge = self._rec_counter = None
            return
        self._lag_gauge = metrics.gauge("journal.lag_s")
        self._rec_counter = metrics.counter("journal.records")

    @property
    def lag_s(self) -> float:
        """Seconds the oldest un-fsynced record has been at risk."""
        with self._lock:
            return 0.0 if self._dirty_at is None \
                else time.monotonic() - self._dirty_at

    def append(self, kind: str, **fields: Any) -> None:
        rec = {"k": kind, "t": round(time.time(), 6)}
        rec.update(fields)
        data = _encode(rec)
        with self._lock:
            if self._f.closed:
                return
            self._f.write(data)
            self._f.flush()
            self.records_written += 1
            self._since_sync += 1
            if self._dirty_at is None:
                self._dirty_at = time.monotonic()
            if self.fsync_every and self._since_sync >= self.fsync_every:
                self._fsync_locked()
        if self._rec_counter is not None:
            self._rec_counter.inc()
        if self._lag_gauge is not None:
            self._lag_gauge.set(self.lag_s)

    def _fsync_locked(self) -> None:
        os.fsync(self._f.fileno())
        self._since_sync = 0
        self._dirty_at = None

    # -- engine-facing transition helpers ---------------------------------
    def submit(self, req: Any) -> None:
        self.append("submit", id=req.id,
                    prompt=[int(t) for t in req.prompt],
                    max_new=int(req.max_new), priority=int(req.priority),
                    deadline_s=req.deadline_s)

    def admit(self, req: Any) -> None:
        self.append("admit", id=req.id)

    def first_token(self, req: Any) -> None:
        self.append("first_token", id=req.id)

    def finish(self, req: Any, tokens: Any) -> None:
        toks = [int(t) for t in tokens]
        crc = zlib.crc32(json.dumps(toks).encode()) & 0xFFFFFFFF
        self.append("finish", id=req.id, n=len(toks), crc=crc)

    def cancel(self, req: Any, kind: str) -> None:
        """Terminal non-finish record (cancelled / expired / shed)."""
        self.append("cancel", id=req.id, why=kind)

    # ---------------------------------------------------------------------
    def flush(self) -> None:
        """Flush and fsync everything appended so far."""
        with self._lock:
            if self._f.closed:
                return
            self._f.flush()
            self._fsync_locked()
        if self._lag_gauge is not None:
            self._lag_gauge.set(0.0)

    def close(self) -> None:
        with self._lock:
            if self._f.closed:
                return
            self._f.flush()
            self._fsync_locked()
            self._f.close()

    def __enter__(self) -> "Journal":
        return self

    def __exit__(self, *exc: Any) -> None:
        self.close()


class JournalReplay:
    """Classification of a journal file (see :func:`replay`).

    ``incomplete`` preserves journal order, so re-submission reproduces
    the original arrival order (admission order under load may still
    differ — bit-identity is per-request, guaranteed by greedy decode).
    """

    def __init__(self) -> None:
        self.records: List[Dict[str, Any]] = []
        self.submits: Dict[int, Dict[str, Any]] = {}
        self.terminal: Dict[int, str] = {}
        self.finished: Dict[int, Dict[str, Any]] = {}
        self.dropped = 0          # corrupt/torn lines truncated at tail

    @property
    def incomplete(self) -> List[Dict[str, Any]]:
        return [rec for rid, rec in self.submits.items()
                if rid not in self.terminal]

    @property
    def replayed_tokens(self) -> int:
        return sum(len(r["prompt"]) for r in self.incomplete)


def replay(path: str) -> JournalReplay:
    """Read a journal, truncating at the first torn/corrupt record."""
    rep = JournalReplay()
    if not os.path.exists(path):
        return rep
    with open(path, "rb") as f:
        lines = f.readlines()
    for i, line in enumerate(lines):
        rec = _decode(line)
        if rec is None:
            rep.dropped = len(lines) - i
            break
        rep.records.append(rec)
        kind = rec["k"]
        rid = rec.get("id")
        if kind == "submit" and rid is not None:
            rep.submits[rid] = rec
        elif kind in TERMINAL_KINDS and rid is not None:
            rep.terminal[rid] = kind
            if kind == "finish":
                rep.finished[rid] = rec
    return rep
