from .base import SHAPES, SHAPES_BY_NAME, ModelConfig, ShapeSpec, shape_applicable
from .registry import ARCHS, get_config

__all__ = ["SHAPES", "SHAPES_BY_NAME", "ModelConfig", "ShapeSpec",
           "shape_applicable", "ARCHS", "get_config"]
