"""qwen2-moe-a2.7b [moe] — 60 routed experts top-4 + 4 shared experts
(shared hidden 4x1408=5632, sigmoid-gated). [hf:Qwen/Qwen1.5-MoE-A2.7B; hf]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b", family="moe",
    num_layers=24, d_model=2048, num_heads=16, num_kv_heads=16,
    d_ff=1408, vocab_size=151936, head_dim=128,
    qkv_bias=True, rope_theta=1_000_000.0,
    moe=True, num_experts=60, num_experts_per_tok=4,
    moe_d_ff=1408, shared_expert_d_ff=5632,
    norm_topk_prob=False,
)
