"""internvl2-1b [vlm] — InternViT frontend STUB (precomputed patch
embeddings) + Qwen2-0.5B-like LM backbone (tied embeddings, QKV bias).
[arXiv:2404.16821; hf]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-1b", family="vlm",
    num_layers=24, d_model=896, num_heads=14, num_kv_heads=2,
    d_ff=4864, vocab_size=151655, head_dim=64,
    qkv_bias=True, tie_embeddings=True, rope_theta=1_000_000.0,
    frontend="vision_patches", frontend_tokens=256,
)
