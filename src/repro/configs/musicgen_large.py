"""musicgen-large [audio] — decoder-only over EnCodec tokens; text/melody
conditioning frontend is a STUB supplying precomputed frame embeddings
(assignment: backbone only). Plain-GELU MLP, sinusoidal positions.
[arXiv:2306.05284; hf]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large", family="audio",
    num_layers=48, d_model=2048, num_heads=32, num_kv_heads=32,
    d_ff=8192, vocab_size=2048, head_dim=64,
    mlp_gated=False, pos_emb="sinusoidal",
    frontend="audio_frames", frontend_tokens=64,
)
