"""Model / run configuration schema.

One :class:`ModelConfig` per assigned architecture (see ``repro/configs/``),
plus the assigned input-shape set (`SHAPES`). Values are the exact published
configs given in the assignment; reduced smoke variants for CPU tests come
from :meth:`ModelConfig.smoke`.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int                 # 0 => attention-free
    num_kv_heads: int
    d_ff: int
    vocab_size: int

    head_dim: int = 0              # 0 => d_model // num_heads
    # attention flags
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    pos_emb: str = "rope"          # rope | sinusoidal
    # mlp
    mlp_gated: bool = True         # SwiGLU if True, GELU otherwise
    tie_embeddings: bool = False
    rms_eps: float = 1e-5
    # MoE
    moe: bool = False
    num_experts: int = 0
    num_experts_per_tok: int = 0
    moe_d_ff: int = 0              # routed expert hidden size
    shared_expert_d_ff: int = 0    # qwen2-moe shared experts (total hidden)
    dense_residual: bool = False   # arctic: dense FFN in parallel with MoE
    capacity_factor: float = 1.25
    router_aux_weight: float = 1e-3
    norm_topk_prob: bool = True
    # SSM (mamba)
    ssm: bool = False
    ssm_version: int = 1           # 1 = Mamba, 2 = Mamba2 (SSD)
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64         # mamba2
    dt_rank: int = 0               # 0 => d_model // 16  (mamba1)
    # hybrid (zamba2): shared transformer block applied every k SSM layers
    hybrid_attn_every: int = 0
    # modality frontend (STUB per assignment: precomputed embeddings)
    frontend: str = "none"         # none | audio_frames | vision_patches
    frontend_tokens: int = 0
    # numerics
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    # runtime knobs
    attn_chunk_q: int = 256        # chunked-causal attention query block
    ssm_chunk: int = 128           # selective-scan chunk length
    remat: bool = True
    scan_layers: bool = True
    max_seq_len: int = 131072
    # ---- beyond-paper perf knobs (EXPERIMENTS.md §Perf; default = the
    #      paper-faithful baseline behaviour) ----
    attn_bwd_remat: bool = False   # recompute scores in attention backward
    hoist_weight_gather: bool = False  # FSDP gather once per step, not
    #                                    once per microbatch
    moe_expert_pad: int = 0        # inert router-masked experts appended so
    #                                E divides the expert-parallel axis
    ssm_scan_constrain: bool = False   # keep dI/heads sharded inside the
    #                                    selective-scan chunk bodies

    # ---------------------------------------------------------------- derived
    @property
    def hd(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.num_heads, 1)

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a multiple of 256 so it shards 16-ways evenly."""
        return _round_up(self.vocab_size, 256)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def dt_rank_(self) -> int:
        return self.dt_rank or max(1, self.d_model // 16)

    @property
    def attention_free(self) -> bool:
        return self.num_heads == 0

    @property
    def subquadratic(self) -> bool:
        """True if the arch can serve 500k-token contexts (SSM/hybrid)."""
        return self.family in ("ssm", "hybrid")

    # ------------------------------------------------------------- param count
    def param_count(self) -> int:
        D, F, V = self.d_model, self.d_ff, self.padded_vocab
        n = V * D  # embedding
        if not self.tie_embeddings:
            n += D * V
        per_layer = 0
        if not self.attention_free and self.hybrid_attn_every == 0:
            H, KV, hd = self.num_heads, self.num_kv_heads, self.hd
            per_layer += D * H * hd + 2 * D * KV * hd + H * hd * D
            if self.qkv_bias:
                per_layer += (H + 2 * KV) * hd
        if self.ssm:
            dI, N = self.d_inner, self.ssm_state
            if self.ssm_version == 1:
                per_layer += (D * 2 * dI + dI * self.ssm_conv
                              + dI * (self.dt_rank_ + 2 * N)
                              + self.dt_rank_ * dI + dI * N + 2 * dI
                              + dI * D)
            else:
                nh = self.ssm_heads
                per_layer += (D * (2 * dI + 2 * N + nh)
                              + (dI + 2 * N) * self.ssm_conv
                              + 3 * nh + dI + dI * D)
        if self.moe:
            per_layer += D * self.num_experts                      # router
            per_layer += self.num_experts * 3 * D * self.moe_d_ff  # experts
            if self.shared_expert_d_ff:
                per_layer += 3 * D * self.shared_expert_d_ff + D
            if self.dense_residual:
                per_layer += 3 * D * F
        elif F and not self.ssm:
            per_layer += 3 * D * F if self.mlp_gated else 2 * D * F
        per_layer += 2 * D  # norms
        n += self.num_layers * per_layer
        if self.hybrid_attn_every:
            H, KV, hd = self.num_heads, self.num_kv_heads, self.hd
            # one SHARED transformer block (2D concat in-proj + attn + mlp)
            n += (2 * D) * D + D * H * hd + 2 * D * KV * hd + H * hd * D \
                + 3 * D * self.d_ff + 2 * D
        return n

    def active_param_count(self) -> int:
        """Activated params per token (MoE: top-k experts only)."""
        if not self.moe:
            return self.param_count()
        dead = (self.num_experts - self.num_experts_per_tok) \
            * 3 * self.d_model * self.moe_d_ff * self.num_layers
        return self.param_count() - dead

    def smoke(self) -> "ModelConfig":
        """Reduced same-family config for CPU smoke tests."""
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            num_layers=2 if self.hybrid_attn_every == 0 else 3,
            d_model=64,
            num_heads=0 if self.attention_free else 4,
            num_kv_heads=0 if self.attention_free else max(
                1, min(self.num_kv_heads, 2)),
            head_dim=16 if not self.attention_free else 0,
            d_ff=96 if self.d_ff else 0,
            vocab_size=503,           # deliberately odd: exercises padding
            num_experts=8 if self.moe else 0,
            num_experts_per_tok=min(self.num_experts_per_tok, 2),
            moe_d_ff=32 if self.moe else 0,
            shared_expert_d_ff=48 if self.shared_expert_d_ff else 0,
            ssm_state=16 if self.ssm else 0,
            ssm_head_dim=16 if self.ssm else 64,
            dt_rank=8 if self.ssm and self.ssm_version == 1 else 0,
            hybrid_attn_every=2 if self.hybrid_attn_every else 0,
            frontend_tokens=8 if self.frontend != "none" else 0,
            attn_chunk_q=16,
            ssm_chunk=8,
            max_seq_len=256,
        )


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # train | prefill | decode


#: Assigned input shapes (LM family): seq_len x global_batch.
SHAPES: Tuple[ShapeSpec, ...] = (
    ShapeSpec("train_4k", 4_096, 256, "train"),
    ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    ShapeSpec("decode_32k", 32_768, 128, "decode"),
    ShapeSpec("long_500k", 524_288, 1, "decode"),
)

SHAPES_BY_NAME = {s.name: s for s in SHAPES}


def shape_applicable(cfg: ModelConfig, shape: ShapeSpec) -> Tuple[bool, str]:
    """long_500k needs sub-quadratic attention: run for ssm/hybrid only."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, ("skipped: pure full-attention arch (quadratic); "
                       "long_500k runs only for ssm/hybrid (DESIGN.md §4)")
    return True, ""
