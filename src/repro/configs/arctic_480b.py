"""arctic-480b [moe] — 128 experts top-2 IN PARALLEL with a dense residual
FFN path (dense-MoE hybrid). [hf:Snowflake/snowflake-arctic-base; hf]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b", family="moe",
    num_layers=35, d_model=7168, num_heads=56, num_kv_heads=8,
    d_ff=4864, vocab_size=32000, head_dim=128,
    rope_theta=1_000_000.0,
    moe=True, num_experts=128, num_experts_per_tok=2,
    moe_d_ff=4864, dense_residual=True,
    # ~480B params: bf16 params/moments; fp32 master needs the 2-pod mesh
    param_dtype="bfloat16",
)
