"""zamba2-1.2b [hybrid] — Mamba2 backbone + ONE shared transformer block
(weights reused) applied every 6 SSM layers with [hidden, embedding]
concat input projection. [arXiv:2411.15242; hf]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b", family="hybrid",
    num_layers=38, d_model=2048, num_heads=32, num_kv_heads=32,
    d_ff=8192, vocab_size=32000, head_dim=64,
    ssm=True, ssm_version=2, ssm_state=64, ssm_conv=4, ssm_expand=2,
    ssm_head_dim=64,
    hybrid_attn_every=6,
)
