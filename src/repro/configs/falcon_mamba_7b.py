"""falcon-mamba-7b [ssm] — pure Mamba1, attention-free, ssm_state=16.
[arXiv:2410.05355; unverified]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b", family="ssm",
    num_layers=64, d_model=4096, num_heads=0, num_kv_heads=0,
    d_ff=0, vocab_size=65024,
    ssm=True, ssm_version=1, ssm_state=16, ssm_conv=4, ssm_expand=2,
    dt_rank=256,
)
