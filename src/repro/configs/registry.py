"""Architecture registry: --arch <id> resolution for every launcher."""
from __future__ import annotations

from typing import Dict

from .base import ModelConfig
from .qwen2_5_32b import CONFIG as _qwen25_32b
from .stablelm_1_6b import CONFIG as _stablelm
from .qwen3_14b import CONFIG as _qwen3
from .mistral_nemo_12b import CONFIG as _nemo
from .qwen2_moe_a2_7b import CONFIG as _qwen2moe
from .arctic_480b import CONFIG as _arctic
from .musicgen_large import CONFIG as _musicgen
from .falcon_mamba_7b import CONFIG as _falcon_mamba
from .zamba2_1_2b import CONFIG as _zamba2
from .internvl2_1b import CONFIG as _internvl2

ARCHS: Dict[str, ModelConfig] = {c.name: c for c in (
    _qwen25_32b, _stablelm, _qwen3, _nemo, _qwen2moe,
    _arctic, _musicgen, _falcon_mamba, _zamba2, _internvl2,
)}


def get_config(name: str) -> ModelConfig:
    if name.endswith("-smoke"):
        return get_config(name[:-len("-smoke")]).smoke()
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]
