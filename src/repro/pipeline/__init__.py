"""repro.pipeline — task-parallel pipeline scheduling (Pipeflow style).

Built entirely on the condition-task machinery of :mod:`repro.core`: a
pipeline is a static cyclic graph of multi-condition tasks executed by the
work-stealing executor — zero dedicated threads. See
:mod:`repro.pipeline.pipeline` for the construct-by-construct mapping to the
Pipeflow paper (arXiv:2202.00717).
"""
from .data import DataPipe, DataPipeline
from .pipeline import Pipe, Pipeflow, Pipeline, PipeType

__all__ = ["DataPipe", "DataPipeline",
           "Pipe", "Pipeflow", "Pipeline", "PipeType"]
