"""Data-passing pipeline (Pipeflow's ``tf::DataPipeline``).

:class:`DataPipeline` owns one buffer per line and threads it through the
stages: the first pipe *produces* a value (``fn(pf) -> value``), every later
pipe *transforms* it (``fn(pf, value) -> value``). Because at most one slot
of a line is active at any time (the line's slots form a chain in the cyclic
grid), the per-line buffer needs **no lock** — the scheduling dependencies
are the synchronisation, exactly the Pipeflow argument for why task-parallel
pipelines need no queues between stages.
"""
from __future__ import annotations

from typing import Any, List

from .pipeline import Pipe, Pipeflow, Pipeline

__all__ = ["DataPipe", "DataPipeline"]


class DataPipe(Pipe):
    """A stage of a :class:`DataPipeline`.

    First stage: ``fn(pf) -> value`` (or ``pf.stop()``; the value is then
    discarded). Later stages: ``fn(pf, value) -> value``.
    """


class DataPipeline(Pipeline):
    def __init__(self, num_lines: int, *pipes: Pipe, name: str = "data-pipeline"):
        super().__init__(num_lines, *pipes, name=name)
        self._buffers: List[Any] = [None] * num_lines

    def buffer(self, line: int) -> Any:
        """The line's current value (after a run: the last stage's output)."""
        return self._buffers[line]

    def _invoke(self, pipe: Pipe, pf: Pipeflow) -> None:
        if pf.pipe == 0:
            out = pipe.fn(pf)
            if not pf._stopped and pf._defer_on is None:
                self._buffers[pf.line] = out
        else:
            self._buffers[pf.line] = pipe.fn(pf, self._buffers[pf.line])
