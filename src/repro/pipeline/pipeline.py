"""Task-parallel pipeline scheduling framework (Pipeflow, arXiv:2202.00717).

The pipeline is the single most valuable client of the paper's in-graph
control flow (§3.4: condition tasks, weak edges, cycles): ``L`` parallel
*lines* times ``S`` *pipes* (stages) are laid out **once** as a static cyclic
grid of multi-condition tasks over the existing work-stealing
:class:`~repro.core.executor.Executor` — no dedicated pipeline threads, no
data copies, no graph rebuilding between tokens.

Mapping to the Pipeflow paper:

==========================  ===================================================
Pipeflow construct          Here
==========================  ===================================================
``tf::Pipeline(L, ...)``    :class:`Pipeline` — ``Pipeline(num_lines, *pipes)``
``tf::Pipe{SERIAL, fn}``    :class:`Pipe` / :class:`PipeType` (``SERIAL`` |
                            ``PARALLEL``); the first pipe must be SERIAL
``tf::Pipeflow``            :class:`Pipeflow` — the per-line worker view
                            (``pf.line``, ``pf.pipe``, ``pf.token``)
``pf.stop()``               :meth:`Pipeflow.stop` — in-stage termination: only
                            legal at the first pipe; in-flight tokens drain
scheduling tokens           per-(line, pipe) :class:`AtomicInt` join counters;
                            a token *t* runs on line ``t % L``
deferred lines              a line whose next SERIAL pipe is still occupied
                            parks (its task simply is not scheduled) instead
                            of blocking a worker; counted in
                            :attr:`Pipeline.num_deferrals`
``pf.defer(t)``             :meth:`Pipeflow.defer` — token-level deferral
                            (§deferred pipelines): the current token parks
                            at the first pipe until token ``t`` completes
                            the last pipe; in-flight tokens drain meanwhile
                            and no worker blocks. Admission pauses while
                            parked (mint order stays line-round-robin —
                            full Pipeflow token reordering needs dynamic
                            token->line binding, out of scope for the
                            static grid). Resume accounting in
                            :attr:`Pipeline.num_token_deferrals` /
                            :attr:`Pipeline.num_resumes`
``tf::DataPipeline``        :class:`repro.pipeline.data.DataPipeline` —
                            per-line buffers threaded between stages, no locks
==========================  ===================================================

Graph layout (the static cyclic TDG, built once per ``Pipeline``):

* one **multi-condition task per (line, pipe) slot**; slot ``(l, s)`` has two
  weak out-edges: index 0 → ``(l, (s+1) % S)`` (the line moves forward, the
  last pipe wraps to re-admit the line) and index 1 → ``((l+1) % L, s)`` (a
  SERIAL pipe hands the stage to the next token's line);
* one **condition task** (the source) whose integer return selects which
  line's first pipe admits the next token — this is the paper's weak-edge
  bypass: condition successors are scheduled directly, join counters are
  only decremented by the grid itself.

Every edge is weak, so the whole pipeline is a *cycle* in the TDG — exactly
the pattern Figure 6/§3.4 of the Taskflow paper legalises — and a pipeline
run completes (the topology's pending count reaches zero) precisely when a
stop signal has drained every in-flight token.
"""
from __future__ import annotations

import enum
import threading
import time
from typing import Callable, Dict, List, Optional

from ..core.atomic import AtomicInt
from ..core.executor import Executor, Topology
from ..core.graph import HOST, Task, Taskflow

__all__ = ["PipeType", "Pipe", "Pipeflow", "Pipeline"]


class PipeType(enum.Enum):
    SERIAL = "serial"      # at most one line in the stage; strict token order
    PARALLEL = "parallel"  # any number of lines in the stage concurrently


class Pipe:
    """One pipeline stage: ``fn(pf: Pipeflow)`` run on ``domain`` workers."""

    __slots__ = ("kind", "fn", "name", "domain")

    def __init__(self, kind: PipeType, fn: Callable, name: str = "",
                 domain: str = HOST) -> None:
        self.kind = kind
        self.fn = fn
        self.name = name or getattr(fn, "__name__", kind.value)
        self.domain = domain


class Pipeflow:
    """Per-line view handed to every pipe callable (paper's ``tf::Pipeflow``)."""

    __slots__ = ("_line", "_pipe", "_token", "_stopped", "_defer_on",
                 "num_deferrals")

    def __init__(self, line: int) -> None:
        self._line = line
        self._pipe = 0
        self._token = 0
        self._stopped = False
        self._defer_on: Optional[int] = None
        self.num_deferrals = 0

    @property
    def line(self) -> int:
        return self._line

    @property
    def pipe(self) -> int:
        return self._pipe

    @property
    def token(self) -> int:
        return self._token

    def stop(self) -> None:
        """Stop admitting tokens. Only legal at the first pipe; the serial
        stage-0 hand-off chain is broken, so no later line re-enters pipe 0
        and all in-flight tokens drain to completion."""
        if self._pipe != 0:
            raise RuntimeError(
                "Pipeflow.stop() can only be called from the first pipe "
                f"(called from pipe {self._pipe})")
        self._stopped = True

    def defer(self, token: int) -> None:
        """Token-level deferral (Pipeflow §deferred pipelines): park THIS
        token until ``token`` has fully completed the last pipe, then re-run
        the first pipe body with the same token number.

        Only legal at the first pipe — the admission point. While parked,
        admission PAUSES (the parked token holds the SERIAL first pipe; the
        static grid's round-robin hand-off protocol ties mint order to
        lines, so later tokens do not overtake) but every in-flight token
        keeps draining its remaining stages, and no worker blocks — the
        park is pure scheduling state, which is what makes this the
        spin-free back-pressure primitive for admission control. Deferring
        on an already-completed token re-runs the stage body immediately.

        ``token`` must already have been minted (``token < num_tokens``;
        the current token mints only when its first pipe succeeds);
        deferring on a future token could wedge the drain protocol, so it
        raises.
        """
        if self._pipe != 0:
            raise RuntimeError(
                "Pipeflow.defer() can only be called from the first pipe "
                f"(called from pipe {self._pipe})")
        if token == self._token:
            raise ValueError(f"token {token} cannot defer on itself")
        self._defer_on = token


class Pipeline:
    """``L`` lines × ``S`` pipes scheduled purely by executor condition tasks.

    Parameters
    ----------
    num_lines:
        maximum number of tokens in flight (the paper's *parallel lines*).
    pipes:
        :class:`Pipe` objects in stage order; the first must be SERIAL.

    Use :meth:`run` (or ``executor.run(pipeline.taskflow)`` after
    :meth:`reset`) to execute. Token numbering is monotone across runs, so a
    drained pipeline can be re-armed with :meth:`reset` + :meth:`run` to
    continue the stream — the restart pattern the bounded
    :class:`repro.data.pipeline.Prefetcher` uses for back-pressure.
    """

    def __init__(self, num_lines: int, *pipes: Pipe, name: str = "pipeline"):
        if num_lines < 1:
            raise ValueError("pipeline needs at least one line")
        if not pipes:
            raise ValueError("pipeline needs at least one pipe")
        if pipes[0].kind is not PipeType.SERIAL:
            raise ValueError("the first pipe must be SERIAL "
                             "(it mints scheduling tokens, Pipeflow §3)")
        self._pipes: List[Pipe] = list(pipes)
        self._num_lines = num_lines
        self._pipeflows = [Pipeflow(l) for l in range(num_lines)]
        self._counters = [[AtomicInt(0) for _ in pipes]
                          for _ in range(num_lines)]
        # per-(line, pipe) cumulative wall time inside the stage body; a
        # slot runs exclusively (its join counter serialises visits), so
        # plain int accumulation is race-free
        self._stage_ns = [[0] * len(pipes) for _ in range(num_lines)]
        # optional repro.obs.Tracer: when set, every pipe-body interval is
        # also recorded as a span on a per-line track ("line0", "line1",
        # ...) — the stage_times aggregate, promoted to a timeline. Plain
        # attribute so callers can attach/detach between runs.
        self.tracer = None
        self._num_tokens = 0
        self._num_deferrals = AtomicInt(0)
        self._stopped = False
        self._start_line = 0
        self._topology: Optional[Topology] = None
        self._executor: Optional[Executor] = None
        # token-level deferral state (Pipeflow §deferred pipelines)
        self._defer_lock = threading.Lock()
        self._parked = [False] * num_lines
        self._deferred_waiters: Dict[int, List[int]] = {}  # dep -> lines
        self._completed_watermark = -1     # tokens <= this have completed
        self._completed_set: set = set()   # out-of-order completions
        self._num_token_deferrals = AtomicInt(0)
        self._num_resumes = AtomicInt(0)
        self._taskflow = Taskflow(name)
        self._build()
        self.reset()

    # ------------------------------------------------------------- properties
    @property
    def num_lines(self) -> int:
        return self._num_lines

    @property
    def num_pipes(self) -> int:
        return len(self._pipes)

    @property
    def num_tokens(self) -> int:
        """Tokens fully admitted so far (monotone across runs)."""
        return self._num_tokens

    @property
    def num_deferrals(self) -> int:
        """Times a line finished a pipe but parked because its next slot was
        still held (full SERIAL stage / wrap not yet released)."""
        return self._num_deferrals.value()

    @property
    def num_token_deferrals(self) -> int:
        """Times a first-pipe body called :meth:`Pipeflow.defer` (including
        deferrals satisfied immediately because the dependency had already
        completed)."""
        return self._num_token_deferrals.value()

    @property
    def num_resumes(self) -> int:
        """Times a deferred token re-ran its first pipe after its dependency
        completed. Once the pipeline has drained this equals
        :attr:`num_token_deferrals` — every deferral resumes exactly once
        (immediately, when the dependency had already completed)."""
        return self._num_resumes.value()

    @property
    def stage_times(self) -> Dict[str, float]:
        """Cumulative wall-clock seconds spent INSIDE each pipe's body,
        summed over lines and runs (keyed by pipe name). Pure
        observability: where a long-running pipeline actually spends its
        time — e.g. the serve engine's admit/prefill/decode/complete
        breakdown the decode-overlap microbench reports. Safe to read
        concurrently (monotone per-slot counters; a mid-stage read is at
        worst one stage-visit stale)."""
        out: Dict[str, float] = {}
        for s, pipe in enumerate(self._pipes):
            ns = sum(self._stage_ns[l][s] for l in range(self._num_lines))
            out[pipe.name] = out.get(pipe.name, 0.0) + ns / 1e9
        return out

    @property
    def taskflow(self) -> Taskflow:
        return self._taskflow

    # ------------------------------------------------------------------ build
    def _build(self) -> None:
        tf = self._taskflow
        L, S = self._num_lines, len(self._pipes)
        grid: List[List[Task]] = [
            [tf.multi_condition(self._make_slot(l, s), name=f"pipe-L{l}S{s}",
                                domain=self._pipes[s].domain)
             for s in range(S)]
            for l in range(L)]
        for l in range(L):
            for s in range(S):
                # successor 0: same line, next pipe (last pipe wraps to re-
                # admit the line); successor 1: next line, same pipe (SERIAL
                # hand-off). Both edges are weak — the grid is one big cycle.
                grid[l][s].precede(grid[l][(s + 1) % S], grid[(l + 1) % L][s])
        start = tf.condition(lambda: self._start_line, name="pipeline-start")
        start.precede(*[grid[l][0] for l in range(L)])
        self._grid = grid

    def _make_slot(self, l: int, s: int) -> Callable[[], tuple]:
        L, S = self._num_lines, len(self._pipes)
        pipe = self._pipes[s]
        serial = pipe.kind is PipeType.SERIAL
        counters = self._counters

        def run_slot() -> tuple:
            pf = self._pipeflows[l]
            pf._pipe = s
            if s == 0:
                # stage 0 is SERIAL: exactly one line here at a time (a
                # parked line HOLDS the stage — admission pauses), so the
                # token counter, stop flag and parked flag need no
                # synchronisation.
                if self._stopped:
                    self._parked[l] = False  # defensive: dropped by a drain
                    return ()
                if self._parked[l]:
                    self._parked[l] = False
                    self._num_resumes.inc()
                pf._token = self._num_tokens
                pf._stopped = False
                pf._defer_on = None
                while True:
                    _t = time.perf_counter_ns()
                    self._invoke(pipe, pf)
                    _t2 = time.perf_counter_ns()
                    self._stage_ns[l][s] += _t2 - _t
                    if self.tracer is not None:
                        self.tracer.add(pipe.name, f"line{l}",
                                        _t / 1e9, _t2 / 1e9)
                    if pf._stopped:
                        self._stopped = True
                        return ()  # break both chains: in-flight drain
                    dep = pf._defer_on
                    if dep is None:
                        break
                    pf._defer_on = None
                    if dep >= self._num_tokens:
                        raise ValueError(
                            f"token {pf._token} deferred on un-minted "
                            f"token {dep}")
                    self._num_token_deferrals.inc()
                    if not self._register_deferral(l, dep):
                        # dependency already completed: satisfied
                        # immediately — re-run the stage body now
                        self._num_resumes.inc()
                        continue
                    # Park: release NOTHING. The token is not minted, the
                    # SERIAL hand-off chain pauses at this line (no token
                    # overtakes — the static grid's round-robin hand-off
                    # protocol requires mint order to follow lines), and
                    # in-flight tokens keep draining their stages. The
                    # dependency's last pipe re-schedules this slot.
                    self._parked[l] = True
                    return ()
                self._num_tokens += 1
            else:
                _t = time.perf_counter_ns()
                self._invoke(pipe, pf)
                _t2 = time.perf_counter_ns()
                self._stage_ns[l][s] += _t2 - _t
                if self.tracer is not None:
                    self.tracer.add(pipe.name, f"line{l}",
                                    _t / 1e9, _t2 / 1e9)
            if s == S - 1:
                # token fully done: wake a deferred token waiting on it.
                # Done BEFORE this task's pending-tally so the topology
                # cannot finalize between the wake and the resume running.
                self._complete_token(pf._token)
            # Re-arm this slot for its next visit BEFORE releasing successors
            # (the successor may wrap around and decrement us again). Steady
            # state: pipe 0 waits on {SERIAL hand-off, line wrap} = 2; other
            # SERIAL pipes on {previous token, line arrival} = 2; PARALLEL
            # pipes only on the line's arrival = 1.
            counters[l][s].set(2 if (s == 0 or serial) else 1)
            rets = []
            if serial and counters[(l + 1) % L][s].dec() == 0:
                rets.append(1)
            if counters[l][(s + 1) % S].dec() == 0:
                rets.append(0)
            else:
                # deferred line: the next slot is still held (full SERIAL
                # stage or un-wrapped line) — park without blocking a worker.
                pf.num_deferrals += 1
                self._num_deferrals.inc()
            return tuple(rets)

        run_slot.__name__ = f"pipe_{pipe.name}_L{l}S{s}"
        return run_slot

    def _invoke(self, pipe: Pipe, pf: Pipeflow) -> None:
        """Stage dispatch; DataPipeline overrides to thread per-line buffers."""
        pipe.fn(pf)

    # ------------------------------------------------- token-level deferral
    def _is_completed(self, token: int) -> bool:
        return token <= self._completed_watermark or \
            token in self._completed_set

    def _register_deferral(self, line: int, dep: int) -> bool:
        """Park ``line`` until ``dep`` completes. False if ``dep`` already
        completed (the deferral is satisfied immediately)."""
        with self._defer_lock:
            if self._is_completed(dep):
                return False
            if self._executor is None:
                raise RuntimeError(
                    "Pipeflow.defer() needs the pipeline to be driven via "
                    "Pipeline.run(executor) so resumes can be scheduled")
            self._deferred_waiters.setdefault(dep, []).append(line)
            return True

    def _complete_token(self, token: int) -> None:
        """Mark ``token`` complete and reschedule any parked first-pipe slots
        that deferred on it (the weak-edge bypass: scheduled directly, join
        counters untouched). Called inside a slot's execution, so the
        topology's pending count cannot reach zero before the resumes land."""
        with self._defer_lock:
            self._completed_set.add(token)
            while self._completed_watermark + 1 in self._completed_set:
                self._completed_watermark += 1
                self._completed_set.discard(self._completed_watermark)
            waiters = self._deferred_waiters.pop(token, ())
        for line in waiters:
            self._executor._schedule(None, self._grid[line][0]._node)

    # -------------------------------------------------------------- execution
    def reset(self) -> None:
        """Re-arm join counters for a fresh run. Must not be called while a
        topology of this pipeline is in flight. Token numbering continues:
        the next token runs on line ``num_tokens % num_lines``."""
        if self._topology is not None and not self._topology.done():
            raise RuntimeError("cannot reset a running pipeline")
        L, S = self._num_lines, len(self._pipes)
        self._stopped = False
        # a drained run has completed (or dropped) every minted token; fold
        # the completion bookkeeping into the watermark and clear parked state
        with self._defer_lock:
            self._completed_watermark = self._num_tokens - 1
            self._completed_set.clear()
            self._deferred_waiters.clear()
        self._parked = [False] * L
        self._start_line = l0 = self._num_tokens % L
        for l in range(L):
            pf = self._pipeflows[l]
            pf._pipe = 0
            pf._stopped = False
            ring = (l - l0) % L  # distance from the starting line
            # first pipe: the start condition schedules line l0 directly
            # (weak-edge bypass); every later line waits on the SERIAL
            # hand-off alone — the wrap dependency cannot fire in round one.
            self._counters[l][0].set(0 if ring == 0 else 1)
            for s in range(1, S):
                if ring == 0:
                    v = 1  # the very first token has no SERIAL predecessor
                else:
                    v = 2 if self._pipes[s].kind is PipeType.SERIAL else 1
                self._counters[l][s].set(v)

    def idle(self) -> bool:
        """True when no topology of this pipeline is in flight — the drained
        state in which :meth:`run` may re-arm it without rebuilding."""
        return self._topology is None or self._topology.done()

    def run(self, executor: Executor,
            on_complete: Optional[Callable[[Topology], None]] = None
            ) -> Topology:
        """Reset and submit one drain-to-completion run of the pipeline.

        The static grid is built once in ``__init__``; ``run`` only re-arms
        join counters (:meth:`reset`) and resubmits — the re-arm-without-
        rebuild path long-running clients (the serve engine, the prefetcher)
        use to keep one resident pipeline alive across drain/refill cycles.
        """
        self.reset()
        self._executor = executor
        self._topology = executor.run(self._taskflow, on_complete)
        return self._topology
