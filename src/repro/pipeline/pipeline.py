"""Task-parallel pipeline scheduling framework (Pipeflow, arXiv:2202.00717).

The pipeline is the single most valuable client of the paper's in-graph
control flow (§3.4: condition tasks, weak edges, cycles): ``L`` parallel
*lines* times ``S`` *pipes* (stages) are laid out **once** as a static cyclic
grid of multi-condition tasks over the existing work-stealing
:class:`~repro.core.executor.Executor` — no dedicated pipeline threads, no
data copies, no graph rebuilding between tokens.

Mapping to the Pipeflow paper:

==========================  ===================================================
Pipeflow construct          Here
==========================  ===================================================
``tf::Pipeline(L, ...)``    :class:`Pipeline` — ``Pipeline(num_lines, *pipes)``
``tf::Pipe{SERIAL, fn}``    :class:`Pipe` / :class:`PipeType` (``SERIAL`` |
                            ``PARALLEL``); the first pipe must be SERIAL
``tf::Pipeflow``            :class:`Pipeflow` — the per-line worker view
                            (``pf.line``, ``pf.pipe``, ``pf.token``)
``pf.stop()``               :meth:`Pipeflow.stop` — in-stage termination: only
                            legal at the first pipe; in-flight tokens drain
scheduling tokens           per-(line, pipe) :class:`AtomicInt` join counters;
                            a token *t* runs on line ``t % L``
deferred lines              a line whose next SERIAL pipe is still occupied
                            parks (its task simply is not scheduled) instead
                            of blocking a worker; counted in
                            :attr:`Pipeline.num_deferrals`
``tf::DataPipeline``        :class:`repro.pipeline.data.DataPipeline` —
                            per-line buffers threaded between stages, no locks
==========================  ===================================================

Graph layout (the static cyclic TDG, built once per ``Pipeline``):

* one **multi-condition task per (line, pipe) slot**; slot ``(l, s)`` has two
  weak out-edges: index 0 → ``(l, (s+1) % S)`` (the line moves forward, the
  last pipe wraps to re-admit the line) and index 1 → ``((l+1) % L, s)`` (a
  SERIAL pipe hands the stage to the next token's line);
* one **condition task** (the source) whose integer return selects which
  line's first pipe admits the next token — this is the paper's weak-edge
  bypass: condition successors are scheduled directly, join counters are
  only decremented by the grid itself.

Every edge is weak, so the whole pipeline is a *cycle* in the TDG — exactly
the pattern Figure 6/§3.4 of the Taskflow paper legalises — and a pipeline
run completes (the topology's pending count reaches zero) precisely when a
stop signal has drained every in-flight token.
"""
from __future__ import annotations

import enum
from typing import Callable, List, Optional

from ..core.atomic import AtomicInt
from ..core.executor import Executor, Topology
from ..core.graph import HOST, Task, Taskflow

__all__ = ["PipeType", "Pipe", "Pipeflow", "Pipeline"]


class PipeType(enum.Enum):
    SERIAL = "serial"      # at most one line in the stage; strict token order
    PARALLEL = "parallel"  # any number of lines in the stage concurrently


class Pipe:
    """One pipeline stage: ``fn(pf: Pipeflow)`` run on ``domain`` workers."""

    __slots__ = ("kind", "fn", "name", "domain")

    def __init__(self, kind: PipeType, fn: Callable, name: str = "",
                 domain: str = HOST) -> None:
        self.kind = kind
        self.fn = fn
        self.name = name or getattr(fn, "__name__", kind.value)
        self.domain = domain


class Pipeflow:
    """Per-line view handed to every pipe callable (paper's ``tf::Pipeflow``)."""

    __slots__ = ("_line", "_pipe", "_token", "_stopped", "num_deferrals")

    def __init__(self, line: int) -> None:
        self._line = line
        self._pipe = 0
        self._token = 0
        self._stopped = False
        self.num_deferrals = 0

    @property
    def line(self) -> int:
        return self._line

    @property
    def pipe(self) -> int:
        return self._pipe

    @property
    def token(self) -> int:
        return self._token

    def stop(self) -> None:
        """Stop admitting tokens. Only legal at the first pipe; the serial
        stage-0 hand-off chain is broken, so no later line re-enters pipe 0
        and all in-flight tokens drain to completion."""
        if self._pipe != 0:
            raise RuntimeError(
                "Pipeflow.stop() can only be called from the first pipe "
                f"(called from pipe {self._pipe})")
        self._stopped = True


class Pipeline:
    """``L`` lines × ``S`` pipes scheduled purely by executor condition tasks.

    Parameters
    ----------
    num_lines:
        maximum number of tokens in flight (the paper's *parallel lines*).
    pipes:
        :class:`Pipe` objects in stage order; the first must be SERIAL.

    Use :meth:`run` (or ``executor.run(pipeline.taskflow)`` after
    :meth:`reset`) to execute. Token numbering is monotone across runs, so a
    drained pipeline can be re-armed with :meth:`reset` + :meth:`run` to
    continue the stream — the restart pattern the bounded
    :class:`repro.data.pipeline.Prefetcher` uses for back-pressure.
    """

    def __init__(self, num_lines: int, *pipes: Pipe, name: str = "pipeline"):
        if num_lines < 1:
            raise ValueError("pipeline needs at least one line")
        if not pipes:
            raise ValueError("pipeline needs at least one pipe")
        if pipes[0].kind is not PipeType.SERIAL:
            raise ValueError("the first pipe must be SERIAL "
                             "(it mints scheduling tokens, Pipeflow §3)")
        self._pipes: List[Pipe] = list(pipes)
        self._num_lines = num_lines
        self._pipeflows = [Pipeflow(l) for l in range(num_lines)]
        self._counters = [[AtomicInt(0) for _ in pipes]
                          for _ in range(num_lines)]
        self._num_tokens = 0
        self._num_deferrals = AtomicInt(0)
        self._stopped = False
        self._start_line = 0
        self._topology: Optional[Topology] = None
        self._taskflow = Taskflow(name)
        self._build()
        self.reset()

    # ------------------------------------------------------------- properties
    @property
    def num_lines(self) -> int:
        return self._num_lines

    @property
    def num_pipes(self) -> int:
        return len(self._pipes)

    @property
    def num_tokens(self) -> int:
        """Tokens fully admitted so far (monotone across runs)."""
        return self._num_tokens

    @property
    def num_deferrals(self) -> int:
        """Times a line finished a pipe but parked because its next slot was
        still held (full SERIAL stage / wrap not yet released)."""
        return self._num_deferrals.value()

    @property
    def taskflow(self) -> Taskflow:
        return self._taskflow

    # ------------------------------------------------------------------ build
    def _build(self) -> None:
        tf = self._taskflow
        L, S = self._num_lines, len(self._pipes)
        grid: List[List[Task]] = [
            [tf.multi_condition(self._make_slot(l, s), name=f"pipe-L{l}S{s}",
                                domain=self._pipes[s].domain)
             for s in range(S)]
            for l in range(L)]
        for l in range(L):
            for s in range(S):
                # successor 0: same line, next pipe (last pipe wraps to re-
                # admit the line); successor 1: next line, same pipe (SERIAL
                # hand-off). Both edges are weak — the grid is one big cycle.
                grid[l][s].precede(grid[l][(s + 1) % S], grid[(l + 1) % L][s])
        start = tf.condition(lambda: self._start_line, name="pipeline-start")
        start.precede(*[grid[l][0] for l in range(L)])
        self._grid = grid

    def _make_slot(self, l: int, s: int) -> Callable[[], tuple]:
        L, S = self._num_lines, len(self._pipes)
        pipe = self._pipes[s]
        serial = pipe.kind is PipeType.SERIAL
        counters = self._counters

        def run_slot() -> tuple:
            pf = self._pipeflows[l]
            pf._pipe = s
            if s == 0:
                # stage 0 is SERIAL: exactly one line here at a time, so the
                # token counter and stop flag need no synchronisation.
                if self._stopped:
                    return ()
                pf._token = self._num_tokens
                pf._stopped = False
                self._invoke(pipe, pf)
                if pf._stopped:
                    self._stopped = True
                    return ()  # break both chains: in-flight tokens drain
                self._num_tokens += 1
            else:
                self._invoke(pipe, pf)
            # Re-arm this slot for its next visit BEFORE releasing successors
            # (the successor may wrap around and decrement us again). Steady
            # state: pipe 0 waits on {SERIAL hand-off, line wrap} = 2; other
            # SERIAL pipes on {previous token, line arrival} = 2; PARALLEL
            # pipes only on the line's arrival = 1.
            counters[l][s].set(2 if (s == 0 or serial) else 1)
            rets = []
            if serial and counters[(l + 1) % L][s].dec() == 0:
                rets.append(1)
            if counters[l][(s + 1) % S].dec() == 0:
                rets.append(0)
            else:
                # deferred line: the next slot is still held (full SERIAL
                # stage or un-wrapped line) — park without blocking a worker.
                pf.num_deferrals += 1
                self._num_deferrals.inc()
            return tuple(rets)

        run_slot.__name__ = f"pipe_{pipe.name}_L{l}S{s}"
        return run_slot

    def _invoke(self, pipe: Pipe, pf: Pipeflow) -> None:
        """Stage dispatch; DataPipeline overrides to thread per-line buffers."""
        pipe.fn(pf)

    # -------------------------------------------------------------- execution
    def reset(self) -> None:
        """Re-arm join counters for a fresh run. Must not be called while a
        topology of this pipeline is in flight. Token numbering continues:
        the next token runs on line ``num_tokens % num_lines``."""
        if self._topology is not None and not self._topology.done():
            raise RuntimeError("cannot reset a running pipeline")
        L, S = self._num_lines, len(self._pipes)
        self._stopped = False
        self._start_line = l0 = self._num_tokens % L
        for l in range(L):
            pf = self._pipeflows[l]
            pf._pipe = 0
            pf._stopped = False
            ring = (l - l0) % L  # distance from the starting line
            # first pipe: the start condition schedules line l0 directly
            # (weak-edge bypass); every later line waits on the SERIAL
            # hand-off alone — the wrap dependency cannot fire in round one.
            self._counters[l][0].set(0 if ring == 0 else 1)
            for s in range(1, S):
                if ring == 0:
                    v = 1  # the very first token has no SERIAL predecessor
                else:
                    v = 2 if self._pipes[s].kind is PipeType.SERIAL else 1
                self._counters[l][s].set(v)

    def run(self, executor: Executor,
            on_complete: Optional[Callable[[Topology], None]] = None
            ) -> Topology:
        """Reset and submit one drain-to-completion run of the pipeline."""
        self.reset()
        self._topology = executor.run(self._taskflow, on_complete)
        return self._topology
