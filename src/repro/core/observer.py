"""Executor observer interface + a profiler.

The profiler exposes the counters the paper's evaluation reads off the
runtime: per-worker executed-task counts, steal successes/failures,
sleep/active residency (the paper's energy-efficiency mechanism: fewer
busy-wait cycles), and per-domain utilization — used by the co-run
throughput benchmark (paper Figure 11) and reported in EXPERIMENTS.md.
"""
from __future__ import annotations

import threading
import time
from collections import defaultdict
from typing import Any, Dict, Set

__all__ = ["Observer", "Profiler"]


class Observer:
    """Override any subset of hooks; all are called from worker threads."""

    def on_entry(self, worker_id: int, domain: str, task: Any) -> None: ...
    def on_exit(self, worker_id: int, domain: str, task: Any) -> None: ...
    def on_steal(self, worker_id: int, domain: str, ok: bool) -> None: ...
    def on_sleep(self, worker_id: int, domain: str) -> None: ...
    def on_wake(self, worker_id: int, domain: str) -> None: ...


class Profiler(Observer):
    """Aggregating profiler: per-worker AND per-domain counters.

    Every hook registers its worker in the domain's worker set, so
    ``summary()`` normalizes utilization by the number of workers that
    REPORTED (including ones that only ever slept) — a worker that never
    executed a task still holds a core, and counting only the workers in
    ``tasks_executed`` used to overstate utilization on idle domains.
    """

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self.tasks_executed: Dict[int, int] = defaultdict(int)
        self.task_time: Dict[int, float] = defaultdict(float)
        self.steal_ok: Dict[int, int] = defaultdict(int)
        self.steal_fail: Dict[int, int] = defaultdict(int)
        self.sleeps: Dict[int, int] = defaultdict(int)
        self.sleep_time: Dict[int, float] = defaultdict(float)
        #: every worker that fired ANY hook, per domain (and overall)
        self.domain_workers: Dict[str, Set[int]] = defaultdict(set)
        self.domain_tasks: Dict[str, int] = defaultdict(int)
        self.domain_task_time: Dict[str, float] = defaultdict(float)
        self.domain_steal_ok: Dict[str, int] = defaultdict(int)
        self.domain_steal_fail: Dict[str, int] = defaultdict(int)
        self.domain_sleeps: Dict[str, int] = defaultdict(int)
        self.domain_sleep_time: Dict[str, float] = defaultdict(float)
        self._entry_t: Dict[int, float] = {}
        self._sleep_t: Dict[int, float] = {}
        self._t0 = time.perf_counter()

    def on_entry(self, worker_id, domain, task):
        self.domain_workers[domain].add(worker_id)
        self._entry_t[worker_id] = time.perf_counter()

    def on_exit(self, worker_id, domain, task):
        dt = time.perf_counter() - self._entry_t.get(worker_id, time.perf_counter())
        with self._lock:
            self.domain_workers[domain].add(worker_id)
            self.tasks_executed[worker_id] += 1
            self.task_time[worker_id] += dt
            self.domain_tasks[domain] += 1
            self.domain_task_time[domain] += dt

    def on_steal(self, worker_id, domain, ok):
        with self._lock:
            self.domain_workers[domain].add(worker_id)
            if ok:
                self.steal_ok[worker_id] += 1
                self.domain_steal_ok[domain] += 1
            else:
                self.steal_fail[worker_id] += 1
                self.domain_steal_fail[domain] += 1

    def on_sleep(self, worker_id, domain):
        self.domain_workers[domain].add(worker_id)
        self._sleep_t[worker_id] = time.perf_counter()

    def on_wake(self, worker_id, domain):
        t = self._sleep_t.pop(worker_id, None)
        if t is not None:
            with self._lock:
                self.sleeps[worker_id] += 1
                dt = time.perf_counter() - t
                self.sleep_time[worker_id] += dt
                self.domain_sleeps[domain] += 1
                self.domain_sleep_time[domain] += dt

    # -- summaries ----------------------------------------------------------
    def summary(self) -> Dict[str, Any]:
        wall = time.perf_counter() - self._t0
        with self._lock:
            total_tasks = sum(self.tasks_executed.values())
            busy = sum(self.task_time.values())
            asleep = sum(self.sleep_time.values())
            # workers that fired any hook — NOT len(tasks_executed): a
            # worker that only slept still holds a core of the domain
            nworkers = max(sum(len(s) for s in self.domain_workers.values()),
                           1)
            per_domain: Dict[str, Dict[str, Any]] = {}
            for d, workers in self.domain_workers.items():
                nd = max(len(workers), 1)
                d_busy = self.domain_task_time[d]
                d_sleep = self.domain_sleep_time[d]
                per_domain[d] = {
                    "workers": len(workers),
                    "tasks": self.domain_tasks[d],
                    "busy_s": d_busy,
                    "sleep_s": d_sleep,
                    "steals_ok": self.domain_steal_ok[d],
                    "steals_fail": self.domain_steal_fail[d],
                    "utilization":
                        d_busy / (wall * nd) if wall > 0 else 0.0,
                    "sleep_residency":
                        d_sleep / (wall * nd) if wall > 0 else 0.0,
                }
        return {
            "wall_s": wall,
            "tasks": total_tasks,
            "busy_s": busy,
            "sleep_s": asleep,
            "steals_ok": sum(self.steal_ok.values()),
            "steals_fail": sum(self.steal_fail.values()),
            "workers": nworkers,
            "utilization": busy / (wall * nworkers) if wall > 0 else 0.0,
            "sleep_residency": asleep / (wall * nworkers) if wall > 0 else 0.0,
            "per_domain": per_domain,
        }
