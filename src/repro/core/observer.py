"""Executor observer interface + a profiler.

The profiler exposes the counters the paper's evaluation reads off the
runtime: per-worker executed-task counts, steal successes/failures,
sleep/active residency (the paper's energy-efficiency mechanism: fewer
busy-wait cycles), and per-domain utilization — used by the co-run
throughput benchmark (paper Figure 11) and reported in EXPERIMENTS.md.
"""
from __future__ import annotations

import threading
import time
from collections import defaultdict
from typing import Any, Dict

__all__ = ["Observer", "Profiler"]


class Observer:
    """Override any subset of hooks; all are called from worker threads."""

    def on_entry(self, worker_id: int, domain: str, task: Any) -> None: ...
    def on_exit(self, worker_id: int, domain: str, task: Any) -> None: ...
    def on_steal(self, worker_id: int, domain: str, ok: bool) -> None: ...
    def on_sleep(self, worker_id: int, domain: str) -> None: ...
    def on_wake(self, worker_id: int, domain: str) -> None: ...


class Profiler(Observer):
    def __init__(self) -> None:
        self._lock = threading.Lock()
        self.tasks_executed: Dict[int, int] = defaultdict(int)
        self.task_time: Dict[int, float] = defaultdict(float)
        self.steal_ok: Dict[int, int] = defaultdict(int)
        self.steal_fail: Dict[int, int] = defaultdict(int)
        self.sleeps: Dict[int, int] = defaultdict(int)
        self.sleep_time: Dict[int, float] = defaultdict(float)
        self._entry_t: Dict[int, float] = {}
        self._sleep_t: Dict[int, float] = {}
        self._t0 = time.perf_counter()

    def on_entry(self, worker_id, domain, task):
        self._entry_t[worker_id] = time.perf_counter()

    def on_exit(self, worker_id, domain, task):
        dt = time.perf_counter() - self._entry_t.get(worker_id, time.perf_counter())
        with self._lock:
            self.tasks_executed[worker_id] += 1
            self.task_time[worker_id] += dt

    def on_steal(self, worker_id, domain, ok):
        with self._lock:
            if ok:
                self.steal_ok[worker_id] += 1
            else:
                self.steal_fail[worker_id] += 1

    def on_sleep(self, worker_id, domain):
        self._sleep_t[worker_id] = time.perf_counter()

    def on_wake(self, worker_id, domain):
        t = self._sleep_t.pop(worker_id, None)
        if t is not None:
            with self._lock:
                self.sleeps[worker_id] += 1
                self.sleep_time[worker_id] += time.perf_counter() - t

    # -- summaries ----------------------------------------------------------
    def summary(self) -> Dict[str, Any]:
        wall = time.perf_counter() - self._t0
        total_tasks = sum(self.tasks_executed.values())
        busy = sum(self.task_time.values())
        asleep = sum(self.sleep_time.values())
        nworkers = max(len(self.tasks_executed), 1)
        return {
            "wall_s": wall,
            "tasks": total_tasks,
            "busy_s": busy,
            "sleep_s": asleep,
            "steals_ok": sum(self.steal_ok.values()),
            "steals_fail": sum(self.steal_fail.values()),
            "utilization": busy / (wall * nworkers) if wall > 0 else 0.0,
            "sleep_residency": asleep / (wall * nworkers) if wall > 0 else 0.0,
        }
