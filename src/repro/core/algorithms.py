"""Composable parallel algorithms built on the taskflow model.

The paper ships ``parallel_for`` / reductions / pipelines as library
algorithms on top of the same graph primitives; the data pipeline and the
benchmarks use these.
"""
from __future__ import annotations

import threading
from typing import Any, Callable, List, Optional, Sequence

from .graph import HOST, Task, Taskflow

__all__ = ["parallel_for", "parallel_reduce", "linear_pipeline"]


def parallel_for(tf: Taskflow, n: int, body: Callable[[int], None],
                 chunk: int = 1, domain: str = HOST) -> tuple:
    """Add tasks running ``body(i) for i in range(n)`` in ``chunk``-sized
    blocks. Returns (entry, exit) synchronization tasks."""
    entry = tf.static(lambda: None, name="pfor-entry")
    exit_ = tf.static(lambda: None, name="pfor-exit")
    chunk = max(1, chunk)
    for lo in range(0, n, chunk):
        hi = min(n, lo + chunk)

        def run(lo=lo, hi=hi):
            for i in range(lo, hi):
                body(i)

        t = tf.static(run, name=f"pfor-{lo}", domain=domain)
        entry.precede(t)
        t.precede(exit_)
    return entry, exit_


def parallel_reduce(tf: Taskflow, items: Sequence[Any],
                    op: Callable[[Any, Any], Any], init: Any,
                    result: List[Any], chunk: int = 8) -> tuple:
    """Tree-free chunked reduction: chunks reduce locally, exit combines.
    ``result[0]`` holds the value after the exit task runs."""
    lock = threading.Lock()
    partials: List[Any] = []
    entry = tf.static(lambda: None, name="preduce-entry")

    def combine():
        acc = init
        for p in partials:
            acc = op(acc, p)
        result[0] = acc

    exit_ = tf.static(combine, name="preduce-exit")
    items = list(items)
    chunk = max(1, chunk)
    for lo in range(0, len(items), chunk):
        hi = min(len(items), lo + chunk)

        def run(lo=lo, hi=hi):
            acc = None
            first = True
            for x in items[lo:hi]:
                acc = x if first else op(acc, x)
                first = False
            with lock:
                partials.append(acc)

        t = tf.static(run, name=f"preduce-{lo}")
        entry.precede(t)
        t.precede(exit_)
    return entry, exit_


def linear_pipeline(tf: Taskflow, stages: Sequence[Callable[[Any], Any]],
                    source: Callable[[], Optional[Any]],
                    sink: Callable[[Any], None],
                    depth: int = 4) -> Task:
    """Token-based software pipeline (paper's pipeline pattern): up to
    ``depth`` tokens in flight, each flowing through ``stages`` in order.

    Built with a conditional cycle: a scheduler condition task keeps
    re-entering while the source yields tokens — no unrolling.
    """
    state = {"inflight": 0, "done": False}
    lock = threading.Lock()

    def pump(sf):
        # dynamic task: spawn one chain per available token, then re-check
        spawned = 0
        while True:
            with lock:
                if state["done"] or state["inflight"] >= depth:
                    break
            item = source()
            if item is None:
                with lock:
                    state["done"] = True
                break
            with lock:
                state["inflight"] += 1
            # build one stage-chain per token; the box threads the value
            # (bind box per-iteration: closures must NOT share the loop var)
            box = {"v": item}

            def mk(stage, box=box):
                def run():
                    box["v"] = stage(box["v"])
                return run

            chain = [sf.static(mk(s), name=f"stage{si}")
                     for si, s in enumerate(stages)]

            def finish(box=box):
                sink(box["v"])
                with lock:
                    state["inflight"] -= 1

            chain.append(sf.static(finish, name="sink"))
            for a, b in zip(chain, chain[1:]):
                a.precede(b)
            spawned += 1

    pump_t = tf.dynamic(pump, name="pipeline-pump")

    def again() -> int:
        with lock:
            return 1 if state["done"] and state["inflight"] == 0 else 0

    cond = tf.condition(again, name="pipeline-cond")
    stop = tf.static(lambda: None, name="pipeline-stop")
    # zero-dependency source (paper Fig. 6 pitfall 1: a pure cycle has
    # nothing for the scheduler to start with)
    init = tf.static(lambda: None, name="pipeline-init")
    init.precede(pump_t)
    pump_t.precede(cond)
    cond.precede(pump_t, stop)  # 0 -> loop back, 1 -> stop
    return stop
