"""Heterogeneous work-stealing executor — paper §4, Algorithms 2–8.

Architecture (paper Figure 8):

* one worker pool **per execution domain** (default: ``host`` for CPU work,
  ``accel`` for compiled-XLA work; arbitrary domains supported);
* every worker owns **one task queue per domain** so a task of any domain can
  be produced by any worker without synchronization, but a worker only
  *consumes* (pops/steals) tasks of its own domain;
* one **shared queue + event notifier per domain** for external submission
  and sleep/wake;
* two scheduler-level atomic arrays, ``actives[d]`` and ``thieves[d]``.

Invariant (paper §4.4): *one worker is making steal attempts while an active
worker exists, unless all workers are active* — the last thief to become
active wakes a peer to take over its thief role; cross-domain submissions
wake a worker of the target domain when that domain is fully idle.
"""
from __future__ import annotations

import os
import random
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Sequence

from .atomic import AtomicInt
from .graph import (ACCEL, HOST, Node, Subflow, Task, Taskflow, TaskType)
from .notifier import EventNotifier, Waiter
from .observer import Observer
from .wsq import WorkStealingQueue

__all__ = ["Executor", "Topology", "TaskError"]

_NSTRIPES = 64


class TaskError(RuntimeError):
    """Raised by Topology.wait() when a task failed; carries the cause."""


class Topology:
    """One execution (or repeated execution) of a taskflow: a future."""

    def __init__(self, taskflow: Taskflow, pred: Optional[Callable[[], bool]],
                 on_complete: Optional[Callable[["Topology"], None]]) -> None:
        self.taskflow = taskflow
        self.pending = AtomicInt(0)
        self.event = threading.Event()
        self.cancelled = False
        self.exceptions: List[BaseException] = []
        self.num_passes = 0
        self._pred = pred
        self._on_complete = on_complete
        self._sources: List[Node] = []

    # -- user API -------------------------------------------------------------
    def wait(self, timeout: Optional[float] = None) -> "Topology":
        if not self.event.wait(timeout):
            raise TimeoutError("topology did not complete in time")
        if self.exceptions:
            raise TaskError(
                f"task failed in taskflow {self.taskflow.name!r}: "
                f"{self.exceptions[0]!r}") from self.exceptions[0]
        return self

    def done(self) -> bool:
        return self.event.is_set()

    def cancel(self) -> None:
        """Stop scheduling successors; already-queued tasks drain as no-ops."""
        self.cancelled = True


class _Worker:
    __slots__ = ("id", "domain", "domain_idx", "queues", "waiter", "rng",
                 "thread", "device")

    def __init__(self, wid: int, domain: str, domain_idx: int, ndomains: int,
                 device: Any = None) -> None:
        self.id = wid
        self.domain = domain
        self.domain_idx = domain_idx
        self.queues = [WorkStealingQueue() for _ in range(ndomains)]
        self.waiter = Waiter()
        self.rng = random.Random(0xC0FFEE ^ wid)
        self.thread: Optional[threading.Thread] = None
        self.device = device


class Executor:
    """Work-stealing executor over heterogeneous domains (paper Algorithm 2-8).

    Parameters
    ----------
    domains:
        mapping domain name -> worker count. Defaults to
        ``{"host": os.cpu_count()}``. Add ``"accel": n`` for device workers.
    devices:
        optional mapping domain name -> list of device objects; worker i of
        that domain is bound to ``devices[d][i % len]`` (paper: "the number
        of domain workers equals the number of domain devices").
    """

    def __init__(self,
                 domains: Optional[Dict[str, int]] = None,
                 devices: Optional[Dict[str, Sequence[Any]]] = None,
                 max_steals: Optional[int] = None,
                 max_yields: int = 100,
                 observer: Optional[Observer] = None) -> None:
        if domains is None:
            domains = {HOST: os.cpu_count() or 1}
        if HOST not in domains:
            domains = {HOST: 1, **domains}
        self._domain_names = list(domains.keys())
        self._dindex = {d: i for i, d in enumerate(self._domain_names)}
        nd = len(self._domain_names)

        self._workers: List[_Worker] = []
        self._workers_by_domain: List[List[_Worker]] = [[] for _ in range(nd)]
        wid = 0
        for d, count in domains.items():
            di = self._dindex[d]
            devs = list((devices or {}).get(d, [])) or [None]
            for k in range(max(1, count)):
                w = _Worker(wid, d, di, nd, devs[k % len(devs)])
                self._workers.append(w)
                self._workers_by_domain[di].append(w)
                wid += 1

        self._shared = [WorkStealingQueue() for _ in range(nd)]
        self._shared_lock = threading.Lock()
        self._notifiers = [EventNotifier() for _ in range(nd)]
        self._actives = [AtomicInt(0) for _ in range(nd)]
        self._thieves = [AtomicInt(0) for _ in range(nd)]
        self._stripes = [threading.Lock() for _ in range(_NSTRIPES)]
        self._stop = False
        self.observer = observer

        self._max_steals = max_steals or (2 * len(self._workers) + 1)
        self._max_yields = max_yields

        self._topo_lock = threading.Lock()
        self._topo_cv = threading.Condition(self._topo_lock)
        self._live_topologies = 0

        for w in self._workers:
            t = threading.Thread(target=self._worker_loop, args=(w,),
                                 name=f"repro-worker-{w.domain}-{w.id}",
                                 daemon=True)
            w.thread = t
            t.start()

    # ------------------------------------------------------------------ public
    @property
    def num_workers(self) -> int:
        return len(self._workers)

    def domain_workers(self, domain: str) -> int:
        return len(self._workers_by_domain[self._dindex[domain]])

    @property
    def domain_names(self) -> List[str]:
        return list(self._domain_names)

    def has_domain(self, domain: str) -> bool:
        return domain in self._dindex

    def run(self, tf: Taskflow,
            on_complete: Optional[Callable[[Topology], None]] = None
            ) -> Topology:
        """Run the taskflow once (paper Listing 1)."""
        return self.run_until(tf, lambda: True, on_complete)

    def run_n(self, tf: Taskflow, n: int,
              on_complete: Optional[Callable[[Topology], None]] = None
              ) -> Topology:
        """Run the taskflow ``n`` times (sequentially)."""
        remaining = [n]

        def pred() -> bool:
            remaining[0] -= 1
            return remaining[0] <= 0

        return self.run_until(tf, pred, on_complete)

    def run_until(self, tf: Taskflow, pred: Callable[[], bool],
                  on_complete: Optional[Callable[[Topology], None]] = None
                  ) -> Topology:
        """Repeatedly run ``tf`` until ``pred()`` is true after a pass."""
        if self._stop:
            raise RuntimeError("executor is shut down")
        topo = Topology(tf, pred, on_complete)
        with self._topo_lock:
            # Per-node run state (_join/_topology) is a soft mapping to ONE
            # live topology (paper §3.3): resubmitting the taskflow while a
            # previous run is in flight would silently corrupt join counters.
            prev = getattr(tf, "_inflight_topology", None)
            if prev is not None and not prev.done():
                raise RuntimeError(
                    f"taskflow {tf.name!r} is already running in a live "
                    "topology; wait() for it to finish (or copy the graph) "
                    "before resubmitting — concurrent runs of one Taskflow "
                    "corrupt per-node join counters (paper §3.3)")
            tf._inflight_topology = topo
            self._live_topologies += 1
        for node in tf._nodes:
            node._topology = topo
            node._parent = None
            node._nested = None
        topo._sources = [n for n in tf._nodes if n.is_source()]
        if not topo._sources:
            if tf._nodes:
                topo.exceptions.append(
                    RuntimeError("taskflow has no source task (paper Fig. 6 "
                                 "pitfall 1: nothing for the scheduler to "
                                 "start with)"))
            self._finalize(None, topo, force=True)
            return topo
        self._submit_sources(None, topo)
        return topo

    def wait_for_all(self) -> None:
        with self._topo_cv:
            while self._live_topologies > 0:
                self._topo_cv.wait(0.05)

    def shutdown(self, wait: bool = True) -> None:
        if wait:
            self.wait_for_all()
        self._stop = True
        for n in self._notifiers:
            n.notify_all()
        for w in self._workers:
            if w.thread is not None:
                w.thread.join(timeout=10.0)

    def __enter__(self) -> "Executor":
        return self

    def __exit__(self, *exc) -> None:
        self.shutdown(wait=not any(exc))

    # ---------------------------------------------------------------- internals
    def _stripe(self, node: Node) -> threading.Lock:
        return self._stripes[id(node) % _NSTRIPES]

    def _arm(self, node: Node) -> None:
        with self._stripe(node):
            node._join = node.num_strong

    def _dec_join(self, node: Node) -> int:
        with self._stripe(node):
            node._join -= 1
            return node._join

    # -- Algorithm 8: submit_graph ---------------------------------------------
    def _submit_sources(self, w: Optional[_Worker], topo: Topology) -> None:
        sources = topo._sources
        # arm join counters for the whole pass (pending==0 here: quiescent)
        for node in topo.taskflow._nodes:
            node._join = node.num_strong
        topo.pending.inc(len(sources))  # bulk: no premature completion
        topo.num_passes += 1
        if w is not None:
            # re-submission from a worker (run_until pass): local queues
            for node in sources:
                d = self._dindex[node.domain]
                w.queues[d].push(node)
                if w.domain_idx != d and \
                        self._actives[d].value() == 0 and \
                        self._thieves[d].value() == 0:
                    self._notifiers[d].notify_one()
            return
        # external submission: ONE shared-lock acquisition for the whole
        # source set (was lock-per-node), then one wake per domain that
        # actually received work — the woken thief turning active wakes a
        # replacement (§4.4), so a single notify drains any batch size
        pushed: Dict[int, int] = {}
        with self._shared_lock:
            for node in sources:
                d = self._dindex[node.domain]
                self._shared[d].push(node)
                pushed[d] = pushed.get(d, 0) + 1
        for d in pushed:
            self._notifiers[d].notify_one()

    # -- Algorithm 5: submit_task ------------------------------------------------
    def _schedule(self, w: Optional[_Worker], node: Node,
                  counted: bool = False) -> None:
        topo = node._topology
        if not counted:
            topo.pending.inc()
        parent = node._parent
        if parent is not None and parent._nested is not None:
            parent._nested.inc()
        self._arm(node)  # re-arm join counter (cycle re-entry, paper §3.4)
        d = self._dindex[node.domain]
        if w is not None:
            w.queues[d].push(node)
            if w.domain_idx != d:
                if self._actives[d].value() == 0 and \
                        self._thieves[d].value() == 0:
                    self._notifiers[d].notify_one()
        else:
            with self._shared_lock:
                self._shared[d].push(node)
            self._notifiers[d].notify_one()

    # -- Algorithm 4: execute_task (visitor) ----------------------------------
    def _invoke(self, w: _Worker, node: Node) -> None:
        topo: Topology = node._topology
        obs = self.observer
        if topo.cancelled:
            self._tally_done(w, node)
            return
        if obs:
            obs.on_entry(w.id, w.domain, node)
        result = None
        deferred = False
        try:
            kind = node.kind
            if kind is TaskType.STATIC:
                node.fn()
            elif kind in (TaskType.CONDITION, TaskType.MULTI_CONDITION):
                result = node.fn()
            elif kind is TaskType.DYNAMIC:
                sf = Subflow(node)
                node.fn(sf)
                deferred = self._spawn_children(w, node, sf._nodes,
                                                detached=sf.detached)
            elif kind is TaskType.MODULE:
                child = node.module_target
                deferred = self._spawn_children(w, node, child._nodes,
                                                detached=False)
            elif kind is TaskType.DEVICE:
                from .deviceflow import DeviceFlow  # lazy: keeps core jax-free
                df = DeviceFlow(device=w.device)
                node.fn(df)
                df._offload()
            else:  # pragma: no cover
                raise RuntimeError(f"unknown task type {kind}")
        except BaseException as e:  # noqa: BLE001 - task isolation
            topo.exceptions.append(e)
            topo.cancelled = True
            deferred = False
        if obs:
            obs.on_exit(w.id, w.domain, node)
        if deferred:
            return  # successors released by the last joining child
        self._release(w, node, result)
        self._tally_done(w, node)

    def _spawn_children(self, w: _Worker, parent: Node,
                        children: List[Node], detached: bool) -> bool:
        """Schedule a subflow / module child graph. Returns True if the
        parent's completion is deferred until the children join."""
        if not children:
            return False
        topo = parent._topology
        sources = [c for c in children if c.is_source()]
        if not sources:
            raise RuntimeError("child graph has no source task")
        for c in children:
            c._topology = topo
            c._parent = None if detached else parent
            c._nested = None
            c._join = c.num_strong
        if detached:
            # paper §3.2: a detached subflow joins at the END of the taskflow
            # — accounted by the topology pending counter only.
            topo.pending.inc(len(sources))
            for c in sources:
                self._schedule(w, c, counted=True)
            return False
        parent._nested = AtomicInt(1)  # self token (latch pattern)
        for c in sources:
            self._schedule(w, c)
        if parent._nested.dec() == 0:  # children already finished (rare race)
            self._finish_join(w, parent)
            return True  # _finish_join released + tallied
        return True

    def _finish_join(self, w: _Worker, parent: Node) -> None:
        """Phase 2 of a joined subflow/module: release the parent's
        successors now that every child (transitively) completed."""
        parent._nested = None
        self._release(w, parent, None)
        self._tally_done(w, parent)

    def _release(self, w: _Worker, node: Node, result: Any) -> None:
        """Release successors (paper Algorithm 4 lines 2-10)."""
        topo: Topology = node._topology
        if topo.cancelled:
            return
        kind = node.kind
        if kind is TaskType.CONDITION:
            if isinstance(result, bool):
                result = int(result)  # pythonic: True->1, False->0
            if not isinstance(result, int):
                return  # non-index return: no successor taken
            if 0 <= result < len(node.successors):
                self._schedule(w, node.successors[result])
        elif kind is TaskType.MULTI_CONDITION:
            if not isinstance(result, (list, tuple)):
                return
            for r in result:
                if isinstance(r, int) and 0 <= r < len(node.successors):
                    self._schedule(w, node.successors[r])
        else:
            for s in node.successors:
                if self._dec_join(s) == 0:
                    self._schedule(w, s)

    def _tally_done(self, w: Optional[_Worker], node: Node) -> None:
        """Account one fully-completed task; propagate joins; detect topology
        completion (paper: executed count balances submitted count)."""
        parent = node._parent
        if parent is not None and parent._nested is not None:
            if parent._nested.dec() == 0:
                self._finish_join(w, parent)
        topo: Topology = node._topology
        if topo.pending.dec() == 0:
            self._finalize(w, topo)

    def _finalize(self, w: Optional[_Worker], topo: Topology,
                  force: bool = False) -> None:
        done = force or topo.cancelled
        if not done:
            try:
                done = bool(topo._pred()) if topo._pred is not None else True
            except BaseException as e:  # noqa: BLE001
                topo.exceptions.append(e)
                done = True
        if not done:
            self._submit_sources(w, topo)  # next pass (run_until / run_n)
            return
        topo.event.set()
        if topo._on_complete is not None:
            try:
                topo._on_complete(topo)
            except BaseException as e:  # noqa: BLE001
                topo.exceptions.append(e)
        with self._topo_cv:
            self._live_topologies -= 1
            self._topo_cv.notify_all()

    # -- Algorithm 2: worker_loop ----------------------------------------------
    def _worker_loop(self, w: _Worker) -> None:
        t: Optional[Node] = None
        while True:
            self._exploit_task(w, t)
            t, alive = self._wait_for_task(w)
            if not alive:
                return

    # -- Algorithm 3: exploit_task -----------------------------------------------
    def _exploit_task(self, w: _Worker, t: Optional[Node]) -> None:
        if t is None:
            return
        d = w.domain_idx
        # adaptive strategy: last thief turning active wakes a replacement
        if self._actives[d].inc() == 1 and self._thieves[d].value() == 0:
            self._notifiers[d].notify_one()
        while t is not None:
            self._invoke(w, t)
            t = w.queues[d].pop()
        self._actives[d].dec()

    # -- Algorithm 7: explore_task -----------------------------------------------
    def _explore_task(self, w: _Worker) -> Optional[Node]:
        d = w.domain_idx
        obs = self.observer
        steals = 0
        yields = 0
        workers = self._workers
        while not self._stop:
            v = workers[w.rng.randrange(len(workers))]
            if v is w:
                t = self._shared[d].steal()
            else:
                t = v.queues[d].steal()
            if t is not None:
                if obs:
                    obs.on_steal(w.id, w.domain, True)
                return t
            if obs:
                obs.on_steal(w.id, w.domain, False)
            steals += 1
            if steals >= self._max_steals:
                time.sleep(0)  # yield
                yields += 1
                if yields >= self._max_yields:
                    return None
        return None

    # -- Algorithm 6: wait_for_task (two-phase commit) -----------------------------
    def _wait_for_task(self, w: _Worker):
        d = w.domain_idx
        notifier = self._notifiers[d]
        obs = self.observer
        self._thieves[d].inc()
        while True:
            t = self._explore_task(w)
            if t is not None:
                if self._thieves[d].dec() == 0:
                    notifier.notify_one()  # last thief: hand over the role
                return t, True
            if self._stop:
                self._thieves[d].dec()
                notifier.notify_all()
                return None, False
            notifier.prepare_wait(w.waiter)
            # re-inspect the shared queue after phase 1 (Algorithm 6 L10-21)
            if not self._shared[d].empty():
                notifier.cancel_wait(w.waiter)
                t = self._shared[d].steal()
                if t is not None:
                    if self._thieves[d].dec() == 0:
                        notifier.notify_one()
                    return t, True
                continue  # goto Line 2: explore again, thief role retained
            if self._stop:
                notifier.cancel_wait(w.waiter)
                self._thieves[d].dec()
                notifier.notify_all()
                return None, False
            if self._thieves[d].dec() == 0:
                # last thief: guard against undetected parallelism
                retry = self._actives[d].value() > 0
                if not retry:
                    for x in self._workers:
                        if not x.queues[d].empty():
                            retry = True
                            break
                if retry:
                    notifier.cancel_wait(w.waiter)
                    self._thieves[d].inc()
                    continue  # goto Line 1
            if obs:
                obs.on_sleep(w.id, w.domain)
            notifier.commit_wait(w.waiter)
            if obs:
                obs.on_wake(w.id, w.domain)
            return None, True  # loop in worker_loop re-enters the protocol
