"""Work-stealing queue (Chase-Lev access discipline).

The paper implements the lock-free deque of Le et al. [PPoPP'13]: the owner
pushes/pops one end while thieves steal from the other end concurrently.

CPython adaptation (see DESIGN.md §2.3): ``collections.deque`` operations are
atomic under the GIL, which subsumes the C++11 memory-model fences of the
original algorithm. We preserve the *access discipline* — only the owning
worker calls :meth:`push`/:meth:`pop` (bottom), any thread may call
:meth:`steal` (top) — so the scheduling behaviour (LIFO for the owner for
locality, FIFO for thieves for load spreading) matches the paper exactly.
"""
from __future__ import annotations

import collections
from typing import Any, Optional

__all__ = ["WorkStealingQueue"]


class WorkStealingQueue:
    """Single-owner, multi-thief task queue."""

    __slots__ = ("_q",)

    def __init__(self) -> None:
        self._q: collections.deque = collections.deque()

    # -- owner end (bottom) -------------------------------------------------
    def push(self, item: Any) -> None:
        """Owner-only: push a task to the bottom of the queue."""
        self._q.append(item)

    def pop(self) -> Optional[Any]:
        """Owner-only: pop the most recently pushed task (LIFO locality)."""
        try:
            return self._q.pop()
        except IndexError:
            return None

    # -- thief end (top) ----------------------------------------------------
    def steal(self) -> Optional[Any]:
        """Any thread: steal the oldest task (FIFO spreading)."""
        try:
            return self._q.popleft()
        except IndexError:
            return None

    # -- introspection --------------------------------------------------------
    def empty(self) -> bool:
        return not self._q

    def __len__(self) -> int:
        return len(self._q)

    def __repr__(self) -> str:  # pragma: no cover
        return f"WorkStealingQueue(len={len(self._q)})"
