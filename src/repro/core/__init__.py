"""repro.core — Taskflow-JAX: the paper's task-graph system.

Host layer (faithful reproduction of the paper):
    Taskflow / Task / Subflow       task-graph model (§3)
    Executor / Topology             heterogeneous work stealing (§4)
    EventNotifier / WorkStealingQueue  runtime data structures (§4.3)

Device layer (TPU-native adaptation):
    JaxGraph / STOP                 in-XLA conditional task graphs (§3.4)
    DeviceFlow                      cudaFlow analogue, single-launch (§3.5)
"""
from .atomic import AtomicInt
from .deviceflow import DeviceFlow
from .executor import Executor, TaskError, Topology
from .graph import ACCEL, HOST, GraphBuilder, Subflow, Task, Taskflow, TaskType
from .jaxgraph import STOP, JaxGraph
from .notifier import EventNotifier, Waiter
from .observer import Observer, Profiler
from .wsq import WorkStealingQueue
from . import algorithms

__all__ = [
    "AtomicInt", "DeviceFlow", "Executor", "TaskError", "Topology",
    "ACCEL", "HOST", "GraphBuilder", "Subflow", "Task", "Taskflow",
    "TaskType", "STOP", "JaxGraph", "EventNotifier", "Waiter",
    "Observer", "Profiler", "WorkStealingQueue", "algorithms",
]
