"""In-XLA conditional task-graph engine — the TPU-native form of §3.4.

The paper's *conditional tasking* lets a task graph contain branches and
cycles so that iterative workloads need neither static unrolling (memory
blow-up, paper Fig. 13/17) nor per-iteration host launches. On TPU the
equivalent mechanism is control flow *inside* the XLA program:

* a task graph whose tasks are **pure functions over a shared state pytree**
  is lowered to ONE compiled program;
* a DAG lowers to a fused topological composition;
* a graph with condition tasks (possibly cyclic) lowers to a
  **program-counter machine**: ``lax.while_loop`` whose body dispatches the
  current *basic block* with ``lax.switch``. Chains of single-entry
  single-exit static tasks are merged into superblocks to keep the switch
  small.

Scheduling-semantics parity with the host runtime: static edges are strong
dependencies (a block runs when its chain predecessor finished), condition
out-edges are weak (the returned index picks the next block) — but because a
single SPMD program is sequential-in-control, *parallel* DAG branches obtain
their parallelism from XLA fusion/SPMD rather than from threads (DESIGN.md
§2.3).
"""
from __future__ import annotations

from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax import lax

__all__ = ["JaxGraph", "STOP"]


class _Stop:
    def __repr__(self) -> str:
        return "STOP"


#: Sentinel successor: leaving through it terminates the graph program.
STOP = _Stop()


class JNode:
    __slots__ = ("fn", "kind", "name", "successors", "idx")

    def __init__(self, fn: Callable, kind: str, name: str) -> None:
        self.fn = fn
        self.kind = kind  # "task" | "cond"
        self.name = name
        self.successors: List[Any] = []  # JNode or STOP
        self.idx = -1


class JTask:
    __slots__ = ("_node",)

    def __init__(self, node: JNode) -> None:
        self._node = node

    def precede(self, *others: Any) -> "JTask":
        for o in others:
            self._node.successors.append(o if o is STOP else o._node)
        return self

    def succeed(self, *others: "JTask") -> "JTask":
        for o in others:
            o._node.successors.append(self._node)
        return self

    @property
    def name(self) -> str:
        return self._node.name


class JaxGraph:
    """Build a (possibly cyclic) graph of pure state transformers."""

    def __init__(self, name: str = "") -> None:
        self.name = name
        self._nodes: List[JNode] = []

    # -- construction -----------------------------------------------------------
    def task(self, fn: Callable[[Any], Any], name: str = "") -> JTask:
        """Static task: ``state -> state``."""
        n = JNode(fn, "task", name or f"t{len(self._nodes)}")
        self._nodes.append(n)
        return JTask(n)

    def cond(self, fn: Callable[[Any], Tuple[Any, Any]], name: str = "") -> JTask:
        """Condition task: ``state -> (successor_index, state)`` (traced
        int32 index selecting among this task's successors, paper §3.4)."""
        n = JNode(fn, "cond", name or f"c{len(self._nodes)}")
        self._nodes.append(n)
        return JTask(n)

    # -- analysis ------------------------------------------------------------------
    def _preds(self) -> Dict[JNode, List[JNode]]:
        preds: Dict[JNode, List[JNode]] = {n: [] for n in self._nodes}
        for n in self._nodes:
            for s in n.successors:
                if s is not STOP:
                    preds[s].append(n)
        return preds

    def _is_dag(self) -> bool:
        if any(n.kind == "cond" for n in self._nodes):
            return False
        color: Dict[JNode, int] = {}

        def dfs(n: JNode) -> bool:
            color[n] = 1
            for s in n.successors:
                if s is STOP:
                    continue
                c = color.get(s, 0)
                if c == 1 or (c == 0 and not dfs(s)):
                    return False
            color[n] = 2
            return True

        return all(dfs(n) for n in self._nodes if color.get(n, 0) == 0)

    def _topo_order(self) -> List[JNode]:
        preds = self._preds()
        indeg = {n: len(ps) for n, ps in preds.items()}
        stack = [n for n in self._nodes if indeg[n] == 0]
        order = []
        while stack:
            n = stack.pop()
            order.append(n)
            for s in n.successors:
                if s is STOP:
                    continue
                indeg[s] -= 1
                if indeg[s] == 0:
                    stack.append(s)
        if len(order) != len(self._nodes):
            raise ValueError("graph has a cycle but no condition task")
        return order

    # -- lowering ----------------------------------------------------------------
    def lower(self, *, max_iters: Optional[int] = None) -> Callable[[Any], Any]:
        """Lower to a single pure function ``state -> state`` (jit-able).

        DAG graphs become a fused composition; conditional/cyclic graphs
        become a PC machine (`lax.while_loop` + `lax.switch`).
        ``max_iters`` optionally bounds the trip count (safety rail).
        """
        if not self._nodes:
            return lambda state: state
        if self._is_dag():
            order = self._topo_order()

            def run_dag(state):
                for n in order:
                    state = n.fn(state)
                return state

            return run_dag
        return self._lower_pc(max_iters)

    def compile(self, example_state: Any, **kw) -> Callable[[Any], Any]:
        """``lower()`` + ``jax.jit`` — ONE launch for the whole graph, the
        cudaFlow/CUDA-Graph effect of paper §3.5."""
        fn = self.lower(**kw)
        return jax.jit(fn)

    # .. PC machine ..
    def _blocks(self) -> Tuple[List[List[JNode]], Dict[JNode, int]]:
        """Partition into superblocks: maximal chains of single-pred static
        tasks, each optionally terminated by a condition task."""
        preds = self._preds()
        # entry = unique node with no STRONG predecessor (weak back-edges
        # from condition tasks do not gate the start — paper §3.4.1 applied
        # to the do-while idiom).
        sources = [n for n in self._nodes
                   if not any(p.kind != "cond" for p in preds[n])]
        if len(sources) > 1:  # prefer a true zero-dependency source
            no_pred = [n for n in sources if not preds[n]]
            if len(no_pred) == 1:
                sources = no_pred
        if len(sources) != 1:
            raise ValueError(
                f"cyclic graph must have exactly one entry task, got "
                f"{[n.name for n in sources]}")
        for n in self._nodes:
            if n.kind == "task" and len(n.successors) > 1:
                raise ValueError(
                    f"static task {n.name!r} has multiple successors in a "
                    "conditional graph; merge the fan-out into one task "
                    "(SPMD control flow cannot fork threads — DESIGN.md §2.3)")
        # jump targets begin blocks
        targets = {sources[0]}
        for n in self._nodes:
            if n.kind == "cond":
                for s in n.successors:
                    if s is not STOP:
                        targets.add(s)
            if len(preds[n]) > 1:
                targets.add(n)
        blocks: List[List[JNode]] = []
        block_of: Dict[JNode, int] = {}
        for t in self._nodes:
            if t not in targets:
                continue
            chain = [t]
            cur = t
            while cur.kind == "task":  # cond terminators end the chain
                succs = cur.successors
                if not succs or succs[0] is STOP or succs[0] in targets:
                    break
                cur = succs[0]
                chain.append(cur)
            blocks.append(chain)
            for n in chain:
                block_of[n] = len(blocks) - 1
        # sanity: every node must live in exactly one block
        placed = sum(len(b) for b in blocks)
        if placed != len(self._nodes):
            unplaced = [n.name for n in self._nodes if n not in block_of]
            raise ValueError(f"unreachable tasks (no path from source): "
                             f"{unplaced}")
        return blocks, block_of

    def _lower_pc(self, max_iters: Optional[int]) -> Callable[[Any], Any]:
        blocks, block_of = self._blocks()
        nblocks = len(blocks)
        stop_pc = nblocks

        def make_branch(chain: List[JNode]) -> Callable:
            term = chain[-1]

            def branch(state):
                for n in chain[:-1]:
                    state = n.fn(state)
                if term.kind == "cond":
                    idx, state = term.fn(state)
                    # out-of-range index => no successor taken (Taskflow
                    # semantics): route to STOP via a trailing sentinel slot.
                    k = len(term.successors)
                    succ_pc = jnp.array(
                        [stop_pc if s is STOP else block_of[s]
                         for s in term.successors] + [stop_pc],
                        dtype=jnp.int32)
                    idx = jnp.asarray(idx, jnp.int32)
                    idx = jnp.where((idx >= 0) & (idx < k), idx, k)
                    nxt = succ_pc[idx]
                else:
                    state = term.fn(state)
                    if term.successors and term.successors[0] is not STOP:
                        nxt = jnp.int32(block_of[term.successors[0]])
                    else:
                        nxt = jnp.int32(stop_pc)
                return nxt, state

            return branch

        branches = [make_branch(b) for b in blocks]

        def run(state):
            def cond_fn(carry):
                pc, _, it = carry
                alive = pc < stop_pc
                if max_iters is not None:
                    alive = jnp.logical_and(alive, it < max_iters)
                return alive

            def body_fn(carry):
                pc, st, it = carry
                nxt, st = lax.switch(pc, branches, st)
                return nxt, st, it + 1

            _, final, _ = lax.while_loop(
                cond_fn, body_fn, (jnp.int32(0), state, jnp.int32(0)))
            return final

        return run

    # -- eager reference interpreter (oracle for tests/benchmarks) --------------------
    def run_reference(self, state: Any, max_iters: int = 10_000) -> Any:
        """Execute the graph eagerly in Python — the unrolled / host-driven
        semantics the paper's DAG baselines use. Oracle for ``lower()``."""
        if self._is_dag():
            for n in self._topo_order():
                state = n.fn(state)
            return state
        blocks, block_of = self._blocks()
        pc = 0
        for _ in range(max_iters):
            chain = blocks[pc]
            for n in chain[:-1]:
                state = n.fn(state)
            term = chain[-1]
            if term.kind == "cond":
                idx, state = term.fn(state)
                idx = int(idx)
                if 0 <= idx < len(term.successors):
                    s = term.successors[idx]
                    pc = len(blocks) if s is STOP else block_of[s]
                else:
                    pc = len(blocks)  # out-of-range: no successor taken
            else:
                state = term.fn(state)
                if term.successors and term.successors[0] is not STOP:
                    pc = block_of[term.successors[0]]
                else:
                    pc = len(blocks)
            if pc >= len(blocks):
                return state
        raise RuntimeError("reference interpreter exceeded max_iters")
