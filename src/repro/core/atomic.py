"""Atomic integer used for the scheduler's actives/thieves/pending counters."""
from __future__ import annotations

import threading

__all__ = ["AtomicInt"]


class AtomicInt:
    """Lock-guarded counter with fetch-style semantics.

    (CPython's ``+=`` on attributes is a read-modify-write and is *not*
    atomic across threads; the paper's counters are std::atomic, so we guard
    with a mutex — contention is negligible at scheduler scale.)
    """

    __slots__ = ("_v", "_lock")

    def __init__(self, v: int = 0) -> None:
        self._v = v
        self._lock = threading.Lock()

    def inc(self, n: int = 1) -> int:
        """Add ``n`` and return the NEW value (paper's AtomInc)."""
        with self._lock:
            self._v += n
            return self._v

    def dec(self, n: int = 1) -> int:
        """Subtract ``n`` and return the NEW value (paper's AtomDec)."""
        with self._lock:
            self._v -= n
            return self._v

    def value(self) -> int:
        with self._lock:
            return self._v

    def set(self, v: int) -> None:
        with self._lock:
            self._v = v

    def __repr__(self) -> str:  # pragma: no cover
        return f"AtomicInt({self.value()})"
