"""Two-phase-commit event notifier (EventCount).

Faithful to the paper §4.3: "Event notifier is a two-phase commit protocol
(2PC) that allows a worker to wait on a binary predicate in a non-blocking
fashion" (the Dekker-style EventCount packaged in Eigen, [5] in the paper).

Protocol::

    waiter:   prepare_wait(w)      # phase 1: announce intent, snapshot epoch
              <re-check predicate> # the caller MUST re-inspect its predicate
              commit_wait(w)       # phase 2: sleep unless an epoch bump
              | cancel_wait(w)     #          intervened since phase 1
    notifier: <make predicate true>
              notify_one()/notify_all()

Any ``notify_*`` that happens after ``prepare_wait`` is guaranteed to be
observed by ``commit_wait`` (the epoch snapshot differs), so no wakeup is
lost — exactly the guarantee the paper's Algorithm 6 relies on.

CPython adaptation: the lock-free epoch word becomes an integer guarded by the
condition variable's lock. ``commit_wait`` additionally takes a *liveness
backstop* timeout (default 1s): a production-grade insurance against priority
inversion / missed wakeups that re-checks the epoch and returns control to the
scheduler loop. Spurious returns are counted (``spurious_wakeups``) and safe:
the worker simply re-runs the steal protocol.
"""
from __future__ import annotations

import threading

__all__ = ["Waiter", "EventNotifier"]


class Waiter:
    """Per-worker waiter slot (epoch snapshot)."""

    __slots__ = ("epoch",)

    def __init__(self) -> None:
        self.epoch = -1


class EventNotifier:
    def __init__(self, backstop_s: float = 1.0) -> None:
        self._cond = threading.Condition()
        self._epoch = 0
        self._backstop = backstop_s
        self.num_notifies = 0
        self.num_waits = 0
        self.spurious_wakeups = 0

    # -- waiter side ----------------------------------------------------------
    def prepare_wait(self, w: Waiter) -> None:
        with self._cond:
            w.epoch = self._epoch

    def cancel_wait(self, w: Waiter) -> None:
        w.epoch = -1

    def commit_wait(self, w: Waiter) -> bool:
        """Sleep until an epoch bump (strictly) after ``prepare_wait``.

        Returns True if woken by a notification, False on a backstop timeout.
        """
        with self._cond:
            self.num_waits += 1
            if self._epoch != w.epoch:
                return True  # a notify raced in between phases: consume it
            self._cond.wait(self._backstop)
            if self._epoch == w.epoch:
                # no epoch bump: backstop timeout (or a spurious CV wakeup)
                self.spurious_wakeups += 1
                return False
            # the epoch advanced while waiting — a notification happened,
            # even if the CV wait itself timed out in the same instant
            return True

    # -- notifier side ----------------------------------------------------------
    def notify_one(self) -> None:
        with self._cond:
            self._epoch += 1
            self.num_notifies += 1
            self._cond.notify(1)

    def notify_all(self) -> None:
        with self._cond:
            self._epoch += 1
            self.num_notifies += 1
            self._cond.notify_all()
