"""Task dependency graph (TDG) model — paper §3.

Implements the five task types of the paper's unified programming model:

* **static**      — plain callable, no arguments (``tf.emplace(fn)``).
* **dynamic**     — callable taking a :class:`Subflow`; spawns a child TDG at
                    execution time, joined (default) or detached (§3.2).
* **composable**  — ``tf.composed_of(other_tf)`` module tasks (§3.3).
* **condition**   — callable returning an ``int`` index selecting which
                    successor to run; out-edges are *weak* dependencies
                    (§3.4). ``multi_condition`` returns a list of indices.
* **device (cudaFlow→DeviceFlow)** — callable taking a
                    :class:`repro.core.deviceflow.DeviceFlow`; captures a
                    graph of JAX ops and launches it as ONE compiled XLA
                    program on the worker's accelerator (§3.5).

Strong vs weak dependencies (§3.4.1): an edge is *weak* iff its source is a
condition task. A node's join counter counts only strong in-edges; condition
tasks bypass the counter and schedule their selected successor directly —
this is what allows cycles and in-graph control flow.
"""
from __future__ import annotations

import enum
import inspect
import threading
from typing import Any, Callable, List, Optional, Sequence

__all__ = ["TaskType", "Node", "Task", "Taskflow", "Subflow", "GraphBuilder"]


class TaskType(enum.Enum):
    STATIC = "static"
    DYNAMIC = "dynamic"          # spawns a Subflow
    CONDITION = "condition"
    MULTI_CONDITION = "multi_condition"
    MODULE = "module"            # composed_of
    DEVICE = "device"            # DeviceFlow (cudaFlow analogue)


#: Default execution domains (paper Figure 8: CPU + GPU; generalizable).
HOST = "host"
ACCEL = "accel"


class Node:
    """A node in a TDG. Internal: users hold :class:`Task` handles."""

    __slots__ = (
        "name", "kind", "fn", "domain", "successors",
        "num_strong", "num_weak",
        # --- per-run state (owned by the executor) ---
        "_join", "_topology", "_parent", "_nested", "_graph",
        "module_target",
    )

    def __init__(self, fn: Optional[Callable], kind: TaskType, name: str,
                 domain: str, graph: "GraphBuilder") -> None:
        self.name = name
        self.kind = kind
        self.fn = fn
        self.domain = domain
        self.successors: List["Node"] = []
        self.num_strong = 0          # static count of strong in-edges
        self.num_weak = 0            # static count of weak in-edges
        self._join = 0               # runtime join counter (strong deps left)
        self._topology = None        # Topology of the current run
        self._parent: Optional["Node"] = None  # joining parent (subflow/module)
        self._nested = None          # AtomicInt latch while joining children
        self._graph = graph
        self.module_target: Optional["Taskflow"] = None

    # The executor re-arms the join counter at schedule time so that cyclic
    # graphs (condition-task loops) re-execute nodes with fresh counters.
    def is_source(self) -> bool:
        return self.num_strong == 0 and self.num_weak == 0

    def __repr__(self) -> str:  # pragma: no cover
        return f"Node({self.name!r}, {self.kind.value}, domain={self.domain})"


class Task:
    """Lightweight handle wrapping a node (paper §3.1)."""

    __slots__ = ("_node",)

    def __init__(self, node: Node) -> None:
        self._node = node

    # -- dependency building ---------------------------------------------------
    def precede(self, *tasks: "Task") -> "Task":
        """``self`` runs before each task in ``tasks``.

        If ``self`` is a condition task the edges are *weak*: the i-th call
        position defines the successor index returned by the condition.
        """
        src = self._node
        weak = src.kind in (TaskType.CONDITION, TaskType.MULTI_CONDITION)
        for t in tasks:
            dst = t._node
            src.successors.append(dst)
            if weak:
                dst.num_weak += 1
            else:
                dst.num_strong += 1
        return self

    def succeed(self, *tasks: "Task") -> "Task":
        for t in tasks:
            t.precede(self)
        return self

    # -- attributes --------------------------------------------------------------
    @property
    def name(self) -> str:
        return self._node.name

    def rename(self, name: str) -> "Task":
        self._node.name = name
        return self

    @property
    def kind(self) -> TaskType:
        return self._node.kind

    @property
    def domain(self) -> str:
        return self._node.domain

    def num_successors(self) -> int:
        return len(self._node.successors)

    def __repr__(self) -> str:  # pragma: no cover
        return f"Task({self._node.name!r})"


def _looks_dynamic(fn: Callable) -> bool:
    """A callable taking a first parameter named ``sf``/``subflow`` is dynamic."""
    try:
        params = list(inspect.signature(fn).parameters.values())
    except (TypeError, ValueError):
        return False
    return bool(params) and params[0].name in ("sf", "subflow")


class GraphBuilder:
    """Shared graph-construction API for Taskflow and Subflow (paper: the API
    used for one task type is nearly applicable to all the others)."""

    def __init__(self, name: str = "") -> None:
        self.name = name
        self._nodes: List[Node] = []
        self._counter = 0
        self._inflight_topology = None  # live Topology guard (executor-owned)

    # -- creation -----------------------------------------------------------------
    def _add(self, fn: Optional[Callable], kind: TaskType, name: str,
             domain: str) -> Task:
        if not name:
            name = f"{kind.value}-{self._counter}"
        self._counter += 1
        node = Node(fn, kind, name, domain, self)
        self._nodes.append(node)
        return Task(node)

    def emplace(self, *fns: Callable, domain: str = HOST):
        """Create one task per callable (paper Listing 1). Infers *dynamic*
        tasks from a leading ``sf``/``subflow`` parameter (paper Listing 2)."""
        tasks = []
        for fn in fns:
            kind = TaskType.DYNAMIC if _looks_dynamic(fn) else TaskType.STATIC
            tasks.append(self._add(fn, kind, getattr(fn, "__name__", ""), domain))
        if len(tasks) == 1:
            return tasks[0]
        return tuple(tasks)

    def static(self, fn: Callable, name: str = "", domain: str = HOST) -> Task:
        return self._add(fn, TaskType.STATIC, name, domain)

    def dynamic(self, fn: Callable, name: str = "", domain: str = HOST) -> Task:
        return self._add(fn, TaskType.DYNAMIC, name, domain)

    def condition(self, fn: Callable, name: str = "", domain: str = HOST) -> Task:
        return self._add(fn, TaskType.CONDITION, name, domain)

    def multi_condition(self, fn: Callable, name: str = "",
                        domain: str = HOST) -> Task:
        return self._add(fn, TaskType.MULTI_CONDITION, name, domain)

    def device(self, fn: Callable, name: str = "", domain: str = ACCEL) -> Task:
        """cudaFlow analogue: ``fn(deviceflow)`` captures a JAX op graph that
        is compiled and launched as one XLA program (paper §3.5)."""
        return self._add(fn, TaskType.DEVICE, name, domain)

    # -- introspection ---------------------------------------------------------------
    def num_tasks(self) -> int:
        return len(self._nodes)

    def empty(self) -> bool:
        return not self._nodes

    def tasks(self) -> Sequence[Task]:
        return [Task(n) for n in self._nodes]

    def dump(self) -> str:
        """GraphViz dot output (paper's ``Taskflow::dump``)."""
        lines = [f'digraph "{self.name or "taskflow"}" {{']
        for n in self._nodes:
            shape = "diamond" if n.kind in (TaskType.CONDITION,
                                            TaskType.MULTI_CONDITION) else "box"
            lines.append(f'  "{n.name}" [shape={shape}];')
            weak = n.kind in (TaskType.CONDITION, TaskType.MULTI_CONDITION)
            style = ' [style=dashed]' if weak else ""
            for s in n.successors:
                lines.append(f'  "{n.name}" -> "{s.name}"{style};')
        lines.append("}")
        return "\n".join(lines)


class Taskflow(GraphBuilder):
    """Top-level TDG: the gateway to create tasks and submit to an Executor."""

    def composed_of(self, other: "Taskflow", name: str = "") -> Task:
        """Module task (paper §3.3). The module keeps a *soft* mapping to
        ``other``; two module tasks of the same taskflow must not run
        concurrently (paper Figure 4)."""
        t = self._add(None, TaskType.MODULE, name or f"module-{other.name}",
                      HOST)
        t._node.module_target = other
        return t


class Subflow(GraphBuilder):
    """Child TDG spawned during execution of a dynamic task (paper §3.2)."""

    def __init__(self, parent: Node, name: str = "") -> None:
        super().__init__(name or f"subflow-of-{parent.name}")
        self._parent_node = parent
        self._detached = False
        self._joined = False

    def detach(self) -> None:
        """Let the subflow run independently; it joins at the end of the
        taskflow instead of at its parent (paper §3.2)."""
        if self._joined:
            raise RuntimeError("subflow already joined")
        self._detached = True

    @property
    def detached(self) -> bool:
        return self._detached
