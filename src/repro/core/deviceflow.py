"""DeviceFlow — the cudaFlow analogue (paper §3.5) for JAX/TPU.

A DeviceFlow task *captures* a graph of device operations at its execution
context (stateful parameter capture, paper §3.5.2) and offloads the whole
graph with **one host call**: the captured program is ``jax.jit``-compiled
once and launched as a single XLA executable — the TPU-native equivalent of
CUDA Graph's single-launch of many dependent GPU ops (paper's first design
advantage), with closure capture providing the stateful execution (second
advantage), and arbitrary nested :class:`repro.core.jaxgraph.JaxGraph`
programs providing extensibility (third advantage).

Differences from cudaFlow, and why (DESIGN.md §2.3): JAX op graphs are
*dataflow-captured* — dependencies between captured ops are discovered from
array use-def by XLA, so explicit ``precede`` between device ops is
unnecessary; insertion order is only a recording order. H2D/D2H transfers map
to ``device_put`` / ``device_get`` tasks at the program boundary.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, List, Optional, Tuple

__all__ = ["DeviceFlow"]


class DeviceFlow:
    """Capture-and-launch accelerator graph, bound to a worker's device."""

    def __init__(self, device: Any = None) -> None:
        self.device = device
        self._inputs: Dict[str, Any] = {}
        self._ops: List[Tuple[Callable, List[str], List[str], str]] = []
        self._fetch: List[str] = []
        self._results: Dict[str, Any] = {}
        self._compiled = None
        self._num_launches = 0

    # -- capture API ------------------------------------------------------------
    def copy(self, name: str, host_array: Any) -> "DeviceFlow":
        """H2D transfer task: make ``host_array`` available as ``name``."""
        self._inputs[name] = host_array
        return self

    def kernel(self, fn: Callable, inputs: List[str], outputs: List[str],
               name: str = "") -> "DeviceFlow":
        """Device op task: ``outputs = fn(*inputs)`` (any JAX computation —
        including a lowered JaxGraph program for in-graph control flow)."""
        self._ops.append((fn, list(inputs), list(outputs),
                          name or getattr(fn, "__name__", "op")))
        return self

    def fetch(self, *names: str) -> "DeviceFlow":
        """D2H transfer task: copy ``names`` back after the launch."""
        self._fetch.extend(names)
        return self

    def call(self, fn: Callable, *args: Any, out: str = "out") -> "DeviceFlow":
        """Convenience: capture ``out = fn(*args)`` with positional host args
        (the dominant trainer use: one compiled step function)."""
        arg_names = []
        for i, a in enumerate(args):
            n = f"__arg{len(self._inputs)}_{i}"
            self._inputs[n] = a
            arg_names.append(n)
        self._ops.append((fn, arg_names, [out], getattr(fn, "__name__", "call")))
        self._fetch.append(out)
        return self

    # -- launch -------------------------------------------------------------------
    def _build(self):
        import jax

        ops = list(self._ops)
        fetch = list(self._fetch)

        def program(env: Dict[str, Any]) -> Dict[str, Any]:
            env = dict(env)
            for fn, ins, outs, _ in ops:
                vals = fn(*[env[i] for i in ins])
                if len(outs) == 1:
                    env[outs[0]] = vals
                else:
                    for o, v in zip(outs, vals):
                        env[o] = v
            return {k: env[k] for k in fetch}

        return jax.jit(program)

    def _offload(self, launches: int = 1) -> Dict[str, Any]:
        """Compile once, launch ``launches`` times (paper cudaFlow offload)."""
        import jax

        if self._compiled is None:
            self._compiled = self._build()
        env = self._inputs
        if self.device is not None:
            env = {k: jax.device_put(v, self.device) for k, v in env.items()}
        out: Dict[str, Any] = {}
        for _ in range(max(1, launches)):
            out = self._compiled(env)
            self._num_launches += 1
        # block + D2H at the graph boundary (one sync per launch batch)
        self._results = jax.device_get(out)
        return self._results

    def offload(self, n: int = 1) -> Dict[str, Any]:
        return self._offload(n)

    def result(self, name: str) -> Any:
        return self._results[name]

    @property
    def num_launches(self) -> int:
        return self._num_launches
