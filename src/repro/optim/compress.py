"""Gradient compression with error feedback (distributed-optimization trick).

int8 per-tensor-scale quantization applied to gradients before the
data-parallel reduction, with an error-feedback accumulator carried in the
optimizer state so the quantization error is re-injected next step
(Seide et al. / EF-SGD family). At 1000-node scale this cuts DP all-reduce
bytes 4x for <0.1% loss deltas (tested in tests/test_optim.py).

``compress_grads`` is numerics-exact w.r.t. what a wire-compressed
all-reduce would produce when the reduction is performed on dequantized
values; the wire-level shard_map variant for real meshes lives in
``repro/distributed/collectives.py``.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

__all__ = ["init_error_state", "compress_grads"]


def init_error_state(params: Any) -> Any:
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.bfloat16), params)


def _quantize(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    amax = jnp.max(jnp.abs(x)) + 1e-12
    scale = amax / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compress_grads(grads: Any, err: Any) -> Tuple[Any, Any]:
    """Returns (dequantized grads as seen post-allreduce, new error state)."""

    def one(g, e):
        g32 = g.astype(jnp.float32) + e.astype(jnp.float32)
        q, scale = _quantize(g32)
        deq = q.astype(jnp.float32) * scale
        return deq.astype(g.dtype), (g32 - deq).astype(jnp.bfloat16)

    flat_g, tdef = jax.tree_util.tree_flatten(grads)
    flat_e = jax.tree_util.tree_leaves(err)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    new_g = jax.tree_util.tree_unflatten(tdef, [o[0] for o in out])
    new_e = jax.tree_util.tree_unflatten(tdef, [o[1] for o in out])
    return new_g, new_e
