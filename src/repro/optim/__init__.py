from .adamw import OptConfig, adamw_update, init_opt_state, lr_at
from .compress import compress_grads, init_error_state
