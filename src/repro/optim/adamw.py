"""AdamW with global-norm clipping, decoupled weight decay, and
configurable moment dtype (bf16 moments for the >100B configs).

Optimizer state shards exactly like the parameters (ZeRO: the param_specs
tree is reused for m/v), so optimizer memory scales 1/(data*model).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["OptConfig", "init_opt_state", "adamw_update", "lr_at"]


@dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    moment_dtype: str = "float32"   # bfloat16 for very large models


def lr_at(cfg: OptConfig, step) -> jnp.ndarray:
    """Linear warmup + cosine decay to min_lr_ratio."""
    step = jnp.asarray(step, jnp.float32)
    warm = step / jnp.maximum(1.0, cfg.warmup_steps)
    prog = (step - cfg.warmup_steps) / jnp.maximum(
        1.0, cfg.total_steps - cfg.warmup_steps)
    prog = jnp.clip(prog, 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) \
        * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def init_opt_state(params: Any, cfg: OptConfig) -> Dict[str, Any]:
    dt = {"float32": jnp.float32, "bfloat16": jnp.bfloat16}[cfg.moment_dtype]
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {"m": jax.tree_util.tree_map(zeros, params),
            "v": jax.tree_util.tree_map(zeros, params),
            "count": jnp.zeros((), jnp.int32)}


def global_norm(tree: Any) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def adamw_update(params: Any, grads: Any, state: Dict[str, Any],
                 cfg: OptConfig) -> Tuple[Any, Dict[str, Any], Dict[str, Any]]:
    count = state["count"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    lr = lr_at(cfg, count)
    c = count.astype(jnp.float32)
    bc1 = 1.0 - cfg.b1 ** c
    bc2 = 1.0 - cfg.b2 ** c

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32 = m.astype(jnp.float32) * cfg.b1 + (1 - cfg.b1) * g
        v32 = v.astype(jnp.float32) * cfg.b2 + (1 - cfg.b2) * jnp.square(g)
        step = (m32 / bc1) / (jnp.sqrt(v32 / bc2) + cfg.eps)
        decay = cfg.weight_decay * p.astype(jnp.float32) if p.ndim >= 2 else 0.
        newp = p.astype(jnp.float32) - lr * (step + decay)
        return (newp.astype(p.dtype), m32.astype(m.dtype),
                v32.astype(v.dtype))

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_m = jax.tree_util.tree_leaves(state["m"])
    flat_v = jax.tree_util.tree_leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree_util.tree_unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(tdef, [o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, {"m": new_m, "v": new_v, "count": count}, metrics
