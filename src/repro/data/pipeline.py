"""Data pipeline: deterministic synthetic LM token streams with host-side
prefetch driven by the taskflow runtime.

At production scale the host-domain workers of the paper's executor overlap
batch preparation with the device step (the work-stealing scheduler is what
the paper contributes; the pipeline is one of its natural clients). Each
data shard is seeded by (seed, shard_index, step) so restarts are exactly
reproducible and elastic re-sharding keeps determinism per global example.
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Any, Dict, Iterator, Optional

import numpy as np

__all__ = ["DataConfig", "SyntheticLM", "Prefetcher"]


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    frontend_tokens: int = 0
    d_model: int = 0


class SyntheticLM:
    """Zipf-ish synthetic token stream with learnable n-gram structure
    (a bigram process, so a real model shows decreasing loss)."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        V = cfg.vocab_size
        k = min(64, V)
        # sparse bigram transition structure
        self._next = rng.integers(0, V, size=(V, k)).astype(np.int32)

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed, step))
        B, S = cfg.global_batch, cfg.seq_len
        toks = np.empty((B, S), np.int32)
        cur = rng.integers(0, cfg.vocab_size, size=B)
        # skewed transitions: successor 0 with prob 0.75, else uniform over
        # the k successors — H* ~ 1.6 nats, so a model that learns the
        # primary bigram map drops far below the uniform floor ln(V)
        k = self._next.shape[1]
        choice = np.where(rng.random((B, S)) < 0.75, 0,
                          rng.integers(0, k, size=(B, S))).astype(np.int64)
        for t in range(S):
            toks[:, t] = cur
            cur = self._next[cur, choice[:, t]]
        out = {"tokens": toks}
        if cfg.frontend_tokens:
            out["frontend_embeds"] = rng.standard_normal(
                (B, cfg.frontend_tokens, cfg.d_model)).astype(np.float32)
        return out


class Prefetcher:
    """Bounded prefetch implemented as a 2-stage task-parallel pipeline.

    The prefetch loop is the canonical Pipeflow client: a **produce** stage
    (SERIAL — ``source(step)`` is called strictly in step order, safe for
    stateful sources) followed by a **stage** stage (PARALLEL — results are
    staged into the consumer queue concurrently, re-ordered by step so
    :meth:`get` always yields batches in order).

    Two drive modes share one credit-based core (``_claim``/``_emit``):

    * **manual** — :meth:`produce_one` pushes one token through both stages
      inline; this is the task body the trainer's taskflow schedules on host
      workers. Non-blocking: returns ``False`` when the queue is full or the
      prefetcher is stopped, so a detached prefetch task can never wedge a
      worker (liveness of the trainer topology).
    * **executor** — pass ``executor=``; the prefetcher owns a
      :class:`repro.pipeline.DataPipeline` whose SERIAL first pipe claims
      steps and materialises batches while the PARALLEL second pipe stages
      them. When the bounded queue fills, the first pipe calls ``pf.stop()``
      and the pipeline *drains* (back-pressure without blocked workers);
      :meth:`get` re-arms it once capacity frees up.

    Public API (``produce_one`` / ``get`` / ``stop`` / ``qsize``) is
    unchanged from the thread-queue implementation it replaces.
    """

    def __init__(self, source, depth: int = 2, start_step: int = 0,
                 executor=None):
        self._source = source
        self._depth = depth
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._next = start_step
        self._emit_next = start_step
        self._ready: Dict[int, Any] = {}   # out-of-order staging buffer
        self._inflight = 0                 # claimed but not yet queued
        self._lock = threading.Lock()
        self._stopped = False
        self._executor = executor
        self._topo = None
        self._pump_lock = threading.Lock()
        self._pipeline = None
        if executor is not None:
            from ..pipeline import DataPipe, DataPipeline, PipeType
            self._pipeline = DataPipeline(
                max(1, depth),
                DataPipe(PipeType.SERIAL, self._pipe_produce, name="produce"),
                DataPipe(PipeType.PARALLEL, self._pipe_stage, name="stage"),
                name="prefetch")

    # ------------------------------------------------------ credit-based core
    def _claim(self) -> Optional[int]:
        """Reserve the next step, bounded by queue capacity; None when full
        or stopped. qsize + inflight never exceeds depth, so staging a
        claimed batch can never block."""
        with self._lock:
            if self._stopped or self._inflight + self._q.qsize() >= self._depth:
                return None
            step = self._next
            self._next += 1
            self._inflight += 1
            return step

    def _emit(self, step: int, batch) -> None:
        """Stage a materialised batch; releases to the queue in step order."""
        with self._lock:
            self._ready[step] = batch
            while self._emit_next in self._ready:
                self._q.put_nowait(
                    (self._emit_next, self._ready.pop(self._emit_next)))
                self._emit_next += 1
                self._inflight -= 1

    # -------------------------------------------------------- pipeline stages
    def _pipe_produce(self, pf):
        step = self._claim()
        if step is None:
            pf.stop()  # full or stopped: drain (back-pressure, no blocking)
            return None
        return step, self._source(step)

    def _pipe_stage(self, pf, item):
        self._emit(*item)
        return None

    def _pump(self) -> bool:
        """Re-arm the drained pipeline if there is capacity to fill. Also
        installed as the pipeline's on_complete hook: a topology that drains
        in the instant the consumer empties the queue restarts itself, so a
        blocked :meth:`get` can never strand free capacity."""
        if self._executor is None:
            return False
        with self._pump_lock:
            if self._topo is not None and not self._topo.done():
                return True
            with self._lock:
                idle = (self._stopped or
                        self._inflight + self._q.qsize() >= self._depth)
            if idle:
                return False
            self._topo = self._pipeline.run(self._executor,
                                            lambda _topo: self._pump())
            return True

    # ------------------------------------------------------------- public API
    def start(self) -> bool:
        """Kick the executor-driven pipeline (no-op in manual mode)."""
        return self._pump()

    def produce_one(self) -> bool:
        """One prefetch token pushed through both stages inline (manual
        drive). Non-blocking; False when full or stopped."""
        step = self._claim()
        if step is None:
            return False
        self._emit(step, self._source(step))
        return True

    def get(self, timeout: Optional[float] = 60.0):
        self._pump()  # arm the producer before blocking on an empty queue
        item = self._q.get(timeout=timeout)
        self._pump()  # consumed one slot: refill ahead of the consumer
        return item

    def qsize(self) -> int:
        return self._q.qsize()

    def stop(self) -> None:
        self._stopped = True
