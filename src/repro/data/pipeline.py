"""Data pipeline: deterministic synthetic LM token streams with host-side
prefetch driven by the taskflow runtime.

At production scale the host-domain workers of the paper's executor overlap
batch preparation with the device step (the work-stealing scheduler is what
the paper contributes; the pipeline is one of its natural clients). Each
data shard is seeded by (seed, shard_index, step) so restarts are exactly
reproducible and elastic re-sharding keeps determinism per global example.
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Any, Dict, Iterator, Optional

import numpy as np

__all__ = ["DataConfig", "SyntheticLM", "Prefetcher"]


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    frontend_tokens: int = 0
    d_model: int = 0


class SyntheticLM:
    """Zipf-ish synthetic token stream with learnable n-gram structure
    (a bigram process, so a real model shows decreasing loss)."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        V = cfg.vocab_size
        k = min(64, V)
        # sparse bigram transition structure
        self._next = rng.integers(0, V, size=(V, k)).astype(np.int32)

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed, step))
        B, S = cfg.global_batch, cfg.seq_len
        toks = np.empty((B, S), np.int32)
        cur = rng.integers(0, cfg.vocab_size, size=B)
        # skewed transitions: successor 0 with prob 0.75, else uniform over
        # the k successors — H* ~ 1.6 nats, so a model that learns the
        # primary bigram map drops far below the uniform floor ln(V)
        k = self._next.shape[1]
        choice = np.where(rng.random((B, S)) < 0.75, 0,
                          rng.integers(0, k, size=(B, S))).astype(np.int64)
        for t in range(S):
            toks[:, t] = cur
            cur = self._next[cur, choice[:, t]]
        out = {"tokens": toks}
        if cfg.frontend_tokens:
            out["frontend_embeds"] = rng.standard_normal(
                (B, cfg.frontend_tokens, cfg.d_model)).astype(np.float32)
        return out


class Prefetcher:
    """Bounded prefetch queue fed by host-domain taskflow tasks.

    ``source(step) -> batch``; call :meth:`get` from the trainer. Used both
    standalone (thread) and as tasks inside the trainer taskflow.
    """

    def __init__(self, source, depth: int = 2, start_step: int = 0):
        self._source = source
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._next = start_step
        self._lock = threading.Lock()
        self._stopped = False

    def produce_one(self) -> bool:
        """One prefetch task body (host domain). Non-blocking: skips when
        the queue is full or stopped so a detached prefetch task can never
        wedge a worker (liveness of the trainer topology)."""
        with self._lock:
            if self._stopped or self._q.full():
                return False
            step = self._next
            self._next += 1
        batch = self._source(step)
        try:
            self._q.put_nowait((step, batch))
        except queue.Full:
            with self._lock:
                self._next = min(self._next, step)  # retry this step later
            return False
        return True

    def get(self, timeout: Optional[float] = 60.0):
        return self._q.get(timeout=timeout)

    def qsize(self) -> int:
        return self._q.qsize()

    def stop(self) -> None:
        self._stopped = True
