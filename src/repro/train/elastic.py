"""Elastic scaling: reshard a training state onto a different mesh.

Checkpoints store unsharded (gathered) leaves, so scaling the
data-parallel degree between runs is a pure placement problem: rebuild the
sharding tree for the NEW ShardCtx and device_put every leaf. Used by the
trainer on restore and tested across 1<->2<->4 device meshes.
"""
from __future__ import annotations

from typing import Any, Optional

import jax

from ..configs.base import ModelConfig
from ..distributed.sharding import ShardCtx
from .train_step import opt_shardings, param_shardings

__all__ = ["reshard_state"]


def reshard_state(cfg: ModelConfig, state: Any, ctx: ShardCtx) -> Any:
    """state: {"params":..., "opt":...} -> same tree placed per ctx."""
    pshard = param_shardings(cfg, ctx)
    oshard = opt_shardings(cfg, ctx, pshard)

    def place(tree, shard):
        def put(x, s):
            return jax.device_put(x, s) if s is not None else x
        return jax.tree_util.tree_map(put, tree, shard)

    out = dict(state)
    out["params"] = place(state["params"], pshard)
    if "opt" in state:
        out["opt"] = place(state["opt"],
                           {k: oshard[k] for k in state["opt"].keys()})
    return out
