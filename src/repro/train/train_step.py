"""Sharded train / prefill / decode step builders.

Every builder returns ``(fn, example_inputs)`` where the example inputs are
ShapeDtypeStructs that carry their NamedShardings — so the same object
drives both the multi-pod dry-run (``jax.jit(fn).lower(*examples)``) and
real execution (arrays placed with the same shardings).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import ModelConfig, ShapeSpec
from ..distributed.sharding import (ShardCtx, logical_to_spec, param_specs,
                                    use_shard_ctx)
from ..models import lm
from ..optim.adamw import OptConfig, adamw_update, init_opt_state
from ..optim.compress import compress_grads, init_error_state

__all__ = ["make_train_step", "make_prefill_step", "make_decode_step",
           "train_input_specs", "sharded_zeros", "param_shardings",
           "opt_shardings", "cache_shardings", "batch_shardings"]


# --------------------------------------------------------------- shardings
def _ns(ctx: ShardCtx, spec) -> Optional[NamedSharding]:
    if ctx.mesh is None:
        return None
    return NamedSharding(ctx.mesh, spec)


def param_shardings(cfg: ModelConfig, ctx: ShardCtx):
    shapes = jax.eval_shape(lambda k: lm.init_params(cfg, k),
                            jax.random.PRNGKey(0))
    specs = param_specs(shapes, ctx,
                        stacked_prefixes=("blocks", "tail_blocks"))
    # gblocks (zamba2) have TWO leading stacked dims (group, layer)
    if "gblocks" in shapes:
        def gb(path_keys, leaf):
            from ..distributed.sharding import _rule
            import numpy as np
            path = "gblocks/" + "/".join(
                str(getattr(k, "key", k)) for k in path_keys)
            spec = _rule(path, tuple(np.shape(leaf))[2:], ctx)
            return P(*((None, None) + tuple(spec)))
        specs["gblocks"] = jax.tree_util.tree_map_with_path(
            gb, shapes["gblocks"])
    if ctx.mesh is None:
        return jax.tree_util.tree_map(lambda s: None, specs)
    return jax.tree_util.tree_map(lambda s: _ns(ctx, s), specs,
                                  is_leaf=lambda x: isinstance(x, P))


def opt_shardings(cfg: ModelConfig, ctx: ShardCtx, pshard):
    return {"m": pshard, "v": pshard, "count": _ns(ctx, P())}


def batch_shardings(cfg: ModelConfig, ctx: ShardCtx) -> Dict[str, Any]:
    b = {"tokens": _ns(ctx, logical_to_spec(ctx, ("dp", None)))}
    if cfg.frontend != "none":
        b["frontend_embeds"] = _ns(
            ctx, logical_to_spec(ctx, ("dp", None, None)))
    return b


def _dim_ok(n: int, ctx: ShardCtx, logical: str) -> bool:
    return ctx.mesh is not None and n % ctx.axis_size(logical) == 0 and n > 1


def cache_shardings(cfg: ModelConfig, ctx: ShardCtx, batch: int,
                    cache_shapes) -> Any:
    """Sharding tree for a decode cache. Batch goes to dp when divisible;
    otherwise (B=1 long-context serving) the sequence / inner dims are
    sharded over BOTH axes (sequence-parallel cache, flash-decode style)."""
    b_sharded = _dim_ok(batch, ctx, "dp")
    both = ("data", "model") if ctx.mesh is not None and \
        len(ctx.mesh.axis_names) >= 2 else None
    if ctx.mesh is not None and "pod" in ctx.mesh.axis_names:
        both = ("data", "model")

    def _key_name(k):
        if hasattr(k, "key"):
            return str(k.key)
        if hasattr(k, "idx"):
            return str(k.idx)
        return str(k)

    def spec_for(path_keys, leaf):
        path = "/".join(_key_name(k) for k in path_keys)
        head = path.split("/", 1)[0]
        shape = leaf.shape
        nd = len(shape)
        if head in ("k", "v", "shared_k", "shared_v"):
            # (L?, B, KV, S, hd): sequence-parallel cache over sp
            lead = nd - 4
            spec = [None] * lead
            spec.append("dp" if b_sharded else None)
            spec.append(None)
            spec.append("sp" if b_sharded else (both or "sp"))
            spec.append(None)
            return P(*[_resolve(ctx, s) for s in spec])
        if "ssm" in head:
            conv_like = nd >= 2 and shape[-2] == cfg.ssm_conv - 1
            if conv_like:  # (..., B, K-1, C): shard channels over tp
                lead = nd - 3
                spec = [None] * lead + [
                    "dp" if b_sharded else None, None,
                    "tp" if _dim_ok(shape[-1], ctx, "tp") else None]
                return P(*[_resolve(ctx, s) for s in spec])
            # states (..., B, dI|nh, ...): shard the inner dim
            lead = nd - 3 if nd == 4 else nd - 4  # m1:(B,dI,N) m2:(B,nh,hd,N)
            lead = max(lead, 0)
            inner = shape[lead + 1]
            ax = None
            if not b_sharded and both is not None and \
                    inner % _both_size(ctx) == 0:
                ax = both
            elif _dim_ok(inner, ctx, "tp"):
                ax = "tp"
            spec = [None] * lead + ["dp" if b_sharded else None, ax] \
                + [None] * (nd - lead - 2)
            return P(*[_resolve(ctx, s) for s in spec])
        return P(*([None] * nd))

    specs = jax.tree_util.tree_map_with_path(spec_for, cache_shapes)
    return jax.tree_util.tree_map(lambda s: _ns(ctx, s), specs,
                                  is_leaf=lambda x: isinstance(x, P))


def _resolve(ctx: ShardCtx, s):
    if s is None or isinstance(s, tuple):
        return s
    if s == "dp":
        return ctx.dp if len(ctx.dp) > 1 else ctx.dp[0]
    return getattr(ctx, s, s) if s in ("tp", "sp", "fsdp") else s


def _both_size(ctx: ShardCtx) -> int:
    return ctx.mesh.shape["data"] * ctx.mesh.shape["model"]


def sharded_zeros(shapes, shardings):
    """Instantiate concrete zero arrays matching (shape, sharding) trees."""
    def mk(s, sh):
        z = jnp.zeros(s.shape, s.dtype)
        return jax.device_put(z, sh) if sh is not None else z
    return jax.tree_util.tree_map(mk, shapes, shardings)


# --------------------------------------------------------------- train step
def make_train_step(cfg: ModelConfig, ctx: ShardCtx,
                    opt: Optional[OptConfig] = None,
                    compress: bool = False,
                    microbatches: Optional[int] = None,
                    accum_dtype=None):
    """Returns (train_step, (param_sds, opt_sds, batch_sds)). The function
    signature is (params, opt_state, batch) -> (params, opt_state, metrics).

    microbatches: gradient-accumulation factor. None => auto: one sequence
    per device per microbatch (keeps the remat residual stack at
    O(L * seq * d_model) regardless of global batch). 1 disables.
    accum_dtype: gradient accumulator dtype; None => fp32 unless the model
    is >100B params (where the fp32 accumulator alone is ~7.5GB/dev).
    """
    opt = opt or OptConfig()
    if accum_dtype is None:
        accum_dtype = jnp.bfloat16 if cfg.param_count() > 1e11 \
            else jnp.float32

    def _grads(params, batch):
        return jax.value_and_grad(
            lambda p: lm.loss_fn(cfg, p, batch), has_aux=True)(params)

    def train_step(params, opt_state, batch):
        with use_shard_ctx(ctx):
            B = batch["tokens"].shape[0]
            mb = microbatches
            if mb is None:
                dp = ctx.axis_size("dp")
                mb = max(1, B // dp)  # 1 sequence / device / microbatch
            while B % mb:
                mb -= 1
            if mb <= 1:
                (loss, metrics), grads = _grads(params, batch)
            else:
                split = jax.tree_util.tree_map(
                    lambda t: t.reshape((mb, B // mb) + t.shape[1:]), batch)

                fwd_params = params
                if cfg.hoist_weight_gather and ctx.mesh is not None:
                    # §Perf H2: materialize the FSDP all-gather ONCE per
                    # step (bf16, model-axis sharding only) instead of once
                    # per microbatch; grads transpose back to reduce-scatter
                    import dataclasses as _dc
                    gctx = _dc.replace(ctx, fsdp=None)
                    gshard = param_shardings(cfg, gctx)  # handles gblocks

                    def gather(p, ns):
                        pc = p.astype(jnp.bfloat16) if p.ndim >= 2 else p
                        if ns is None:
                            return pc
                        return jax.lax.with_sharding_constraint(pc, ns)
                    fwd_params = jax.tree_util.tree_map(
                        gather, params, gshard)

                def micro(acc, mbatch):
                    mbatch = jax.tree_util.tree_map(
                        lambda t: constrain_batch(t), mbatch)
                    (l, met), g = _grads(fwd_params, mbatch)
                    acc = jax.tree_util.tree_map(
                        lambda a, gi: a + gi.astype(acc_dt) / mb,
                        acc, g)
                    return acc, dict(met, loss=l)

                def constrain_batch(t):
                    from ..distributed.sharding import constrain
                    return constrain(t, *( ("dp",) + (None,) * (t.ndim - 1)))

                acc_dt = accum_dtype
                zeros = jax.tree_util.tree_map(
                    lambda p: jnp.zeros(p.shape, acc_dt), params)
                grads, mets = jax.lax.scan(micro, zeros, split)
                metrics = jax.tree_util.tree_map(
                    lambda m: jnp.mean(m, axis=0), mets)
                loss = metrics.pop("loss")
            if compress:
                grads, new_err = compress_grads(grads, opt_state["err"])
            params, new_opt, om = adamw_update(
                params, grads,
                {k: opt_state[k] for k in ("m", "v", "count")}, opt)
            if compress:
                new_opt["err"] = new_err
            metrics = dict(metrics, loss=loss, **om)
            return params, new_opt, metrics

    pshard = param_shardings(cfg, ctx)
    oshard = opt_shardings(cfg, ctx, pshard)
    if compress:
        oshard["err"] = pshard
    bshard = batch_shardings(cfg, ctx)

    param_sds = _sds_tree(
        jax.eval_shape(lambda k: lm.init_params(cfg, k),
                       jax.random.PRNGKey(0)), pshard)

    def _opt_shapes(p):
        st = init_opt_state(p, opt)
        if compress:
            st["err"] = init_error_state(p)
        return st

    opt_sds = _sds_tree(jax.eval_shape(_opt_shapes, param_sds), oshard)
    batch_sds = _sds_tree(train_batch_shapes(cfg,
                                             *_dummy_bs(cfg)), bshard)
    return train_step, (param_sds, opt_sds, batch_sds), (pshard, oshard)


def _dummy_bs(cfg):
    return 8, 128


def train_batch_shapes(cfg: ModelConfig, batch: int, seq: int):
    shapes = {"tokens": jax.ShapeDtypeStruct((batch, seq), jnp.int32)}
    if cfg.frontend != "none":
        shapes["frontend_embeds"] = jax.ShapeDtypeStruct(
            (batch, cfg.frontend_tokens, cfg.d_model), jnp.bfloat16)
    return shapes


def _sds_tree(shapes, shardings):
    def mk(s, sh):
        if sh is None:
            return jax.ShapeDtypeStruct(s.shape, s.dtype)
        return jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh)
    return jax.tree_util.tree_map(mk, shapes, shardings)


def train_input_specs(cfg: ModelConfig, ctx: ShardCtx, shape: ShapeSpec,
                      opt: Optional[OptConfig] = None,
                      compress: bool = False):
    """ShapeDtypeStruct stand-ins for every train_step input (assignment:
    weak-type-correct, shardable, no device allocation)."""
    step, (p_sds, o_sds, _), shards = make_train_step(cfg, ctx, opt, compress)
    b_sds = _sds_tree(
        train_batch_shapes(cfg, shape.global_batch, shape.seq_len),
        batch_shardings(cfg, ctx))
    return step, (p_sds, o_sds, b_sds), shards


# --------------------------------------------------------------- serve steps
def make_prefill_step(cfg: ModelConfig, ctx: ShardCtx, shape: ShapeSpec):
    def prefill_step(params, batch):
        with use_shard_ctx(ctx):
            fe = batch.get("frontend_embeds")
            logits, cache = lm.prefill(cfg, params, batch["tokens"],
                                       frontend_embeds=fe)
            return logits, cache

    pshard = param_shardings(cfg, ctx)
    p_sds = _sds_tree(
        jax.eval_shape(lambda k: lm.init_params(cfg, k),
                       jax.random.PRNGKey(0)), pshard)
    b_sds = _sds_tree(
        train_batch_shapes(cfg, shape.global_batch, shape.seq_len),
        batch_shardings(cfg, ctx))
    return prefill_step, (p_sds, b_sds), pshard


def make_decode_step(cfg: ModelConfig, ctx: ShardCtx, shape: ShapeSpec,
                     serve_tp_only: bool = False):
    """serve_step: ONE new token against a seq_len KV cache / SSM state.

    serve_tp_only drops the FSDP axis from the parameter shardings
    (weights resident model-sharded instead of re-gathered per layer —
    §Perf serving iteration; costs params_bytes/tp_size residency)."""
    B = shape.global_batch

    def decode(params, cache, token):
        with use_shard_ctx(ctx):
            return lm.decode_step(cfg, params, cache, token)

    import dataclasses as _dc
    pctx = _dc.replace(ctx, fsdp=None) if serve_tp_only else ctx
    pshard = param_shardings(cfg, pctx)
    p_sds = _sds_tree(
        jax.eval_shape(lambda k: lm.init_params(cfg, k),
                       jax.random.PRNGKey(0)), pshard)
    cache_shapes = jax.eval_shape(
        lambda: lm.init_cache(cfg, B, shape.seq_len))
    cshard = cache_shardings(cfg, ctx, B, cache_shapes)
    c_sds = _sds_tree(cache_shapes, cshard)
    t_sds = jax.ShapeDtypeStruct(
        (B,), jnp.int32,
        sharding=_ns(ctx, logical_to_spec(ctx, ("dp",)))
        if _dim_ok(B, ctx, "dp") else _ns(ctx, P(None)))
    return decode, (p_sds, c_sds, t_sds), (pshard, cshard)
