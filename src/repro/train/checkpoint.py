"""Sharded, atomic, restartable checkpointing.

Layout:  <dir>/ckpt_<step>/
            manifest.json       tree structure, shapes, dtypes, step, hash
            data/<idx>.bin      raw little-endian buffers (bf16 as uint16)

Guarantees needed at 1000-node scale:
* **atomicity** — writes go to ``.tmp-<step>`` and are renamed only after
  the manifest (written last) is fsynced; a crashed save can never be
  mistaken for a valid checkpoint;
* **restart** — ``restore_latest`` picks the newest *complete* checkpoint,
  validating the manifest leaf count;
* **elasticity** — ``restore`` takes target shardings; leaves are
  device_put against the *new* mesh, so the data-parallel degree may change
  between runs (tests/test_checkpoint.py exercises 1<->2 device reshard);
* **async** — the trainer snapshots to host (device_get) and hands the
  write to a detached host-domain task (paper's heterogeneous tasking),
  overlapping checkpoint I/O with the next train step.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

__all__ = ["CheckpointManager"]


def _flatten(tree: Any):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def _dtype_name(x) -> str:
    return str(x.dtype)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep

    # ------------------------------------------------------------------ save
    def save(self, step: int, tree: Any) -> Path:
        """Blocking sharded save (call from a host-domain task for async)."""
        leaves, treedef = _flatten(tree)
        tmp = self.dir / f".tmp-{step}"
        if tmp.exists():
            shutil.rmtree(tmp)
        (tmp / "data").mkdir(parents=True)
        metas: List[Dict] = []
        h = hashlib.sha256()
        for i, leaf in enumerate(leaves):
            arr = np.asarray(jax.device_get(leaf))
            dt = _dtype_name(arr)
            if dt == "bfloat16":
                raw = arr.view(np.uint16)
            else:
                raw = arr
            buf = raw.tobytes()
            h.update(buf[:4096])
            with open(tmp / "data" / f"{i}.bin", "wb") as f:
                f.write(buf)
            metas.append({"shape": list(arr.shape), "dtype": dt})
        manifest = {"step": step, "num_leaves": len(leaves),
                    "treedef": str(treedef), "leaves": metas,
                    "hash": h.hexdigest()}
        with open(tmp / "manifest.json", "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        final = self.dir / f"ckpt_{step:08d}"
        if final.exists():
            shutil.rmtree(final)
        tmp.rename(final)
        self._gc()
        return final

    def _gc(self) -> None:
        ckpts = self.all_steps()
        for s in ckpts[:-self.keep] if self.keep else []:
            shutil.rmtree(self.dir / f"ckpt_{s:08d}", ignore_errors=True)

    # ---------------------------------------------------------------- restore
    def all_steps(self) -> List[int]:
        out = []
        for p in self.dir.glob("ckpt_*"):
            mf = p / "manifest.json"
            if not mf.exists():
                continue
            try:
                m = json.loads(mf.read_text())
                n = m["num_leaves"]
                if all((p / "data" / f"{i}.bin").exists() for i in range(n)):
                    out.append(int(m["step"]))
            except (json.JSONDecodeError, KeyError):
                continue
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, example_tree: Any,
                shardings: Any = None) -> Any:
        """Restore into the structure of ``example_tree``; if ``shardings``
        given, leaves are placed with them (elastic reshard on load)."""
        import ml_dtypes

        path = self.dir / f"ckpt_{step:08d}"
        manifest = json.loads((path / "manifest.json").read_text())
        leaves, treedef = _flatten(example_tree)
        if manifest["num_leaves"] != len(leaves):
            raise ValueError(
                f"checkpoint has {manifest['num_leaves']} leaves, "
                f"model expects {len(leaves)}")
        shard_leaves = (jax.tree_util.tree_leaves(
            shardings, is_leaf=lambda x: x is None)
            if shardings is not None else [None] * len(leaves))
        out = []
        for i, (meta, ex, sh) in enumerate(
                zip(manifest["leaves"], leaves, shard_leaves)):
            raw = (path / "data" / f"{i}.bin").read_bytes()
            if meta["dtype"] == "bfloat16":
                arr = np.frombuffer(raw, np.uint16).reshape(
                    meta["shape"]).view(ml_dtypes.bfloat16)
            else:
                arr = np.frombuffer(raw, np.dtype(meta["dtype"])).reshape(
                    meta["shape"])
            if tuple(arr.shape) != tuple(np.shape(ex)):
                raise ValueError(f"leaf {i} shape {arr.shape} != model "
                                 f"{np.shape(ex)}")
            out.append(jax.device_put(arr, sh) if sh is not None
                       else jax.numpy.asarray(arr))
        return jax.tree_util.tree_unflatten(treedef, out)

    def restore_latest(self, example_tree: Any, shardings: Any = None
                       ) -> Tuple[Optional[int], Any]:
        step = self.latest_step()
        if step is None:
            return None, example_tree
        return step, self.restore(step, example_tree, shardings)
