"""Trainer-as-taskflow: the production training loop expressed as the
paper's conditional task graph.

Graph (one cyclic TDG — no unrolling across steps, paper §3.4):

    init ─> prefetch(host) ─> step(accel) ─> ckpt?(cond) ─┬─> save(host,
                 ^                                        │   detached)
                 │                                        v
                 └──────────────(0) loop(cond) <──────────┘
                                   │(1)
                                   v
                                  done

* ``prefetch`` arms the :class:`repro.data.pipeline.Prefetcher` in its
  executor-pipeline mode: the prefetcher owns a 2-stage produce/stage
  :class:`repro.pipeline.DataPipeline` scheduled on THIS trainer's host
  workers (no dedicated thread, no manual subflow), so batch materialisation
  overlaps the device step via heterogeneous work stealing and back-pressure
  is the pipeline's stop/drain protocol;
* ``step`` is a DEVICE task: one compiled XLA program (cudaFlow analogue);
* ``ckpt?`` is a condition task that routes through an async checkpoint
  branch every ``ckpt_every`` steps — the save runs as a host task off the
  critical path (snapshot first, write detached);
* ``loop`` is the condition task closing the cycle.

Fault tolerance: a device-step failure cancels the topology; ``run()``
restores the latest complete checkpoint and resubmits the graph
(``max_restarts``). ``fail_at_step`` injects a crash for the tests.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from ..core import ACCEL, HOST, Executor, TaskError, Taskflow
from ..configs.base import ModelConfig
from ..data.pipeline import DataConfig, Prefetcher, SyntheticLM
from ..distributed.sharding import ShardCtx
from ..models import lm
from ..optim.adamw import OptConfig, init_opt_state
from .checkpoint import CheckpointManager
from .train_step import make_train_step

__all__ = ["TrainerConfig", "Trainer"]


@dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_every: int = 50
    log_every: int = 10
    prefetch_depth: int = 2
    max_restarts: int = 2
    microbatches: Optional[int] = 1
    fail_at_step: Optional[int] = None     # failure injection (tests)
    seed: int = 0


class Trainer:
    def __init__(self, cfg: ModelConfig, tc: TrainerConfig,
                 batch: int, seq_len: int,
                 opt: Optional[OptConfig] = None,
                 ctx: Optional[ShardCtx] = None,
                 ckpt_dir: Optional[str] = None,
                 executor: Optional[Executor] = None):
        self.cfg = cfg
        self.tc = tc
        self.opt = opt or OptConfig()
        self.ctx = ctx or ShardCtx(mesh=None)
        self.ckpt = CheckpointManager(ckpt_dir) if ckpt_dir else None
        self._own_executor = executor is None
        self.executor = executor or Executor(
            domains={HOST: 2, ACCEL: 1},
            devices={ACCEL: jax.devices()[:1]})
        self.data = SyntheticLM(DataConfig(
            vocab_size=cfg.vocab_size, seq_len=seq_len, global_batch=batch,
            seed=tc.seed, frontend_tokens=(cfg.frontend_tokens if
                                           cfg.frontend != "none" else 0),
            d_model=cfg.d_model))
        step_fn, _, _ = make_train_step(cfg, self.ctx, self.opt,
                                        microbatches=tc.microbatches)
        self._step_fn = jax.jit(step_fn, donate_argnums=(0, 1))
        self.history: List[Dict[str, float]] = []
        self._failed_once = False

    # ------------------------------------------------------------------ state
    def init_state(self):
        params = lm.init_params(self.cfg, jax.random.PRNGKey(self.tc.seed))
        opt_state = init_opt_state(params, self.opt)
        return {"params": params, "opt": opt_state, "step": 0}

    # ------------------------------------------------------------------- run
    def run(self) -> Dict[str, Any]:
        state = self.init_state()
        start = 0
        if self.ckpt is not None:
            s, restored = self.ckpt.restore_latest(
                {"params": state["params"], "opt": state["opt"]})
            if s is not None:
                state["params"] = restored["params"]
                state["opt"] = restored["opt"]
                state["step"] = s
                start = s
        restarts = 0
        while True:
            try:
                self._run_taskflow(state)
                break
            except TaskError as e:
                restarts += 1
                if restarts > self.tc.max_restarts or self.ckpt is None:
                    raise
                s, restored = self.ckpt.restore_latest(
                    {"params": state["params"], "opt": state["opt"]})
                if s is None:
                    state = self.init_state()
                else:
                    state["params"] = restored["params"]
                    state["opt"] = restored["opt"]
                    state["step"] = s
        if self._own_executor:
            self.executor.shutdown()
        return {"state": state, "history": self.history,
                "restarts": restarts}

    # ------------------------------------------------- the conditional TDG
    def _run_taskflow(self, state: Dict[str, Any]) -> None:
        tc = self.tc
        prefetcher = Prefetcher(self.data.batch_at, tc.prefetch_depth,
                                start_step=state["step"],
                                executor=self.executor)
        tf = Taskflow("trainer")

        t_init = tf.static(lambda: None, name="init")

        # executor-pipeline prefetch: start() re-arms the prefetcher's
        # produce/stage DataPipeline on the shared executor whenever queue
        # capacity is free; the pipeline itself drains for back-pressure
        t_prefetch = tf.static(prefetcher.start, name="prefetch",
                               domain=HOST)

        def device_step():
            step = state["step"]
            if tc.fail_at_step is not None and step == tc.fail_at_step \
                    and not self._failed_once:
                self._failed_once = True
                raise RuntimeError(f"injected failure at step {step}")
            _, batch = prefetcher.get()
            batch = {k: jax.numpy.asarray(v) for k, v in batch.items()}
            params, opt_state, metrics = self._step_fn(
                state["params"], state["opt"], batch)
            state["params"], state["opt"] = params, opt_state
            state["step"] = step + 1
            if step % tc.log_every == 0 or step + 1 == tc.total_steps:
                m = {k: float(v) for k, v in metrics.items()}
                m["step"] = step
                self.history.append(m)

        t_step = tf.static(device_step, name="train-step", domain=ACCEL)

        def ckpt_due() -> int:
            due = (self.ckpt is not None
                   and state["step"] % tc.ckpt_every == 0)
            return 0 if due else 1

        t_ckpt_cond = tf.condition(ckpt_due, name="ckpt?")

        def save(sf):
            # snapshot on the critical path, write detached (async ckpt)
            step = state["step"]
            snap = jax.device_get({"params": state["params"],
                                   "opt": state["opt"]})
            sf.static(lambda: self.ckpt.save(step, snap), name="ckpt-write")
            sf.detach()

        t_save = tf.dynamic(save, name="ckpt-save", domain=HOST)

        def loop() -> int:
            return 1 if state["step"] >= tc.total_steps else 0

        t_loop = tf.condition(loop, name="loop?")
        t_done = tf.static(lambda: prefetcher.stop(), name="done")

        t_init.precede(t_prefetch)
        t_prefetch.precede(t_step)
        t_step.precede(t_ckpt_cond)
        t_ckpt_cond.precede(t_save, t_loop)   # 0 -> save, 1 -> skip
        t_save.precede(t_loop)
        t_loop.precede(t_prefetch, t_done)    # 0 -> continue, 1 -> done

        self.executor.run(tf).wait()
        if self.ckpt is not None and state["step"] >= tc.total_steps:
            self.ckpt.save(state["step"],
                           jax.device_get({"params": state["params"],
                                           "opt": state["opt"]}))
