from . import attention, layers, lm, mamba, mlp, moe
