"""Shared layer primitives: norms, RoPE, positional embeddings, init."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig

__all__ = ["rms_norm", "rope", "sinusoidal_positions", "dense_init",
           "normal_init", "dtype_of"]


def dtype_of(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16,
            "float16": jnp.float16}[name]


def normal_init(key, shape, scale: float = 0.02, dtype=jnp.float32):
    return (jax.random.normal(key, shape) * scale).astype(dtype)


def dense_init(key, shape, dtype=jnp.float32):
    """Fan-in scaled init (LeCun normal)."""
    fan_in = shape[0] if len(shape) == 2 else int(np.prod(shape[:-1]))
    scale = 1.0 / max(1.0, fan_in) ** 0.5
    return (jax.random.normal(key, shape) * scale).astype(dtype)


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * scale.astype(jnp.float32)).astype(dt)


def _rope_freqs(hd: int, theta: float) -> np.ndarray:
    return 1.0 / (theta ** (np.arange(0, hd, 2, dtype=np.float32) / hd))


def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """Rotary embedding. x: (..., S, H, hd); positions: (..., S) int32."""
    hd = x.shape[-1]
    freqs = jnp.asarray(_rope_freqs(hd, theta))                  # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs    # (..,S,hd/2)
    cos = jnp.cos(angles)[..., None, :]                          # (..,S,1,hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin,
                           x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(positions: jnp.ndarray, d_model: int) -> jnp.ndarray:
    """(..., S) int32 -> (..., S, D) sinusoidal embedding (musicgen)."""
    half = d_model // 2
    freqs = jnp.exp(-np.log(10_000.0) * jnp.arange(half, dtype=jnp.float32)
                    / half)
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)
