"""Mamba1 (selective scan) and Mamba2 (SSD) blocks.

Mamba1 (falcon-mamba): chunked selective scan — within a chunk the linear
recurrence h_t = a_t*h_{t-1} + b_t runs as a log-depth associative scan;
chunks are linked by a lax.scan carry. Activation memory is O(S_chunk * dI * N)
instead of O(S * dI * N).

Mamba2 (zamba2): the **SSD dual form** — scalar-per-head decay turns the
recurrence into (i) a causal matmul within each chunk (MXU-friendly) and
(ii) a tiny cross-chunk state recurrence. This is the TPU-native adaptation:
the GPU implementation's fused scan kernel becomes matmuls + one short scan.

Both blocks expose ``*_step`` single-token decode paths carrying
(conv_buffer, ssm_state) — this is what makes 500k-token decoding O(1) per
step (no KV cache), the reason long_500k is assigned to these archs.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..distributed.sharding import constrain
from .layers import dense_init, dtype_of, normal_init, rms_norm

__all__ = ["init_mamba", "mamba_forward", "mamba_step", "init_mamba_state"]


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal conv. x: (B,S,C); w: (C,K); b: (C,)."""
    B, S, C = x.shape
    K = w.shape[1]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    y = sum(xp[:, k:k + S, :] * w[:, k] for k in range(K))
    return y + b


def _conv_step(buf: jnp.ndarray, x1: jnp.ndarray, w: jnp.ndarray,
               b: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Single-token conv. buf: (B,K-1,C) past inputs; x1: (B,C)."""
    window = jnp.concatenate([buf, x1[:, None, :]], axis=1)   # (B,K,C)
    y = jnp.einsum("bkc,ck->bc", window, w) + b
    return y, window[:, 1:, :]


# ===================================================================== Mamba1
def _init_m1(key, cfg: ModelConfig) -> Dict[str, jnp.ndarray]:
    D, dI, N, R, K = (cfg.d_model, cfg.d_inner, cfg.ssm_state,
                      cfg.dt_rank_, cfg.ssm_conv)
    pdt = dtype_of(cfg.param_dtype)
    ks = jax.random.split(key, 6)
    A = jnp.tile(jnp.arange(1, N + 1, dtype=jnp.float32)[None, :], (dI, 1))
    return {
        "in_proj": dense_init(ks[0], (D, 2 * dI), pdt),
        "conv_w": normal_init(ks[1], (dI, K), 0.2, pdt),
        "conv_b": jnp.zeros((dI,), pdt),
        "x_proj": dense_init(ks[2], (dI, R + 2 * N), pdt),
        "dt_proj": normal_init(ks[3], (R, dI), R ** -0.5, pdt),
        "dt_bias": jnp.log(jnp.expm1(  # softplus^-1 of U(1e-3, 1e-1)
            jax.random.uniform(ks[4], (dI,), minval=1e-3, maxval=1e-1)
        )).astype(pdt),
        "A_log": jnp.log(A),                      # fp32: recurrence stability
        "ssm_D": jnp.ones((dI,), jnp.float32),
        "out_proj": dense_init(ks[5], (dI, D), pdt),
    }


def _m1_scan(dt, A, Bc, Cc, xh, h0, chunk: int, constrain_tp: bool = False):
    """Chunked selective scan. dt,xh: (B,S,dI); A: (dI,N); Bc,Cc: (B,S,N);
    h0: (B,dI,N) fp32. Returns y (B,S,dI) and final state."""
    B_, S, dI = xh.shape
    N = A.shape[1]
    chunk = min(chunk, S)
    if S % chunk:
        chunk = S  # smoke shapes: fall back to one chunk
    nch = S // chunk

    def to_chunks(t):
        return t.reshape(B_, nch, chunk, *t.shape[2:]).swapaxes(0, 1)

    def chunk_step(h, inp):
        dt_c, x_c, b_c, c_c = inp
        if constrain_tp:
            # §Perf H4: keep the channel dim sharded through the chunk
            # body — GSPMD otherwise replicates the (B,c,dI,N) tensors
            dt_c = constrain(dt_c, "dp", None, "tp")
            x_c = constrain(x_c, "dp", None, "tp")
        a = jnp.exp(dt_c[..., None] * A)                       # (B,c,dI,N)
        b = (dt_c * x_c)[..., None] * b_c[:, :, None, :]       # (B,c,dI,N)
        if constrain_tp:
            a = constrain(a, "dp", None, "tp", None)
            b = constrain(b, "dp", None, "tp", None)

        def comb(l, r):
            al, bl = l
            ar, br = r
            return al * ar, ar * bl + br

        aa, bb = jax.lax.associative_scan(comb, (a, b), axis=1)
        hs = aa * h[:, None] + bb                              # (B,c,dI,N)
        if constrain_tp:
            hs = constrain(hs, "dp", None, "tp", None)
        y_c = jnp.einsum("bcdn,bcn->bcd", hs, c_c)
        return hs[:, -1], y_c

    hT, ys = jax.lax.scan(
        chunk_step, h0,
        (to_chunks(dt.astype(jnp.float32)), to_chunks(xh.astype(jnp.float32)),
         to_chunks(Bc.astype(jnp.float32)), to_chunks(Cc.astype(jnp.float32))))
    y = ys.swapaxes(0, 1).reshape(B_, S, dI)
    return y, hT


def _m1_forward(p, x, cfg: ModelConfig, h0=None, return_state=False):
    B, S, D = x.shape
    dI, N, R = cfg.d_inner, cfg.ssm_state, cfg.dt_rank_
    cdt = dtype_of(cfg.compute_dtype)
    xz = x @ p["in_proj"].astype(cdt)
    xh, z = jnp.split(xz, 2, axis=-1)
    xh = constrain(xh, "dp", None, "tp")
    xh = jax.nn.silu(_causal_conv(xh, p["conv_w"].astype(cdt),
                                  p["conv_b"].astype(cdt)))
    proj = xh @ p["x_proj"].astype(cdt)
    dtr, Bc, Cc = jnp.split(proj, [R, R + N], axis=-1)
    dt = jax.nn.softplus(
        dtr @ p["dt_proj"].astype(cdt)
        + p["dt_bias"].astype(jnp.float32))                    # (B,S,dI) f32
    dt = constrain(dt, "dp", None, "tp")
    A = -jnp.exp(p["A_log"])                                   # (dI,N) f32
    if h0 is None:
        h0 = jnp.zeros((B, dI, N), jnp.float32)
    y, hT = _m1_scan(dt, A, Bc, Cc, xh, h0, cfg.ssm_chunk,
                     constrain_tp=cfg.ssm_scan_constrain)
    y = y + p["ssm_D"] * xh.astype(jnp.float32)
    y = (y.astype(cdt)) * jax.nn.silu(z)
    out = y @ p["out_proj"].astype(cdt)
    out = constrain(out, "dp", None, None)
    if return_state:
        # conv tail: last K-1 pre-conv inputs (recompute projection tail)
        tail = (x[:, -(cfg.ssm_conv - 1):, :]
                @ p["in_proj"].astype(cdt))[..., :dI]
        return out, (tail, hT)
    return out


def _m1_step(p, x1, cfg: ModelConfig, state):
    """x1: (B, D); state = (conv_buf (B,K-1,dI), h (B,dI,N))."""
    conv_buf, h = state
    cdt = dtype_of(cfg.compute_dtype)
    dI, N, R = cfg.d_inner, cfg.ssm_state, cfg.dt_rank_
    xz = x1 @ p["in_proj"].astype(cdt)
    xh, z = jnp.split(xz, 2, axis=-1)
    xh, conv_buf = _conv_step(conv_buf.astype(cdt), xh,
                              p["conv_w"].astype(cdt),
                              p["conv_b"].astype(cdt))
    xh = jax.nn.silu(xh)
    proj = xh @ p["x_proj"].astype(cdt)
    dtr, Bc, Cc = jnp.split(proj, [R, R + N], axis=-1)
    dt = jax.nn.softplus(dtr @ p["dt_proj"].astype(cdt)
                         + p["dt_bias"].astype(jnp.float32))   # (B,dI)
    A = -jnp.exp(p["A_log"])
    a = jnp.exp(dt[..., None] * A)                             # (B,dI,N)
    h = a * h + (dt * xh.astype(jnp.float32))[..., None] \
        * Bc.astype(jnp.float32)[:, None, :]
    y = jnp.einsum("bdn,bn->bd", h, Cc.astype(jnp.float32)) \
        + p["ssm_D"] * xh.astype(jnp.float32)
    y = y.astype(cdt) * jax.nn.silu(z)
    return y @ p["out_proj"].astype(cdt), (conv_buf, h)


# ===================================================================== Mamba2
def _init_m2(key, cfg: ModelConfig) -> Dict[str, jnp.ndarray]:
    D, dI, N, K = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_conv
    nh = cfg.ssm_heads
    pdt = dtype_of(cfg.param_dtype)
    ks = jax.random.split(key, 4)
    conv_dim = dI + 2 * N
    return {
        "in_proj": dense_init(ks[0], (D, 2 * dI + 2 * N + nh), pdt),
        "conv_w": normal_init(ks[1], (conv_dim, K), 0.2, pdt),
        "conv_b": jnp.zeros((conv_dim,), pdt),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nh)).astype(jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(
            jax.random.uniform(ks[2], (nh,), minval=1e-3, maxval=1e-1)
        )).astype(jnp.float32),
        "ssm_D": jnp.ones((nh,), jnp.float32),
        "ssm_norm": jnp.ones((dI,), pdt),
        "out_proj": dense_init(ks[3], (dI, D), pdt),
    }


def _ssd_scan(xh, dt, A, Bc, Cc, h0, chunk: int):
    """SSD dual form. xh: (B,S,nh,hp); dt: (B,S,nh) f32; A: (nh,) f32;
    Bc,Cc: (B,S,N); h0: (B,nh,hp,N) f32. Returns y (B,S,nh,hp), final h."""
    B_, S, nh, hp = xh.shape
    N = Bc.shape[-1]
    chunk = min(chunk, S)
    if S % chunk:
        chunk = S
    nch = S // chunk

    def to_chunks(t):
        return t.reshape(B_, nch, chunk, *t.shape[2:]).swapaxes(0, 1)

    loga = dt * A                                              # (B,S,nh) <= 0

    def chunk_step(h, inp):
        x_c, dt_c, la_c, b_c, c_c = inp        # (B,c,nh,hp) (B,c,nh) (B,c,N)
        L = jnp.cumsum(la_c, axis=1)                           # (B,c,nh)
        # intra-chunk: causal "attention" with decay
        seg = L[:, :, None, :] - L[:, None, :, :]              # (B,c,c,nh)
        tri = jnp.tril(jnp.ones((chunk, chunk), bool))
        decay = jnp.where(tri[None, :, :, None], jnp.exp(seg), 0.0)
        cb = jnp.einsum("btn,bsn->bts", c_c, b_c)              # (B,c,c)
        w = cb[..., None] * decay * dt_c[:, None, :, :]        # (B,t,s,nh)
        y = jnp.einsum("btsh,bshp->bthp", w, x_c)
        # inter-chunk: contribution of carried state
        y = y + jnp.einsum("btn,bhpn->bthp", c_c, h) \
            * jnp.exp(L)[..., None]
        # chunk state: sum_s exp(L_last - L_s) dt_s x_s B_s^T
        rdecay = jnp.exp(L[:, -1:, :] - L)                     # (B,c,nh)
        hc = jnp.einsum("bshp,bsn->bhpn",
                        x_c * (dt_c * rdecay)[..., None], b_c)
        h = h * jnp.exp(L[:, -1])[..., None, None] + hc
        return h, y

    hT, ys = jax.lax.scan(
        chunk_step, h0,
        (to_chunks(xh.astype(jnp.float32)), to_chunks(dt),
         to_chunks(loga), to_chunks(Bc.astype(jnp.float32)),
         to_chunks(Cc.astype(jnp.float32))))
    y = ys.swapaxes(0, 1).reshape(B_, S, nh, hp)
    return y, hT


def _m2_forward(p, x, cfg: ModelConfig, h0=None, return_state=False):
    B, S, D = x.shape
    dI, N, nh, hp = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    cdt = dtype_of(cfg.compute_dtype)
    zxbcdt = x @ p["in_proj"].astype(cdt)
    z, xh, Bc, Cc, dt = jnp.split(
        zxbcdt, [dI, 2 * dI, 2 * dI + N, 2 * dI + 2 * N], axis=-1)
    xbc = jnp.concatenate([xh, Bc, Cc], axis=-1)
    xbc = jax.nn.silu(_causal_conv(xbc, p["conv_w"].astype(cdt),
                                   p["conv_b"].astype(cdt)))
    xh, Bc, Cc = jnp.split(xbc, [dI, dI + N], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,S,nh)
    A = -jnp.exp(p["A_log"])                                     # (nh,)
    xh = constrain(xh, "dp", None, "tp")
    xhh = xh.reshape(B, S, nh, hp)
    if h0 is None:
        h0 = jnp.zeros((B, nh, hp, N), jnp.float32)
    y, hT = _ssd_scan(xhh, dt, A, Bc, Cc, h0, cfg.ssm_chunk)
    y = y + p["ssm_D"][:, None] * xhh.astype(jnp.float32)
    y = y.reshape(B, S, dI).astype(cdt)
    y = rms_norm(y * jax.nn.silu(z), p["ssm_norm"], cfg.rms_eps)
    out = y @ p["out_proj"].astype(cdt)
    out = constrain(out, "dp", None, None)
    if return_state:
        tail = (x[:, -(cfg.ssm_conv - 1):, :] @ p["in_proj"].astype(cdt)
                )[..., dI:2 * dI + 2 * N]
        return out, (tail, hT)
    return out


def _m2_step(p, x1, cfg: ModelConfig, state):
    conv_buf, h = state
    B = x1.shape[0]
    dI, N, nh, hp = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    cdt = dtype_of(cfg.compute_dtype)
    zxbcdt = x1 @ p["in_proj"].astype(cdt)
    z, xh, Bc, Cc, dt = jnp.split(
        zxbcdt, [dI, 2 * dI, 2 * dI + N, 2 * dI + 2 * N], axis=-1)
    xbc = jnp.concatenate([xh, Bc, Cc], axis=-1)
    xbc, conv_buf = _conv_step(conv_buf.astype(cdt), xbc,
                               p["conv_w"].astype(cdt),
                               p["conv_b"].astype(cdt))
    xbc = jax.nn.silu(xbc)
    xh, Bc, Cc = jnp.split(xbc, [dI, dI + N], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,nh)
    A = -jnp.exp(p["A_log"])
    a = jnp.exp(dt * A)                                          # (B,nh)
    xhh = xh.reshape(B, nh, hp).astype(jnp.float32)
    h = a[..., None, None] * h \
        + (dt[..., None] * xhh)[..., None] \
        * Bc.astype(jnp.float32)[:, None, None, :]
    y = jnp.einsum("bhpn,bn->bhp", h, Cc.astype(jnp.float32)) \
        + p["ssm_D"][:, None] * xhh
    y = y.reshape(B, dI).astype(cdt)
    y = rms_norm(y * jax.nn.silu(z), p["ssm_norm"], cfg.rms_eps)
    return y @ p["out_proj"].astype(cdt), (conv_buf, h)


# ==================================================================== dispatch
def init_mamba(key, cfg: ModelConfig) -> Dict[str, jnp.ndarray]:
    return _init_m1(key, cfg) if cfg.ssm_version == 1 else _init_m2(key, cfg)


def mamba_forward(p, x, cfg: ModelConfig, h0=None, return_state=False):
    f = _m1_forward if cfg.ssm_version == 1 else _m2_forward
    return f(p, x, cfg, h0, return_state)


def mamba_step(p, x1, cfg: ModelConfig, state):
    f = _m1_step if cfg.ssm_version == 1 else _m2_step
    return f(p, x1, cfg, state)


def init_mamba_state(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    """(conv_buf, h) zeros for decode."""
    K = cfg.ssm_conv
    if cfg.ssm_version == 1:
        return (jnp.zeros((batch, K - 1, cfg.d_inner), dtype),
                jnp.zeros((batch, cfg.d_inner, cfg.ssm_state), jnp.float32))
    conv_dim = cfg.d_inner + 2 * cfg.ssm_state
    return (jnp.zeros((batch, K - 1, conv_dim), dtype),
            jnp.zeros((batch, cfg.ssm_heads, cfg.ssm_head_dim,
                       cfg.ssm_state), jnp.float32))
