"""Unified decoder LM covering all 10 assigned architectures.

Families:
  dense / audio / vlm : [ln -> GQA attention] + [ln -> (SwiGLU|GELU) MLP]
  moe                 : attention + MoE FFN (optional shared experts /
                        dense-residual path)
  ssm                 : [ln -> Mamba1] blocks, attention-free
  hybrid (zamba2)     : groups of `hybrid_attn_every` Mamba2 layers, each
                        group followed by ONE SHARED transformer block whose
                        weights are reused across groups, fed with
                        concat([hidden, embeddings]) @ fused_proj

Layer stacks are scan-over-layers (stacked params, `jax.lax.scan`) with
optional remat — this keeps HLO size O(1) in depth, which is what makes the
512-device dry-run compiles tractable.

Entry points (all pure):
  init_params / forward / loss_fn                      (training)
  init_cache / prefill / decode_step                   (serving)
Modality frontends are STUBS per the assignment: `frontend_embeds`
(B, frontend_tokens, d_model) arrive precomputed (see launch.dryrun
input_specs) and are prepended to the token embeddings.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from jax.sharding import PartitionSpec as P

from ..configs.base import ModelConfig
from ..distributed.sharding import (constrain, manual_serve_map,
                                    serve_attn_sharded, serve_kv_cache_spec,
                                    serve_param_specs, serve_pool_spec,
                                    serve_tp_size)
from .attention import (attention, decode_attention, decode_attention_rows,
                        init_attention)
from .layers import dtype_of, normal_init, rms_norm, sinusoidal_positions
from .mamba import init_mamba, init_mamba_state, mamba_forward, mamba_step
from .mlp import init_mlp, mlp
from .moe import init_moe, moe_layer

__all__ = ["init_params", "forward", "loss_fn", "init_cache", "prefill",
           "prefill_window_paged", "decode_step", "decode_step_paged",
           "decode_step_slots", "decode_chunk_paged", "decode_chunk_slots"]


# ------------------------------------------------------------------ init
def _init_block(key, cfg: ModelConfig) -> Dict[str, Any]:
    """One repeated layer's params (flat dict: path-based sharding rules)."""
    pdt = dtype_of(cfg.param_dtype)
    ks = jax.random.split(key, 3)
    p: Dict[str, Any] = {"ln1": jnp.ones((cfg.d_model,), pdt)}
    if cfg.ssm:
        p.update(init_mamba(ks[0], cfg))
        return p
    p.update(init_attention(ks[0], cfg))
    p["ln2"] = jnp.ones((cfg.d_model,), pdt)
    if cfg.moe:
        p.update(init_moe(ks[1], cfg))
    else:
        p.update(init_mlp(ks[1], cfg))
    return p


def _init_shared_block(key, cfg: ModelConfig) -> Dict[str, Any]:
    """zamba2 shared transformer block (reused across groups)."""
    pdt = dtype_of(cfg.param_dtype)
    ks = jax.random.split(key, 3)
    from .layers import dense_init
    p = {"fused_proj": dense_init(ks[0], (2 * cfg.d_model, cfg.d_model), pdt),
         "ln1": jnp.ones((cfg.d_model,), pdt),
         "ln2": jnp.ones((cfg.d_model,), pdt)}
    p.update(init_attention(ks[1], cfg))
    p.update(init_mlp(ks[2], cfg))
    return p


def init_params(cfg: ModelConfig, key) -> Dict[str, Any]:
    pdt = dtype_of(cfg.param_dtype)
    k_embed, k_blocks, k_head, k_shared = jax.random.split(key, 4)
    Vp = cfg.padded_vocab
    params: Dict[str, Any] = {
        "embed": normal_init(k_embed, (Vp, cfg.d_model), 0.02, pdt),
        "final_norm": jnp.ones((cfg.d_model,), pdt),
    }
    L = cfg.num_layers
    if cfg.hybrid_attn_every:
        every = cfg.hybrid_attn_every
        G, tail = L // every, L % every
        kg = jax.random.split(k_blocks, G * every).reshape(G, every, 2)
        params["gblocks"] = jax.vmap(jax.vmap(
            lambda k: _init_block(k, cfg)))(kg)
        if tail:
            kt = jax.random.split(jax.random.fold_in(k_blocks, 1), tail)
            params["tail_blocks"] = jax.vmap(
                lambda k: _init_block(k, cfg))(kt)
        params["shared_block"] = _init_shared_block(k_shared, cfg)
    else:
        kb = jax.random.split(k_blocks, L)
        params["blocks"] = jax.vmap(lambda k: _init_block(k, cfg))(kb)
    if not cfg.tie_embeddings:
        params["lm_head"] = normal_init(k_head, (cfg.d_model, Vp), 0.02, pdt)
    return params


# ------------------------------------------------------------------ blocks
def _block_apply(p, x, cfg: ModelConfig, positions):
    """One layer, full-sequence (train/prefill w/o cache). Returns (x, aux)."""
    aux = jnp.zeros((), jnp.float32)
    h = rms_norm(x, p["ln1"], cfg.rms_eps)
    if cfg.ssm:
        x = x + mamba_forward(p, h, cfg)
        return x, aux
    x = x + attention(p, h, cfg, positions)
    h2 = rms_norm(x, p["ln2"], cfg.rms_eps)
    if cfg.moe:
        y, aux = moe_layer(p, h2, cfg)
        x = x + y
    else:
        x = x + mlp(p, h2, cfg)
    return x, aux


def _shared_block_apply(p, x, x0, cfg: ModelConfig, positions):
    """zamba2 shared block: concat([x, x0]) -> proj -> attn -> mlp."""
    cdt = dtype_of(cfg.compute_dtype)
    h = jnp.concatenate([x, x0], axis=-1) @ p["fused_proj"].astype(cdt)
    a = attention(p, rms_norm(h, p["ln1"], cfg.rms_eps), cfg, positions)
    h = h + a
    h = h + mlp(p, rms_norm(h, p["ln2"], cfg.rms_eps), cfg)
    return x + h


def _stack_scan(stacked, x, cfg, positions, remat: bool):
    """lax.scan over a stacked layer dict; accumulates MoE aux."""

    def body(carry, layer_p):
        xx, aux = carry
        xx = constrain(xx, "dp", None, None)
        xx, a = _block_apply(layer_p, xx, cfg, positions)
        return (xx, aux + a), None

    fn = jax.checkpoint(body,
                        policy=jax.checkpoint_policies.nothing_saveable) \
        if remat else body
    (x, aux), _ = jax.lax.scan(fn, (x, jnp.zeros((), jnp.float32)), stacked)
    return x, aux


# ------------------------------------------------------------------ forward
def _embed(cfg: ModelConfig, params, tokens, frontend_embeds):
    cdt = dtype_of(cfg.compute_dtype)
    x = jnp.take(params["embed"], tokens, axis=0).astype(cdt)
    if cfg.frontend != "none":
        if frontend_embeds is None:
            raise ValueError(f"{cfg.name} requires frontend_embeds "
                             f"({cfg.frontend} stub)")
        x = jnp.concatenate([frontend_embeds.astype(cdt), x], axis=1)
    B, S = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    if cfg.pos_emb == "sinusoidal":
        x = x + sinusoidal_positions(positions, cfg.d_model).astype(cdt)
    return x, positions


def forward(cfg: ModelConfig, params, tokens,
            frontend_embeds=None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Teacher-forced logits over the full (frontend + token) sequence.
    Returns (logits_f32 (B, S_total, padded_vocab), moe_aux_loss)."""
    x, positions = _embed(cfg, params, tokens, frontend_embeds)
    x = constrain(x, "dp", None, None)
    aux = jnp.zeros((), jnp.float32)
    if cfg.hybrid_attn_every:
        x0 = x
        sb = params["shared_block"]

        def group(carry, gp):
            xx, aux = carry
            (xx, a), _ = jax.lax.scan(
                lambda c, lp: (( _block_apply(lp, c[0], cfg, positions)[0],
                                 c[1]), None),
                (xx, aux), gp)
            xx = _shared_block_apply(sb, xx, x0, cfg, positions)
            return (xx, a), None

        gfn = jax.checkpoint(group,
                             policy=jax.checkpoint_policies.nothing_saveable)\
            if cfg.remat else group
        (x, aux), _ = jax.lax.scan(gfn, (x, aux), params["gblocks"])
        if "tail_blocks" in params:
            x, a2 = _stack_scan(params["tail_blocks"], x, cfg, positions,
                                cfg.remat)
            aux = aux + a2
    else:
        x, aux = _stack_scan(params["blocks"], x, cfg, positions, cfg.remat)
    x = rms_norm(x, params["final_norm"], cfg.rms_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x,
                        head.astype(dtype_of(cfg.compute_dtype)),
                        preferred_element_type=jnp.float32)
    logits = constrain(logits, "dp", None, "tp")
    return logits, aux


def loss_fn(cfg: ModelConfig, params, batch) -> Tuple[jnp.ndarray, Dict]:
    """Next-token cross-entropy (fp32, padded-vocab masked) + MoE aux."""
    tokens = batch["tokens"]
    fe = batch.get("frontend_embeds")
    logits, aux = forward(cfg, params, tokens, fe)
    F = cfg.frontend_tokens if cfg.frontend != "none" else 0
    S = tokens.shape[1]
    if F > 0:
        pred = logits[:, F - 1:F + S - 1]     # predict t_0..t_{S-1}
        labels = tokens
    else:
        pred = logits[:, :S - 1]
        labels = tokens[:, 1:]
    vmask = jnp.arange(cfg.padded_vocab) < cfg.vocab_size
    pred = jnp.where(vmask, pred, -1e30)
    logz = jax.nn.logsumexp(pred, axis=-1)
    # NOTE: gather(labels) over the vocab-sharded logits would force an
    # all-gather of the full (B,S,V) tensor; the equality-mask reduction
    # keeps the contraction sharded over `tp` (saved ~24GB/dev, see
    # EXPERIMENTS.md §Perf iteration log).
    onehot = labels[..., None] == jnp.arange(cfg.padded_vocab,
                                             dtype=labels.dtype)
    gold = jnp.sum(jnp.where(onehot, pred, 0.0), axis=-1)
    ce = jnp.mean(logz - gold)
    zloss = 1e-4 * jnp.mean(jnp.square(logz))
    total = ce + zloss + cfg.router_aux_weight * aux
    return total, {"ce": ce, "aux": aux, "zloss": zloss,
                   "ppl_proxy": jnp.exp(jnp.minimum(ce, 20.0))}


# ------------------------------------------------------------------ serving
def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Dict[str, Any]:
    """Decode cache pytree. Attention: (L, B, KV, S_max, hd) KV tensors,
    sequence dim shardable over `sp`. SSM: per-layer (conv_buf, h)."""
    cdt = dtype_of(cfg.compute_dtype)
    L = cfg.num_layers
    cache: Dict[str, Any] = {"pos": jnp.zeros((), jnp.int32)}
    if cfg.hybrid_attn_every:
        every = cfg.hybrid_attn_every
        G, tail = L // every, L % every
        conv, h = init_mamba_state(cfg, batch, cdt)
        cache["g_ssm"] = (
            jnp.tile(conv[None, None], (G, every) + (1,) * conv.ndim),
            jnp.tile(h[None, None], (G, every) + (1,) * h.ndim))
        if tail:
            cache["tail_ssm"] = (
                jnp.tile(conv[None], (tail,) + (1,) * conv.ndim),
                jnp.tile(h[None], (tail,) + (1,) * h.ndim))
        KV, hd = cfg.num_kv_heads, cfg.hd
        cache["shared_k"] = jnp.zeros((G, batch, KV, max_len, hd), cdt)
        cache["shared_v"] = jnp.zeros((G, batch, KV, max_len, hd), cdt)
    elif cfg.ssm:
        conv, h = init_mamba_state(cfg, batch, cdt)
        cache["ssm"] = (
            jnp.tile(conv[None], (L,) + (1,) * conv.ndim),
            jnp.tile(h[None], (L,) + (1,) * h.ndim))
    else:
        KV, hd = cfg.num_kv_heads, cfg.hd
        cache["k"] = jnp.zeros((L, batch, KV, max_len, hd), cdt)
        cache["v"] = jnp.zeros((L, batch, KV, max_len, hd), cdt)
    return cache


def _block_decode(p, x1, cfg: ModelConfig, layer_cache, pos, attn_fn=None):
    """One layer, one token. x1: (B, D). Returns (x1, new_layer_cache).

    ``attn_fn(p, h1, layer_cache) -> (y, new_layer_cache)`` swaps the
    attention/cache implementation (the paged path passes one reading
    through a block table); everything around it — ln1, residuals, ln2,
    MoE/MLP — is shared, so the paged and contiguous decode paths cannot
    structurally diverge."""
    h = rms_norm(x1, p["ln1"], cfg.rms_eps)
    if cfg.ssm:
        y, st = mamba_step(p, h, cfg, layer_cache)
        return x1 + y, st
    if attn_fn is None:
        ck, cv = layer_cache
        y, ck, cv = decode_attention(p, h[:, None, :], cfg, ck, cv, pos)
        layer_cache = (ck, cv)
    else:
        y, layer_cache = attn_fn(p, h[:, None, :], layer_cache)
    x1 = x1 + y[:, 0]
    h2 = rms_norm(x1, p["ln2"], cfg.rms_eps)
    if cfg.moe:
        y2, _ = moe_layer(p, h2[:, None, :], cfg)
        x1 = x1 + y2[:, 0]
    else:
        x1 = x1 + mlp(p, h2[:, None, :], cfg)[:, 0]
    return x1, layer_cache


def _shared_block_decode(p, x1, x0, cfg, ck, cv, pos):
    cdt = dtype_of(cfg.compute_dtype)
    h = jnp.concatenate([x1, x0], axis=-1) @ p["fused_proj"].astype(cdt)
    a, ck, cv = decode_attention(
        p, rms_norm(h, p["ln1"], cfg.rms_eps)[:, None, :], cfg, ck, cv, pos)
    h = h + a[:, 0]
    h = h + mlp(p, rms_norm(h, p["ln2"], cfg.rms_eps)[:, None, :],
                cfg)[:, 0]
    return x1 + h, ck, cv


def decode_step(cfg: ModelConfig, params, cache, token
                ) -> Tuple[jnp.ndarray, Dict[str, Any]]:
    """One decode step. token: (B,) int32 current input token.
    Returns (logits (B, padded_vocab) f32, updated cache)."""
    cdt = dtype_of(cfg.compute_dtype)
    pos = cache["pos"]
    x1 = jnp.take(params["embed"], token, axis=0).astype(cdt)
    if cfg.pos_emb == "sinusoidal":
        x1 = x1 + sinusoidal_positions(pos[None], cfg.d_model
                                       ).astype(cdt)[0]
    new_cache = dict(cache)
    if cfg.hybrid_attn_every:
        x0 = x1
        sb = params["shared_block"]

        def group(carry, xs):
            xx = carry
            gp, g_ssm, ck, cv = xs

            def layer(c, l_xs):
                lp, st = l_xs
                c, st = _block_decode(lp, c, cfg, st, pos)
                return c, st

            xx, g_ssm = jax.lax.scan(layer, xx, (gp, g_ssm))
            xx, ck, cv = _shared_block_decode(sb, xx, x0, cfg, ck, cv, pos)
            return xx, (g_ssm, ck, cv)

        x1, (g_ssm, sk, sv) = jax.lax.scan(
            group, x1, (params["gblocks"], cache["g_ssm"],
                        cache["shared_k"], cache["shared_v"]))
        new_cache["g_ssm"], new_cache["shared_k"], new_cache["shared_v"] = \
            g_ssm, sk, sv
        if "tail_blocks" in params:
            def layer(c, l_xs):
                lp, st = l_xs
                return _block_decode(lp, c, cfg, st, pos)

            x1, tail = jax.lax.scan(layer, x1,
                                    (params["tail_blocks"],
                                     cache["tail_ssm"]))
            new_cache["tail_ssm"] = tail
    elif cfg.ssm:
        def layer(c, l_xs):
            lp, st = l_xs
            return _block_decode(lp, c, cfg, st, pos)

        x1, ssm = jax.lax.scan(layer, x1, (params["blocks"], cache["ssm"]))
        new_cache["ssm"] = ssm
    else:
        def layer(c, l_xs):
            lp, ck, cv = l_xs
            c, (ck, cv) = _block_decode(lp, c, cfg, (ck, cv), pos)
            return c, (ck, cv)

        x1, (k, v) = jax.lax.scan(layer, x1,
                                  (params["blocks"], cache["k"], cache["v"]))
        new_cache["k"], new_cache["v"] = k, v
    x1 = rms_norm(x1, params["final_norm"], cfg.rms_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bd,dv->bv", x1, head.astype(cdt),
                        preferred_element_type=jnp.float32)
    new_cache["pos"] = pos + 1
    return logits, new_cache


def _shared_block_decode_rows(p, x1, x0, cfg, ck, cv, pos):
    """Per-row-position zamba2 shared block (slot-resident decode)."""
    cdt = dtype_of(cfg.compute_dtype)
    h = jnp.concatenate([x1, x0], axis=-1) @ p["fused_proj"].astype(cdt)
    a, ck, cv = decode_attention_rows(
        p, rms_norm(h, p["ln1"], cfg.rms_eps)[:, None, :], cfg, ck, cv, pos)
    h = h + a[:, 0]
    h = h + mlp(p, rms_norm(h, p["ln2"], cfg.rms_eps)[:, None, :],
                cfg)[:, 0]
    return x1 + h, ck, cv


def decode_step_slots(cfg: ModelConfig, params, state, token, pos
                      ) -> Tuple[jnp.ndarray, Dict[str, Any]]:
    """One decode step over the SLOT-RESIDENT state pool of an SSM/hybrid
    architecture — the recurrent-state counterpart of
    :func:`decode_step_paged`, with PER-ROW positions.

    The continuous-batching engine keeps one fixed-slot pool of recurrent
    state (mamba ``(conv_buf, h)`` per layer; zamba2 additionally the shared
    block's per-slot KV span): sequences claim a slot at admission (their
    prefilled state is scattered in), decode side by side at their own
    positions, and release the slot at retirement. ``state`` is
    :func:`init_cache`'s pytree minus the scalar ``pos`` (replaced by the
    per-row ``pos`` argument). Every op here is row-wise — no cross-batch
    reduction — so a resident row's tokens are bit-identical to the grouped
    per-call path regardless of who shares the batch; inactive slots step on
    stale state harmlessly (their output is discarded host-side, their slot
    is overwritten at the next admission).

    This mirrors :func:`decode_step`'s scan skeleton with the scalar
    ``cache["pos"]`` replaced by the per-row argument (the per-layer math is
    shared through :func:`_block_decode`); a change to the embed / final
    norm / lm-head framing there must be mirrored here, or the slot path
    silently diverges from the reference it is tested against.

    token: (B,) int32 current input token; pos: (B,) int32 per-row position.
    Returns (logits (B, padded_vocab) f32, new state).
    """
    if not (cfg.ssm or cfg.hybrid_attn_every):
        raise ValueError(f"{cfg.name}: slot-state decode is the SSM/hybrid "
                         "path; attention archs page their KV instead")
    cdt = dtype_of(cfg.compute_dtype)
    x1 = jnp.take(params["embed"], token, axis=0).astype(cdt)
    if cfg.pos_emb == "sinusoidal":
        x1 = x1 + sinusoidal_positions(pos, cfg.d_model).astype(cdt)
    new_state = dict(state)
    if cfg.hybrid_attn_every:
        x0 = x1
        sb = params["shared_block"]

        def group(carry, xs):
            xx = carry
            gp, g_ssm, ck, cv = xs

            def layer(c, l_xs):
                lp, st = l_xs
                c, st = _block_decode(lp, c, cfg, st, pos)
                return c, st

            xx, g_ssm = jax.lax.scan(layer, xx, (gp, g_ssm))
            xx, ck, cv = _shared_block_decode_rows(sb, xx, x0, cfg, ck, cv,
                                                   pos)
            return xx, (g_ssm, ck, cv)

        x1, (g_ssm, sk, sv) = jax.lax.scan(
            group, x1, (params["gblocks"], state["g_ssm"],
                        state["shared_k"], state["shared_v"]))
        new_state["g_ssm"], new_state["shared_k"], new_state["shared_v"] = \
            g_ssm, sk, sv
        if "tail_blocks" in params:
            def layer(c, l_xs):
                lp, st = l_xs
                return _block_decode(lp, c, cfg, st, pos)

            x1, tail = jax.lax.scan(layer, x1,
                                    (params["tail_blocks"],
                                     state["tail_ssm"]))
            new_state["tail_ssm"] = tail
    else:
        def layer(c, l_xs):
            lp, st = l_xs
            return _block_decode(lp, c, cfg, st, pos)

        x1, ssm = jax.lax.scan(layer, x1, (params["blocks"], state["ssm"]))
        new_state["ssm"] = ssm
    x1 = rms_norm(x1, params["final_norm"], cfg.rms_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bd,dv->bv", x1, head.astype(cdt),
                        preferred_element_type=jnp.float32)
    return logits, new_state


def decode_step_paged(cfg: ModelConfig, params, pool_kv, tables,
                      lengths, token, active, impl: Optional[str] = None
                      ) -> Tuple[jnp.ndarray, Any]:
    """One decode step through a paged KV cache (continuous batching).

    Unlike :func:`decode_step`, every batch row carries its OWN position:
    ``lengths[b]`` is where row ``b``'s next KV entry lands and how far its
    causal mask extends — rows admitted at different times decode side by
    side. The pool layout and scatter helpers live in
    :mod:`repro.serve.kvcache`; the contiguous path above remains the
    reference implementation (the two agree token-for-token under greedy
    decoding, see ``tests/test_serve_continuous.py``).

    pool_kv: (L, 2, N, KV, block, hd) stacked K/V pages; tables:
    (B, max_blocks) int32; lengths: (B,) int32; token: (B,) int32; active:
    (B,) bool (inactive rows write KV to the sink block and their logits
    are discarded). ``impl`` (trace-static) picks the attention read path —
    the gather-free kernel/page-loop or the materializing ``"gather"``
    oracle; see :func:`repro.models.attention.paged_decode_attention`.
    Returns (logits (B, padded_vocab) f32, pool_kv).
    Attention architectures only — SSM/hybrid states are O(1) per sequence
    and take the contiguous path.
    """
    if cfg.ssm or cfg.hybrid_attn_every:
        raise ValueError(f"{cfg.name}: paged decode requires a pure "
                         "attention architecture")
    from .attention import paged_decode_attention

    cdt = dtype_of(cfg.compute_dtype)
    pos = lengths
    x1 = jnp.take(params["embed"], token, axis=0).astype(cdt)
    if cfg.pos_emb == "sinusoidal":
        x1 = x1 + sinusoidal_positions(pos, cfg.d_model).astype(cdt)

    def paged_attn(lp, h1, layer_cache):
        return paged_decode_attention(lp, h1, cfg, layer_cache,
                                      tables, pos, active, impl=impl)

    def layer(c, l_xs):
        lp, pkv = l_xs
        c, pkv = _block_decode(lp, c, cfg, pkv, pos, attn_fn=paged_attn)
        return c, pkv

    x1, pool_kv = jax.lax.scan(layer, x1, (params["blocks"], pool_kv))
    x1 = rms_norm(x1, params["final_norm"], cfg.rms_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bd,dv->bv", x1, head.astype(cdt),
                        preferred_element_type=jnp.float32)
    return logits, pool_kv


def _decode_chunk_scan(step, state, carry, n: int):
    """Shared chunk loop of :func:`decode_chunk_paged` /
    :func:`decode_chunk_slots`: ``n`` greedy steps of ``step(state, tok,
    lengths, active) -> (logits, state)`` threading the device carry.
    Rows with ``rem == 0`` are inactive: their token repeats (stable
    carry) and the engine discards their emitted tokens host-side."""
    lengths, last, rem = carry

    def body(c, _):
        st, tok, ln, rm = c
        active = rm > 0
        logits, st = step(st, tok, ln, active)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        nxt = jnp.where(active, nxt, tok)
        ln = ln + active.astype(jnp.int32)
        rm = rm - active.astype(jnp.int32)
        return (st, nxt, ln, rm), nxt

    (state, tok, ln, rm), toks = jax.lax.scan(
        body, (state, last, lengths, rem), None, length=n)
    return state, (ln, tok, rm), toks.swapaxes(0, 1)


def _serve_tp_active(cfg: ModelConfig, ctx) -> bool:
    """True when lm entry points should run under shard_map serve TP."""
    return ctx is not None and serve_tp_size(ctx) > 1


def decode_chunk_paged(cfg: ModelConfig, params, pool_kv, tables, carry,
                       n: int, impl: Optional[str] = None, ctx=None):
    """``n`` greedy paged decode steps over the resident batch in one traced
    loop — the chunk program of the continuous-batching engine.

    ``carry = (lengths, last, rem)`` is the DEVICE-RESIDENT decode carry:
    per-row KV length / last emitted token / decode steps remaining. The
    async-lookahead engine feeds chunk N's output carry straight into chunk
    N+1 without a host round-trip, so the device-side dependency chain never
    waits on host scheduling; the synchronous engine passes uploaded host
    mirrors through the SAME function (one compiled program serves both
    modes). Inactive rows' KV writes go to the sink block.

    Returns ``(pool_kv, (lengths, last, rem), toks)`` with ``toks`` the
    ``(B, n)`` greedy tokens (rows active for ``k < n`` steps repeat their
    final token in the tail — the host takes ``toks[b, :k]``).

    ``ctx``: optional ShardCtx — with a multi-device ``model`` axis the
    chunk runs under shard_map (KV-head-sharded pool and weights, exact-bit
    TP; tables/carry/tokens replicated). The Pallas/XLA paged read kernels
    are untouched: every shape they see is just the per-shard KV slice.
    """
    if _serve_tp_active(cfg, ctx):
        pspec = serve_param_specs(cfg, params, ctx)
        pool = serve_pool_spec(cfg, ctx)
        R = P()

        def run(prm, pkv, tbl, ln, last, rem):
            return decode_chunk_paged(cfg, prm, pkv, tbl, (ln, last, rem),
                                      n, impl=impl)

        f = manual_serve_map(run, ctx,
                             in_specs=(pspec, pool, R, R, R, R),
                             out_specs=(pool, (R, R, R), R))
        return f(params, pool_kv, tables, *carry)

    def step(pkv, tok, ln, active):
        return decode_step_paged(cfg, params, pkv, tables, ln, tok, active,
                                 impl=impl)

    return _decode_chunk_scan(step, pool_kv, carry, n)


def decode_chunk_slots(cfg: ModelConfig, params, state, carry, n: int,
                       ctx=None):
    """``n`` greedy decode steps over the SSM/hybrid slot-state pool — the
    recurrent-state counterpart of :func:`decode_chunk_paged`, with the same
    device-resident ``(lengths, last, rem)`` carry contract (chunk N+1 can
    consume chunk N's carry without a host sync). Inactive slots step on
    stale state harmlessly (row-wise math; tokens discarded host-side).

    Returns ``(state, (lengths, last, rem), toks)``.

    ``ctx``: optional ShardCtx — SSM/hybrid slot state and weights stay
    fully replicated on a mesh (per-shard compute is identical, hence
    trivially bit-exact); the shard_map wrap keeps the engine's data flow
    uniform with the paged path.
    """
    if _serve_tp_active(cfg, ctx):
        R = P()

        def run(prm, st, ln, last, rem):
            return decode_chunk_slots(cfg, prm, st, (ln, last, rem), n)

        f = manual_serve_map(run, ctx, in_specs=(R, R, R, R, R),
                             out_specs=(R, (R, R, R), R))
        return f(params, state, *carry)

    def step(st, tok, ln, active):
        return decode_step_slots(cfg, params, st, tok, ln)

    return _decode_chunk_scan(step, state, carry, n)


def _block_window(p, x, cfg: ModelConfig, attn_fn, pkv_l):
    """One layer over a chunked-prefill window. Mirrors :func:`_block_apply`
    with the attention swapped for a paged read/write through ``attn_fn(p,
    h, pkv_l) -> (y, pkv_l)`` — ln1/residual/ln2/MoE-or-MLP stay shared so
    the window path cannot structurally diverge from full prefill."""
    h = rms_norm(x, p["ln1"], cfg.rms_eps)
    y, pkv_l = attn_fn(p, h, pkv_l)
    x = x + y
    h2 = rms_norm(x, p["ln2"], cfg.rms_eps)
    if cfg.moe:
        y2, _ = moe_layer(p, h2, cfg)
        x = x + y2
    else:
        x = x + mlp(p, h2, cfg)
    return x, pkv_l


def prefill_window_paged(cfg: ModelConfig, params, pool_kv, tables, tokens,
                         start, valid, last_idx, ctx=None
                         ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Process one chunked-prefill WINDOW for every mid-prefill row of the
    resident batch, writing the window's KV straight into the paged pool.

    This is the second half of two-phase admission's chunked prefill: the
    engine admits a prompt on its prompt-only footprint, scatters its first
    window at the merge, and then feeds the remainder through THIS function
    one fixed-size window per pipeline cycle — resident rows keep decoding
    in the overlapped cycles, and because the window shape ``(B, C)`` never
    depends on prompt lengths, mixed-length admission groups share one
    compiled program.

    pool_kv: (L, 2, N, KV, block, hd); tables: (B, max_blocks) int32;
    tokens: (B, C) int32 window tokens (invalid entries arbitrary); start:
    (B,) int32 per-row window origin (absolute position of window column 0);
    valid: (B, C) bool (False for rows not prefilling and past-prompt
    tails); last_idx: (B,) int32 window column of each row's FINAL prompt
    token, clipped into range — its logits seed the row's first generated
    token, consumed only for rows whose prompt ends in this window.
    Returns (first_tokens (B,) int32 greedy, pool_kv). Attention archs only.
    """
    if cfg.ssm or cfg.hybrid_attn_every:
        raise ValueError(f"{cfg.name}: paged chunked prefill requires a "
                         "pure attention architecture")
    if _serve_tp_active(cfg, ctx):
        pspec = serve_param_specs(cfg, params, ctx)
        pool = serve_pool_spec(cfg, ctx)
        R = P()

        def run(prm, pkv, tbl, tk, st, vd, li):
            return prefill_window_paged(cfg, prm, pkv, tbl, tk, st, vd, li)

        f = manual_serve_map(run, ctx,
                             in_specs=(pspec, pool, R, R, R, R, R),
                             out_specs=(R, pool))
        return f(params, pool_kv, tables, tokens, start, valid, last_idx)
    from .attention import paged_prefill_window_attention

    cdt = dtype_of(cfg.compute_dtype)
    B, C = tokens.shape
    positions = start[:, None] + jnp.arange(C, dtype=jnp.int32)[None, :]
    x = jnp.take(params["embed"], tokens, axis=0).astype(cdt)
    if cfg.pos_emb == "sinusoidal":
        x = x + sinusoidal_positions(positions, cfg.d_model).astype(cdt)

    def win_attn(lp, h, pkv_l):
        return paged_prefill_window_attention(lp, h, cfg, pkv_l, tables,
                                              positions, valid)

    def layer(c, l_xs):
        lp, pkv_l = l_xs
        c, pkv_l = _block_window(lp, c, cfg, win_attn, pkv_l)
        return c, pkv_l

    x, pool_kv = jax.lax.scan(layer, x, (params["blocks"], pool_kv))
    x = rms_norm(x, params["final_norm"], cfg.rms_eps)
    x_last = x[jnp.arange(B), last_idx]
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bd,dv->bv", x_last, head.astype(cdt),
                        preferred_element_type=jnp.float32)
    return jnp.argmax(logits, axis=-1).astype(jnp.int32), pool_kv


def prefill(cfg: ModelConfig, params, tokens, max_len: int = 0,
            frontend_embeds=None, last_positions=None, ctx=None):
    """Process a prompt, producing last-position logits + a primed cache.

    For attention archs the KV cache is computed per layer; for SSM archs
    the (conv, h) states are produced by the chunked scans. max_len=0 sizes
    the cache exactly at the prompt length (the dry-run prefill cell).
    ``last_positions`` ((B,) int32, optional) picks a PER-ROW logit
    position instead of the shared final one — mixed-length admission
    groups are right-padded to one window shape, so each row's first-token
    logits sit at its own prompt end.
    """
    if (_serve_tp_active(cfg, ctx) and frontend_embeds is None
            and serve_attn_sharded(cfg, serve_tp_size(ctx))):
        # serve TP: run the whole prefill under shard_map; the primed cache
        # k/v come back KV-head-sharded, ready for the engine's pool scatter
        pspec = serve_param_specs(cfg, params, ctx)
        kv = serve_kv_cache_spec(cfg, ctx)
        R = P()
        cache_spec = {"pos": R, "k": kv, "v": kv}
        args = [params, tokens]
        specs = [pspec, R]
        if last_positions is not None:
            args.append(last_positions)
            specs.append(R)

        def run(prm, tk, *rest):
            return prefill(cfg, prm, tk, max_len=max_len,
                           last_positions=rest[0] if rest else None)

        f = manual_serve_map(run, ctx, in_specs=tuple(specs),
                             out_specs=(R, cache_spec))
        return f(*args)
    B, S = tokens.shape[:2]
    F = cfg.frontend_tokens if cfg.frontend != "none" else 0
    if F and last_positions is not None:
        # last_positions indexes the CONCATENATED (frontend + token)
        # sequence; the serve engine only uses it on frontend-free archs,
        # and silently off-by-F logits would be worse than refusing
        raise ValueError(f"{cfg.name}: last_positions does not account for "
                         "the frontend prefix; offset by frontend_tokens "
                         "first")
    total = S + F
    max_len = max(max_len, total)
    x, positions = _embed(cfg, params, tokens, frontend_embeds)
    cache = init_cache(cfg, B, max_len)
    cdt = dtype_of(cfg.compute_dtype)

    if cfg.hybrid_attn_every:
        x0 = x
        sb = params["shared_block"]
        g_conv, g_h = [], []
        sks, svs = [], []
        G = cfg.num_layers // cfg.hybrid_attn_every
        gp_all = params["gblocks"]
        for g in range(G):  # python loop: G is small (<=6)
            gp = jax.tree_util.tree_map(lambda t: t[g], gp_all)
            convs, hs = [], []
            for l in range(cfg.hybrid_attn_every):
                lp = jax.tree_util.tree_map(lambda t: t[l], gp)
                h = rms_norm(x, lp["ln1"], cfg.rms_eps)
                y, (cv, hh) = mamba_forward(lp, h, cfg, return_state=True)
                x = x + y
                convs.append(cv)
                hs.append(hh)
            # shared block with kv capture
            hcat = jnp.concatenate([x, x0], axis=-1) \
                @ sb["fused_proj"].astype(cdt)
            a, (k, v) = attention(sb, rms_norm(hcat, sb["ln1"], cfg.rms_eps),
                                  cfg, positions, return_kv=True)
            hcat = hcat + a
            hcat = hcat + mlp(sb, rms_norm(hcat, sb["ln2"], cfg.rms_eps), cfg)
            x = x + hcat
            k = k.transpose(0, 2, 1, 3)  # (B,KV,S,hd)
            v = v.transpose(0, 2, 1, 3)
            pad = max_len - total
            if pad:
                k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
                v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
            sks.append(constrain(k.astype(cdt), "dp", None, "sp", None))
            svs.append(constrain(v.astype(cdt), "dp", None, "sp", None))
            g_conv.append(jnp.stack(convs))
            g_h.append(jnp.stack(hs))
        cache["g_ssm"] = (jnp.stack(g_conv).astype(cdt), jnp.stack(g_h))
        cache["shared_k"] = jnp.stack(sks)
        cache["shared_v"] = jnp.stack(svs)
        if "tail_blocks" in params:
            convs, hs = [], []
            for l in range(cfg.num_layers % cfg.hybrid_attn_every):
                lp = jax.tree_util.tree_map(lambda t: t[l],
                                            params["tail_blocks"])
                h = rms_norm(x, lp["ln1"], cfg.rms_eps)
                y, (cv, hh) = mamba_forward(lp, h, cfg, return_state=True)
                x = x + y
                convs.append(cv)
                hs.append(hh)
            cache["tail_ssm"] = (jnp.stack(convs).astype(cdt),
                                 jnp.stack(hs))
    elif cfg.ssm:
        def body(carry, lp):
            xx = carry
            h = rms_norm(xx, lp["ln1"], cfg.rms_eps)
            y, (conv, hh) = mamba_forward(lp, h, cfg, return_state=True)
            return xx + y, (conv.astype(cdt), hh)

        fn = jax.checkpoint(body,
                            policy=jax.checkpoint_policies.nothing_saveable)\
            if cfg.remat else body
        x, ssm = jax.lax.scan(fn, x, params["blocks"])
        cache["ssm"] = ssm
    else:
        pad = max_len - total

        def body(carry, lp):
            xx = carry
            h = rms_norm(xx, lp["ln1"], cfg.rms_eps)
            y, (k, v) = attention(lp, h, cfg, positions, return_kv=True)
            xx = xx + y
            h2 = rms_norm(xx, lp["ln2"], cfg.rms_eps)
            if cfg.moe:
                y2, _ = moe_layer(lp, h2, cfg)
                xx = xx + y2
            else:
                xx = xx + mlp(lp, h2, cfg)
            k = k.transpose(0, 2, 1, 3)
            v = v.transpose(0, 2, 1, 3)
            if pad:
                k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
                v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
            # sequence-parallel cache layout (matches decode's shardings;
            # without this the (L,B,KV,S,hd) output replicates S: ~26GB/dev
            # at 32k — §Perf H6)
            k = constrain(k.astype(cdt), "dp", None, "sp", None)
            v = constrain(v.astype(cdt), "dp", None, "sp", None)
            return xx, (k, v)

        fn = jax.checkpoint(body,
                            policy=jax.checkpoint_policies.nothing_saveable)\
            if cfg.remat else body
        x, (k, v) = jax.lax.scan(fn, x, params["blocks"])
        cache["k"], cache["v"] = k, v
    x_last = x[:, -1] if last_positions is None \
        else x[jnp.arange(x.shape[0]), last_positions]
    x_last = rms_norm(x_last, params["final_norm"], cfg.rms_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bd,dv->bv", x_last, head.astype(cdt),
                        preferred_element_type=jnp.float32)
    cache["pos"] = jnp.asarray(total, jnp.int32)
    return logits, cache
