"""Mixture-of-Experts layer: top-k routing with capacity-based dispatch.

Dispatch uses the sort-free Switch-style ranking (cumsum of expert one-hots)
to place each (token, expert) assignment into an (E, C) slot table, then a
gather -> batched expert einsum -> scatter-add combine. This formulation:

* never materializes the (T, E, C) dispatch tensor (memory O(E*C*D));
* shards the expert dim over the ``tp`` mesh axis (expert parallelism) when
  E divides the axis, otherwise shards the expert hidden dim;
* drops tokens over capacity (capacity_factor), standard for TPU MoE.

Variants: qwen2-moe adds 4 shared experts (one fused SwiGLU of hidden 5632
with a sigmoid gate); arctic adds a dense FFN *in parallel* with the MoE
(dense-MoE hybrid residual).

The router's load-balancing auxiliary loss (Switch-style) is returned so the
train step can add ``router_aux_weight * aux``.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..distributed.sharding import constrain
from .layers import dense_init, dtype_of, normal_init
from .mlp import init_mlp, mlp

__all__ = ["init_moe", "moe_layer"]


def init_moe(key, cfg: ModelConfig) -> Dict[str, jnp.ndarray]:
    D, F = cfg.d_model, cfg.moe_d_ff
    # §Perf H3: optional inert experts appended so E divides the
    # expert-parallel axis; their router logits are masked to -inf in
    # moe_layer, so the computed function is EXACTLY the 60-expert model.
    E = cfg.num_experts + cfg.moe_expert_pad
    pdt = dtype_of(cfg.param_dtype)
    ks = jax.random.split(key, 6)
    p = {
        "router": normal_init(ks[0], (D, E), 0.02, jnp.float32),
        "e_wi": dense_init(ks[1], (E, D, F), pdt),
        "e_wg": dense_init(ks[2], (E, D, F), pdt),
        "e_wd": dense_init(ks[3], (E, F, D), pdt),
    }
    if cfg.shared_expert_d_ff:
        p.update(init_mlp(ks[4], cfg, cfg.shared_expert_d_ff, prefix="shared_"))
        p["shared_gate"] = jnp.zeros((D, 1), pdt)
    if cfg.dense_residual:
        p.update(init_mlp(ks[5], cfg, cfg.d_ff, prefix="dense_"))
    return p


def _capacity(T: int, cfg: ModelConfig) -> int:
    c = int(T * cfg.num_experts_per_tok * cfg.capacity_factor
            / cfg.num_experts) + 1
    return max(8, -(-c // 8) * 8)  # round up to 8 for TPU-friendly tiling


def moe_layer(p, x, cfg: ModelConfig) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, D) -> (y, aux_loss)."""
    B, S, D = x.shape
    E = cfg.num_experts + cfg.moe_expert_pad   # padded rows are inert
    E_real, K = cfg.num_experts, cfg.num_experts_per_tok
    cdt = dtype_of(cfg.compute_dtype)
    xt = x.reshape(B * S, D)
    T = B * S
    C = _capacity(T, cfg)

    logits = (xt.astype(jnp.float32) @ p["router"].astype(jnp.float32))
    if cfg.moe_expert_pad:
        logits = jnp.where(jnp.arange(E) < E_real, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)                       # (T, E)
    gate, eidx = jax.lax.top_k(probs, K)                          # (T, K)
    if cfg.norm_topk_prob:
        gate = gate / jnp.sum(gate, axis=-1, keepdims=True)

    # Switch-style aux loss: E * sum_e(frac_tokens_e * mean_prob_e)
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(eidx, E, dtype=jnp.float32), axis=1), axis=0)
    aux = E * jnp.sum(me * ce)

    # ---- rank assignments into (E, C) slots -------------------------------
    flat_e = eidx.reshape(-1)                                     # (T*K,)
    flat_g = gate.reshape(-1).astype(jnp.float32)
    flat_t = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[:, None],
                              (T, K)).reshape(-1)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)           # (T*K, E)
    pos = jnp.cumsum(onehot, axis=0) - onehot                     # excl. rank
    mypos = jnp.take_along_axis(pos, flat_e[:, None], axis=1)[:, 0]
    keep = mypos < C
    slot_e = jnp.where(keep, flat_e, E)            # overflow -> dropped row
    slot_c = jnp.where(keep, mypos, 0)
    slot_tok = jnp.full((E + 1, C), T, dtype=jnp.int32)
    slot_tok = slot_tok.at[slot_e, slot_c].set(
        jnp.where(keep, flat_t, T), mode="drop")[:E]              # (E, C)
    slot_gate = jnp.zeros((E + 1, C), dtype=jnp.float32)
    slot_gate = slot_gate.at[slot_e, slot_c].set(
        jnp.where(keep, flat_g, 0.0), mode="drop")[:E]            # (E, C)

    # ---- gather -> expert FFN -> combine ----------------------------------
    xpad = jnp.concatenate(
        [xt, jnp.zeros((1, D), xt.dtype)], axis=0)                # T sentinel
    xe = xpad[slot_tok].astype(cdt)                               # (E, C, D)
    xe = constrain(xe, "tp" if E % _tp_size() == 0 else None, None, None)
    wi = p["e_wi"].astype(cdt)
    wg = p["e_wg"].astype(cdt)
    wd = p["e_wd"].astype(cdt)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, wg)) \
        * jnp.einsum("ecd,edf->ecf", xe, wi)
    eo = jnp.einsum("ecf,efd->ecd", h, wd)                        # (E, C, D)
    eo = eo * slot_gate[..., None].astype(eo.dtype)
    out = jnp.zeros((T + 1, D), cdt).at[slot_tok.reshape(-1)].add(
        eo.reshape(E * C, D))[:T]
    y = out.reshape(B, S, D)
    y = constrain(y, "dp", None, None)

    if cfg.shared_expert_d_ff:
        shared = mlp(p, x.astype(cdt), cfg, prefix="shared_")
        sg = jax.nn.sigmoid(
            x.astype(jnp.float32) @ p["shared_gate"].astype(jnp.float32))
        y = y + shared * sg.astype(cdt)
    if cfg.dense_residual:
        y = y + mlp(p, x.astype(cdt), cfg, prefix="dense_")
    return y, aux


def _tp_size() -> int:
    from ..distributed.sharding import current_ctx
    ctx = current_ctx()
    if ctx is None or ctx.mesh is None or ctx.tp is None:
        return 1 << 30  # never divides: unsharded expert dim
    return ctx.mesh.shape[ctx.tp]
