"""Feed-forward layers: SwiGLU (gated) and plain-GELU variants."""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..distributed.sharding import constrain, gather_tp
from .layers import dense_init, dtype_of

__all__ = ["init_mlp", "mlp"]


def init_mlp(key, cfg: ModelConfig, d_ff: int = 0,
             prefix: str = "") -> Dict[str, jnp.ndarray]:
    """prefix in {"", "shared_", "dense_"} distinguishes the qwen2-moe shared
    experts and the arctic dense-residual path in the sharding rules."""
    D = cfg.d_model
    F = d_ff or cfg.d_ff
    pdt = dtype_of(cfg.param_dtype)
    ks = jax.random.split(key, 3)
    if cfg.mlp_gated:
        return {prefix + "wi": dense_init(ks[0], (D, F), pdt),
                prefix + "wg": dense_init(ks[1], (D, F), pdt),
                prefix + "wd": dense_init(ks[2], (F, D), pdt)}
    return {prefix + "wi": dense_init(ks[0], (D, F), pdt),
            prefix + "wd": dense_init(ks[2], (F, D), pdt)}


def mlp(p, x, cfg: ModelConfig, prefix: str = "") -> jnp.ndarray:
    cdt = dtype_of(cfg.compute_dtype)
    wi = p[prefix + "wi"].astype(cdt)
    wd = p[prefix + "wd"].astype(cdt)
    if cfg.mlp_gated:
        wg = p[prefix + "wg"].astype(cdt)
        h = jax.nn.silu(x @ wg) * (x @ wi)
    else:
        h = jax.nn.gelu(x @ wi)
    h = constrain(h, "dp", None, "tp")
    if h.shape[-1] != wd.shape[0]:     # serve TP: concat local d_ff columns
        h = gather_tp(h, -1)
    y = h @ wd
    if y.shape[-1] != cfg.d_model:     # serve TP: concat wd columns
        y = gather_tp(y, -1)
    return constrain(y, "dp", None, None)
