"""GQA attention: chunked-causal (train/prefill) + cached decode.

Three implementations share one math definition (``ref`` oracle lives in
``repro/kernels/ref.py``):

* ``full``    — materializes (S x S) scores; short sequences / smoke tests.
* ``chunked`` — lax.scan over query blocks with a causal mask; O(S * chunk)
                activation memory. This is the XLA-level flash pattern and
                the default for the dry-run meshes.
* ``pallas``  — the TPU flash kernel in ``repro/kernels/flash_attention.py``
                (validated in interpret mode; selected via cfg when on TPU).

Sharding: weights follow Megatron column/row specs (sharding.py); activation
constraints keep (B,S,·) on the dp axis and let GSPMD propagate the head
dimension from the weight shards.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..distributed.sharding import constrain, gather_tp
from .layers import dense_init, dtype_of, rms_norm, rope

__all__ = ["init_attention", "attention", "decode_attention",
           "decode_attention_rows", "paged_decode_attention",
           "paged_prefill_window_attention", "NEG_INF"]

NEG_INF = -2.0 ** 30  # large-but-finite: keeps bf16 softmax NaN-free


def init_attention(key, cfg: ModelConfig) -> Dict[str, jnp.ndarray]:
    D, H, KV, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.hd
    pdt = dtype_of(cfg.param_dtype)
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (D, H * hd), pdt),
        "wk": dense_init(ks[1], (D, KV * hd), pdt),
        "wv": dense_init(ks[2], (D, KV * hd), pdt),
        "wo": dense_init(ks[3], (H * hd, D), pdt),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H * hd,), pdt)
        p["bk"] = jnp.zeros((KV * hd,), pdt)
        p["bv"] = jnp.zeros((KV * hd,), pdt)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), pdt)
        p["k_norm"] = jnp.ones((hd,), pdt)
    return p


def _project_qkv(p, x, cfg: ModelConfig, positions):
    B, S, D = x.shape
    # head counts come from the WEIGHTS, not cfg: under shard_map serve TP
    # each shard sees its local KV/mp and H/mp head slices
    hd = cfg.hd
    H = p["wq"].shape[-1] // hd
    KV = p["wk"].shape[-1] // hd
    cdt = dtype_of(cfg.compute_dtype)
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"].astype(cdt))
    k = jnp.einsum("bsd,dh->bsh", x, p["wk"].astype(cdt))
    v = jnp.einsum("bsd,dh->bsh", x, p["wv"].astype(cdt))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(cdt)
        k = k + p["bk"].astype(cdt)
        v = v + p["bv"].astype(cdt)
    q = q.reshape(B, S, H, hd)
    k = k.reshape(B, S, KV, hd)
    v = v.reshape(B, S, KV, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.rms_eps)
        k = rms_norm(k, p["k_norm"], cfg.rms_eps)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def _full_attention(q, k, v, q_pos, k_pos):
    """Reference path: (B,S,H,hd) x (B,T,KV,hd) with causal mask."""
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, S, KV, G, hd)
    s = jnp.einsum("bqkgh,bskh->bkgqs", qg, k,
                   preferred_element_type=jnp.float32)
    s = s * (hd ** -0.5)
    mask = q_pos[:, None, None, :, None] >= k_pos[:, None, None, None, :]
    s = jnp.where(mask, s, NEG_INF)
    pmax = jnp.max(s, axis=-1, keepdims=True)
    e = jnp.exp(s - pmax)
    probs = e / jnp.sum(e, axis=-1, keepdims=True)
    out = jnp.einsum("bkgqs,bskh->bqkgh", probs.astype(v.dtype), v)
    return out.reshape(B, S, H, hd)


def _chunked_attention(q, k, v, q_pos, k_pos, chunk_q: int,
                       bwd_remat: bool = False):
    """lax.scan over query chunks; keys stay whole (masked). Activation
    memory O(S*chunk) instead of O(S^2).

    bwd_remat=True is the flash-backward pattern: scores/probs are
    RECOMPUTED per chunk in the backward pass instead of being stacked
    across the scan (saves O(S^2) fp32 HBM traffic per layer —
    EXPERIMENTS.md §Perf H1)."""
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    pad = (-S) % chunk_q
    if pad:
        # ragged tail (§Perf H5): pad the QUERY side only — padded rows are
        # fully masked (q_pos = -inf) and sliced off; keys stay whole. The
        # earlier fallback to full attention materialized O(S^2) scores
        # whenever frontend tokens made S_total non-divisible (musicgen).
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        q_pos = jnp.pad(q_pos, ((0, 0), (0, pad)),
                        constant_values=-(2 ** 30))
    Sp = S + pad
    nq = Sp // chunk_q
    qg = q.reshape(B, nq, chunk_q, KV, G, hd).transpose(1, 0, 2, 3, 4, 5)
    qp = q_pos.reshape(B, nq, chunk_q).transpose(1, 0, 2)

    def body(_, inp):
        qb, qpb = inp                                   # (B,cq,KV,G,hd)
        s = jnp.einsum("bqkgh,bskh->bkgqs", qb, k,
                       preferred_element_type=jnp.float32) * (hd ** -0.5)
        mask = qpb[:, None, None, :, None] >= k_pos[:, None, None, None, :]
        s = jnp.where(mask, s, NEG_INF)
        pmax = jnp.max(s, axis=-1, keepdims=True)
        e = jnp.exp(s - pmax)
        probs = e / jnp.sum(e, axis=-1, keepdims=True)
        ob = jnp.einsum("bkgqs,bskh->bqkgh", probs.astype(v.dtype), v)
        return None, ob.reshape(B, chunk_q, H, hd)

    if bwd_remat:
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable)
    _, out = jax.lax.scan(body, None, (qg, qp))
    out = out.transpose(1, 0, 2, 3, 4).reshape(B, Sp, H, hd)
    return out[:, :S]


def attention(p, x, cfg: ModelConfig, positions,
              impl: str = "chunked",
              return_kv: bool = False):
    """Causal self-attention over the whole sequence (train / prefill)."""
    B, S, D = x.shape
    cdt = dtype_of(cfg.compute_dtype)
    q, k, v = _project_qkv(p, x, cfg, positions)
    q = constrain(q, "dp", None, "tp", None)
    k = constrain(k, "dp", None, None, None)
    v = constrain(v, "dp", None, None, None)
    if impl == "pallas":
        from ..kernels.ops import flash_attention
        out = flash_attention(q, k, v, causal=True)
    elif impl == "full" or S <= cfg.attn_chunk_q or \
            (S % cfg.attn_chunk_q != 0 and S <= 8192):
        # ragged mid-length sequences: measured BETTER with one fused
        # S^2 attention than with padded chunking (musicgen train_4k:
        # frac 0.0243 full vs 0.0215 chunked — EXPERIMENTS.md §Perf H5);
        # long ragged sequences must chunk (O(S^2) fp32 would be ~8GB+)
        out = _full_attention(q, k, v, positions, positions)
    else:
        out = _chunked_attention(q, k, v, positions, positions,
                                 cfg.attn_chunk_q,
                                 bwd_remat=cfg.attn_bwd_remat)
    out2 = out.reshape(B, S, -1)
    if out2.shape[-1] != p["wo"].shape[0]:   # serve TP: concat local heads
        out2 = gather_tp(out2, -1)
    y = jnp.einsum("bsh,hd->bsd", out2, p["wo"].astype(cdt))
    if y.shape[-1] != cfg.d_model:           # serve TP: concat wo columns
        y = gather_tp(y, -1)
    y = constrain(y, "dp", None, None)
    if return_kv:
        return y, (k, v)
    return y


def paged_decode_attention(p, x, cfg: ModelConfig, pool_kv, tables,
                           pos, active, impl: Optional[str] = None):
    """One-token decode against ONE layer's paged KV pool (the
    paged-attention read path of the continuous-batching engine; the
    contiguous :func:`decode_attention` stays as the reference).

    x: (B, 1, D); pool_kv: (2, N, KV, block, hd) — this layer's stacked K/V
    pages (the new token's K and V land in one fused scatter); tables:
    (B, max_blocks) int32 block tables (tail entries point at the sink
    block); pos: (B,) int32 PER-ROW positions — rows of a continuously
    batched decode sit at different sequence lengths, which is exactly what
    the contiguous cache's single scalar ``pos`` cannot express; active:
    (B,) bool — masked rows write their KV to the sink and their output is
    discarded by the engine. Returns (y (B, 1, D), pool_kv).

    impl selects the read path (must be trace-static):

    * ``"pallas"`` — the gather-free Pallas kernel of
      :mod:`repro.kernels.paged_attention` (Mosaic on TPU, interpreter
      elsewhere): pages are read in place through the scalar-prefetched
      block table and blocks past each row's length are skipped.
    * ``"xla"``    — the same blockwise algorithm as a traced-bound page
      loop (the non-TPU fast path).
    * ``"gather"`` — the original materialize-then-mask path over the
      fully padded span: O(max_blocks) per row regardless of length. Kept
      as the reference oracle the kernels are tested against.
    * None         — :func:`repro.kernels.ops.default_paged_impl`.
    """
    from ..kernels.ops import default_paged_impl, paged_attention
    from ..serve.kvcache import append_kv, gather_read_attention

    if impl is None:
        impl = default_paged_impl()
    B, _, D = x.shape
    hd = cfg.hd
    H = p["wq"].shape[-1] // hd        # local head count under serve TP
    cdt = dtype_of(cfg.compute_dtype)
    q, k, v = _project_qkv(p, x, cfg, pos[:, None])
    pool_kv = append_kv(pool_kv, k[:, 0], v[:, 0], tables, pos, active)
    if impl == "gather":
        out = gather_read_attention(q.reshape(B, H, hd), pool_kv, tables,
                                    pos)
    else:
        out = paged_attention(q.reshape(B, H, hd), pool_kv, tables, pos,
                              impl=impl)
    out2 = out.reshape(B, H * hd).astype(cdt)
    if out2.shape[-1] != p["wo"].shape[0]:   # serve TP: concat local heads
        out2 = gather_tp(out2, -1)
    y = jnp.einsum("bh,hd->bd", out2, p["wo"].astype(cdt))
    if y.shape[-1] != cfg.d_model:           # serve TP: concat wo columns
        y = gather_tp(y, -1)
    return y[:, None, :], pool_kv


def paged_prefill_window_attention(p, x, cfg: ModelConfig, pool_kv, tables,
                                   positions, valid):
    """One chunked-prefill WINDOW against one layer's paged KV pool.

    The two-phase-admission engine feeds a prompt into the pool in
    fixed-size windows across successive pipeline cycles; each window's K/V
    is scattered through the row's block table and its queries then attend
    to the row's full paged prefix (earlier windows included) plus the
    causal part of the window itself. The read is the gather path — prefill
    runs once per window, not once per generated token, so the
    materializing read is fine here; the per-token decode hot path stays on
    the gather-free kernels.

    x: (B, C, D) window hidden states; pool_kv: (2, N, KV, block, hd) this
    layer's pages; tables: (B, max_blocks) int32; positions: (B, C) int32
    absolute positions ``start[b] + c``; valid: (B, C) bool — False entries
    (rows not prefilling, window tail past the prompt) scatter to the sink
    block and their outputs are junk the engine never reads. Valid entries
    always form a per-row prefix, so a valid query's causal span
    ``kpos <= positions[b, c]`` is fully populated. Returns (y (B, C, D),
    pool_kv).
    """
    from ..serve.kvcache import gather_pages, scatter_token_window

    B, C, D = x.shape
    hd = cfg.hd
    H = p["wq"].shape[-1] // hd        # local head counts under serve TP
    KV = p["wk"].shape[-1] // hd
    G = H // KV
    cdt = dtype_of(cfg.compute_dtype)
    q, k, v = _project_qkv(p, x, cfg, positions)
    pool_kv = scatter_token_window(pool_kv, k, v, tables, positions[:, 0],
                                   valid)
    ks, vs = gather_pages(pool_kv, tables)           # (B, KV, T, hd)
    T = ks.shape[2]
    qg = q.reshape(B, C, KV, G, hd)
    s = jnp.einsum("bckgh,bksh->bkgcs", qg, ks,
                   preferred_element_type=jnp.float32) * (hd ** -0.5)
    kpos = jnp.arange(T, dtype=jnp.int32)
    mask = kpos[None, None, None, None, :] <= positions[:, None, None, :, None]
    s = jnp.where(mask, s, NEG_INF)
    pmax = jnp.max(s, axis=-1, keepdims=True)
    e = jnp.exp(s - pmax)
    probs = (e / jnp.sum(e, axis=-1, keepdims=True)).astype(vs.dtype)
    out = jnp.einsum("bkgcs,bksh->bckgh", probs, vs)
    out2 = out.reshape(B, C, H * hd).astype(cdt)
    if out2.shape[-1] != p["wo"].shape[0]:   # serve TP: concat local heads
        out2 = gather_tp(out2, -1)
    y = jnp.einsum("bch,hd->bcd", out2, p["wo"].astype(cdt))
    if y.shape[-1] != cfg.d_model:           # serve TP: concat wo columns
        y = gather_tp(y, -1)
    return y, pool_kv


def decode_attention_rows(p, x, cfg: ModelConfig, cache_k, cache_v, pos):
    """Per-row-position variant of :func:`decode_attention` for the
    slot-resident hybrid (zamba2) shared block: rows of a continuously
    batched decode sit at different sequence positions, so each row writes
    its token at its OWN ``pos[b]`` and masks its OWN causal extent — the
    contiguous path's single scalar ``pos`` cannot express that.

    x: (B, 1, D); cache_[kv]: (B, KV, S_max, hd) slot-pool caches (each slot
    owns a fixed contiguous span — attention state here is the per-slot pool
    entry, not a paged table); pos: (B,) int32. Returns (y, cache_k,
    cache_v). Row-wise math: a row's output depends only on its own cache
    row, so resident rows are bit-identical to the grouped per-call path.
    """
    B, _, D = x.shape
    hd = cfg.hd
    H = p["wq"].shape[-1] // hd        # local head counts under serve TP
    KV = p["wk"].shape[-1] // hd
    G = H // KV
    S_max = cache_k.shape[2]
    q, k, v = _project_qkv(p, x, cfg, pos[:, None])
    cdt = dtype_of(cfg.compute_dtype)
    bidx = jnp.arange(B, dtype=jnp.int32)
    cache_k = cache_k.at[bidx, :, pos].set(k[:, 0].astype(cache_k.dtype))
    cache_v = cache_v.at[bidx, :, pos].set(v[:, 0].astype(cache_v.dtype))
    qg = q.reshape(B, KV, G, hd)
    s = jnp.einsum("bkgh,bksh->bkgs", qg, cache_k,
                   preferred_element_type=jnp.float32) * (hd ** -0.5)
    kpos = jnp.arange(S_max, dtype=jnp.int32)
    s = jnp.where((kpos[None, :] <= pos[:, None])[:, None, None, :],
                  s, NEG_INF)
    pmax = jnp.max(s, axis=-1, keepdims=True)
    e = jnp.exp(s - pmax)
    probs = (e / jnp.sum(e, axis=-1, keepdims=True)).astype(cache_v.dtype)
    out = jnp.einsum("bkgs,bksh->bkgh", probs, cache_v)
    out2 = out.reshape(B, H * hd).astype(cdt)
    if out2.shape[-1] != p["wo"].shape[0]:   # serve TP: concat local heads
        out2 = gather_tp(out2, -1)
    y = jnp.einsum("bh,hd->bd", out2, p["wo"].astype(cdt))
    if y.shape[-1] != cfg.d_model:           # serve TP: concat wo columns
        y = gather_tp(y, -1)
    return y[:, None, :], cache_k, cache_v


def decode_attention(p, x, cfg: ModelConfig, cache_k, cache_v, pos):
    """One-token decode against a KV cache.

    x: (B, 1, D); cache_[kv]: (B, KV, S_max, hd) — S_max is sharded over the
    ``sp`` axis for long contexts (sequence-parallel cache; the softmax
    reductions over the sharded S dim lower to cross-shard collectives,
    the flash-decode pattern). pos: scalar int32 current position.
    Returns (y, cache_k, cache_v) with the new token written at ``pos``.
    """
    B, _, D = x.shape
    hd = cfg.hd
    H = p["wq"].shape[-1] // hd        # local head counts under serve TP
    KV = p["wk"].shape[-1] // hd
    G = H // KV
    S_max = cache_k.shape[2]
    positions = jnp.full((B, 1), pos, dtype=jnp.int32)
    q, k, v = _project_qkv(p, x, cfg, positions)
    cdt = dtype_of(cfg.compute_dtype)
    # write new kv at pos
    cache_k = jax.lax.dynamic_update_slice_in_dim(
        cache_k, k.astype(cache_k.dtype).transpose(0, 2, 1, 3), pos, axis=2)
    cache_v = jax.lax.dynamic_update_slice_in_dim(
        cache_v, v.astype(cache_v.dtype).transpose(0, 2, 1, 3), pos, axis=2)
    qg = q.reshape(B, KV, G, hd)
    s = jnp.einsum("bkgh,bksh->bkgs", qg, cache_k,
                   preferred_element_type=jnp.float32) * (hd ** -0.5)
    kpos = jnp.arange(S_max, dtype=jnp.int32)
    s = jnp.where((kpos <= pos)[None, None, None, :], s, NEG_INF)
    pmax = jnp.max(s, axis=-1, keepdims=True)
    e = jnp.exp(s - pmax)
    probs = (e / jnp.sum(e, axis=-1, keepdims=True)).astype(cache_v.dtype)
    out = jnp.einsum("bkgs,bksh->bkgh", probs, cache_v)
    out2 = out.reshape(B, H * hd).astype(cdt)
    if out2.shape[-1] != p["wo"].shape[0]:   # serve TP: concat local heads
        out2 = gather_tp(out2, -1)
    y = jnp.einsum("bh,hd->bd", out2, p["wo"].astype(cdt))
    if y.shape[-1] != cfg.d_model:           # serve TP: concat wo columns
        y = gather_tp(y, -1)
    return y[:, None, :], cache_k, cache_v
