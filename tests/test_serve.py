import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import lm
from repro.serve.engine import ServeEngine


@pytest.mark.parametrize("arch", ["qwen3-14b", "falcon-mamba-7b",
                                  "zamba2-1.2b"])
def test_generate_matches_teacher_forced_argmax(arch):
    cfg = get_config(arch).smoke()
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, decode_chunk=4)
    prompts = [np.arange(1, 9, dtype=np.int32),
               np.arange(3, 11, dtype=np.int32)]
    outs = eng.generate(prompts, max_new=8)
    assert all(o.shape == (8,) for o in outs)
    full = np.concatenate([prompts[0], outs[0]])
    logits, _ = lm.forward(cfg, params, jnp.asarray(full[None, :-1]))
    pred = np.asarray(jnp.argmax(logits[0, len(prompts[0]) - 1:], -1))
    match = (pred[:8] == outs[0]).mean()
    assert match >= 0.85, f"{arch}: decode/forward agreement {match}"


def test_unequal_prompts_rejected():
    cfg = get_config("stablelm-1.6b").smoke()
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params)
    with pytest.raises(AssertionError):
        eng.generate([np.arange(4), np.arange(7)], max_new=2)
