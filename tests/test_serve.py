import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import lm
from repro.serve.engine import ServeEngine


@pytest.mark.parametrize("arch", ["qwen3-14b", "falcon-mamba-7b",
                                  "zamba2-1.2b"])
def test_generate_matches_teacher_forced_argmax(arch):
    cfg = get_config(arch).smoke()
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, decode_chunk=4)
    prompts = [np.arange(1, 9, dtype=np.int32),
               np.arange(3, 11, dtype=np.int32)]
    outs = eng.generate(prompts, max_new=8)
    assert all(o.shape == (8,) for o in outs)
    full = np.concatenate([prompts[0], outs[0]])
    logits, _ = lm.forward(cfg, params, jnp.asarray(full[None, :-1]))
    pred = np.asarray(jnp.argmax(logits[0, len(prompts[0]) - 1:], -1))
    match = (pred[:8] == outs[0]).mean()
    assert match >= 0.85, f"{arch}: decode/forward agreement {match}"


def test_unequal_prompts_grouped_and_ordered():
    """Mixed-length prompts are grouped by length; each group flows through
    the 4-stage pipeline and results scatter back to request order."""
    cfg = get_config("stablelm-1.6b").smoke()
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    with ServeEngine(cfg, params, decode_chunk=4) as eng:
        prompts = [np.arange(1, 5, dtype=np.int32),      # len 4 -> group A
                   np.arange(2, 9, dtype=np.int32),      # len 7 -> group B
                   np.arange(3, 7, dtype=np.int32)]      # len 4 -> group A
        outs = eng.generate(prompts, max_new=6)
        assert all(o.shape == (6,) for o in outs)
        # greedy determinism: identical to serving each group on its own
        ref_a = eng.generate([prompts[0], prompts[2]], max_new=6)
        ref_b = eng.generate([prompts[1]], max_new=6)
        np.testing.assert_array_equal(outs[0], ref_a[0])
        np.testing.assert_array_equal(outs[2], ref_a[1])
        np.testing.assert_array_equal(outs[1], ref_b[0])


def test_generate_empty_and_engine_close():
    cfg = get_config("stablelm-1.6b").smoke()
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params)
    assert eng.generate([], max_new=4) == []
    eng.close()  # idempotent, also fine before any generate
    eng.close()
