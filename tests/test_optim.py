import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim.adamw import OptConfig, adamw_update, init_opt_state, lr_at
from repro.optim.compress import compress_grads, init_error_state


def test_lr_schedule_shape():
    cfg = OptConfig(lr=1e-3, warmup_steps=10, total_steps=100,
                    min_lr_ratio=0.1)
    assert float(lr_at(cfg, 0)) == 0.0
    assert float(lr_at(cfg, 10)) == pytest.approx(1e-3, rel=1e-3)
    assert float(lr_at(cfg, 100)) == pytest.approx(1e-4, rel=1e-2)
    assert float(lr_at(cfg, 55)) < 1e-3


def test_adamw_minimizes_quadratic():
    cfg = OptConfig(lr=0.05, warmup_steps=0, total_steps=200,
                    weight_decay=0.0, clip_norm=10.0)
    params = {"w": jnp.array([3.0, -2.0, 1.5])}
    state = init_opt_state(params, cfg)

    def loss(p):
        return jnp.sum(jnp.square(p["w"] - jnp.array([1.0, 1.0, 1.0])))

    for _ in range(200):
        g = jax.grad(loss)(params)
        params, state, _ = adamw_update(params, g, state, cfg)
    assert float(loss(params)) < 1e-3


def test_grad_clipping():
    cfg = OptConfig(lr=1e-3, clip_norm=1.0, warmup_steps=0)
    params = {"w": jnp.zeros(4)}
    state = init_opt_state(params, cfg)
    huge = {"w": jnp.full(4, 1e6)}
    _, _, metrics = adamw_update(params, huge, state, cfg)
    assert float(metrics["grad_norm"]) == pytest.approx(2e6, rel=1e-3)


def test_bf16_moments_roundtrip():
    cfg = OptConfig(moment_dtype="bfloat16")
    params = {"w": jnp.ones((8, 8))}
    state = init_opt_state(params, cfg)
    assert state["m"]["w"].dtype == jnp.bfloat16
    g = {"w": jnp.full((8, 8), 0.1)}
    p2, s2, _ = adamw_update(params, g, state, cfg)
    assert bool(jnp.all(jnp.isfinite(p2["w"])))


def test_compression_error_feedback():
    """int8 + error feedback: accumulated compressed grads track the true
    sum much better than compression without feedback."""
    rng = np.random.default_rng(0)
    g_seq = [jnp.asarray(rng.standard_normal(256).astype(np.float32)) * 0.01
             for _ in range(50)]
    err = init_error_state({"w": g_seq[0]})["w"] if False else \
        jnp.zeros(256, jnp.bfloat16)
    acc_fb = jnp.zeros(256)
    acc_nofb = jnp.zeros(256)
    acc_true = jnp.zeros(256)
    for g in g_seq:
        (dq, ), (err, ) = compress_grads((g,), (err,))
        acc_fb += dq
        (dq2, ), _ = compress_grads((g,), (jnp.zeros(256, jnp.bfloat16),))
        acc_nofb += dq2
        acc_true += g
    e_fb = float(jnp.linalg.norm(acc_fb - acc_true))
    e_nofb = float(jnp.linalg.norm(acc_nofb - acc_true))
    assert e_fb <= e_nofb * 1.05
    assert e_fb < 0.05 * float(jnp.linalg.norm(acc_true)) + 1e-3


def test_compressed_training_converges():
    cfg = OptConfig(lr=0.05, warmup_steps=0, weight_decay=0.0)
    params = {"w": jnp.array([3.0, -2.0])}
    state = init_opt_state(params, cfg)
    err = init_error_state(params)

    def loss(p):
        return jnp.sum(jnp.square(p["w"] - 1.0))

    for _ in range(150):
        g = jax.grad(loss)(params)
        g, err = compress_grads(g, err)
        params, state, _ = adamw_update(params, g, state, cfg)
    assert float(loss(params)) < 1e-2
