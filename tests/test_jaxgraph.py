import jax
import jax.numpy as jnp
import pytest

from repro.core import STOP, JaxGraph


def _state(**kw):
    base = {"i": jnp.int32(0), "x": jnp.float32(1.0)}
    base.update(kw)
    return base


def test_dag_composition_matches_reference():
    g = JaxGraph()
    a = g.task(lambda s: {**s, "x": s["x"] + 1})
    b = g.task(lambda s: {**s, "x": s["x"] * 3})
    a.precede(b)
    st = _state()
    out = g.compile(st)(st)
    ref = g.run_reference(st)
    assert float(out["x"]) == float(ref["x"]) == 6.0


def test_do_while_cycle():
    g = JaxGraph()
    stepn = g.task(lambda s: {"i": s["i"] + 1, "x": s["x"] * 2})
    chk = g.cond(lambda s: (jnp.where(s["i"] >= 6, 1, 0), s))
    stepn.precede(chk)
    chk.precede(stepn, STOP)
    st = _state()
    out = g.compile(st)(st)
    assert int(out["i"]) == 6 and float(out["x"]) == 64.0
    ref = g.run_reference(st)
    assert int(ref["i"]) == 6


def test_branching_conditions():
    g = JaxGraph()
    init = g.task(lambda s: {**s, "i": s["i"] * 0})

    def coin(s):
        s = {**s, "i": s["i"] + 1}
        return jnp.where(s["i"] == 2, 0, 1), s

    f1 = g.cond(coin)
    f2 = g.cond(coin)
    f3 = g.cond(coin)
    init.precede(f1)
    f1.precede(f1, f2)
    f2.precede(f1, f3)
    f3.precede(f1, STOP)
    st = _state()
    out = g.compile(st)(st)
    ref = g.run_reference(st)
    assert int(out["i"]) == int(ref["i"]) == 5


def test_superblocks_merge_static_chains():
    g = JaxGraph()
    ts = [g.task(lambda s, k=k: {**s, "i": s["i"] + k}) for k in range(5)]
    for a, b in zip(ts, ts[1:]):
        a.precede(b)
    c = g.cond(lambda s: (jnp.where(s["i"] > 100, 1, 0), s))
    ts[-1].precede(c)
    c.precede(ts[0], STOP)
    blocks, _ = g._blocks()
    assert len(blocks) == 1           # whole chain + cond fused to 1 block
    st = _state()
    out = g.compile(st)(st)
    assert int(out["i"]) == int(g.run_reference(st)["i"])


def test_static_fanout_in_cyclic_graph_rejected():
    g = JaxGraph()
    a = g.task(lambda s: s)
    b = g.task(lambda s: s)
    c = g.cond(lambda s: (jnp.int32(0), s))
    a.precede(b)
    a.precede(c)
    c.precede(a, STOP)
    with pytest.raises(ValueError, match="multiple successors"):
        g.lower()


def test_out_of_range_condition_index_stops():
    g = JaxGraph()
    c = g.cond(lambda s: (jnp.int32(7), s))
    t = g.task(lambda s: {**s, "i": s["i"] + 100})
    c.precede(t)
    st = _state()
    out = g.compile(st)(st)
    assert int(out["i"]) == 0          # successor not taken


def test_max_iters_bound():
    g = JaxGraph()
    stepn = g.task(lambda s: {**s, "i": s["i"] + 1})
    c = g.cond(lambda s: (jnp.int32(0), s))     # loops forever
    stepn.precede(c)
    c.precede(stepn, STOP)
    st = _state()
    out = jax.jit(g.lower(max_iters=10))(st)
    assert int(out["i"]) == 10
