"""Durable serving: journal, snapshot/restore, drain, checkpoint preempt.

Covers durability boundary by boundary (docs/robustness.md "Durability &
recovery"): the request WAL (checksummed records, torn-tail truncation,
fsync-lag accounting), the snapshot container (atomic writes, typed
:class:`SnapshotCorrupt` on any integrity failure), graceful drain
(admission gate, deadline checkpoint-preemption, typed teardown of the
un-drained backlog), SSM/hybrid checkpoint preemption (exact state
capture — no prefill replay, bit-identical output), the
preemption-aware hopeless-deadline check at admission, warm restart
(``prefix.warm_hits`` on the first post-restore request), and the
teardown interplay cases (close during drain, watchdog mid-drain,
restore from drained vs crashed state).

Bit-identity assertions pin ``paged_impl="gather"`` (the materializing
oracle) as everywhere else in the serve tests. The kill-and-recover
subprocess driver lives in test_serve_recover.py.
"""
import os
import threading
import time

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import lm
from repro.serve.engine import JOURNAL_FILE, SNAPSHOT_FILE, ServeEngine
from repro.serve.errors import (DeadlineExceeded, EngineClosed, ServeError,
                                SnapshotCorrupt)
from repro.serve.journal import Journal, replay
from repro.serve.scheduler import Scheduler, ServeRequest
from repro.serve.snapshot import (corrupt_snapshot, read_snapshot,
                                  write_snapshot)


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("stablelm-1.6b").smoke()
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


@pytest.fixture(scope="module")
def ssm_setup():
    cfg = get_config("falcon-mamba-7b").smoke()
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _prompts(cfg, sizes, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(1, cfg.vocab_size, size=n).astype(np.int32)
            for n in sizes]


def _wait(pred, timeout=60.0, what="condition"):
    """Poll until ``pred()`` — drain() gates admission the instant it is
    called, so tests must not race it against the admit stage."""
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        if pred():
            return
        time.sleep(0.01)
    raise AssertionError(f"timed out waiting for {what}")


class _Rec:
    """Minimal request stand-in for journal-only tests."""

    def __init__(self, rid, prompt, max_new=8, priority=0,
                 deadline_s=None):
        self.id = rid
        self.prompt = prompt
        self.max_new = max_new
        self.priority = priority
        self.deadline_s = deadline_s


# ----------------------------------------------------------------- journal
def test_journal_roundtrip_classifies(tmp_path):
    p = str(tmp_path / "j.wal")
    a = _Rec(1, np.arange(1, 5, dtype=np.int32))
    b = _Rec(2, np.arange(5, 12, dtype=np.int32), deadline_s=3.0)
    c = _Rec(3, np.arange(2, 9, dtype=np.int32))
    with Journal(p) as j:
        j.submit(a)
        j.submit(b)
        j.admit(a)
        j.first_token(a)
        j.finish(a, [7, 8, 9])
        j.submit(c)
        j.cancel(c, "cancelled")
    rep = replay(p)
    assert rep.dropped == 0
    assert set(rep.submits) == {1, 2, 3}
    assert rep.terminal == {1: "finish", 3: "cancel"}
    inc = rep.incomplete
    assert [r["id"] for r in inc] == [2]
    assert inc[0]["prompt"] == list(range(5, 12))
    assert inc[0]["deadline_s"] == 3.0
    assert rep.replayed_tokens == 7


def test_journal_torn_tail_truncates(tmp_path):
    p = str(tmp_path / "j.wal")
    with Journal(p) as j:
        for i in range(4):
            j.submit(_Rec(i, np.arange(1, 4, dtype=np.int32)))
    with open(p, "ab") as f:                    # torn final write
        f.write(b"deadbeef {\"k\": \"subm")
    rep = replay(p)
    assert len(rep.submits) == 4 and rep.dropped == 1
    # corruption mid-file truncates everything AT and AFTER it
    lines = open(p, "rb").readlines()
    lines[2] = b"00000000 {}\n"
    with open(p, "wb") as f:
        f.writelines(lines)
    rep = replay(p)
    assert len(rep.submits) == 2 and rep.dropped == 3


def test_journal_fsync_cadence_and_lag(tmp_path):
    p = str(tmp_path / "j.wal")
    j = Journal(p, fsync_every=0)               # fsync only on flush/close
    j.submit(_Rec(1, np.arange(3, dtype=np.int32)))
    assert j.lag_s >= 0.0
    time.sleep(0.02)
    assert j.lag_s > 0.0                        # un-fsynced data at risk
    j.flush()
    assert j.lag_s == 0.0
    j.close()
    j.close()                                   # idempotent
    with pytest.raises(ValueError):
        Journal(str(tmp_path / "x.wal"), fsync_every=-1)


# ---------------------------------------------------------------- snapshot
def test_snapshot_container_roundtrip(tmp_path):
    p = str(tmp_path / "s.snap")
    meta = {"queue": [{"id": 4}], "note": "x"}
    arrs = {"a": np.arange(12, dtype=np.int32).reshape(3, 4),
            "b": np.zeros((0,), np.float32)}
    n = write_snapshot(p, meta, arrs)
    assert n == os.path.getsize(p)
    m2, a2 = read_snapshot(p)
    assert m2["queue"] == [{"id": 4}] and m2["version"] == 1
    assert np.array_equal(a2["a"], arrs["a"]) and a2["b"].size == 0


def test_snapshot_corruption_typed(tmp_path):
    p = str(tmp_path / "s.snap")
    write_snapshot(p, {}, {"a": np.arange(64, dtype=np.int32)})
    blob = open(p, "rb").read()
    # payload bit flip
    corrupt_snapshot(p)
    with pytest.raises(SnapshotCorrupt):
        read_snapshot(p)
    # truncation (torn write)
    with open(p, "wb") as f:
        f.write(blob[:-7])
    with pytest.raises(SnapshotCorrupt):
        read_snapshot(p)
    # bad magic
    with open(p, "wb") as f:
        f.write(b"NOTASNAP" + blob[8:])
    with pytest.raises(SnapshotCorrupt):
        read_snapshot(p)
    # missing file
    with pytest.raises(SnapshotCorrupt):
        read_snapshot(str(tmp_path / "missing.snap"))
    assert issubclass(SnapshotCorrupt, ServeError)


# ------------------------------------------------------ scheduler additions
def test_scheduler_hopeless_head_fails_typed():
    s = Scheduler(max_admit=4)
    doomed = ServeRequest(np.arange(1, 9, dtype=np.int32), 64,
                          deadline_s=0.01)
    fine = ServeRequest(np.arange(1, 5, dtype=np.int32), 4)
    now = time.perf_counter()
    doomed.deadline_at = now + 0.01
    for r in (doomed, fine):
        s.enqueue(r)
    events = []
    s.on_event = lambda kind, r: events.append((kind, r.id))
    group = s.try_admit(free_slots=4, blocks_free=None,
                        hopeless=lambda r: "too slow"
                        if r is doomed else None)
    assert group == [fine]
    assert events == [("expired", doomed.id)]
    with pytest.raises(DeadlineExceeded):
        doomed.result(timeout=1.0)


def test_scheduler_export_waiting():
    s = Scheduler(max_admit=4)
    reqs = [ServeRequest(np.arange(1, 5, dtype=np.int32), 4, priority=p)
            for p in (1, 0, 1)]
    for r in reqs:
        s.enqueue(r)
    reqs[2].cancel()
    exported = s.export_waiting()
    # tier order, cancelled requests excluded
    assert exported == [reqs[1], reqs[0]]


# -------------------------------------------------------------------- drain
def test_drain_lets_residents_finish_then_gates(setup):
    cfg, params = setup
    prompts = _prompts(cfg, (12, 9))
    with ServeEngine(cfg, params, max_batch=4, kv_blocks=64, block_size=8,
                     paged_impl="gather") as ref_eng:
        ref = [ref_eng.result(r)
               for r in [ref_eng.submit(p, 8) for p in prompts]]
    eng = ServeEngine(cfg, params, max_batch=4, kv_blocks=64, block_size=8,
                      paged_impl="gather")
    reqs = [eng.submit(p, 8) for p in prompts]
    _wait(lambda: all(r.admitted_at is not None for r in reqs),
          what="rows seated")
    assert eng.drain(deadline_s=30.0)           # generous: they finish
    outs = [eng.result(r) for r in reqs]
    assert all(np.array_equal(a, b) for a, b in zip(ref, outs))
    assert eng.stats["drain_preempted"] == 0
    with pytest.raises(EngineClosed):           # admission gate is typed
        eng.submit(prompts[0], 4)
    assert isinstance(EngineClosed("x"), RuntimeError)
    eng.drain()                                 # idempotent
    eng.close()


def test_drain_deadline_preempts_and_close_fails_backlog_typed(setup):
    cfg, params = setup
    prompts = _prompts(cfg, (10, 11, 9), seed=3)
    eng = ServeEngine(cfg, params, max_batch=2, decode_chunk=2,
                      kv_blocks=64, block_size=8, paged_impl="gather")
    # long decodes: the drain deadline lands mid-stream
    reqs = [eng.submit(p, 200) for p in prompts]
    _wait(lambda: any(r.first_token_at is not None for r in reqs),
          what="decode in flight")
    assert eng.drain(deadline_s=0.05, timeout=60.0)
    assert eng.stats["drain_preempted"] > 0
    # preempted + never-admitted requests sit in the gated queue; close
    # settles every future typed — result() never hangs untyped
    eng.close(timeout=10.0)
    for r in reqs:
        with pytest.raises(ServeError):
            r.result(timeout=5.0)


# --------------------------------------------------- journal on the engine
def test_journal_records_engine_lifecycle(setup, tmp_path):
    cfg, params = setup
    prompts = _prompts(cfg, (12, 9), seed=1)
    jp = str(tmp_path / JOURNAL_FILE)
    with ServeEngine(cfg, params, max_batch=4, kv_blocks=64, block_size=8,
                     paged_impl="gather") as plain:
        ref = [plain.result(r)
               for r in [plain.submit(p, 8) for p in prompts]]
    eng = ServeEngine(cfg, params, max_batch=4, kv_blocks=64, block_size=8,
                      paged_impl="gather", journal=Journal(jp))
    outs = [eng.result(r) for r in [eng.submit(p, 8) for p in prompts]]
    eng.close()
    # journaling is observational: the served tokens are bit-identical
    assert all(np.array_equal(a, b) for a, b in zip(ref, outs))
    rep = replay(jp)
    kinds = [r["k"] for r in rep.records]
    assert kinds.count("submit") == 2 and kinds.count("finish") == 2
    assert kinds.count("admit") == 2 and kinds.count("first_token") == 2
    assert rep.incomplete == []


def test_recover_replays_incomplete_bit_identical(setup, tmp_path):
    cfg, params = setup
    prompts = _prompts(cfg, (12, 9, 17), seed=2)
    with ServeEngine(cfg, params, max_batch=4, kv_blocks=64, block_size=8,
                     paged_impl="gather") as ref_eng:
        ref = [ref_eng.result(r)
               for r in [ref_eng.submit(p, 8) for p in prompts]]
    # hand-build a crashed journal: 3 submits, only #2 finished
    state = tmp_path / "state"
    state.mkdir()
    with Journal(str(state / JOURNAL_FILE)) as j:
        for i, p in enumerate(prompts):
            j.submit(_Rec(10 + i, p, max_new=8))
        j.finish(_Rec(11, prompts[1]), ref[1])
    eng = ServeEngine(cfg, params, max_batch=4, kv_blocks=64, block_size=8,
                      paged_impl="gather")
    replayed = eng.recover(str(state))
    assert sorted(replayed) == [10, 12]         # the finished one skipped
    assert np.array_equal(eng.result(replayed[10]), ref[0])
    assert np.array_equal(eng.result(replayed[12]), ref[2])
    assert eng.stats["recovered"] == 2
    assert eng.stats["replayed_tokens"] == len(prompts[0]) \
        + len(prompts[2])
    # the consumed journal rotated aside; the fresh one holds the replays
    assert (state / (JOURNAL_FILE + ".replayed")).exists()
    eng.drain()
    rep = replay(str(state / JOURNAL_FILE))
    assert len(rep.submits) == 2 and len(rep.incomplete) == 0
    eng.close()


# ------------------------------------------------------------- warm restart
def test_snapshot_warm_restart_first_request_hits(setup, tmp_path):
    cfg, params = setup
    system = np.arange(1, 25, dtype=np.int32)   # shared "system prompt"
    tails = _prompts(cfg, (8, 6), seed=4)
    prompts = [np.concatenate([system, t]) for t in tails]
    state = tmp_path / "state"
    state.mkdir()
    eng = ServeEngine(cfg, params, max_batch=4, kv_blocks=64, block_size=8,
                      paged_impl="gather", prefix_cache=True,
                      journal=Journal(str(state / JOURNAL_FILE)))
    ref = [eng.result(r) for r in [eng.submit(p, 8) for p in prompts]]
    assert eng.drain(deadline_s=10.0)
    eng.snapshot(str(state / SNAPSHOT_FILE))
    eng.close()

    eng2 = ServeEngine(cfg, params, max_batch=4, kv_blocks=64,
                       block_size=8, paged_impl="gather",
                       prefix_cache=True)
    assert eng2.recover(str(state)) == {}       # nothing incomplete
    assert eng2.stats["warm_started"] > 0
    # the FIRST post-restart request hits the restored prefix trie
    out = eng2.result(eng2.submit(prompts[0], 8))
    assert eng2._prefix.stats["warm_hits"] > 0
    assert np.array_equal(out, ref[0])
    eng2.close()


def test_corrupt_snapshot_falls_back_cold_never_wrong(setup, tmp_path):
    cfg, params = setup
    prompts = _prompts(cfg, (12, 9), seed=5)
    state = tmp_path / "state"
    state.mkdir()
    eng = ServeEngine(cfg, params, max_batch=4, kv_blocks=64, block_size=8,
                      paged_impl="gather", prefix_cache=True)
    ref = [eng.result(r) for r in [eng.submit(p, 8) for p in prompts]]
    assert eng.drain()
    eng.snapshot(str(state / SNAPSHOT_FILE))
    eng.close()
    corrupt_snapshot(str(state / SNAPSHOT_FILE))

    eng2 = ServeEngine(cfg, params, max_batch=4, kv_blocks=64,
                       block_size=8, paged_impl="gather",
                       prefix_cache=True)
    with pytest.raises(SnapshotCorrupt):        # restore() itself is typed
        eng2.restore(str(state / SNAPSHOT_FILE))
    eng2.recover(str(state))                    # recover() absorbs it: cold
    assert eng2.stats["warm_started"] == 0
    outs = [eng2.result(r) for r in [eng2.submit(p, 8) for p in prompts]]
    assert all(np.array_equal(a, b) for a, b in zip(ref, outs))
    eng2.close()


def test_snapshot_corrupt_fault_site(setup, tmp_path):
    cfg, params = setup
    sp = str(tmp_path / SNAPSHOT_FILE)
    eng = ServeEngine(cfg, params, max_batch=2, kv_blocks=32, block_size=8,
                      paged_impl="gather", prefix_cache=True,
                      fault_inject="snapshot_corrupt")
    eng.result(eng.submit(_prompts(cfg, (12,), seed=6)[0], 4))
    assert eng.drain()
    eng.snapshot(sp)
    eng.close()
    with pytest.raises(SnapshotCorrupt):
        read_snapshot(sp)


def test_restore_from_drained_snapshot_resubmits_queue(setup, tmp_path):
    cfg, params = setup
    prompts = _prompts(cfg, (10, 11, 9), seed=7)
    with ServeEngine(cfg, params, max_batch=4, kv_blocks=64, block_size=8,
                     paged_impl="gather") as ref_eng:
        ref = [ref_eng.result(r)
               for r in [ref_eng.submit(p, 32) for p in prompts]]
    state = tmp_path / "state"
    state.mkdir()
    # NO journal: the snapshot's queue descriptors are the only record
    eng = ServeEngine(cfg, params, max_batch=2, decode_chunk=2,
                      kv_blocks=64, block_size=8, paged_impl="gather")
    reqs = [eng.submit(p, 32) for p in prompts]
    _wait(lambda: any(r.admitted_at is not None for r in reqs),
          what="rows seated")
    eng.drain(deadline_s=0.0, timeout=60.0)     # checkpoint-preempt now
    eng.snapshot(str(state / SNAPSHOT_FILE))
    eng.close()
    del reqs

    eng2 = ServeEngine(cfg, params, max_batch=2, decode_chunk=2,
                       kv_blocks=64, block_size=8, paged_impl="gather")
    replayed = eng2.recover(str(state))
    assert len(replayed) > 0                    # drained backlog replays
    for old_id, r in replayed.items():
        out = eng2.result(r, timeout=120.0)
        # old ids are 1-based in submission order within the dead engine
        matches = [np.array_equal(out, x) for x in ref]
        assert any(matches)
    eng2.close()


# -------------------------------------------------- hopeless-deadline check
def test_hopeless_deadline_fails_at_admission(setup):
    cfg, params = setup
    prompts = _prompts(cfg, (12, 10), seed=8)
    eng = ServeEngine(cfg, params, max_batch=2, kv_blocks=64, block_size=8,
                      paged_impl="gather")
    # warm the service-rate model
    eng.result(eng.submit(prompts[0], 8))
    assert eng._decode_rate > 0.0
    # a deadline far under the work's service time at the observed rate
    need_s = (len(prompts[1]) + 200) / eng._decode_rate
    doomed = eng.submit(prompts[1], 200, deadline_s=min(0.05,
                                                        need_s / 100))
    with pytest.raises(DeadlineExceeded) as ei:
        doomed.result(timeout=30.0)
    assert "hopeless" in str(ei.value) or "deadline" in str(ei.value)
    eng.close()


# --------------------------------------------- SSM checkpoint preemption
def test_ssm_boost_preempt_checkpoint_no_replay(ssm_setup):
    cfg, params = ssm_setup
    prompts = _prompts(cfg, (10, 11, 9), seed=9)
    with ServeEngine(cfg, params, max_batch=4) as ref_eng:
        ref = [ref_eng.result(r)
               for r in [ref_eng.submit(p, 24) for p in prompts]]
    eng = ServeEngine(cfg, params, max_batch=2, decode_chunk=2)
    lo = [eng.submit(p, 24, priority=1) for p in prompts[:2]]
    _wait(lambda: all(r.first_token_at is not None for r in lo),
          what="low-tier rows decoding")
    hi = eng.submit(prompts[2], 24, priority=0)
    outs = [eng.result(r, timeout=120.0) for r in lo] \
        + [eng.result(hi, timeout=120.0)]
    assert eng.stats["preempted"] > 0           # boost fired (non-paged!)
    # checkpoint restore, not replay: one prefill per request even though
    # a row was preempted mid-decode
    assert eng.stats["prefills"] == len(prompts)
    assert all(np.array_equal(a, b) for a, b in zip(ref, outs))
    eng.close()


def test_ssm_drain_deadline_checkpoint_preempts(ssm_setup):
    cfg, params = ssm_setup
    prompts = _prompts(cfg, (10, 9), seed=10)
    eng = ServeEngine(cfg, params, max_batch=2, decode_chunk=2)
    reqs = [eng.submit(p, 200) for p in prompts]
    _wait(lambda: all(r.first_token_at is not None for r in reqs),
          what="rows decoding")
    assert eng.drain(deadline_s=0.05, timeout=60.0)
    assert eng.stats["drain_preempted"] > 0
    # the checkpoints captured exact state on the way out
    assert all(r._ssm_ckpt is not None or r.done() for r in reqs)
    eng.close(timeout=10.0)
    for r in reqs:
        with pytest.raises(ServeError):
            r.result(timeout=5.0)


# -------------------------------------------------------- teardown interplay
def test_close_during_active_drain_settles_everything(setup):
    cfg, params = setup
    prompts = _prompts(cfg, (10, 11), seed=11)
    eng = ServeEngine(cfg, params, max_batch=2, decode_chunk=2,
                      kv_blocks=64, block_size=8, paged_impl="gather")
    reqs = [eng.submit(p, 200) for p in prompts]
    _wait(lambda: any(r.first_token_at is not None for r in reqs),
          what="decode in flight")
    t = threading.Thread(target=eng.drain,
                         kwargs={"deadline_s": 120.0, "timeout": 120.0})
    t.start()
    time.sleep(0.2)
    eng.close(timeout=5.0)                      # close races the drain
    t.join(timeout=30.0)
    assert not t.is_alive()
    for r in reqs:                              # typed or done — never hung
        try:
            r.result(timeout=5.0)
        except ServeError:
            pass


def test_watchdog_fires_mid_drain(setup):
    cfg, params = setup
    prompts = _prompts(cfg, (10,), seed=12)
    eng = ServeEngine(cfg, params, max_batch=2, decode_chunk=2,
                      kv_blocks=64, block_size=8, paged_impl="gather",
                      watchdog_s=0.3,
                      fault_inject="chunk_latency:at=2,ms=1500")
    r = eng.submit(prompts[0], 64)
    _wait(lambda: r.admitted_at is not None, what="row seated")
    eng.drain(deadline_s=30.0, timeout=30.0)
    # the injected stall tripped the watchdog while draining: the future
    # is typed, drain returned, close is clean — nothing hangs
    with pytest.raises(ServeError):
        r.result(timeout=10.0)
    assert eng.stats["watchdog_fires"] > 0
    eng.close(timeout=5.0)
