import threading
import time

from repro.core import EventNotifier, Waiter


def test_notify_between_prepare_and_commit_not_lost():
    n = EventNotifier()
    w = Waiter()
    n.prepare_wait(w)
    n.notify_one()                       # races in between the two phases
    t0 = time.perf_counter()
    assert n.commit_wait(w) is True      # must return immediately
    assert time.perf_counter() - t0 < 0.5


def test_commit_wait_backstop_timeout_returns_false():
    """No notification at all: commit_wait must report the backstop
    timeout as False (it used to return ``woke or True`` == True)."""
    n = EventNotifier(backstop_s=0.05)
    w = Waiter()
    n.prepare_wait(w)
    t0 = time.perf_counter()
    assert n.commit_wait(w) is False
    assert time.perf_counter() - t0 >= 0.04      # actually slept
    assert n.spurious_wakeups == 1


def test_commit_wait_notified_mid_sleep_returns_true():
    n = EventNotifier(backstop_s=5.0)
    w = Waiter()
    n.prepare_wait(w)
    t = threading.Timer(0.05, n.notify_one)
    t.start()
    t0 = time.perf_counter()
    assert n.commit_wait(w) is True
    assert time.perf_counter() - t0 < 2.0        # woke well before backstop
    t.join()
    assert n.spurious_wakeups == 0


def test_cancel_wait():
    n = EventNotifier()
    w = Waiter()
    n.prepare_wait(w)
    n.cancel_wait(w)
    assert w.epoch == -1


def test_wakeup_under_stress():
    """Producers notify after flag-set; consumers must always observe the
    flag (no lost wakeups across 200 rounds)."""
    n = EventNotifier(backstop_s=5.0)
    flag = [0]
    results = []

    def consumer():
        for expect in range(1, 201):
            w = Waiter()
            while True:
                if flag[0] >= expect:
                    break
                n.prepare_wait(w)
                if flag[0] >= expect:          # re-check (2PC!)
                    n.cancel_wait(w)
                    break
                n.commit_wait(w)
            results.append(expect)

    def producer():
        for _ in range(200):
            flag[0] += 1
            n.notify_all()
            time.sleep(0)

    ct = threading.Thread(target=consumer)
    pt = threading.Thread(target=producer)
    ct.start()
    time.sleep(0.01)
    pt.start()
    ct.join(timeout=30)
    pt.join(timeout=30)
    assert results == list(range(1, 201))
    assert n.spurious_wakeups < 50  # liveness backstop rarely needed
