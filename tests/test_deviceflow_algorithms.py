import threading

import jax.numpy as jnp
import numpy as np

from repro.core import ACCEL, DeviceFlow, Executor, HOST, Taskflow
from repro.core.algorithms import linear_pipeline, parallel_for, parallel_reduce


def test_deviceflow_capture_and_offload():
    df = DeviceFlow()
    x = np.arange(8, dtype=np.float32)
    df.copy("x", x)
    df.kernel(lambda x: x * 2.0, ["x"], ["y"])
    df.kernel(lambda x, y: x + y, ["x", "y"], ["z"])
    df.fetch("z")
    out = df.offload()
    np.testing.assert_allclose(out["z"], x * 3.0)
    # repeated offload reuses the compiled program (single launch each)
    df.offload(2)
    assert df.num_launches == 3


def test_deviceflow_call_convenience():
    df = DeviceFlow()
    df.call(lambda a, b: jnp.dot(a, b), np.ones((4, 4), np.float32),
            np.ones((4,), np.float32), out="r")
    out = df.offload()
    np.testing.assert_allclose(out["r"], np.full(4, 4.0))


def test_deviceflow_task_in_executor():
    results = {}
    ex = Executor(domains={HOST: 1, ACCEL: 1})
    try:
        tf = Taskflow()

        def build(df: DeviceFlow):
            df.copy("a", np.full(16, 3.0, np.float32))
            df.kernel(lambda a: jnp.sum(a * a), ["a"], ["s"])
            df.fetch("s")
            results["df"] = df

        t = tf.device(build)
        done = tf.static(lambda: results.__setitem__(
            "val", float(results["df"].result("s"))))
        t.precede(done)
        ex.run(tf).wait()
        assert results["val"] == 16 * 9.0
    finally:
        ex.shutdown()


def test_parallel_for(executor):
    tf = Taskflow()
    out = [0] * 100
    entry, exit_ = parallel_for(tf, 100, lambda i: out.__setitem__(i, i * i),
                                chunk=7)
    check = tf.static(lambda: None)
    exit_.precede(check)
    executor.run(tf).wait()
    assert out == [i * i for i in range(100)]


def test_parallel_reduce(executor):
    tf = Taskflow()
    result = [None]
    parallel_reduce(tf, list(range(1, 101)), lambda a, b: a + b, 0,
                    result, chunk=9)
    executor.run(tf).wait()
    assert result[0] == 5050


def test_linear_pipeline(executor):
    tf = Taskflow()
    items = list(range(20))
    it = iter(items)
    lock = threading.Lock()
    sunk = []

    def source():
        with lock:
            return next(it, None)

    linear_pipeline(tf, [lambda x: x + 1, lambda x: x * 2],
                    source, lambda v: sunk.append(v), depth=3)
    executor.run(tf).wait()
    assert sorted(sunk) == sorted((x + 1) * 2 for x in items)
