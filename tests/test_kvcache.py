"""Paged KV-cache pool: allocator invariants + gather/scatter correctness."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.serve.kvcache import (SINK_BLOCK, BlockPool, append_kv,
                                 gather_pages, init_kv_pool,
                                 scatter_prefill_row)


# ------------------------------------------------------------- allocator
def test_alloc_free_accounting():
    bp = BlockPool(num_blocks=9, block_size=4)
    assert bp.num_free == 8          # block 0 is the reserved sink
    a = bp.alloc(3)
    b = bp.alloc(2)
    assert SINK_BLOCK not in a + b
    assert len(set(a + b)) == 5      # no id handed out twice
    assert bp.num_free == 3 and bp.num_allocated == 5
    bp.free(a)
    assert bp.num_free == 6 and bp.num_allocated == 2
    c = bp.alloc(6)                  # re-uses the freed ids
    assert len(set(b + c)) == 8
    assert bp.num_free == 0


def test_alloc_is_all_or_nothing():
    bp = BlockPool(num_blocks=5, block_size=4)
    assert bp.alloc(5) is None       # only 4 usable
    assert bp.num_free == 4          # nothing was taken
    got = bp.alloc(4)
    assert got is not None and bp.alloc(1) is None


def test_double_free_and_sink_free_raise():
    bp = BlockPool(num_blocks=4, block_size=2)
    ids = bp.alloc(2)
    bp.free(ids)
    with pytest.raises(ValueError, match="not allocated"):
        bp.free([ids[0]])
    with pytest.raises(ValueError, match="not allocated"):
        bp.free([SINK_BLOCK])


def test_blocks_for_and_fragmentation():
    bp = BlockPool(num_blocks=17, block_size=4)
    assert bp.blocks_for(1) == 1 and bp.blocks_for(4) == 1
    assert bp.blocks_for(5) == 2 and bp.blocks_for(17) == 5
    # carve holes: free every other allocation
    held = [bp.alloc(1) for _ in range(16)]
    for i in range(0, 16, 2):
        bp.free(held[i])
    frag = bp.fragmentation()
    assert frag > 0.5                # free set is maximally shattered
    assert bp.defragment() == pytest.approx(bp.fragmentation())
    # freeing the rest makes the free set contiguous again
    for i in range(1, 16, 2):
        bp.free(held[i])
    assert bp.fragmentation() == 0.0


def test_defragment_ascending_run_property():
    """After compaction the free list hands out ASCENDING, contiguous-when-
    possible id runs (LIFO pop order), and the metric never increases."""
    bp = BlockPool(num_blocks=33, block_size=4)
    held = [bp.alloc(1) for _ in range(32)]
    import random
    random.Random(7).shuffle(held)
    for ids in held[:24]:                # free in shuffled order
        bp.free(ids)
    before = bp.fragmentation()
    after = bp.defragment()
    assert after <= before
    # ascending-run property: subsequent allocations pop ascending ids,
    # and with every lower id free the run is perfectly contiguous
    got = bp.alloc(24)
    assert all(b > a for a, b in zip(got, got[1:]))  # strictly ascending
    # the freed ids come back as the sorted set itself: one pass, no holes
    # beyond those still held by the live allocations
    assert got == sorted(got)


def test_double_free_raises_and_frees_nothing_further():
    """The double-free ValueError path: a batch containing an already-free
    id raises, ids BEFORE the bad one in the batch are returned (the loop
    is not transactional — documented behaviour), nothing after."""
    bp = BlockPool(num_blocks=8, block_size=2)
    a = bp.alloc(3)
    b = bp.alloc(2)
    bp.free([a[0]])
    free_before = bp.num_free
    with pytest.raises(ValueError, match=f"free of block {a[0]}"):
        bp.free([a[1], a[0], a[2]])      # a[1] freed, a[0] double-free
    assert bp.num_free == free_before + 1      # only a[1] made it back
    assert bp.num_allocated == 1 + len(b)      # a[2] still held
    bp.free([a[2]] + b)                        # and still freeable


def test_grow_table_extends_in_place_and_is_all_or_nothing():
    """Mid-decode growth: grow_table appends the granted ids to the row's
    block list; on exhaustion it returns None and takes nothing (the
    engine's preemption signal)."""
    bp = BlockPool(num_blocks=6, block_size=4)
    mine = bp.alloc(2)
    snapshot = list(mine)
    got = bp.grow_table(mine, 2)
    assert got is not None and mine == snapshot + got
    assert bp.num_free == 1
    assert bp.grow_table(mine, 2) is None      # all-or-nothing: 1 < 2
    assert bp.num_free == 1 and len(mine) == 4
    bp.free(mine)


# ------------------------------------------------------- gather / scatter
def test_scatter_gather_roundtrip_and_sink():
    cfg = get_config("stablelm-1.6b").smoke()
    pool = init_kv_pool(cfg, num_blocks=8, block_size=4)
    L, two, N, KV, bs, hd = pool.shape
    assert two == 2                  # K and V stacked: one-scatter appends
    S = 6
    rng = np.random.default_rng(0)
    krow = jnp.asarray(rng.standard_normal((L, KV, S, hd)), pool.dtype)
    vrow = jnp.asarray(rng.standard_normal((L, KV, S, hd)), pool.dtype)
    blocks = jnp.asarray([3, 5], jnp.int32)
    pool = scatter_prefill_row(pool, blocks, krow, vrow)
    tables = jnp.zeros((1, 3), jnp.int32).at[0, :2].set(blocks)
    ks, vs = gather_pages(pool[0], tables)       # (1, KV, 3*bs, hd) each
    np.testing.assert_array_equal(np.asarray(ks[0, :, :S]),
                                  np.asarray(krow[0]))
    np.testing.assert_array_equal(np.asarray(vs[0, :, :S]),
                                  np.asarray(vrow[0]))
    # table tail points at the sink: those positions read zeros
    np.testing.assert_array_equal(np.asarray(ks[0, :, 2 * bs:]), 0.0)

    # append the 7th token (block idx 1, offset 2) on the active row:
    # K and V land in ONE scatter
    new_k = jnp.full((1, KV, hd), 7.0, pool.dtype)
    new_v = jnp.full((1, KV, hd), 5.0, pool.dtype)
    p_act = append_kv(pool[0], new_k, new_v, tables,
                      jnp.asarray([S], jnp.int32), jnp.asarray([True]))
    ks2, vs2 = gather_pages(p_act, tables)
    np.testing.assert_array_equal(np.asarray(ks2[0, :, S]),
                                  np.asarray(new_k[0]))
    np.testing.assert_array_equal(np.asarray(vs2[0, :, S]),
                                  np.asarray(new_v[0]))
    # inactive row: the write is redirected to the sink block
    p_in = append_kv(pool[0], new_k * 9, new_v * 9, tables,
                     jnp.asarray([S], jnp.int32), jnp.asarray([False]))
    np.testing.assert_array_equal(np.asarray(p_in[:, 3:6]),
                                  np.asarray(pool[0][:, 3:6]))
    assert np.any(np.asarray(p_in[0, SINK_BLOCK]) == 63.0)
    assert np.any(np.asarray(p_in[1, SINK_BLOCK]) == 45.0)


def test_scatter_token_window_and_table_extension():
    """Chunked-prefill window scatter through the tables + the device-side
    per-row table-extension scatter used by mid-decode growth."""
    from repro.serve.kvcache import (extend_block_tables,
                                     scatter_token_window, set_table_rows)
    cfg = get_config("stablelm-1.6b").smoke()
    pool = init_kv_pool(cfg, num_blocks=8, block_size=4)
    L, _, N, KV, bs, hd = pool.shape
    B, mb = 2, 4
    tables = jnp.zeros((B, mb), jnp.int32)
    tables = set_table_rows(tables, jnp.asarray([1], jnp.int32),
                            jnp.asarray([[2, 3, 0, 0]], jnp.int32))
    # grow row 1 by one block at column 2 — in-place device scatter
    tables = extend_block_tables(tables, jnp.asarray([1], jnp.int32),
                                 jnp.asarray([2], jnp.int32),
                                 jnp.asarray([6], jnp.int32))
    np.testing.assert_array_equal(np.asarray(tables),
                                  [[0, 0, 0, 0], [2, 3, 6, 0]])
    # write a 5-token window starting at position 6 on row 1 (crosses the
    # block-1 -> block-2 boundary); row 0 invalid -> sink
    C = 5
    rng = np.random.default_rng(0)
    ks = jnp.asarray(rng.standard_normal((B, C, KV, hd)), pool.dtype)
    vs = jnp.asarray(rng.standard_normal((B, C, KV, hd)), pool.dtype)
    valid = np.zeros((B, C), bool)
    valid[1, :4] = True                  # 4 valid tokens, 1 past-prompt
    p0 = scatter_token_window(pool[0], ks, vs, tables,
                              jnp.asarray([0, 6], jnp.int32),
                              jnp.asarray(valid))
    got_k, got_v = gather_pages(p0, tables)
    np.testing.assert_array_equal(np.asarray(got_k[1, :, 6:10]),
                                  np.asarray(ks[1, :4]).swapaxes(0, 1))
    np.testing.assert_array_equal(np.asarray(got_v[1, :, 6:10]),
                                  np.asarray(vs[1, :4]).swapaxes(0, 1))
    # row 0 (all invalid) and the past-prompt tail went to the sink: blocks
    # owned by nobody are untouched
    for untouched in (1, 4, 5, 7):
        np.testing.assert_array_equal(np.asarray(p0[:, untouched]),
                                      np.asarray(pool[0][:, untouched]))


def test_init_kv_pool_rejects_ssm():
    cfg = get_config("falcon-mamba-7b").smoke()
    with pytest.raises(ValueError, match="attention"):
        init_kv_pool(cfg, 8, 4)


def test_set_carry_rows_scatter():
    from repro.serve.kvcache import set_carry_rows
    lengths = jnp.asarray([5, 0, 9, 0], jnp.int32)
    last = jnp.asarray([11, 0, 12, 0], jnp.int32)
    rem = jnp.asarray([3, 0, 1, 0], jnp.int32)
    # seat rows 1 and 3; pad with a repeat of row 3 (idempotent)
    rows = jnp.asarray([1, 3, 3], jnp.int32)
    ln, la, rm = set_carry_rows(
        lengths, last, rem, rows,
        jnp.asarray([7, 4, 4], jnp.int32),
        jnp.asarray([21, 22, 22], jnp.int32),
        jnp.asarray([8, 6, 6], jnp.int32))
    np.testing.assert_array_equal(np.asarray(ln), [5, 7, 9, 4])
    np.testing.assert_array_equal(np.asarray(la), [11, 21, 12, 22])
    np.testing.assert_array_equal(np.asarray(rm), [3, 8, 1, 6])


def test_deferred_free_keeps_invariant_and_defragment():
    """Deferred blocks stay allocated for accounting, are skipped by
    defragment's free-list sort, and release in FIFO fence order."""
    bp = BlockPool(num_blocks=9, block_size=4)
    a = bp.alloc(4)
    b = bp.alloc(2)
    bp.free_deferred(a)
    bp.free(b)
    assert bp.num_free + bp.num_allocated == bp.num_blocks - 1
    assert bp.num_deferred == 4
    bp.defragment()                  # must not disturb deferred blocks
    assert bp.num_deferred == 4
    bp.release_deferred()
    bp.free_deferred(bp.alloc(1))    # second batch enters young stage
    assert bp.release_deferred() == 4
    assert bp.num_deferred == 1
    assert bp.release_deferred() == 1
    assert bp.num_free == bp.num_blocks - 1


# --------------------------------------------------- refcounts (prefix CoW)
def test_refcount_lifecycle_and_shared_accounting():
    """alloc -> rc 1, incref pins, each free drops ONE ref, release only at
    zero; num_shared counts rc>1 blocks; the num_free+num_allocated
    invariant never sees a shared block twice."""
    bp = BlockPool(num_blocks=9, block_size=4)
    ids = bp.alloc(3)
    assert all(bp.refcount(b) == 1 for b in ids)
    assert bp.num_shared == 0
    bp.incref(ids[:2])
    assert bp.refcount(ids[0]) == 2 and bp.num_shared == 2
    assert bp.num_free + bp.num_allocated == bp.num_blocks - 1
    bp.free(ids)                       # ids[2] released, ids[0:2] survive
    assert bp.num_free == 6 and bp.num_allocated == 2
    assert bp.refcount(ids[2]) == 0
    got = bp.alloc(6)                  # never re-hands a live-ref block
    assert ids[0] not in got and ids[1] not in got
    bp.free(got)
    bp.free(ids[:2])
    assert bp.num_free == bp.num_blocks - 1


def test_refcount_free_deferred_last_ref_only_fences():
    """free_deferred on a shared block just unpins; the LAST reference is
    what enters the fence — and a parked co-holder keeps the block out of
    defragment's way the whole time."""
    bp = BlockPool(num_blocks=6, block_size=4)
    ids = bp.alloc(2)
    bp.incref(ids)
    bp.free_deferred(ids)              # co-holder remains: no fence
    assert bp.num_deferred == 0
    assert all(bp.refcount(b) == 1 for b in ids)
    bp.defragment()                    # live-ref blocks not in free list
    bp.free_deferred(ids)              # last refs: fenced now
    assert bp.num_deferred == 2
    with pytest.raises(ValueError, match="not live"):
        bp.incref(ids[:1])             # deferred blocks are un-pinnable
    bp.release_deferred()
    assert bp.release_deferred() == 2
    assert bp.num_free == bp.num_blocks - 1


def test_defragment_raises_on_live_block_in_free_list():
    """Regression for the refcount-era defragment: a live or fenced id in
    the free list means the accounting is corrupt — sort must refuse
    instead of silently blessing a block some table still reads."""
    bp = BlockPool(num_blocks=6, block_size=4)
    ids = bp.alloc(2)
    bp._free.append(ids[1])            # simulate upstream corruption
    with pytest.raises(RuntimeError, match="corrupt"):
        bp.defragment()
    bp._free.remove(ids[1])
    bp.free(ids)
    assert bp.defragment() == 0.0


def test_copy_blocks_forks_without_touching_source():
    """The CoW device primitive: dst pages become bit-copies of src pages,
    src pages and every other page are untouched, and the SINK->SINK
    padding convention is a harmless self-copy."""
    from repro.serve.kvcache import copy_blocks
    rng = np.random.default_rng(0)
    pool = jnp.asarray(rng.normal(size=(2, 2, 6, 1, 4, 3)), jnp.float32)
    before = np.asarray(pool)
    out = np.asarray(copy_blocks(
        pool, jnp.asarray([2, 4, SINK_BLOCK], jnp.int32),
        jnp.asarray([5, 1, SINK_BLOCK], jnp.int32)))
    np.testing.assert_array_equal(out[:, :, 5], before[:, :, 2])
    np.testing.assert_array_equal(out[:, :, 1], before[:, :, 4])
    for untouched in (0, 2, 3, 4):
        np.testing.assert_array_equal(out[:, :, untouched],
                                      before[:, :, untouched])
