"""Paged KV-cache pool: allocator invariants + gather/scatter correctness."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.serve.kvcache import (SINK_BLOCK, BlockPool, append_kv,
                                 gather_pages, init_kv_pool,
                                 scatter_prefill_row)


# ------------------------------------------------------------- allocator
def test_alloc_free_accounting():
    bp = BlockPool(num_blocks=9, block_size=4)
    assert bp.num_free == 8          # block 0 is the reserved sink
    a = bp.alloc(3)
    b = bp.alloc(2)
    assert SINK_BLOCK not in a + b
    assert len(set(a + b)) == 5      # no id handed out twice
    assert bp.num_free == 3 and bp.num_allocated == 5
    bp.free(a)
    assert bp.num_free == 6 and bp.num_allocated == 2
    c = bp.alloc(6)                  # re-uses the freed ids
    assert len(set(b + c)) == 8
    assert bp.num_free == 0


def test_alloc_is_all_or_nothing():
    bp = BlockPool(num_blocks=5, block_size=4)
    assert bp.alloc(5) is None       # only 4 usable
    assert bp.num_free == 4          # nothing was taken
    got = bp.alloc(4)
    assert got is not None and bp.alloc(1) is None


def test_double_free_and_sink_free_raise():
    bp = BlockPool(num_blocks=4, block_size=2)
    ids = bp.alloc(2)
    bp.free(ids)
    with pytest.raises(ValueError, match="not allocated"):
        bp.free([ids[0]])
    with pytest.raises(ValueError, match="not allocated"):
        bp.free([SINK_BLOCK])


def test_blocks_for_and_fragmentation():
    bp = BlockPool(num_blocks=17, block_size=4)
    assert bp.blocks_for(1) == 1 and bp.blocks_for(4) == 1
    assert bp.blocks_for(5) == 2 and bp.blocks_for(17) == 5
    # carve holes: free every other allocation
    held = [bp.alloc(1) for _ in range(16)]
    for i in range(0, 16, 2):
        bp.free(held[i])
    frag = bp.fragmentation()
    assert frag > 0.5                # free set is maximally shattered
    assert bp.defragment() == pytest.approx(bp.fragmentation())
    # freeing the rest makes the free set contiguous again
    for i in range(1, 16, 2):
        bp.free(held[i])
    assert bp.fragmentation() == 0.0


# ------------------------------------------------------- gather / scatter
def test_scatter_gather_roundtrip_and_sink():
    cfg = get_config("stablelm-1.6b").smoke()
    pool = init_kv_pool(cfg, num_blocks=8, block_size=4)
    L, two, N, KV, bs, hd = pool.shape
    assert two == 2                  # K and V stacked: one-scatter appends
    S = 6
    rng = np.random.default_rng(0)
    krow = jnp.asarray(rng.standard_normal((L, KV, S, hd)), pool.dtype)
    vrow = jnp.asarray(rng.standard_normal((L, KV, S, hd)), pool.dtype)
    blocks = jnp.asarray([3, 5], jnp.int32)
    pool = scatter_prefill_row(pool, blocks, krow, vrow)
    tables = jnp.zeros((1, 3), jnp.int32).at[0, :2].set(blocks)
    ks, vs = gather_pages(pool[0], tables)       # (1, KV, 3*bs, hd) each
    np.testing.assert_array_equal(np.asarray(ks[0, :, :S]),
                                  np.asarray(krow[0]))
    np.testing.assert_array_equal(np.asarray(vs[0, :, :S]),
                                  np.asarray(vrow[0]))
    # table tail points at the sink: those positions read zeros
    np.testing.assert_array_equal(np.asarray(ks[0, :, 2 * bs:]), 0.0)

    # append the 7th token (block idx 1, offset 2) on the active row:
    # K and V land in ONE scatter
    new_k = jnp.full((1, KV, hd), 7.0, pool.dtype)
    new_v = jnp.full((1, KV, hd), 5.0, pool.dtype)
    p_act = append_kv(pool[0], new_k, new_v, tables,
                      jnp.asarray([S], jnp.int32), jnp.asarray([True]))
    ks2, vs2 = gather_pages(p_act, tables)
    np.testing.assert_array_equal(np.asarray(ks2[0, :, S]),
                                  np.asarray(new_k[0]))
    np.testing.assert_array_equal(np.asarray(vs2[0, :, S]),
                                  np.asarray(new_v[0]))
    # inactive row: the write is redirected to the sink block
    p_in = append_kv(pool[0], new_k * 9, new_v * 9, tables,
                     jnp.asarray([S], jnp.int32), jnp.asarray([False]))
    np.testing.assert_array_equal(np.asarray(p_in[:, 3:6]),
                                  np.asarray(pool[0][:, 3:6]))
    assert np.any(np.asarray(p_in[0, SINK_BLOCK]) == 63.0)
    assert np.any(np.asarray(p_in[1, SINK_BLOCK]) == 45.0)


def test_init_kv_pool_rejects_ssm():
    cfg = get_config("falcon-mamba-7b").smoke()
    with pytest.raises(ValueError, match="attention"):
        init_kv_pool(cfg, 8, 4)
