"""End-to-end behaviour tests for the whole system: the trainer taskflow
trains a small model on the learnable synthetic bigram stream and the loss
must drop substantially; serving then runs off the trained weights."""
import numpy as np
import pytest

from repro.configs import get_config
from repro.optim.adamw import OptConfig
from repro.serve.engine import ServeEngine
from repro.train.trainer import Trainer, TrainerConfig


@pytest.mark.slow
def test_end_to_end_training_reduces_loss(tmp_path):
    cfg = get_config("stablelm-1.6b").smoke()
    steps = 60
    tc = TrainerConfig(total_steps=steps, ckpt_every=25, log_every=5,
                       microbatches=1, seed=0)
    tr = Trainer(cfg, tc, batch=8, seq_len=64,
                 opt=OptConfig(lr=3e-3, warmup_steps=10, total_steps=steps,
                               weight_decay=0.0),
                 ckpt_dir=str(tmp_path / "ckpt"))
    out = tr.run()
    hist = out["history"]
    first = hist[0]["loss"]
    last = min(h["loss"] for h in hist[-3:])
    # bigram data: ~64 tokens of 503 are reachable per context -> the loss
    # should fall well below the uniform floor ln(503)=6.22
    assert last < first - 0.5, (first, last)

    # serve from the trained params
    eng = ServeEngine(cfg, out["state"]["params"], decode_chunk=4)
    outs = eng.generate([np.arange(1, 9, dtype=np.int32)], max_new=6)
    assert outs[0].shape == (6,)
    assert all(0 <= t < cfg.padded_vocab for t in outs[0])


@pytest.mark.slow
def test_resume_is_deterministic(tmp_path):
    """Train 8 steps straight vs 4 + resume + 4: same data path, and the
    final losses agree closely (state roundtrips through the checkpoint)."""
    cfg = get_config("internvl2-1b").smoke()
    opt = OptConfig(lr=1e-3, warmup_steps=2, total_steps=8)

    tcA = TrainerConfig(total_steps=8, ckpt_every=100, log_every=1, seed=1)
    a = Trainer(cfg, tcA, batch=2, seq_len=32, opt=opt,
                ckpt_dir=str(tmp_path / "a")).run()

    tcB1 = TrainerConfig(total_steps=4, ckpt_every=4, log_every=1, seed=1)
    Trainer(cfg, tcB1, batch=2, seq_len=32, opt=opt,
            ckpt_dir=str(tmp_path / "b")).run()
    tcB2 = TrainerConfig(total_steps=8, ckpt_every=4, log_every=1, seed=1)
    b = Trainer(cfg, tcB2, batch=2, seq_len=32, opt=opt,
                ckpt_dir=str(tmp_path / "b")).run()

    la = [h for h in a["history"] if h["step"] == 7][0]["loss"]
    lb = [h for h in b["history"] if h["step"] == 7][0]["loss"]
    assert abs(la - lb) < 5e-2, (la, lb)
