"""Serve-layer observability: tracer/metrics units, Chrome trace export
schema, and end-to-end span/metric consistency through the serve engine.

The e2e tests validate the ISSUE's acceptance contract: a traced serve run
produces a Perfetto-loadable artifact whose spans reconstruct every
request lifecycle (queued -> admitted -> decode -> retired, preemption
re-entries included) and whose metrics agree with the engine's own
bookkeeping (TTFT histogram count == completed requests, decode span time
bounded by wall time, pool gauges drained to idle).
"""
import json
import math
import threading
import time

import numpy as np
import pytest

from repro.obs import (TRACK_ENGINE, Counter, Gauge, Histogram,
                       MetricsRegistry, Observability, StatsLogger, Tracer,
                       chrome_trace_events, env_enabled, export_chrome_trace,
                       from_env)


# ------------------------------------------------------------------ tracer
def test_tracer_ring_wrap_keeps_newest_and_counts_dropped():
    tr = Tracer(capacity=8)
    for i in range(11):
        tr.add(f"s{i}", "t", float(i), float(i) + 0.5)
    assert len(tr) == 8
    assert tr.dropped == 3
    names = [s[0] for s in tr.spans()]
    assert names == [f"s{i}" for i in range(3, 11)]  # oldest-first, newest 8
    tr.clear()
    assert len(tr) == 0 and tr.dropped == 0


def test_tracer_disabled_records_nothing():
    tr = Tracer(enabled=False)
    tr.add("a", "t", 0.0, 1.0)
    tr.instant("b", "t")
    with tr.span("c", "t"):
        pass
    assert len(tr) == 0
    tr.enabled = True           # re-checked per call
    tr.add("a", "t", 0.0, 1.0)
    assert len(tr) == 1


def test_tracer_span_context_and_instant():
    tr = Tracer()
    with tr.span("work", "t", {"k": 1}):
        pass
    tr.instant("mark", "t")
    spans = tr.spans()
    assert [s[0] for s in spans] == ["work", "mark"]
    work, mark = spans
    assert work[3] >= work[2] and work[4] == {"k": 1}
    assert mark[2] == mark[3]   # zero duration == instant
    t0 = tr.t0
    tr.clear()
    assert tr.t0 == t0          # one clock across clears


def test_tracer_thread_safety_no_lost_spans():
    tr = Tracer(capacity=10_000)

    def burst(k):
        for i in range(500):
            tr.add(f"w{k}", "t", 0.0, 1.0)

    threads = [threading.Thread(target=burst, args=(k,)) for k in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert len(tr) == 2000 and tr.dropped == 0


# ----------------------------------------------------------------- metrics
def test_counter_and_gauge():
    c = Counter("c")
    c.inc()
    c.inc(4)
    assert c.value == 5
    c.reset()
    assert c.value == 0
    g = Gauge("g")
    g.set(7)
    g.inc(-2)
    assert g.value == 5


def test_histogram_exact_percentiles_and_summary():
    h = Histogram("h")
    for i in range(1, 101):                      # 1ms .. 100ms
        h.record(i / 1000.0)
    assert h.count == 100
    assert h.percentile(50) == pytest.approx(0.050)
    assert h.percentile(99) == pytest.approx(0.099)
    assert h.percentile(100) == pytest.approx(0.100)
    s = h.summary()
    assert s["count"] == 100
    assert s["min"] == pytest.approx(0.001)
    assert s["max"] == pytest.approx(0.100)
    assert s["mean"] == pytest.approx(sum(range(1, 101)) / 100 / 1000.0)
    assert s["p50"] == pytest.approx(0.050)


def test_histogram_bucket_fallback_beyond_retention():
    h = Histogram("h", keep_samples=10)
    rng = np.random.default_rng(0)
    vals = rng.lognormal(mean=math.log(0.01), sigma=1.0, size=2000)
    for v in vals:
        h.record(float(v))
    # beyond the retention cap: bucket interpolation, still within one
    # growth factor of the exact percentile (geometric-midpoint bound)
    for q in (50.0, 99.0):
        exact = float(np.percentile(vals, q))
        approx = h.percentile(q)
        assert exact / h.growth <= approx <= exact * h.growth
    assert h.summary()["count"] == 2000


def test_histogram_validation_and_empty():
    with pytest.raises(ValueError):
        Histogram("h", base=0.0)
    h = Histogram("h")
    assert h.percentile(50) == 0.0
    assert h.summary()["count"] == 0
    with pytest.raises(ValueError):
        h.percentile(101)


def test_registry_get_or_create_kind_mismatch_and_inplace_reset():
    reg = MetricsRegistry()
    c = reg.counter("x")
    assert reg.counter("x") is c                 # get-or-create
    with pytest.raises(TypeError, match="already registered"):
        reg.gauge("x")
    h = reg.histogram("lat")
    c.inc(3)
    h.record(0.5)
    snap = reg.snapshot()
    assert snap["x"] == 3 and snap["lat"]["count"] == 1
    reg.reset()
    assert c.value == 0 and h.count == 0         # SAME handles, zeroed
    assert reg.names() == ["lat", "x"]


# ------------------------------------------------------------------ export
def _validate_chrome_trace(payload):
    """The trace-event-schema assertions the ISSUE's acceptance names."""
    assert set(payload) >= {"traceEvents", "displayTimeUnit", "otherData"}
    assert payload["displayTimeUnit"] == "ms"
    assert {"spans", "dropped_spans"} <= set(payload["otherData"])
    events = payload["traceEvents"]
    tracks = {}
    for ev in events:
        assert {"name", "ph", "pid", "tid"} <= set(ev)
        assert ev["ph"] in ("M", "X", "i")
        if ev["ph"] == "M":
            if ev["name"] == "thread_name":
                tracks[ev["tid"]] = ev["args"]["name"]
        elif ev["ph"] == "X":
            assert ev["ts"] >= 0 and ev["dur"] > 0
        else:                                    # instant
            assert ev["s"] == "t" and "dur" not in ev
    n_spans = sum(ev["ph"] in ("X", "i") for ev in events)
    assert n_spans == payload["otherData"]["spans"]
    # every span event rides a named track
    for ev in events:
        if ev["ph"] in ("X", "i"):
            assert ev["tid"] in tracks
    return tracks


def test_export_chrome_trace_schema(tmp_path):
    tr = Tracer()
    t0 = tr.t0
    tr.add("cycle", TRACK_ENGINE, t0 + 0.001, t0 + 0.002)
    tr.add("decode", "slot0", t0 + 0.001, t0 + 0.003, {"req": 1})
    tr.add("decode", "slot10", t0 + 0.002, t0 + 0.004)
    tr.add("decode", "slot2", t0 + 0.002, t0 + 0.004)
    tr.instant("retired", "slot0", t0 + 0.005, {"req": 1})
    reg = MetricsRegistry()
    reg.counter("serve.tokens_out").inc(42)
    path = str(tmp_path / "trace.json")
    export_chrome_trace(path, tr, reg)
    payload = json.loads(open(path).read())
    tracks = _validate_chrome_trace(payload)
    assert payload["otherData"]["metrics"]["serve.tokens_out"] == 42
    # engine track first, then natural (slot2 < slot10) order
    ordered = [tracks[tid] for tid in sorted(tracks)]
    assert ordered == [TRACK_ENGINE, "slot0", "slot2", "slot10"]
    # args survive the round trip
    ev = next(e for e in payload["traceEvents"]
              if e["ph"] == "X" and e["args"].get("req") == 1)
    assert ev["name"] == "decode"


def test_stats_logger_line_and_thread():
    reg = MetricsRegistry()
    tok = reg.counter("serve.tokens_out")
    reg.gauge("serve.queue_depth").set(3)
    reg.histogram("serve.ttft_s").record(0.25)
    lines = []
    logger = StatsLogger(reg, interval=0.05, emit=lines.append)
    tok.inc(100)
    line = logger.line()
    assert "tok/s" in line and "queue 3" in line and "ttft_p50 250ms" in line
    logger.start()
    with pytest.raises(RuntimeError, match="already started"):
        logger.start()
    tok.inc(50)
    time.sleep(0.2)
    logger.stop()
    assert lines, "logger thread emitted nothing"
    logger.stop()                                # idempotent
    with pytest.raises(ValueError):
        StatsLogger(reg, interval=0.0)


def test_observability_bundle_and_env(tmp_path, monkeypatch):
    obs = Observability(trace_capacity=16)
    t0 = obs.tracer.t0
    obs.tracer.add("a", "t", t0 + 0.1, t0 + 0.2)
    obs.metrics.counter("c").inc()
    path = obs.export(str(tmp_path / "t.json"))
    _validate_chrome_trace(json.loads(open(path).read()))
    obs.reset()
    assert len(obs.tracer) == 0 and obs.metrics.snapshot()["c"] == 0

    assert env_enabled("1") and env_enabled("TRUE") and env_enabled(" on ")
    assert not env_enabled("") and not env_enabled("0")
    monkeypatch.delenv("REPRO_OBS", raising=False)
    assert from_env() is None
    monkeypatch.setenv("REPRO_OBS", "1")
    assert isinstance(from_env(), Observability)


# ------------------------------------------------- ServeRequest lifecycle
def test_serve_request_timestamps_and_timeout_message():
    from repro.serve.scheduler import ServeRequest

    req = ServeRequest(np.arange(1, 5, dtype=np.int32), 4)
    assert req.ttft is None and req.queue_wait is None
    with pytest.raises(TimeoutError) as ei:
        req.result(timeout=0.01)
    msg = str(ei.value)
    assert "submitted_at=unset" in msg and "preempted 0x" in msg
    req.submitted_at = 10.0
    req.admitted_at = 10.5
    req.first_token_at = 11.0
    req.finished_at = 12.0
    assert req.queue_wait == pytest.approx(0.5)
    assert req.ttft == pytest.approx(1.0)
    with pytest.raises(TimeoutError) as ei:
        req.result(timeout=0.01)
    assert "first_token_at=11.000" in str(ei.value)


# ------------------------------------------------------------- engine e2e
@pytest.fixture(scope="module")
def setup():
    import jax
    from repro.configs import get_config
    from repro.models import lm

    cfg = get_config("stablelm-1.6b").smoke()
    return cfg, lm.init_params(cfg, jax.random.PRNGKey(0))


def test_engine_lifecycle_spans_and_metric_consistency(setup, tmp_path):
    from repro.serve.engine import ServeEngine

    cfg, params = setup
    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, cfg.vocab_size, size=s).astype(np.int32)
               for s in (4, 7, 4, 5)]
    max_new = 8
    obs = Observability()
    with ServeEngine(cfg, params, decode_chunk=4, obs=obs) as eng:
        t_run0 = time.perf_counter()
        reqs = [eng.submit(p, max_new) for p in prompts]
        outs = [eng.result(r, timeout=240.0) for r in reqs]
        wall = time.perf_counter() - t_run0
        assert all(o.shape == (max_new,) for o in outs)

        # ---- request timestamps: monotone lifecycle on one clock
        for r in reqs:
            assert r.submitted_at <= r.admitted_at <= r.first_token_at \
                <= r.finished_at
            assert r.ttft == pytest.approx(
                r.first_token_at - r.submitted_at)
            assert r.queue_wait >= 0.0

        # ---- spans reconstruct every lifecycle
        spans = obs.tracer.spans()
        by_name = {}
        for name, track, ts, te, args in spans:
            assert te >= ts
            by_name.setdefault(name, []).append((track, ts, te, args))
        for required in ("queued", "admitted", "decode", "retired",
                        "admission", "cycle"):
            assert required in by_name, f"missing {required} spans"
        # one queued->admitted chain and one retired instant per request
        for evt in ("queued", "admitted", "retired"):
            got = sorted(a["req"] for _, _, _, a in by_name[evt])
            assert got == sorted(r.id for r in reqs)
        assert all(t == TRACK_ENGINE for t, _, _, _ in by_name["cycle"])
        # "decode" spans live on BOTH slot tracks (request lifecycle) and
        # line tracks (the decode PIPE body) — the lifecycle ones are the
        # slot-track subset
        slot_decode = [(t, ts, te) for t, ts, te, _ in by_name["decode"]
                       if t.startswith("slot")]
        line_tracks = {t for t, _, _, _ in by_name["decode"]
                       if not t.startswith("slot")}
        assert slot_decode
        assert all(t.startswith("line") for t in line_tracks)

        # ---- acceptance: per-slot decode span time bounded by wall time
        per_slot = {}
        for track, ts, te in slot_decode:
            per_slot[track] = per_slot.get(track, 0.0) + (te - ts)
        assert per_slot and all(v <= wall for v in per_slot.values())

        # ---- metrics agree with the engine's own bookkeeping
        snap = obs.metrics.snapshot()
        assert snap["serve.ttft_s"]["count"] == len(reqs)  # acceptance
        assert snap["serve.queue_wait_s"]["count"] == len(reqs)
        assert snap["serve.requests.admitted"] == len(reqs)
        assert snap["serve.requests.retired"] == len(reqs)
        assert snap["serve.tokens_out"] == eng.stats["tokens_out"]
        assert snap["engine.cycle_s"]["count"] == len(by_name["cycle"])
        # drained: gauges back to idle
        assert snap["serve.queue_depth"] == 0
        assert snap["serve.resident_rows"] == 0
        assert snap["pool.blocks_used"] == 0
        # retired prompts' blocks stay PARKED in the prefix trie (rc 1)
        # when the cache is on; free + parked covers every usable block
        parked = eng._prefix.num_parked if eng._prefix is not None else 0
        assert snap["pool.blocks_free"] + parked == eng._pool.num_blocks - 1
        # TTFT histogram and per-request properties tell one story
        assert snap["serve.ttft_s"]["max"] <= wall

        path = str(tmp_path / "trace.json")
        obs.export(path)
    payload = json.loads(open(path).read())
    tracks = _validate_chrome_trace(payload)
    assert TRACK_ENGINE in tracks.values()
    assert any(t.startswith("slot") for t in tracks.values())
    assert any(t.startswith("line") for t in tracks.values())
    assert payload["otherData"]["metrics"]["serve.requests.retired"] \
        == len(reqs)


def test_preemption_reentry_visible_in_trace(setup):
    """Pool exhaustion preempts the youngest row; its track must show the
    re-entry: a second queued/admitted chain, a preempted instant, and
    preempt/grow counters equal to the engine's stats."""
    from repro.serve.engine import ServeEngine

    cfg, params = setup
    rng = np.random.default_rng(1)
    prompts = [rng.integers(1, cfg.vocab_size, size=16).astype(np.int32)
               for _ in range(2)]
    obs = Observability()
    with ServeEngine(cfg, params, decode_chunk=4, kv_blocks=10,
                     block_size=4, paged_impl="gather", obs=obs) as eng:
        reqs = [eng.submit(p, max_new=16) for p in prompts]
        [r.result(timeout=240.0) for r in reqs]
        stats = dict(eng.stats)
        snap = obs.metrics.snapshot()
        spans = obs.tracer.spans()
    assert stats["preempted"] >= 1
    assert snap["serve.requests.preempted"] == stats["preempted"]
    assert snap["pool.grown_blocks"] == stats["grown_blocks"]
    pre = [(t, a) for n, t, _, _, a in spans if n == "preempted"]
    assert len(pre) == stats["preempted"]
    victim_ids = {a["req"] for _, a in pre}
    # the victim was admitted more than once: the re-entry is on the trace
    for vid in victim_ids:
        admits = [1 for n, _, _, _, a in spans
                  if n == "admitted" and a["req"] == vid]
        assert len(admits) >= 2
        vr = next(r for r in reqs if r.id == vid)
        assert vr.preempted_count >= 1
    # TTFT still counts each request ONCE (first token only)
    assert snap["serve.ttft_s"]["count"] == len(reqs)
