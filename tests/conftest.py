import os

# Tests run on the single host device (smoke configs). The 512-device
# virtualization is ONLY for the dry-run (repro/launch/dryrun.py) and the
# subprocess-based mesh tests, which set XLA_FLAGS themselves.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import pytest  # noqa: E402


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running system test")


@pytest.fixture(scope="session")
def executor():
    from repro.core import Executor
    ex = Executor(domains={"host": 4})
    yield ex
    ex.shutdown(wait=False)
