import numpy as np

from repro.data.pipeline import DataConfig, Prefetcher, SyntheticLM


def test_batches_deterministic_by_step():
    cfg = DataConfig(vocab_size=100, seq_len=16, global_batch=4, seed=3)
    d1, d2 = SyntheticLM(cfg), SyntheticLM(cfg)
    b1 = d1.batch_at(7)
    b2 = d2.batch_at(7)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = d1.batch_at(8)
    assert not np.array_equal(b1["tokens"], b3["tokens"])


def test_bigram_structure_learnable():
    cfg = DataConfig(vocab_size=50, seq_len=64, global_batch=8, seed=0)
    d = SyntheticLM(cfg)
    b = d.batch_at(0)["tokens"]
    # every transition must be one of the k successors of the bigram table
    nxt = d._next
    ok = 0
    for row in b:
        for t in range(len(row) - 1):
            ok += row[t + 1] in nxt[row[t]]
    assert ok == b.shape[0] * (b.shape[1] - 1)


def test_frontend_embeds_shape():
    cfg = DataConfig(vocab_size=10, seq_len=8, global_batch=2, seed=0,
                     frontend_tokens=4, d_model=16)
    b = SyntheticLM(cfg).batch_at(0)
    assert b["frontend_embeds"].shape == (2, 4, 16)


def test_prefetcher_nonblocking_when_full():
    cfg = DataConfig(vocab_size=10, seq_len=4, global_batch=1, seed=0)
    src = SyntheticLM(cfg)
    p = Prefetcher(src.batch_at, depth=2)
    assert p.produce_one() and p.produce_one()
    assert p.produce_one() is False          # full -> skip, never block
    step, batch = p.get()
    assert step == 0
    assert p.produce_one()                   # space again
    p.stop()
    assert p.produce_one() is False
