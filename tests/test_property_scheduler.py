"""Property-based tests (hypothesis) for the scheduler's invariants:

* Theorem 1 (paper): every submitted task graph completes — no lost tasks,
  no duplicates — for arbitrary DAGs.
* Dependency safety: a task never starts before all strong predecessors
  finished.
* Conditional semantics: a chain of condition tasks with data-driven
  loop-backs executes exactly as the sequential reference interpreter.
"""
import threading

import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need the optional hypothesis dep")
from hypothesis import HealthCheck, given, settings, strategies as st  # noqa: E402

from repro.core import Executor, Taskflow

_EX = None


def _ex() -> Executor:
    global _EX
    if _EX is None:
        _EX = Executor(domains={"host": 4})
    return _EX


@st.composite
def dags(draw):
    n = draw(st.integers(min_value=1, max_value=40))
    edges = []
    for j in range(1, n):
        for i in range(j):
            if draw(st.booleans()):
                edges.append((i, j))
    return n, edges


@settings(max_examples=40, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(dags())
def test_random_dag_runs_every_task_exactly_once(dag):
    n, edges = dag
    tf = Taskflow()
    lock = threading.Lock()
    runs = [0] * n
    done = [False] * n

    def body(i):
        def fn():
            with lock:
                for (u, v) in edges:
                    if v == i:
                        assert done[u], f"task {i} ran before dep {u}"
                runs[i] += 1
                done[i] = True
        return fn

    tasks = [tf.static(body(i), name=f"t{i}") for i in range(n)]
    for u, v in edges:
        tasks[u].precede(tasks[v])
    _ex().run(tf).wait(timeout=30)
    assert runs == [1] * n


@st.composite
def cond_chains(draw):
    """Chain t0 -> c1 -> c2 -> ... where each condition may loop back to an
    earlier node a bounded number of times."""
    n = draw(st.integers(min_value=2, max_value=10))
    spec = []
    for i in range(1, n):
        back = draw(st.integers(min_value=0, max_value=i - 1))
        loops = draw(st.integers(min_value=0, max_value=3))
        spec.append((back, loops))
    return n, spec


def _simulate(n, spec):
    """Reference semantics: visit counts under the paper's condition rule."""
    visits = [0] * n
    budget = {}
    i = 0
    while i < n:
        visits[i] += 1
        if i == 0:
            i = 1
            continue
        back, loops = spec[i - 1]
        used = budget.get(i, 0)
        if used < loops:
            budget[i] = used + 1
            i = back
        else:
            i += 1
    return visits


@settings(max_examples=40, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(cond_chains())
def test_conditional_chain_matches_reference(chain):
    n, spec = chain
    expect = _simulate(n, spec)

    tf = Taskflow()
    visits = [0] * n
    budget = {}
    tasks = [tf.static(lambda: visits.__setitem__(0, visits[0] + 1),
                       name="t0")]
    for i in range(1, n):
        back, loops = spec[i - 1]

        def cond(i=i, loops=loops):
            visits[i] += 1
            used = budget.get(i, 0)
            if used < loops:
                budget[i] = used + 1
                return 0       # loop back
            return 1           # continue

        tasks.append(tf.condition(cond, name=f"c{i}"))
    stop = tf.static(lambda: None, name="stop")
    # zero-dependency source: t0 itself may be a weak back-edge target
    # (paper Fig. 6 pitfall 1), so an init task guarantees a source
    init = tf.static(lambda: None, name="init")
    init.precede(tasks[0])
    tasks[0].precede(tasks[1])                  # strong entry edge
    # weak edges per condition: index 0 = loop-back target, 1 = next
    for i in range(1, n):
        back, _ = spec[i - 1]
        nxt = tasks[i + 1] if i + 1 < n else stop
        tasks[i].precede(tasks[back], nxt)
    _ex().run(tf).wait(timeout=30)
    assert visits == expect


@settings(max_examples=20, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(st.lists(st.sampled_from(["push", "pop", "steal"]),
                min_size=1, max_size=200))
def test_wsq_model(ops):
    """WorkStealingQueue behaves like a deque with owner-bottom/thief-top."""
    from collections import deque
    from repro.core import WorkStealingQueue
    q = WorkStealingQueue()
    model = deque()
    k = 0
    for op in ops:
        if op == "push":
            q.push(k)
            model.append(k)
            k += 1
        elif op == "pop":
            expect = model.pop() if model else None
            assert q.pop() == expect
        else:
            expect = model.popleft() if model else None
            assert q.steal() == expect
    assert len(q) == len(model)
