import json
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train.checkpoint import CheckpointManager


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"params": {"w": jax.random.normal(k, (16, 8)),
                       "b": jnp.ones((8,), jnp.bfloat16)},
            "opt": {"m": jnp.zeros((16, 8)),
                    "count": jnp.int32(7)}}


def test_roundtrip_including_bf16(tmp_path):
    mgr = CheckpointManager(tmp_path)
    t = _tree()
    mgr.save(5, t)
    restored = mgr.restore(5, t)
    for a, b in zip(jax.tree_util.tree_leaves(t),
                    jax.tree_util.tree_leaves(restored)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_latest_and_gc(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    t = _tree()
    for s in (1, 2, 3, 4):
        mgr.save(s, t)
    assert mgr.all_steps() == [3, 4]
    assert mgr.latest_step() == 4


def test_partial_checkpoint_ignored(tmp_path):
    mgr = CheckpointManager(tmp_path)
    t = _tree()
    mgr.save(1, t)
    # simulate a crash mid-save: valid dir but missing data file
    broken = tmp_path / "ckpt_00000009"
    shutil.copytree(tmp_path / "ckpt_00000001", broken)
    (broken / "data" / "0.bin").unlink()
    m = json.loads((broken / "manifest.json").read_text())
    m["step"] = 9
    (broken / "manifest.json").write_text(json.dumps(m))
    assert mgr.latest_step() == 1      # 9 is incomplete -> ignored
    step, restored = mgr.restore_latest(t)
    assert step == 1


def test_structure_mismatch_raises(tmp_path):
    mgr = CheckpointManager(tmp_path)
    mgr.save(1, _tree())
    bad = {"params": {"w": jnp.zeros((16, 8))}}    # fewer leaves
    with pytest.raises(ValueError, match="leaves"):
        mgr.restore(1, bad)


def test_shape_mismatch_raises(tmp_path):
    mgr = CheckpointManager(tmp_path)
    t = _tree()
    mgr.save(1, t)
    bad = jax.tree_util.tree_map(lambda x: jnp.zeros((2,) + x.shape,
                                                     x.dtype), t)
    with pytest.raises(ValueError, match="shape"):
        mgr.restore(1, bad)


def test_elastic_restore_with_shardings(tmp_path):
    """restore() accepts a shardings tree (None = host) — the elastic path;
    with one device this degenerates to SingleDeviceSharding placement."""
    mgr = CheckpointManager(tmp_path)
    t = _tree()
    mgr.save(3, t)
    sh = jax.tree_util.tree_map(
        lambda _: jax.sharding.SingleDeviceSharding(jax.devices()[0]), t)
    restored = mgr.restore(3, t, shardings=sh)
    leaf = jax.tree_util.tree_leaves(restored)[0]
    assert leaf.sharding.device_set == {jax.devices()[0]}
