"""The trip-count-aware HLO analyzer is what grounds the roofline — verify
it against programs with known exact costs."""
import jax
import jax.numpy as jnp

from repro.distributed.hlo_analysis import HloCost, analyze_hlo


def _compile(fn, *args):
    return jax.jit(fn).lower(*args).compile()


def test_scan_flops_multiplied_by_trip_count():
    x = jnp.zeros((128, 128))
    w = jnp.zeros((8, 128, 128))

    def scanned(x, w):
        return jax.lax.scan(lambda c, wi: (c @ wi, None), x, w)[0]

    cost = analyze_hlo(_compile(scanned, x, w).as_text())
    assert cost.flops == 8 * 2 * 128 ** 3
    assert cost.unknown_trip_loops == 0


def test_unrolled_matches_scan():
    x = jnp.zeros((128, 128))
    w = jnp.zeros((4, 128, 128))

    def unrolled(x, w):
        for i in range(4):
            x = x @ w[i]
        return x

    def scanned(x, w):
        return jax.lax.scan(lambda c, wi: (c @ wi, None), x, w)[0]

    cu = analyze_hlo(_compile(unrolled, x, w).as_text())
    cs = analyze_hlo(_compile(scanned, x, w).as_text())
    assert cu.flops == cs.flops == 4 * 2 * 128 ** 3


def test_nested_scan_trip_products():
    x = jnp.zeros((64, 64))
    w = jnp.zeros((3, 64, 64))

    def inner(c, wi):
        return c @ wi, None

    def outer(c, _):
        c, _ = jax.lax.scan(inner, c, w)
        return c, None

    def fn(x):
        return jax.lax.scan(outer, x, None, length=5)[0]

    cost = analyze_hlo(_compile(fn, x).as_text())
    assert cost.flops == 5 * 3 * 2 * 64 ** 3


def test_data_dependent_while_counts_once_and_flags():
    x = jnp.zeros((64, 64))

    def fn(x):
        def cond(c):
            return jnp.sum(c[0]) < 1e9
        def body(c):
            m, i = c
            return (m @ m, i + 1)
        return jax.lax.while_loop(cond, body, (x, jnp.int32(0)))[0]

    cost = analyze_hlo(_compile(fn, x).as_text())
    assert cost.flops == 2 * 64 ** 3          # body counted once
    assert cost.unknown_trip_loops >= 1       # ...and flagged


def test_dus_bytes_are_slice_sized():
    big = jnp.zeros((1024, 1024))
    upd = jnp.zeros((1, 1024))

    def fn(big, upd):
        return jax.lax.dynamic_update_slice(big, upd, (5, 0))

    # donated buffer: in-place DUS -> traffic ~2x the update (8KB), not
    # ~2x the 4MB buffer
    c = jax.jit(fn, donate_argnums=(0,)).lower(big, upd).compile()
    cost = analyze_hlo(c.as_text())
    assert cost.bytes_accessed < 1e5
    # non-donated: XLA inserts a defensive copy of the full buffer — that
    # copy is genuine traffic and must be counted (~8.4MB), but the DUS
    # itself must still be slice-sized
    cost2 = analyze_hlo(_compile(fn, big, upd).as_text())
    assert 4e6 < cost2.bytes_accessed < 1.2e7


# ------------------------------------------------- collective classification
# Post-SPMD HLO with one instance of each collective type and known shapes;
# the analyzer must classify each by its LARGEST of (result, operand) bytes.
# f32[64,128] = 32 KiB, f32[256,128] = 128 KiB.
_COLLECTIVE_HLO = """\
HloModule spmd_test

ENTRY %main.1 (p0: f32[64,128]) -> f32[64,128] {
  %p0 = f32[64,128]{1,0} parameter(0)
  %ag = f32[256,128]{1,0} all-gather(f32[64,128]{1,0} %p0), dimensions={0}, replica_groups={{0,1,2,3}}
  %ar = f32[256,128]{1,0} all-reduce(f32[256,128]{1,0} %ag), replica_groups={{0,1,2,3}}, to_apply=%add.1
  %rs = f32[64,128]{1,0} reduce-scatter(f32[256,128]{1,0} %ar), dimensions={0}, replica_groups={{0,1,2,3}}, to_apply=%add.1
  ROOT %cp = f32[64,128]{1,0} collective-permute(f32[64,128]{1,0} %rs), source_target_pairs={{0,1},{1,2},{2,3},{3,0}}
}
"""

_ASYNC_HLO = """\
HloModule spmd_async_test

ENTRY %main.1 (p0: f32[64,128]) -> f32[256,128] {
  %p0 = f32[64,128]{1,0} parameter(0)
  %ags = (f32[64,128]{1,0}, f32[256,128]{1,0}) all-gather-start(f32[64,128]{1,0} %p0), dimensions={0}, replica_groups={{0,1,2,3}}
  ROOT %agd = f32[256,128]{1,0} all-gather-done((f32[64,128]{1,0}, f32[256,128]{1,0}) %ags)
}
"""

_KIB = 1024.0


def test_collective_sizes_classified_per_type():
    cost = analyze_hlo(_COLLECTIVE_HLO)
    # each type keyed on max(result, operand) bytes
    assert cost.collective_bytes["all-gather"] == 128 * _KIB
    assert cost.collective_bytes["all-reduce"] == 128 * _KIB
    assert cost.collective_bytes["reduce-scatter"] == 128 * _KIB
    assert cost.collective_bytes["collective-permute"] == 32 * _KIB
    assert cost.collective_bytes["all-to-all"] == 0.0
    for c in ("all-gather", "all-reduce", "reduce-scatter",
              "collective-permute"):
        assert cost.collective_counts[c] == 1
        assert cost.collective_max_bytes[c] == cost.collective_bytes[c]
    # collective_total is the sum over every type
    assert cost.collective_total == (128 + 128 + 128 + 32) * _KIB


def test_async_collective_counted_once():
    # the -start carries the cost; the -done must not double-count
    cost = analyze_hlo(_ASYNC_HLO)
    assert cost.collective_counts["all-gather"] == 1
    assert cost.collective_bytes["all-gather"] == 128 * _KIB
    assert cost.collective_max_bytes["all-gather"] == 128 * _KIB


def test_collective_max_bytes_ignores_trip_counts():
    # a loop repeats the SAME transfer: totals scale with the trip count,
    # the largest single collective does not
    body = HloCost()
    body.collective_bytes["all-gather"] = 128 * _KIB
    body.collective_counts["all-gather"] = 1
    body.collective_max_bytes["all-gather"] = 128 * _KIB
    total = HloCost()
    total.add(body, 24)
    assert total.collective_bytes["all-gather"] == 24 * 128 * _KIB
    assert total.collective_counts["all-gather"] == 24
    assert total.collective_max_bytes["all-gather"] == 128 * _KIB
    assert total.collective_total == 24 * 128 * _KIB
