"""Per-kernel shape/dtype sweeps against the pure-jnp oracles (ref.py).

Interpret-mode Pallas on CPU is slow, so the sweep sizes are modest but
cover: GQA group ratios, non-square blocks, both dtypes, block-boundary
and remainder-free shapes.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.kernels.ops import flash_attention, lsdnn_layer, mamba_scan
from repro.kernels.ref import (flash_attention_ref, lsdnn_layer_ref,
                               mamba_scan_ref)

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("B,S,H,KV,hd,dtype", [
    (2, 128, 4, 2, 64, jnp.float32),
    (1, 256, 8, 8, 64, jnp.float32),
    (1, 128, 8, 1, 128, jnp.bfloat16),
    (2, 192, 6, 2, 32, jnp.float32),      # S not a multiple of 128
])
def test_flash_attention_matches_ref(B, S, H, KV, hd, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), dtype)
    k = jax.random.normal(ks[1], (B, S, KV, hd), dtype)
    v = jax.random.normal(ks[2], (B, S, KV, hd), dtype)
    out = flash_attention(q, k, v, block_q=64, block_k=64)
    ref = flash_attention_ref(q, k, v)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    assert jnp.max(jnp.abs(out.astype(jnp.float32)
                           - ref.astype(jnp.float32))) < tol


def test_flash_attention_non_causal():
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 128, 4, 32))
    k = jax.random.normal(ks[1], (1, 128, 4, 32))
    v = jax.random.normal(ks[2], (1, 128, 4, 32))
    out = flash_attention(q, k, v, causal=False, block_q=64, block_k=64)
    ref = flash_attention_ref(q, k, v, causal=False)
    assert jnp.max(jnp.abs(out - ref)) < 2e-5


@pytest.mark.parametrize("B,S,dI,N,block_d,chunk", [
    (2, 64, 128, 16, 64, 32),
    (1, 96, 64, 8, 64, 32),               # S % chunk != 0 -> chunk=S fallback
    (1, 128, 256, 16, 128, 64),
])
def test_mamba_scan_matches_ref(B, S, dI, N, block_d, chunk):
    ks = jax.random.split(KEY, 5)
    dt = jax.nn.softplus(jax.random.normal(ks[0], (B, S, dI))) * 0.1
    x = jax.random.normal(ks[1], (B, S, dI))
    Bc = jax.random.normal(ks[2], (B, S, N))
    Cc = jax.random.normal(ks[3], (B, S, N))
    A = -jnp.exp(jax.random.normal(ks[4], (dI, N)) * 0.5)
    if S % chunk:
        chunk = S
    y, hT = mamba_scan(dt, x, Bc, Cc, A, block_d=block_d, chunk=chunk)
    yr, hr = mamba_scan_ref(dt, A, Bc, Cc, x)
    assert jnp.max(jnp.abs(y - yr)) < 1e-4
    assert jnp.max(jnp.abs(hT - hr)) < 1e-4


@pytest.mark.parametrize("T,F,G,dtype", [
    (128, 256, 128, jnp.float32),
    (256, 128, 64, jnp.float32),
    (64, 64, 64, jnp.bfloat16),
])
def test_lsdnn_layer_matches_ref(T, F, G, dtype):
    ks = jax.random.split(KEY, 3)
    y = jax.random.normal(ks[0], (T, F), dtype)
    w = jax.random.normal(ks[1], (F, G), dtype) * 0.05
    b = jax.random.normal(ks[2], (G,), dtype)
    out = lsdnn_layer(y, w, b, block_m=64, block_n=64, block_k=64)
    ref = lsdnn_layer_ref(y, w, b)
    tol = 0.3 if dtype == jnp.bfloat16 else 1e-4
    assert jnp.max(jnp.abs(out.astype(jnp.float32)
                           - ref.astype(jnp.float32))) < tol


def test_lsdnn_clamps_at_cap():
    y = jnp.ones((64, 64)) * 10.0
    w = jnp.ones((64, 64)) * 1.0
    b = jnp.zeros((64,))
    out = lsdnn_layer(y, w, b, cap=32.0, block_m=64, block_n=64, block_k=64)
    assert float(jnp.max(out)) == 32.0
    assert float(jnp.min(out)) >= 0.0
