"""Gather-free paged decode-attention kernels vs. the gather oracle.

The Pallas kernel (interpret mode) and the traced-bound XLA page loop must
reproduce the materialize-then-mask reference (``kvcache.gather_pages`` +
masked softmax) across GQA ratios, ragged per-row lengths, rows parked on
the sink block, and block sizes that do not divide ``pos + 1``. Also the
causal block-pruning parity for the prefill flash kernel: skipping
fully-above-diagonal kv blocks is bit-identical to masking them.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.kernels.flash_attention import flash_attention
from repro.kernels.paged_attention import paged_attention
from repro.models.attention import init_attention, paged_decode_attention
from repro.serve.kvcache import gather_read_attention as _gather_oracle

KEY = jax.random.PRNGKey(0)


def _make_case(B, H, KV, hd, bs, mb, lengths, seed=0, dtype=jnp.float32):
    """Random pool + disjoint per-row block tables covering ``lengths``.

    Rows with length < 0 are left entirely on the sink block (the engine's
    inactive-slot state); their length is clamped to 0 for the mask.
    """
    rng = np.random.default_rng(seed)
    N = B * mb + 1
    ks = jax.random.split(KEY, 2)
    q = jax.random.normal(ks[0], (B, H, hd), dtype)
    pool_kv = jax.random.normal(ks[1], (2, N, KV, bs, hd), dtype)
    tables = np.zeros((B, mb), np.int32)
    free = list(rng.permutation(np.arange(1, N)))
    for b in range(B):
        if lengths[b] < 0:
            continue                       # sink-parked row
        nb = lengths[b] // bs + 1
        for j in range(nb):
            tables[b, j] = free.pop()
    lengths = np.maximum(np.asarray(lengths, np.int32), 0)
    return q, pool_kv, jnp.asarray(tables), jnp.asarray(lengths)


@pytest.mark.parametrize("impl", ["pallas", "xla"])
@pytest.mark.parametrize("H,KV", [(4, 4), (4, 2), (8, 1)])
def test_paged_matches_gather_across_gqa_and_ragged_lengths(impl, H, KV):
    B, hd, bs, mb = 5, 32, 16, 6
    # ragged: empty row (pos=0), mid-block, exact block boundary (bs does
    # not divide pos+1 except row 3), near-capacity
    lengths = [0, 7, bs - 1, 2 * bs, mb * bs - 1]
    q, pool_kv, tables, ln = _make_case(B, H, KV, hd, bs, mb, lengths)
    out = paged_attention(q, pool_kv, tables, ln, impl=impl)
    ref = _gather_oracle(q, pool_kv, tables, ln)
    assert jnp.max(jnp.abs(out - ref)) < 2e-5


@pytest.mark.parametrize("impl", ["pallas", "xla"])
def test_paged_inactive_sink_rows(impl):
    """Rows parked on the sink block (every table entry 0) stay finite and
    match the oracle; live rows are untouched by their presence."""
    B, H, KV, hd, bs, mb = 4, 4, 2, 16, 8, 4
    lengths = [5, -1, 20, -1]              # rows 1 and 3 are sink-parked
    q, pool_kv, tables, ln = _make_case(B, H, KV, hd, bs, mb, lengths)
    assert int(tables[1].sum()) == 0 and int(tables[3].sum()) == 0
    out = paged_attention(q, pool_kv, tables, ln, impl=impl)
    ref = _gather_oracle(q, pool_kv, tables, ln)
    assert bool(jnp.all(jnp.isfinite(out)))
    assert jnp.max(jnp.abs(out - ref)) < 2e-5


@pytest.mark.parametrize("impl", ["pallas", "xla"])
@pytest.mark.parametrize("bs,pos", [(4, 4), (4, 10), (3, 7), (5, 5)])
def test_paged_block_size_not_dividing_pos(impl, bs, pos):
    B, H, KV, hd, mb = 2, 4, 2, 16, 4
    q, pool_kv, tables, ln = _make_case(B, H, KV, hd, bs, mb, [pos, pos % bs])
    out = paged_attention(q, pool_kv, tables, ln, impl=impl)
    ref = _gather_oracle(q, pool_kv, tables, ln)
    assert jnp.max(jnp.abs(out - ref)) < 2e-5


@pytest.mark.parametrize("impl", ["pallas", "xla"])
def test_paged_decode_attention_impl_switch_parity(impl):
    """Full module-level op (projection + fused append + read + output
    proj): the gather-free impls match the gather oracle, and the fused
    K/V append leaves identical pool contents."""
    cfg = get_config("stablelm-1.6b").smoke()
    B, mb, bs, N = 3, 4, 4, 16
    p = init_attention(jax.random.PRNGKey(1), cfg)
    x = jax.random.normal(jax.random.PRNGKey(2), (B, 1, cfg.d_model))
    pool_kv = jax.random.normal(
        jax.random.PRNGKey(3), (2, N, cfg.num_kv_heads, bs, cfg.hd))
    rng = np.random.default_rng(0)
    perm = rng.permutation(np.arange(1, N))[:B * mb].reshape(B, mb)
    tables = jnp.asarray(perm.astype(np.int32))
    pos = jnp.asarray([0, 5, 11], jnp.int32)
    active = jnp.asarray([True, True, False])
    y_ref, pool_ref = paged_decode_attention(p, x, cfg, pool_kv, tables,
                                             pos, active, impl="gather")
    y, pool = paged_decode_attention(p, x, cfg, pool_kv, tables,
                                     pos, active, impl=impl)
    np.testing.assert_array_equal(np.asarray(pool), np.asarray(pool_ref))
    act = np.asarray(active)
    diff = np.abs(np.asarray(y, np.float32) - np.asarray(y_ref, np.float32))
    assert diff[act].max() < 2e-2      # bf16 compute dtype tolerance
    assert np.isfinite(np.asarray(y, np.float32)).all()


@pytest.mark.parametrize("S,block", [(128, 64), (256, 64), (192, 32)])
def test_flash_causal_prune_bit_identical(S, block):
    """Skipping fully-above-diagonal kv blocks (compute + fetch) is
    bit-identical to masking them to NEG_INF."""
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (2, S, 4, 32))
    k = jax.random.normal(ks[1], (2, S, 2, 32))
    v = jax.random.normal(ks[2], (2, S, 2, 32))
    pruned = flash_attention(q, k, v, causal=True, block_q=block,
                             block_k=block, prune=True)
    masked = flash_attention(q, k, v, causal=True, block_q=block,
                             block_k=block, prune=False)
    np.testing.assert_array_equal(np.asarray(pruned), np.asarray(masked))


def test_flash_non_causal_ignores_prune_flag():
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 128, 4, 32))
    k = jax.random.normal(ks[1], (1, 128, 4, 32))
    v = jax.random.normal(ks[2], (1, 128, 4, 32))
    a = flash_attention(q, k, v, causal=False, block_q=64, block_k=64,
                        prune=True)
    b = flash_attention(q, k, v, causal=False, block_q=64, block_k=64,
                        prune=False)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
