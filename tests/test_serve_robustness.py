"""SLO-aware overload control + fault tolerance of the serve runtime.

Covers the robustness surface end to end: tiered admission (strict
priority, reserved best-effort seats, the strict-cap floor), queue and
mid-decode deadline expiry, ``cancel()`` from every request state,
load shedding (typed :class:`Overloaded` at submit), per-row failure
isolation (a poisoned decode chunk fails only the seated rows — the
engine rebuilds its device state and keeps serving bit-identically),
the watchdog (typed :class:`WatchdogTimeout` instead of a hung
``result()``), typed teardown (:class:`EngineClosed`), and the
determinism of the fault-injection harness itself.

Engine tests pin ``paged_impl="gather"`` where they assert exact token
equality (see test_serve_continuous.py's bit-identity notes) and run
both the synchronous and the async-lookahead decode loops where the
reclamation path differs (deferred-free fence vs plain free).
"""
import time

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import lm
from repro.serve.engine import ServeEngine
from repro.serve.errors import (DeadlineExceeded, EngineClosed, Overloaded,
                                RequestCancelled, RowFailed, ServeError,
                                WatchdogTimeout)
from repro.serve.faultinject import FaultInjected, FaultInjector
from repro.serve.scheduler import Scheduler, ServeRequest


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("stablelm-1.6b").smoke()
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _pool_restored(eng) -> bool:
    parked = eng._prefix.num_parked if eng._prefix is not None else 0
    return eng._pool.num_free + parked == eng._pool.num_blocks - 1


def _reference(cfg, params, prompt, max_new):
    import jax.numpy as jnp
    logits, cache = lm.prefill(cfg, params, jnp.asarray(prompt[None]),
                               max_len=len(prompt) + max_new)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    out = [int(tok[0])]
    for _ in range(max_new - 1):
        logits, cache = lm.decode_step(cfg, params, cache, tok)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        out.append(int(tok[0]))
    return out


def _wait_idle(eng, timeout=30.0):
    deadline = time.time() + timeout
    while time.time() < deadline:
        if eng._pipeline.idle() and eng._scheduler.num_waiting == 0:
            return
        time.sleep(0.01)
    raise TimeoutError("engine did not go idle")


# --------------------------------------------------------------- scheduler
def _req(prio=0, deadline_s=None, size=4):
    return ServeRequest(np.arange(1, 1 + size, dtype=np.int32), 4,
                        priority=prio, deadline_s=deadline_s)


def test_scheduler_strict_priority_order():
    s = Scheduler(max_admit=4)
    lo = [_req(prio=1) for _ in range(3)]
    hi = [_req(prio=0) for _ in range(3)]
    for r in lo + hi:
        s.enqueue(r)
    group = s.try_admit(free_slots=4, blocks_free=None)
    # tier 0 admits first even though tier 1 enqueued earlier; the last
    # seat goes to the oldest tier-1 request
    assert [r.priority for r in group] == [0, 0, 0, 1]
    assert group[3] is lo[0]


def _stamp(r):
    """Stamp the absolute deadline the engine's submit() would."""
    r.submitted_at = time.perf_counter()
    if r.deadline_s is not None:
        r.deadline_at = r.submitted_at + r.deadline_s
    return r


def test_scheduler_edf_within_tier():
    # within one tier, deadline requests admit earliest-deadline-first,
    # AHEAD of deadline-less ones, which keep FIFO order among themselves
    s = Scheduler(max_admit=8)
    plain_a = _stamp(_req())
    far = _stamp(_req(deadline_s=60.0))
    near = _stamp(_req(deadline_s=5.0))
    plain_b = _stamp(_req())
    for r in (plain_a, far, near, plain_b):
        s.enqueue(r)
    group = s.try_admit(free_slots=8, blocks_free=None)
    assert group == [near, far, plain_a, plain_b]


def test_scheduler_edf_is_fifo_without_deadlines():
    # a pure-FIFO workload is untouched by EDF (ids are the tiebreak)
    s = Scheduler(max_admit=8)
    reqs = [_stamp(_req()) for _ in range(5)]
    for r in reqs:
        s.enqueue(r)
    assert s.try_admit(free_slots=8, blocks_free=None) == reqs


def test_scheduler_edf_requeue_merges_by_deadline():
    # a preempted deadline request re-enters at its deadline position,
    # not merely at its id position
    s = Scheduler(max_admit=8)
    urgent = _stamp(_req(deadline_s=1.0))     # oldest id, tightest deadline
    later = _stamp(_req(deadline_s=120.0))
    plain = _stamp(_req())
    for r in (later, plain):
        s.enqueue(r)
    s.requeue_front([urgent])                 # e.g. preempted mid-decode
    group = s.try_admit(free_slots=8, blocks_free=None)
    assert group == [urgent, later, plain]


def test_scheduler_reserved_seats_beat_head_of_line_blocking():
    s = Scheduler(max_admit=4, tier_targets={1: 0.25})
    for _ in range(8):
        s.enqueue(_req(prio=0, size=8))
    starved = _req(prio=1, size=4)
    s.enqueue(starved)
    # block budget covers only the strict pass's tier-0 picks; the
    # reserved pass admits tier 1's guaranteed seat on top
    group = s.try_admit(free_slots=4, blocks_free=100,
                        need_for=lambda r: r.prompt_len)
    assert starved in group
    assert sum(1 for r in group if r.priority == 0) >= 1


def test_scheduler_strict_cap_floor_keeps_tier0_admissible():
    # reserved shares that floor-round up to the whole cap must still
    # leave >= 1 strict-priority seat for the top tier
    s = Scheduler(max_admit=2, tier_targets={1: 1.0})
    for _ in range(4):
        s.enqueue(_req(prio=1))
    head = _req(prio=0)
    s.enqueue(head)
    group = s.try_admit(free_slots=2, blocks_free=None)
    assert head in group


def test_scheduler_queue_deadline_expires_typed():
    s = Scheduler(max_admit=4)
    events = []
    s.on_event = lambda kind, r: events.append((kind, r))
    r = _req(deadline_s=0.01)
    r.submitted_at = time.perf_counter()
    r.deadline_at = r.submitted_at + r.deadline_s
    s.enqueue(r)
    time.sleep(0.03)
    assert s.expire_waiting() == 1
    assert events == [("expired", r)]
    assert s.num_waiting == 0
    with pytest.raises(DeadlineExceeded):
        r.result(timeout=1.0)


def test_cancel_waiting_request_fails_immediately():
    s = Scheduler(max_admit=4)
    r = _req()
    s.enqueue(r)
    assert r.cancel() is True
    with pytest.raises(RequestCancelled):
        r.result(timeout=1.0)
    assert s.expire_waiting() == 1     # sweep drops the queue entry
    assert r.cancel() is False         # already done


# ---------------------------------------------------------- fault injector
def test_fault_injector_deterministic_schedule():
    spec = "grow_fail:p=0.3,seed=7;alloc_fail:every=3;chunk_latency:at=2,ms=5"
    a = FaultInjector.parse(spec)
    b = FaultInjector.parse(spec)
    pat_a = [(site, a.fire(site)) for _ in range(50)
             for site in ("grow_fail", "alloc_fail", "chunk_latency")]
    pat_b = [(site, b.fire(site)) for _ in range(50)
             for site in ("grow_fail", "alloc_fail", "chunk_latency")]
    assert pat_a == pat_b              # same spec -> same schedule
    assert a.counts() == b.counts()
    ca = a.counts()
    assert ca["alloc_fail"]["fires"] == 50 // 3
    assert ca["chunk_latency"]["fires"] == 1          # at=2 fires once
    assert a.latency_s("chunk_latency") == pytest.approx(0.005)
    assert a.fire("preempt") is False  # no clause -> never fires


def test_fault_injector_spec_validation():
    with pytest.raises(ValueError):
        FaultInjector.parse("bogus_site")
    with pytest.raises(ValueError):
        FaultInjector.parse("grow_fail:p=0.5,at=3")   # two triggers
    with pytest.raises(ValueError):
        FaultInjector.parse("grow_fail;grow_fail")    # duplicate clause
    bare = FaultInjector.parse("preempt")
    assert bare.fire("preempt") is True
    assert bare.fire("preempt") is False              # bare site: n=1


# ------------------------------------------------------------ load shedding
def test_submit_sheds_typed_overloaded(setup):
    from repro.obs import Observability
    cfg, params = setup
    obs = Observability()
    with ServeEngine(cfg, params, decode_chunk=2, shed_budget_s=0.05,
                     obs=obs) as eng:
        # cold start: no service-rate estimate yet, so the p90-queue-wait
        # FALLBACK decides; it never sheds before 8 recorded admissions —
        # prime its histogram past the arming threshold
        assert eng._decode_rate == 0.0
        for _ in range(10):
            eng._mh["qwait"].record(1.0)
        with pytest.raises(Overloaded) as ei:
            eng.submit(np.arange(1, 5, dtype=np.int32), max_new=4)
        assert ei.value.tier == 0
        assert ei.value.est_wait_s > ei.value.budget_s
        assert eng.stats["shed"] == 1
        assert eng._scheduler.num_waiting == 0   # shed before enqueue
        # a dict budget sheds only its listed tiers
        eng._shed_budget = {1: 0.05}
        r = eng.submit(np.arange(1, 5, dtype=np.int32), max_new=4)
        assert eng.result(r, timeout=120.0).shape == (4,)
        # the completed request primed the SERVICE-RATE model, which now
        # outranks the stale histogram: an IDLE engine has ~zero queued
        # work, so a tier-1 submit must NOT shed despite the p90 saying 1s
        assert eng._decode_rate > 0.0
        r = eng.submit(np.arange(1, 5, dtype=np.int32), max_new=4,
                       priority=1)
        assert eng.result(r, timeout=120.0).shape == (4,)
        # under real queued work the rate model sheds: pin the rate so the
        # estimate is deterministic, then load the engine with a long
        # tier-0 resident before probing tier 1
        long = eng.submit(np.arange(1, 5, dtype=np.int32), max_new=400)
        eng._decode_rate = 100.0      # 400 queued tokens -> ~4s >> 0.05s
        with pytest.raises(Overloaded) as ei:
            eng.submit(np.arange(1, 5, dtype=np.int32), max_new=4,
                       priority=1)
        assert ei.value.est_wait_s > ei.value.budget_s
        # tier 0 is absent from the dict budget: never shed
        r0 = eng.submit(np.arange(1, 5, dtype=np.int32), max_new=4)
        assert eng.result(r0, timeout=120.0).shape == (4,)
        long.cancel()


def test_service_rate_estimator(setup):
    """The rate model's arithmetic: (resident remaining + waiting work at
    tiers <= priority) / observed tokens-per-second."""
    cfg, params = setup
    with ServeEngine(cfg, params, decode_chunk=2) as eng:
        assert eng._estimated_wait_s(0) is None      # no rate, no metrics
        eng._note_rate(20, 0.5)                      # 40 tok/s
        assert eng._decode_rate == pytest.approx(40.0)
        eng._note_rate(0, 1.0)                       # empty cycles skipped
        assert eng._decode_rate == pytest.approx(40.0)
        from repro.serve.scheduler import ServeRequest
        eng._scheduler.enqueue(ServeRequest([1, 2], 30, priority=0))
        eng._scheduler.enqueue(ServeRequest([1, 2], 50, priority=2))
        # tier 0 sees only its own backlog; tier 2 sees both
        assert eng._estimated_wait_s(0) == pytest.approx(30 / 40.0)
        assert eng._estimated_wait_s(2) == pytest.approx(80 / 40.0)
        eng._scheduler.fail_all_waiting(RuntimeError("drain"))


# ------------------------------------------------ deadlines + cancel (engine)
@pytest.mark.parametrize("async_decode", [False, True])
def test_mid_decode_deadline_expiry_reclaims_row(setup, async_decode):
    cfg, params = setup
    p = np.arange(1, 6, dtype=np.int32)
    with ServeEngine(cfg, params, decode_chunk=2,
                     async_decode=async_decode) as eng:
        eng.generate([p], max_new=3)   # warm-up
        # enough decode work that the deadline lapses mid-flight
        r = eng.submit(p, max_new=64, deadline_s=0.05)
        with pytest.raises(DeadlineExceeded):
            eng.result(r, timeout=120.0)
        assert eng.stats["expired"] >= 1
        _wait_idle(eng)
        assert _pool_restored(eng)
        # the engine serves on, bit-identically
        out = eng.generate([p], max_new=4)[0]
        assert out.tolist() == _reference(cfg, params, p, 4)


@pytest.mark.parametrize("async_decode", [False, True])
def test_cancel_seated_request_reclaims_row(setup, async_decode):
    cfg, params = setup
    p = np.arange(1, 6, dtype=np.int32)
    with ServeEngine(cfg, params, decode_chunk=2,
                     async_decode=async_decode) as eng:
        eng.generate([p], max_new=3)
        r = eng.submit(p, max_new=64)
        deadline = time.time() + 30
        while r.state != "decoding" and time.time() < deadline:
            time.sleep(0.002)
        assert r.cancel() is True
        with pytest.raises(RequestCancelled):
            eng.result(r, timeout=120.0)
        assert eng.stats["cancelled"] >= 1
        _wait_idle(eng)
        assert _pool_restored(eng)
        out = eng.generate([p], max_new=4)[0]
        assert out.tolist() == _reference(cfg, params, p, 4)


def test_cancel_queued_request_never_occupies_a_slot(setup):
    cfg, params = setup
    p = np.arange(1, 6, dtype=np.int32)
    # alloc_fail on every opportunity: admission can never seat anything,
    # so the request stays waiting until cancelled
    with ServeEngine(cfg, params, decode_chunk=2,
                     fault_inject="alloc_fail:every=1") as eng:
        r = eng.submit(p, max_new=4)
        assert r.cancel() is True
        with pytest.raises(RequestCancelled):
            eng.result(r, timeout=10.0)
        assert eng.stats["admitted"] == 0


# ------------------------------------------------------- failure isolation
@pytest.mark.parametrize("async_decode", [False, True])
def test_decode_fault_fails_rows_typed_engine_serves_on(setup,
                                                        async_decode):
    """A poisoned decode-chunk sync (``chunk_sync_exc``) fails only the
    rows seated in that cycle — typed :class:`RowFailed` with the
    injected fault as ``__cause__`` — and the engine rebuilds its device
    state and keeps producing bit-identical tokens."""
    cfg, params = setup
    p = np.arange(1, 6, dtype=np.int32)
    with ServeEngine(cfg, params, decode_chunk=2, paged_impl="gather",
                     async_decode=async_decode,
                     fault_inject="chunk_sync_exc:at=2") as eng:
        r = eng.submit(p, max_new=8)
        with pytest.raises(RowFailed) as ei:
            eng.result(r, timeout=120.0)
        assert isinstance(ei.value.__cause__, FaultInjected)
        assert eng._broken is None
        assert eng.stats["row_failures"] >= 1
        _wait_idle(eng)
        assert _pool_restored(eng)
        out = eng.generate([p], max_new=6)[0]
        assert out.tolist() == _reference(cfg, params, p, 6)


def test_benign_faults_keep_tokens_bit_identical_and_deterministic(setup):
    """grow_fail/preempt faults are BENIGN: greedy replay after eviction
    or preemption reproduces the same tokens run-to-run and against the
    no-fault reference. Raw opportunity COUNTS are not asserted equal —
    a stalled row retries its grow once per pump cycle, and the number
    of idle cycles while it waits is wall-clock-dependent — but the
    count-deterministic ``at=`` trigger must fire exactly once in both
    runs, and the seeded ``p=`` trigger must fire in both."""
    cfg, params = setup
    rng = np.random.default_rng(3)
    prompts = [rng.integers(1, cfg.vocab_size, size=s).astype(np.int32)
               for s in (5, 9, 6, 7)]
    spec = "grow_fail:p=0.5,seed=13;preempt:at=3"

    def _run():
        with ServeEngine(cfg, params, decode_chunk=2, block_size=4,
                         kv_blocks=32, paged_impl="gather",
                         fault_inject=spec) as eng:
            outs = eng.generate(prompts, max_new=10)
            return [o.tolist() for o in outs], eng._fi.counts()

    outs_a, counts_a = _run()
    outs_b, counts_b = _run()
    assert outs_a == outs_b
    for c in (counts_a, counts_b):
        assert c["preempt"]["fires"] == 1          # at=3: once, both runs
        assert c["grow_fail"]["fires"] >= 1
        assert c["grow_fail"]["opportunities"] > 0
    for p, o in zip(prompts, outs_a):
        assert o == _reference(cfg, params, p, 10)


# ------------------------------------------------------- watchdog + teardown
def test_watchdog_fails_futures_instead_of_hanging(setup):
    """An injected stuck decode cycle (multi-second sync-point stall)
    trips the watchdog: every outstanding future fails typed
    :class:`WatchdogTimeout` well before ``result()``'s own timeout."""
    cfg, params = setup
    p = np.arange(1, 6, dtype=np.int32)
    with ServeEngine(cfg, params, decode_chunk=2, watchdog_s=0.25,
                     fault_inject="chunk_latency:at=2,ms=60000") as eng:
        r = eng.submit(p, max_new=16)
        t0 = time.time()
        with pytest.raises(WatchdogTimeout):
            eng.result(r, timeout=30.0)
        assert time.time() - t0 < 20.0
        assert eng.stats["watchdog_fires"] == 1
        assert isinstance(eng._broken, WatchdogTimeout)


def test_close_fails_outstanding_typed_engine_closed(setup):
    """Teardown with requests still outstanding (admission pinned shut by
    a perpetual alloc fault) propagates :class:`EngineClosed` into every
    pending future — ``result()`` never hangs on a closed engine."""
    cfg, params = setup
    p = np.arange(1, 6, dtype=np.int32)
    eng = ServeEngine(cfg, params, decode_chunk=2,
                      fault_inject="alloc_fail:every=1")
    reqs = [eng.submit(p, max_new=4) for _ in range(3)]
    eng.close(timeout=0.5)
    for r in reqs:
        with pytest.raises(EngineClosed):
            r.result(timeout=5.0)


# ------------------------------------------------------------ SLO plumbing
def test_per_tier_ttft_histograms_and_counters(setup):
    from repro.obs import Observability
    cfg, params = setup
    obs = Observability()
    p = np.arange(1, 6, dtype=np.int32)
    with ServeEngine(cfg, params, decode_chunk=2, obs=obs) as eng:
        r0 = eng.submit(p, max_new=4, priority=0)
        r2 = eng.submit(p, max_new=4, priority=2)
        eng.result(r0, timeout=120.0)
        eng.result(r2, timeout=120.0)
    h0 = obs.metrics.get("serve.ttft_s.tier0")
    h2 = obs.metrics.get("serve.ttft_s.tier2")
    assert h0 is not None and h0.count == 1
    assert h2 is not None and h2.count == 1
    assert r0.ttft is not None and r2.ttft is not None


def test_typed_errors_are_serve_errors():
    for klass in (Overloaded, DeadlineExceeded, RequestCancelled,
                  RowFailed, WatchdogTimeout, EngineClosed):
        assert issubclass(klass, ServeError)
    assert issubclass(DeadlineExceeded, TimeoutError)
    assert issubclass(WatchdogTimeout, TimeoutError)
