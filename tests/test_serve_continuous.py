"""Continuous-batching engine: resident pipeline, mid-stream admission,
overlap, bit-identical greedy outputs, back-pressure, failure isolation,
and the two-phase admission paths (chunked prefill, mid-decode block-table
growth with preemption, SSM/hybrid slot-pool residency).

Bit-identity notes: tests that assert EXACT token equality against the
contiguous reference under adversarial allocation patterns pin
``paged_impl="gather"`` — the oracle read path computes the reference math
verbatim, so equality is structural. The gather-free xla/pallas paths
reorder the bf16 online-softmax reductions (logit deltas ~1e-3, tolerance
parity in ``test_paged_attention.py``); the default-impl tests below keep
asserting exact tokens on their seeds, as they always have."""
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import lm
from repro.serve.engine import ServeEngine


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("stablelm-1.6b").smoke()
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _pool_restored(eng) -> bool:
    """Every block found its way back to the pool — modulo blocks the
    prefix cache keeps PARKED for reuse when the suite runs under the
    ``REPRO_PREFIX_CACHE=1`` CI leg (parked blocks are index-held and
    evictable on pressure, not leaked)."""
    parked = eng._prefix.num_parked if eng._prefix is not None else 0
    return eng._pool.num_free + parked == eng._pool.num_blocks - 1


def _reference(cfg, params, prompt, max_new):
    """Greedy decode through the CONTIGUOUS cache — the pre-paged math."""
    logits, cache = lm.prefill(cfg, params, jnp.asarray(prompt[None]),
                               max_len=len(prompt) + max_new)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    out = [int(tok[0])]
    for _ in range(max_new - 1):
        logits, cache = lm.decode_step(cfg, params, cache, tok)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        out.append(int(tok[0]))
    return out


def test_generate_shim_bit_identical_to_contiguous(setup):
    """generate() (the submit/result shim) produces greedy tokens equal to
    the contiguous reference for every mixed-length prompt, in input order."""
    cfg, params = setup
    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, cfg.vocab_size, size=s).astype(np.int32)
               for s in (4, 7, 4, 5)]
    with ServeEngine(cfg, params, decode_chunk=4) as eng:
        outs = eng.generate(prompts, max_new=6)
        assert all(o.shape == (6,) for o in outs)
        for p, o in zip(prompts, outs):
            assert o.tolist() == _reference(cfg, params, p, 6)


def test_submit_mid_decode_overlaps_and_orders(setup):
    """B submitted while A is mid-decode: B's prefill lands BETWEEN decode
    cycles of the SAME pipeline run (observer/stage-log based), both retire
    individually, and each matches its independent reference."""
    cfg, params = setup
    pa = np.arange(1, 6, dtype=np.int32)
    pb = np.arange(2, 9, dtype=np.int32)
    with ServeEngine(cfg, params, decode_chunk=2,
                     record_stages=True) as eng:
        eng.generate([pa], max_new=3)   # warm-up: compile both programs
        base_events = len(eng.stage_log)

        ra = eng.submit(pa, max_new=24)   # 12 decode cycles at chunk=2
        # wait until A is demonstrably mid-decode
        deadline = time.time() + 60
        while time.time() < deadline:
            if any(s == "decode" and n for s, _, n, _ in
                   eng.stage_log[base_events:]):
                break
            time.sleep(0.002)
        topo = eng._pipeline._topology
        rb = eng.submit(pb, max_new=4)
        a_out = eng.result(ra, timeout=120)
        b_out = eng.result(rb, timeout=120)

        # same resident run: the topology A started is the one B rode
        assert eng._pipeline._topology is topo
        ev = eng.stage_log[base_events:]
        # find B's admission cycle (second admit event)
        admits = [(i, tok) for i, (s, tok, _, _) in enumerate(ev)
                  if s == "admit"]
        assert len(admits) == 2, f"expected 2 admissions, got {admits}"
        b_prefill_i = next(i for i, (s, tok, _, _) in enumerate(ev)
                           if s == "prefill" and tok == admits[1][1])
        decode_i = [i for i, (s, _, n, _) in enumerate(ev)
                    if s == "decode" and n]
        # prefill of B overlaps decode of A: decode cycles both before and
        # after it in the event order of one run
        assert any(i < b_prefill_i for i in decode_i)
        assert any(i > b_prefill_i for i in decode_i)
        # per-sequence retirement: two separate complete events retired work
        retires = [n for s, _, n, _ in ev if s == "complete" and n]
        assert len(retires) == 2 and all(n == 1 for n in retires)

        assert a_out.tolist() == _reference(cfg, params, pa, 24)
        assert b_out.tolist() == _reference(cfg, params, pb, 4)


def test_kv_exhaustion_defers_admission_and_recovers(setup):
    """Pool too small for two sequences: the admit stage parks via
    defer(token) instead of spinning, and every request still completes."""
    cfg, params = setup
    with ServeEngine(cfg, params, decode_chunk=4, kv_blocks=5, block_size=4,
                     record_stages=True) as eng:
        prompts = [np.arange(1, 5, dtype=np.int32) for _ in range(3)]
        reqs = [eng.submit(p, max_new=12) for p in prompts]
        outs = [eng.result(r, timeout=240) for r in reqs]
        ref = _reference(cfg, params, prompts[0], 12)
        assert all(o.tolist() == ref for o in outs)
        assert eng.stats["admit_parks"] >= 1
        pl = eng._pipeline
        assert pl.num_token_deferrals == pl.num_resumes >= 1
        # every block returned to the pool (or parked by the prefix index)
        assert _pool_restored(eng)


def test_engine_goes_idle_and_rearms_without_rebuild(setup):
    cfg, params = setup
    with ServeEngine(cfg, params, decode_chunk=4) as eng:
        r1 = eng.result(eng.submit(np.arange(1, 5, dtype=np.int32), 4))
        deadline = time.time() + 30
        while not eng._pipeline.idle() and time.time() < deadline:
            time.sleep(0.002)
        assert eng._pipeline.idle()          # drained: zero idle cost
        pl = eng._pipeline
        r2 = eng.result(eng.submit(np.arange(1, 5, dtype=np.int32), 4))
        assert eng._pipeline is pl           # same grid, re-armed
        np.testing.assert_array_equal(r1, r2)


def test_stage_exception_fails_only_its_group_and_engine_serves_on(setup):
    """Per-group failure isolation (PR 8): a raising prefill launch fails
    ONLY the admitted group — typed :class:`RowFailed`, original exception
    as ``__cause__`` — releases its untouched blocks, and the engine keeps
    serving: a subsequent request completes bit-identically."""
    from repro.serve.errors import RowFailed
    cfg, params = setup
    eng = ServeEngine(cfg, params, decode_chunk=4)
    boom = RuntimeError("injected prefill failure")
    real_prefill = eng._prefill

    def bad_prefill(params, tokens, last_positions, max_len):
        raise boom

    eng._prefill = bad_prefill
    req = eng.submit(np.arange(1, 5, dtype=np.int32), 4)
    with pytest.raises(RowFailed) as exc:
        req.result(timeout=60)               # surfaces typed, no deadlock
    assert exc.value.__cause__ is boom
    assert eng._broken is None               # the engine was NOT torn down
    assert eng.stats["row_failures"] >= 1
    deadline = time.time() + 30
    while not eng._pipeline.idle() and time.time() < deadline:
        time.sleep(0.002)
    assert _pool_restored(eng)               # the group's blocks came back
    eng._prefill = real_prefill
    out = eng.result(eng.submit(np.arange(1, 5, dtype=np.int32), 4),
                     timeout=240)
    assert out.tolist() == _reference(cfg, params,
                                      np.arange(1, 5, dtype=np.int32), 4)
    eng.close()                              # still clean to close


def test_submit_validates_and_timeout_names_state(setup):
    cfg, params = setup
    with ServeEngine(cfg, params, kv_blocks=5, block_size=4,
                     max_seq_len=16) as eng:
        with pytest.raises(ValueError, match="exceeds"):
            eng.submit(np.arange(1, 14, dtype=np.int32), max_new=8)
    # the timeout error names the request id AND its current engine state
    from repro.serve.scheduler import ServeRequest
    req = ServeRequest(np.arange(1, 5, dtype=np.int32), 4)
    req.state = "decoding"
    with pytest.raises(TimeoutError,
                       match=rf"request {req.id} .*state: decoding"):
        req.result(timeout=0.01)


# ---------------------------------------------------- two-phase admission
def test_chunked_prefill_overlaps_resident_decode(setup):
    """A prompt longer than decode_chunk * block_size prefills across >= 2
    pipeline cycles (window 0 via the prefill stage, the rest streamed by
    the decode stage) WHILE the resident row keeps decoding — asserted via
    the engine stage log — and its greedy tokens are bit-identical to the
    per-call generate() shim / contiguous reference."""
    cfg, params = setup
    rng = np.random.default_rng(3)
    pa = rng.integers(1, cfg.vocab_size, size=4).astype(np.int32)
    pb = rng.integers(1, cfg.vocab_size, size=20).astype(np.int32)
    # prefix_cache pinned OFF: this test asserts the COLD stage-log shape
    # (window 0 in the prefill stage, decode events on both sides of the
    # streamed windows). With the cache on, the warm-up registers pa and
    # the re-submitted ra becomes a hit whose tiny suffix streams as a
    # prefill_chunk before any decode event — a different, valid schedule.
    with ServeEngine(cfg, params, decode_chunk=2, block_size=4,
                     prefill_chunk=8, paged_impl="gather",
                     record_stages=True, prefix_cache=False) as eng:
        assert len(pb) > eng.decode_chunk * eng._pool.block_size
        eng.generate([pa], max_new=3)   # warm-up: compile the programs
        base = len(eng.stage_log)
        ra = eng.submit(pa, max_new=40)   # 20 decode cycles at chunk=2
        deadline = time.time() + 60
        while time.time() < deadline:
            if any(s == "decode" and n for s, _, n, _ in
                   eng.stage_log[base:]):
                break
            time.sleep(0.002)
        rb = eng.submit(pb, max_new=4)
        a_out = eng.result(ra, timeout=120)
        b_out = eng.result(rb, timeout=120)

        ev = eng.stage_log[base:]
        # window 0 (prefill stage) + streamed windows (decode stage):
        # 20 tokens at window size 8 = 1 + 2 windows across >= 2 cycles
        wins = [i for i, (s, _, _, _) in enumerate(ev)
                if s == "prefill_chunk"]
        assert len(wins) >= 2, f"expected >=2 streamed windows, got {wins}"
        cycles = {ev[i][1] for i in wins}
        assert len(cycles) >= 2      # across distinct pipeline cycles
        decode_i = [i for i, (s, _, n, _) in enumerate(ev)
                    if s == "decode" and n]
        # the resident row kept decoding around the streamed windows
        assert any(i < wins[0] for i in decode_i)
        assert any(i > wins[0] for i in decode_i)
        assert a_out.tolist() == _reference(cfg, params, pa, 40)
        assert b_out.tolist() == _reference(cfg, params, pb, 4)


def test_mixed_length_group_admits_in_one_prefill(setup):
    """No length buckets: requests of four different prompt lengths ride
    ONE admission group / ONE compiled prefill launch (chunked prefill
    keys the shape on the window size), outputs bit-identical."""
    cfg, params = setup
    rng = np.random.default_rng(4)
    prompts = [rng.integers(1, cfg.vocab_size, size=s).astype(np.int32)
               for s in (3, 9, 5, 7)]
    with ServeEngine(cfg, params, decode_chunk=4, paged_impl="gather",
                     record_stages=True) as eng:
        outs = eng.generate(prompts, max_new=5)
        admits = [i for s, _, i, _ in eng.stage_log if s == "admit"]
        assert len(admits) == 1 and len(admits[0]) == 4
        assert eng.stats["prefills"] == 1
        for p, o in zip(prompts, outs):
            assert o.tolist() == _reference(cfg, params, p, 5)


def test_prompt_only_admission_grows_and_preempts(setup):
    """Two-phase admission: a workload whose full prompt+max_new footprint
    exceeds the pool admits BOTH sequences on prompt-only footprint (the
    old all-or-nothing policy served them one at a time), grows block
    tables mid-decode, and on pool exhaustion preempts the youngest row
    back to the wait queue — the re-queued request still completes with
    correct tokens instead of deadlocking."""
    cfg, params = setup
    rng = np.random.default_rng(1)
    prompts = [rng.integers(1, cfg.vocab_size, size=16).astype(np.int32)
               for _ in range(2)]
    with ServeEngine(cfg, params, decode_chunk=4, kv_blocks=10,
                     block_size=4, paged_impl="gather",
                     record_stages=True) as eng:
        # full footprints do NOT fit together: the old policy backpressured
        usable = eng._pool.num_blocks - 1
        assert 2 * eng._pool.blocks_for(16 + 16) > usable
        # ... but the prompt-only footprints do
        assert 2 * eng._pool.blocks_for(16) <= usable
        reqs = [eng.submit(p, max_new=16) for p in prompts]
        outs = [eng.result(r, timeout=240) for r in reqs]
        admits = [i for s, _, i, _ in eng.stage_log if s == "admit"]
        # strictly more concurrency: both admitted in the FIRST group
        assert len(admits[0]) == 2
        assert eng.stats["grown_blocks"] >= 1
        assert eng.stats["preempted"] >= 1
        for p, o in zip(prompts, outs):
            assert o.tolist() == _reference(cfg, params, p, 16)
        # every block found its way back to the pool (or parked for reuse)
        assert _pool_restored(eng)


@pytest.mark.parametrize("arch", ["falcon-mamba-7b", "zamba2-1.2b"])
def test_ssm_and_hybrid_serve_resident(arch):
    """Mamba/zamba2 complete submit()/result() through the RESIDENT
    pipeline (fixed-slot recurrent-state pool) with tokens identical to the
    grouped per-call path — the retired fallback, kept as the baseline."""
    cfg = get_config(arch).smoke()
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    prompts = [np.arange(1, 6, dtype=np.int32),
               np.arange(2, 10, dtype=np.int32),
               np.arange(4, 9, dtype=np.int32)]
    with ServeEngine(cfg, params, decode_chunk=2, max_seq_len=64,
                     record_stages=True) as eng:
        assert not eng.paged
        ref = eng._generate_grouped(prompts, 6)
        reqs = [eng.submit(p, max_new=6) for p in prompts]
        outs = [eng.result(r, timeout=240) for r in reqs]
        for r, o in zip(ref, outs):
            np.testing.assert_array_equal(r, o)
        # served by the resident grid, not a throwaway per-call pipeline
        assert eng.stats["decode_cycles"] >= 1
        assert eng.stats["retired"] == 3
        assert all(o.tolist() == _reference(cfg, params, p, 6)
                   for p, o in zip(prompts, outs))
