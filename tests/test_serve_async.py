"""Async decode lookahead: async-vs-sync parity and the new scheduling
hazards it must solve (one-chunk-late retirement, seat-generation token
discard, deferred-free fence).

Parity tests pin ``paged_impl="gather"`` — the bit-exact oracle read path
— so EXACT token equality against the synchronous engine is structural
(the xla/pallas online softmax reorders bf16 reductions; see
``test_serve_continuous.py``). The async engine runs the SAME compiled
chunk program on the same carry values, so its streams must match
token-for-token under every admission pattern: chunked prefill,
mid-decode block-table growth, preemption-requeue, and SSM/hybrid slot
serving."""
import threading
import time

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import lm
from repro.serve.engine import ServeEngine
from repro.serve.kvcache import BlockPool


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("stablelm-1.6b").smoke()
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _pool_restored(eng) -> bool:
    """Every non-parked block back on the free list. With the prefix cache
    on, retired prompts' blocks stay PARKED (rc 1, trie-held) rather than
    free — they are reclaimable on demand, so the drain invariant counts
    them."""
    parked = eng._prefix.num_parked if eng._prefix is not None else 0
    return eng._pool.num_free + parked == eng._pool.num_blocks - 1


def _both_modes(cfg, params, prompts, max_new, **kw):
    outs = {}
    engines = {}
    for mode in (False, True):
        with ServeEngine(cfg, params, async_decode=mode, **kw) as eng:
            outs[mode] = eng.generate(prompts, max_new=max_new)
            engines[mode] = eng
    return outs[False], outs[True], engines[True]


def test_async_parity_mixed_lengths(setup):
    """Mixed-length prompts through one admission group: async greedy
    tokens are bit-identical to the synchronous engine on the oracle."""
    cfg, params = setup
    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, cfg.vocab_size, size=s).astype(np.int32)
               for s in (4, 7, 4, 5)]
    sync, async_, eng = _both_modes(cfg, params, prompts, 6,
                                    decode_chunk=4, paged_impl="gather")
    for s, a in zip(sync, async_):
        np.testing.assert_array_equal(s, a)
    assert eng.overlap_stats["cycles"] >= 1


def test_async_parity_chunked_prefill(setup):
    """A prompt longer than the prefill window streams windows while a
    resident row decodes; completion is deferred one cycle in async mode
    but the streams stay bit-identical."""
    cfg, params = setup
    rng = np.random.default_rng(3)
    prompts = [rng.integers(1, cfg.vocab_size, size=4).astype(np.int32),
               rng.integers(1, cfg.vocab_size, size=20).astype(np.int32)]
    sync, async_, eng = _both_modes(
        cfg, params, prompts, 10, decode_chunk=2, block_size=4,
        prefill_chunk=8, paged_impl="gather")
    for s, a in zip(sync, async_):
        np.testing.assert_array_equal(s, a)
    assert eng.stats["prefill_windows"] >= 2


def test_async_parity_growth_and_preemption(setup):
    """Tight pool: both rows admit on prompt-only footprint, grow
    mid-decode, and pool exhaustion preempts the youngest — whose
    in-flight chunk tokens are discarded (seat generation) and whose
    re-run emits an identical stream. Every block returns to the pool
    (the deferred-free fence drains)."""
    cfg, params = setup
    rng = np.random.default_rng(1)
    prompts = [rng.integers(1, cfg.vocab_size, size=16).astype(np.int32)
               for _ in range(2)]
    kw = dict(decode_chunk=4, kv_blocks=10, block_size=4,
              paged_impl="gather")
    with ServeEngine(cfg, params, **kw) as s_eng:
        sync = s_eng.generate(prompts, max_new=16)
    with ServeEngine(cfg, params, async_decode=True, **kw) as a_eng:
        reqs = [a_eng.submit(p, max_new=16) for p in prompts]
        async_ = [a_eng.result(r, timeout=240) for r in reqs]
        stats = dict(a_eng.stats)
    assert stats["grown_blocks"] >= 1
    assert stats["preempted"] >= 1
    assert any(r.preempted_count >= 1 for r in reqs)
    for s, a in zip(sync, async_):
        np.testing.assert_array_equal(s, a)
    # fence fully drained: every block found its way back
    assert a_eng._pool.num_deferred == 0
    assert _pool_restored(a_eng)


@pytest.mark.parametrize("arch", ["falcon-mamba-7b", "zamba2-1.2b"])
def test_async_parity_ssm_slots(arch):
    """SSM/hybrid slot serving under the async carry: the state pool and
    the (lengths, last, rem) carry stay device-resident, streams match the
    synchronous engine exactly (row-wise math — no oracle pin needed)."""
    cfg = get_config(arch).smoke()
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    prompts = [np.arange(1, 6, dtype=np.int32),
               np.arange(2, 10, dtype=np.int32),
               np.arange(4, 9, dtype=np.int32)]
    sync, async_, eng = _both_modes(cfg, params, prompts, 6,
                                    decode_chunk=2, max_seq_len=64)
    assert not eng.paged
    for s, a in zip(sync, async_):
        np.testing.assert_array_equal(s, a)
    assert eng.stats["retired"] == 3


def test_async_dispatch_precedes_sync_in_stage_log(setup):
    """The decode stage is split dispatch -> sync: each cycle's log shows
    the NEXT chunk dispatched before the PREVIOUS chunk's tokens are
    synced (the sync event names the dispatch cycle it drains), i.e. the
    pipeline really runs one chunk deep."""
    cfg, params = setup
    with ServeEngine(cfg, params, decode_chunk=2, async_decode=True,
                     record_stages=True, paged_impl="gather") as eng:
        eng.generate([np.arange(1, 6, dtype=np.int32)], max_new=12)
        ev = [(s, tok, info) for s, tok, info, _ in eng.stage_log
              if s in ("dispatch", "sync")]
    # at least one cycle shows dispatch(token k) followed by sync(token k)
    # draining an EARLIER dispatch token
    paired = [(tok, info[0]) for s, tok, info in ev if s == "sync"]
    assert paired, f"no sync events in {ev}"
    assert all(prev < tok for tok, prev in paired)
    disp = {tok for s, tok, _ in ev if s == "dispatch"}
    assert all(prev in disp for _, prev in paired)
    # one-chunk-late drain: some cycle both dispatched new work AND synced
    # the previous chunk (true depth-2 overlap, not alternation)
    sync_toks = {tok for s, tok, _ in ev if s == "sync"}
    assert disp & sync_toks


def test_async_overlap_stats_populated(setup):
    cfg, params = setup
    with ServeEngine(cfg, params, decode_chunk=2, async_decode=True,
                     paged_impl="gather") as eng:
        eng.generate([np.arange(1, 6, dtype=np.int32)], max_new=12)
        o = eng.overlap_stats
    assert o["cycles"] >= 6            # 11 steps at chunk 2, one-late drain
    assert o["total_s"] > 0
    # every accounted second is dispatch, wait, or bookkeeping
    assert o["dispatch_s"] + o["wait_s"] + o["book_s"] == \
        pytest.approx(o["total_s"], rel=0.05)


def test_async_tight_pool_stall_yields_to_resident(setup):
    """Pool so tight every sequence must grow into ALL usable blocks: the
    admission gate lets the STALLED resident claim fence-released blocks
    before new admissions (without it, admit/preempt livelock: the waiting
    request re-admits, takes the released block, and is immediately
    preempted to feed the older row — forever). All requests complete,
    streams bit-identical, pool restored."""
    cfg, params = setup
    prompts = [np.arange(1, 5, dtype=np.int32) for _ in range(3)]
    kw = dict(decode_chunk=4, kv_blocks=5, block_size=4,
              paged_impl="gather")
    with ServeEngine(cfg, params, **kw) as s_eng:
        sync = s_eng.generate(prompts, max_new=12)
    with ServeEngine(cfg, params, async_decode=True, **kw) as a_eng:
        async_ = a_eng.generate(prompts, max_new=12)
        stats = dict(a_eng.stats)
    assert stats["retired"] == 3
    for s, a in zip(sync, async_):
        np.testing.assert_array_equal(s, a)
    assert a_eng._pool.num_deferred == 0
    assert _pool_restored(a_eng)


# ------------------------------------------------------- deferred-free fence
def test_blockpool_deferred_free_fence():
    """free_deferred parks blocks behind TWO release_deferred advances;
    they stay accounted as allocated (invariant holds), invisible to
    alloc, and double-free of a deferred block raises."""
    pool = BlockPool(8, 4)
    ids = pool.alloc(4)
    rest = pool.alloc(3)
    assert pool.num_free == 0
    pool.free_deferred(ids)
    assert pool.num_deferred == 4
    assert pool.num_free == 0                      # invisible to alloc
    assert pool.num_free + pool.num_allocated == pool.num_blocks - 1
    with pytest.raises(ValueError, match="deferred"):
        pool.free(ids[:1])                         # double free via free()
    with pytest.raises(ValueError):
        pool.free_deferred(ids[:1])                # and via free_deferred()
    assert pool.release_deferred() == 0            # young -> old: not yet
    assert pool.alloc(1) is None
    assert pool.release_deferred() == 4            # old -> free list
    assert pool.num_deferred == 0
    got = pool.alloc(4)
    assert got is not None and sorted(got) == sorted(ids)
    pool.free(got)
    pool.free(rest)
    assert pool.num_free == pool.num_blocks - 1


def test_engine_fence_blocks_not_reallocated_while_chunk_in_flight(setup):
    """Engine-level fence proof: wrap the pool so every alloc/grow result
    is checked against the live deferred set — a preempted row's blocks
    must never be handed out before two fence advances (i.e. while a chunk
    that may still write them is in flight)."""
    cfg, params = setup
    rng = np.random.default_rng(1)
    prompts = [rng.integers(1, cfg.vocab_size, size=16).astype(np.int32)
               for _ in range(2)]
    with ServeEngine(cfg, params, decode_chunk=4, kv_blocks=10,
                     block_size=4, paged_impl="gather",
                     async_decode=True) as eng:
        pool = eng._pool
        lock = threading.Lock()
        young, old = set(), set()   # mirror of the pool's two fence stages
        defers = []
        violations = []
        orig_alloc, orig_fd = pool.alloc, pool.free_deferred
        orig_rel = pool.release_deferred

        def alloc(n, **kw):      # use_reserved= passes through untouched
            ids = orig_alloc(n, **kw)
            with lock:
                if ids and (young | old) & set(ids):
                    violations.append(("alloc", ids))
            return ids

        def free_deferred(ids):
            with lock:
                # only a block's LAST reference enters the fence: a shared
                # id (prefix-cache co-holder) is merely unpinned and may
                # later be evicted/freed/reallocated legitimately
                fenced = [b for b in ids if pool.refcount(b) == 1]
                young.update(fenced)
                if fenced:
                    defers.append(fenced)
            orig_fd(ids)

        def release_deferred():
            with lock:
                # mirror the pool: the current `old` stage becomes
                # allocatable after this advance, `young` ages into `old`
                old.clear()
                old.update(young)
                young.clear()
            return orig_rel()

        pool.alloc = alloc
        pool.free_deferred = free_deferred
        pool.release_deferred = release_deferred
        reqs = [eng.submit(p, max_new=16) for p in prompts]
        outs = [eng.result(r, timeout=240) for r in reqs]
        assert eng.stats["preempted"] >= 1
        if eng._prefix is None or eng._prefix.num_parked == 0:
            assert defers, "preemption never went through the deferred fence"
        else:
            # prefix-cache leg: the preempted row's blocks can ALL be
            # index-registered — then refcounts (parked, unreachable by
            # alloc while referenced) are the protection path, and only
            # last-reference drops would have fenced
            assert eng.stats["preempted"] >= 1
        assert not violations, violations
        assert all(o.shape == (16,) for o in outs)
