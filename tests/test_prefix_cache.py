"""Prefix caching: copy-on-write KV block sharing with reuse-aware eviction.

Three layers, matching the feature's own:

* :class:`BlockPool` refcount invariants — sharing must never let a block
  reach the free list (or the deferred fence) while a reference survives,
  and double frees past the LAST reference must stay loud;
* :class:`PrefixCache` trie properties — longest chained match, the
  ``prompt_len - 1`` cap, hash-collision disambiguation, leaf-first
  reuse-scored eviction and the parent-before-child invariant;
* engine-level copy-on-write parity — cache-hit admissions (full-chunk and
  forked partial tail, sync AND async decode) must emit greedy tokens
  bit-identical to the cache-off engine and the contiguous reference
  (``paged_impl="gather"`` pins the oracle read path, so equality is
  structural), including under preempt-while-shared pressure and an
  artificially triggered ``_cow_guard`` fork.
"""
import time

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import lm
from repro.serve.engine import ServeEngine
from repro.serve.kvcache import BlockPool
from repro.serve.prefix import PrefixCache


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("stablelm-1.6b").smoke()
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _invariant(pool) -> bool:
    """Each allocated id counts once however many references hold it."""
    return pool.num_free + pool.num_allocated == pool.num_blocks - 1


def _reference(cfg, params, prompt, max_new):
    """Greedy decode through the CONTIGUOUS cache — the pre-paged math."""
    import jax.numpy as jnp
    logits, cache = lm.prefill(cfg, params, jnp.asarray(prompt[None]),
                               max_len=len(prompt) + max_new)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    out = [int(tok[0])]
    for _ in range(max_new - 1):
        logits, cache = lm.decode_step(cfg, params, cache, tok)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        out.append(int(tok[0]))
    return out


# ===================================================== pool refcount layer
def test_shared_block_survives_coholder_free():
    pool = BlockPool(8, 4)
    ids = pool.alloc(2)
    pool.incref(ids)                       # second holder
    assert all(pool.refcount(b) == 2 for b in ids)
    assert pool.num_shared == 2
    pool.free(ids)                         # first holder retires
    assert all(pool.refcount(b) == 1 for b in ids)
    assert pool.num_free == 5              # NOT released
    assert _invariant(pool)
    pool.free(ids)                         # last reference drops
    assert pool.num_free == 7
    assert all(pool.refcount(b) == 0 for b in ids)
    with pytest.raises(ValueError):        # free past the last ref: loud
        pool.free(ids[:1])
    assert _invariant(pool)


def test_alloc_never_hands_out_live_ref_blocks():
    pool = BlockPool(6, 4)
    ids = pool.alloc(3)
    pool.incref(ids[:1])
    pool.free(ids)                         # ids[0] keeps one live ref
    got = pool.alloc(5)
    assert got is None                     # all-or-nothing: ids[0] held
    got = pool.alloc(4)
    assert got is not None and ids[0] not in got
    assert _invariant(pool)
    pool.free(got)
    pool.free(ids[:1])
    assert pool.num_free == pool.num_blocks - 1


def test_free_deferred_shared_only_unpins():
    """free_deferred of a SHARED block drops one ref without fencing it:
    surviving holders' tables still read it. Only the LAST reference
    enters the fence."""
    pool = BlockPool(8, 4)
    ids = pool.alloc(2)
    pool.incref(ids)
    pool.free_deferred(ids)                # shared: unpin, no fence
    assert pool.num_deferred == 0
    assert all(pool.refcount(b) == 1 for b in ids)
    pool.free_deferred(ids)                # last ref: fenced now
    assert pool.num_deferred == 2
    assert all(pool.refcount(b) == 0 for b in ids)
    with pytest.raises(ValueError):
        pool.incref(ids[:1])               # deferred blocks un-pinnable
    assert _invariant(pool)
    pool.release_deferred()
    assert pool.release_deferred() == 2
    assert pool.num_free == pool.num_blocks - 1


def test_incref_of_free_block_raises():
    pool = BlockPool(4, 4)
    ids = pool.alloc(1)
    pool.free(ids)
    with pytest.raises(ValueError):
        pool.incref(ids)
    with pytest.raises(ValueError):
        pool.incref([0])                   # the sink is never live


def test_defragment_guards_refcount_corruption():
    """A live/deferred/sink id smuggled into the free list is a refcount
    bug upstream — defragment detects it loudly instead of reordering a
    block some table still points at."""
    pool = BlockPool(8, 4)
    ids = pool.alloc(2)
    pool.defragment()                      # clean pool: fine
    pool._free.append(ids[0])              # simulate the corruption
    with pytest.raises(RuntimeError, match="corrupt"):
        pool.defragment()
    pool._free.remove(ids[0])
    pool._free.append(0)
    with pytest.raises(RuntimeError, match="corrupt"):
        pool.defragment()
    pool._free.remove(0)
    pool.free(ids)
    assert pool.defragment() == 0.0


def test_fragmentation_excludes_parked_and_deferred():
    """Only genuinely FREE blocks shape the fragmentation metric: parked
    (referenced) and fenced blocks are neither free nor movable."""
    pool = BlockPool(10, 4)
    ids = pool.alloc(9)
    pool.free([ids[0], ids[2], ids[4]])    # shattered free set
    pool.incref([ids[6]])
    pool.free([ids[6]])                    # parked: one live ref remains
    pool.free_deferred([ids[8]])           # fenced
    frag = pool.fragmentation()
    assert 0.0 <= frag <= 1.0
    free_before = pool.num_free
    pool.defragment()                      # must not touch parked/fenced
    assert pool.num_free == free_before
    assert pool.refcount(ids[6]) == 1
    assert pool.num_deferred == 1


# ========================================================= prefix trie layer
def _tok(*vals):
    return np.asarray(vals, np.int32)


def test_register_then_match_longest_prefix():
    pool = BlockPool(16, 4)
    prompt = np.arange(1, 13, dtype=np.int32)        # 12 tokens = 3 chunks
    blocks = pool.alloc(3)
    px = PrefixCache(pool)
    assert px.register(prompt, blocks) == 3
    assert px.num_nodes == 3
    # register holds one index ref per block: owner's free PARKS them
    pool.free(blocks)
    assert px.num_parked == 3
    assert _invariant(pool)
    # a longer prompt sharing the prefix matches the whole chain
    longer = np.concatenate([prompt, _tok(99, 98)])
    assert px.peek(longer) == 12
    hit = px.match_and_pin(longer)
    assert hit.blocks == blocks and hit.tokens == 12
    assert hit.partial_block is None
    assert all(pool.refcount(b) == 2 for b in blocks)
    px.unpin(hit.blocks)
    # a diverging prompt only matches up to the divergence chunk
    div = np.concatenate([prompt[:8], _tok(77, 77, 77, 77, 77)])
    assert px.peek(div) == 8
    assert px.stats["hits"] == 1


def test_match_caps_at_prompt_len_minus_one():
    """At least one prompt token must be COMPUTED (its logits seed the
    first output token), so an exactly-covered prompt matches its last
    chunk only PARTIALLY — as a copy-on-write fork source."""
    pool = BlockPool(16, 4)
    prompt = np.arange(1, 9, dtype=np.int32)         # 8 tokens = 2 chunks
    blocks = pool.alloc(2)
    px = PrefixCache(pool)
    px.register(prompt, blocks)
    pool.free(blocks)                                # owner retires: parked
    hit = px.match_and_pin(prompt)                   # the same prompt again
    assert hit.tokens == 7                           # capped at plen - 1
    assert hit.blocks == blocks[:1]
    assert hit.partial_block == blocks[1] and hit.partial_len == 3
    assert pool.refcount(blocks[1]) == 2             # partial is pinned too
    px.unpin(hit.blocks + [hit.partial_block])
    assert px.num_parked == 2


def test_partial_tail_best_divergence():
    """The partial match is the child extending the match FURTHEST —
    token-compared, not hash-compared."""
    pool = BlockPool(16, 4)
    px = PrefixCache(pool)
    a = np.concatenate([_tok(1, 2, 3, 4), _tok(5, 6, 7, 8)])
    b = np.concatenate([_tok(1, 2, 3, 4), _tok(5, 9, 9, 9)])
    ba, bb = pool.alloc(2), pool.alloc(2)
    px.register(a, ba)
    px.register(b, bb)                     # shares node for chunk 0
    assert px.num_nodes == 3               # chunk0 + two divergent tails
    probe = np.concatenate([_tok(1, 2, 3, 4), _tok(5, 6, 7, 0), _tok(0)])
    hit = px.match_and_pin(probe)
    assert hit.tokens == 7                 # chunk0 + 3 tokens of a's tail
    assert hit.partial_block == ba[1] and hit.partial_len == 3
    px.unpin(hit.blocks + [hit.partial_block])
    pool.free(ba)
    pool.free(bb)


def test_hash_collisions_disambiguated_by_tokens():
    """Every chunk hashing to the same bucket still matches by token
    comparison — collisions cost a chain scan, never a wrong block."""
    pool = BlockPool(16, 4)
    px = PrefixCache(pool, hash_fn=lambda parent, chunk: 7)
    a = np.arange(1, 9, dtype=np.int32)
    b = np.arange(51, 59, dtype=np.int32)
    ba, bb = pool.alloc(2), pool.alloc(2)
    px.register(a, ba)
    px.register(b, bb)
    assert px.num_nodes == 4
    ha = px.match_and_pin(np.concatenate([a, _tok(99)]))
    hb = px.match_and_pin(np.concatenate([b, _tok(99)]))
    assert ha.blocks == ba and hb.blocks == bb
    px.unpin(ha.blocks)
    px.unpin(hb.blocks)
    pool.free(ba)
    pool.free(bb)


def test_register_skips_existing_nodes():
    """Re-registering a cached prefix creates nothing: the canonical block
    stays, the new row's duplicate simply retires with the row."""
    pool = BlockPool(16, 4)
    px = PrefixCache(pool)
    prompt = np.arange(1, 9, dtype=np.int32)
    first, second = pool.alloc(2), pool.alloc(2)
    assert px.register(prompt, first) == 2
    assert px.register(prompt, second) == 0
    assert px.num_nodes == 2
    pool.free(first)                       # parked via the index refs
    pool.free(second)                      # fully released: never indexed
    assert px.num_parked == 2
    assert pool.num_free == pool.num_blocks - 1 - 2


def test_evict_leaf_first_keeps_parent_chains():
    pool = BlockPool(16, 4)
    px = PrefixCache(pool)
    prompt = np.arange(1, 17, dtype=np.int32)        # 4-chunk chain
    blocks = pool.alloc(4)
    px.register(prompt, blocks)
    pool.free(blocks)                      # all parked
    assert px.evict(1) == 1                # only the leaf is a candidate
    assert px.num_nodes == 3
    assert px.check_parent_invariant()
    assert px.peek(prompt) == 12           # surviving chain still matches
    assert px.evict(10) == 3               # drains leaf-by-leaf
    assert px.num_nodes == 0
    assert pool.num_free == pool.num_blocks - 1


def test_evict_reuse_score_takes_coldest():
    """Two parked single-chunk entries: the one with hits (recently used)
    outlives the never-hit one — reuse value, not age alone."""
    pool = BlockPool(16, 4)
    px = PrefixCache(pool)
    hot = np.arange(1, 6, dtype=np.int32)
    cold = np.arange(51, 56, dtype=np.int32)
    bh, bc = pool.alloc(1), pool.alloc(1)
    px.register(hot, bh)
    px.register(cold, bc)
    pool.free(bh)
    pool.free(bc)
    for _ in range(3):                     # bump hot's reuse stats
        h = px.match_and_pin(hot)
        px.unpin(h.blocks)
    time.sleep(0.01)                       # recency separation
    assert px.evict(1) == 1
    assert px.peek(hot) == 4               # hot survived
    assert px.peek(cold) == 0              # cold evicted
    assert px.evict(1) == 1                # pressure keeps draining: hot too


def test_pinned_chains_untouchable_by_eviction():
    pool = BlockPool(16, 4)
    px = PrefixCache(pool)
    prompt = np.arange(1, 9, dtype=np.int32)
    blocks = pool.alloc(2)
    px.register(prompt, blocks)
    pool.free(blocks)
    hit = px.match_and_pin(np.concatenate([prompt, _tok(9)]))
    assert px.evict(2) == 0                # both blocks pinned by the hit
    assert px.num_nodes == 2
    px.unpin(hit.blocks)
    assert px.evict(2) == 2


def test_preempt_while_shared_pool_emulation():
    """The engine's preempt-while-shared flow at the pool+index level:
    A registers and retires (prefix parked); B pins the chain and adds its
    own suffix; B is preempted (free_deferred of its WHOLE table). The
    suffix blocks enter the fence; the shared prefix merely drops B's pin
    and stays parked — ready for B's re-admission to hit again."""
    pool = BlockPool(16, 4)
    px = PrefixCache(pool)
    prompt = np.arange(1, 13, dtype=np.int32)        # 3 chunks
    a_blocks = pool.alloc(3)
    px.register(prompt, a_blocks)
    pool.free(a_blocks)                    # A retires: parked
    assert px.num_parked == 3

    b_prompt = np.concatenate([prompt, _tok(91, 92, 93, 94, 95)])
    hit = px.match_and_pin(b_prompt)
    assert hit.blocks == a_blocks
    suffix = pool.alloc(2)
    table = list(hit.blocks) + suffix
    # preemption under async decode: the whole table defers ONE ref each
    pool.free_deferred(table)
    assert pool.num_deferred == 2          # only B's own suffix fenced
    assert all(pool.refcount(b) == 1 for b in a_blocks)
    assert px.num_parked == 3              # shared prefix survived intact
    assert _invariant(pool)
    # re-admission hits the same chain again
    assert px.peek(b_prompt) == 12
    pool.release_deferred()
    pool.release_deferred()
    assert pool.num_free + px.num_parked == pool.num_blocks - 1


# ======================================================== engine CoW layer
@pytest.mark.parametrize("async_decode", [False, True])
def test_engine_hit_parity_and_savings(setup, async_decode):
    """Six prompts sharing a 40-token prefix: later admissions HIT the
    chain the first group registered, admission budgets shrink, and greedy
    tokens stay bit-identical to the cache-off engine on the oracle."""
    cfg, params = setup
    rng = np.random.default_rng(11)
    common = rng.integers(1, cfg.vocab_size, size=40).astype(np.int32)
    prompts = [np.concatenate([common, rng.integers(
        1, cfg.vocab_size, size=6).astype(np.int32)]) for _ in range(6)]
    # max_batch=4 splits the 6 prompts into >= 2 admission groups: group 1
    # is cold and registers the chain, later groups must HIT it
    kw = dict(decode_chunk=2, block_size=8, prefill_chunk=16, max_batch=4,
              paged_impl="gather", async_decode=async_decode)
    with ServeEngine(cfg, params, prefix_cache=False, **kw) as eng:
        base = eng.generate(prompts, max_new=8)
    with ServeEngine(cfg, params, prefix_cache=True, **kw) as eng:
        outs = eng.generate(prompts, max_new=8)
        stats = dict(eng.stats)
        parked = eng._prefix.num_parked
        assert eng._pool.num_free + parked == eng._pool.num_blocks - 1
    for b, o in zip(base, outs):
        np.testing.assert_array_equal(b, o)
    assert stats["prefix_hits"] >= 1
    assert stats["prefix_tokens_saved"] >= 40
    assert parked >= 5                     # the common chain stays parked


@pytest.mark.parametrize("async_decode", [False, True])
def test_engine_partial_tail_cow_fork_parity(setup, async_decode):
    """B's prompt shares A's prefix MID-BLOCK: admission forks A's cached
    tail block (device copy) before B's own prefill writes land in it, so
    A's bits survive and both streams match the cache-off engine."""
    cfg, params = setup
    rng = np.random.default_rng(12)
    common = rng.integers(1, cfg.vocab_size, size=44).astype(np.int32)
    a = np.concatenate([common, rng.integers(
        1, cfg.vocab_size, size=12).astype(np.int32)])
    b = np.concatenate([common, rng.integers(
        1, cfg.vocab_size, size=12).astype(np.int32)])
    kw = dict(decode_chunk=2, block_size=8, prefill_chunk=16,
              paged_impl="gather", async_decode=async_decode)
    with ServeEngine(cfg, params, prefix_cache=False, **kw) as eng:
        base = [eng.generate([a], max_new=6)[0],
                eng.generate([b], max_new=6)[0]]
    with ServeEngine(cfg, params, prefix_cache=True, **kw) as eng:
        outs = [eng.generate([a], max_new=6)[0],   # A registers the chain
                eng.generate([b], max_new=6)[0]]   # B hits + forks
        stats = dict(eng.stats)
    for x, y in zip(base, outs):
        np.testing.assert_array_equal(x, y)
    assert stats["cow_forks"] >= 1
    assert stats["prefix_hits"] >= 1
    # 5 full chunks (40) + a partial tail (44..47 land mid-block)
    assert stats["prefix_tokens_saved"] >= 41


def test_engine_preempt_while_shared_parity(setup):
    """Tight pool, shared prompts: growth pressure preempts a row whose
    table points at SHARED prefix blocks. The preemption must only unpin
    them (co-holders and the index keep reading them), the replay must
    re-hit, and every stream must match the contiguous reference."""
    cfg, params = setup
    rng = np.random.default_rng(13)
    common = rng.integers(1, cfg.vocab_size, size=8).astype(np.int32)
    prompts = [np.concatenate([common, rng.integers(
        1, cfg.vocab_size, size=4).astype(np.int32)]) for _ in range(2)]
    with ServeEngine(cfg, params, decode_chunk=4, kv_blocks=10,
                     block_size=4, paged_impl="gather",
                     prefix_cache=True) as eng:
        outs = eng.generate(prompts, max_new=16)
        stats = dict(eng.stats)
        parked = eng._prefix.num_parked
        assert eng._pool.num_free + parked == eng._pool.num_blocks - 1
    for p, o in zip(prompts, outs):
        assert o.tolist() == _reference(cfg, params, p, 16)
    assert stats["preempted"] >= 1


def test_engine_cow_guard_forks_artificially_shared_block(setup):
    """_cow_guard is defense-in-depth: the engine's own flows never write
    a shared block, so trigger it by hand — pin a decoding row's current
    write block from outside and verify the engine forks (device copy +
    table repoint) instead of corrupting the co-holder's bits."""
    cfg, params = setup
    prompt = np.arange(1, 5, dtype=np.int32)
    with ServeEngine(cfg, params, decode_chunk=1, block_size=16,
                     paged_impl="gather", prefix_cache=True) as eng:
        req = eng.submit(prompt, max_new=48)
        # seat + first block: all 48 decode writes land in blocks[0]
        deadline = time.time() + 60
        shared = None
        while time.time() < deadline and shared is None:
            for blocks in eng._slot_blocks:
                if blocks:
                    eng._pool.incref([blocks[0]])
                    shared = blocks[0]
                    break
            time.sleep(0.001)
        assert shared is not None, "row never seated"
        out = eng.result(req, timeout=240)
        stats = dict(eng.stats)
        # our pin still holds the ORIGINAL block; the row forked away
        assert eng._pool.refcount(shared) == 1
        eng._pool.free([shared])
        assert eng._pool.num_free + eng._prefix.num_parked \
            == eng._pool.num_blocks - 1
    assert stats["cow_forks"] >= 1
    assert out.tolist() == _reference(cfg, params, prompt, 48)
